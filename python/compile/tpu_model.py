"""TPU roofline model for the Find-Winners kernel (DESIGN.md §9, §11.5).

The reproduction testbed is a single CPU core: the physical data-parallel
axis of the paper's GPU column does not exist, so device speedups cannot be
*measured* here. This module computes the clearly-labeled *estimate* used in
EXPERIMENTS.md §TPU-model: per-bucket kernel time on a TPU-v4-like core from
first principles, using the L1 kernel's actual BlockSpec schedule.

Model (exact flavor, diff² on the VPU):

- HBM traffic per batch: each signal tile is re-read once per unit tile and
  vice versa under the `(m/bm, n/bn)` grid:
      bytes = m·12·(n/bn) + n·12·(m/bm) + m·16 (outputs)
- VPU work per pair: 3 sub + 3 mul + 2 add (distance) + ~4 compare/select
  (running top-2 merge) ≈ 12 lane-ops.
- Roofline time = max(bytes / BW, ops / VPU_THROUGHPUT); the kernel is
  compute(VPU)-bound for all buckets at the default 128×128 blocks.

Usage: python -m compile.tpu_model [--manifest ../artifacts/manifest.json]
"""

from __future__ import annotations

import argparse
import json

# TPU-v4-like single-core budget (public figures, order-of-magnitude).
HBM_BW = 1.2e12  # bytes/s
VPU_OPS = 3.5e12  # f32 lane-ops/s
OPS_PER_PAIR = 12.0

DEFAULT_BLOCK = 128


def bucket_estimate(m: int, n: int, bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK):
    """Returns (bytes, ops, time_s, bound) for one batch of the bucket."""
    tiles_m = max(1, m // bm)
    tiles_n = max(1, n // bn)
    bytes_moved = m * 12 * tiles_n + n * 12 * tiles_m + m * 16
    ops = m * n * OPS_PER_PAIR
    t_mem = bytes_moved / HBM_BW
    t_cmp = ops / VPU_OPS
    t = max(t_mem, t_cmp)
    return bytes_moved, ops, t, ("memory" if t_mem > t_cmp else "vpu")


def vmem_bytes(bm: int, bn: int, d: int = 3) -> int:
    """Mirror of kernels.find_winners.vmem_footprint_bytes."""
    return (bm + bn) * d * 4 + 2 * bm * bn * 4 + 4 * bm * 4


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--manifest", default="../artifacts/manifest.json")
    p.add_argument("--block-m", type=int, default=DEFAULT_BLOCK)
    p.add_argument("--block-n", type=int, default=DEFAULT_BLOCK)
    args = p.parse_args(argv)

    man = json.load(open(args.manifest))
    buckets = sorted(
        {(e["m"], e["n"]) for e in man["artifacts"]},
    )
    print(
        "TPU-v4-like roofline ESTIMATE (not a measurement) — exact flavor, "
        f"blocks {args.block_m}x{args.block_n}, "
        f"VMEM/step {vmem_bytes(args.block_m, args.block_n)/2**20:.2f} MiB"
    )
    print(f"{'m':>6} {'n':>6} {'batch_time':>12} {'per_signal':>12} {'bound':>7}")
    for m, n in buckets:
        _, _, t, bound = bucket_estimate(m, n, args.block_m, args.block_n)
        print(f"{m:>6} {n:>6} {t:>12.3e} {t / m:>12.3e} {bound:>7}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
