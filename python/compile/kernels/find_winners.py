"""Layer-1 Pallas kernel: batched top-2 nearest-unit search ("Find Winners").

This is the paper's GPU hot-spot (section 2.5, Fig. 5) rethought for TPU:

- paper (CUDA): one *thread per signal*; a threadblock stages a contiguous
  batch of reference vectors in __shared__ memory with a coalesced load, then
  every thread scans the staged batch sequentially, keeping a running top-2
  in registers.
- here (Pallas): one *row of the distance block per signal*; ``BlockSpec``
  stages a ``[block_n, d]`` tile of the unit array in VMEM (the TPU analogue
  of shared memory — the HBM->VMEM tile copy is the coalesced load), the
  ``[block_m, block_n]`` distance block is computed vectorized on the VPU,
  and the running top-2 lives in the output refs, merged across unit tiles
  exactly like the per-thread registers of the CUDA kernel.

The grid is ``(m / block_m, n / block_n)`` with the unit-tile axis innermost,
so each signal tile accumulates over all unit tiles sequentially — the same
schedule the CUDA kernel expresses with its shared-memory loop.

Distances use the *naive difference form* ``sum((s-u)**2)`` so that the
kernel, the jnp oracle (``ref.py``), the scan flavor (``model.py``) and the
rust scalar path share bit-exact semantics (required for the multi-signal ==
batched-PJRT replication invariant, DESIGN.md section 7). The MXU
``|s|^2 - 2 s.u^T + |u|^2`` expansion is available as ``flavor="mxu"`` for
the TPU-perf discussion (DESIGN.md section 9); it changes float rounding, so
it is NOT used for the parity artifacts.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO that the rust
runtime can run anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PAD_VALUE

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _block_distances(s, u, flavor: str):
    """Distance block f32[bm, bn] between signal tile s[bm,d] and unit tile u[bn,d]."""
    if flavor == "mxu":
        # MXU-friendly expansion: one [bm,d]x[d,bn] matmul feeds the systolic
        # array; the rank-1 norm terms ride on the VPU.
        s2 = jnp.sum(s * s, axis=-1)[:, None]
        u2 = jnp.sum(u * u, axis=-1)[None, :]
        return s2 - 2.0 * jnp.dot(s, u.T, preferred_element_type=jnp.float32) + u2
    # "exact": naive difference form, bit-compatible with ref.py and rust.
    diff = s[:, None, :] - u[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def _kernel(s_ref, u_ref, i1_ref, i2_ref, d1_ref, d2_ref, *, block_n, flavor):
    j = pl.program_id(1)

    s = s_ref[...]
    u = u_ref[...]
    d = _block_distances(s, u, flavor)
    bm, bn = d.shape

    # In-block top-2 (tie-break: lowest index, via argmin).
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    bi1 = jnp.argmin(d, axis=1).astype(jnp.int32)
    bd1 = jnp.min(d, axis=1)
    masked = jnp.where(col == bi1[:, None], jnp.inf, d)
    bi2 = jnp.argmin(masked, axis=1).astype(jnp.int32)
    bd2 = jnp.min(masked, axis=1)

    base = j * block_n
    bi1 = bi1 + base
    bi2 = bi2 + base

    # Reset the running top-2 at the first unit tile of every signal tile.
    @pl.when(j == 0)
    def _init():
        d1_ref[...] = jnp.full((bm,), jnp.inf, jnp.float32)
        d2_ref[...] = jnp.full((bm,), jnp.inf, jnp.float32)
        i1_ref[...] = jnp.zeros((bm,), jnp.int32)
        i2_ref[...] = jnp.zeros((bm,), jnp.int32)

    d1, d2 = d1_ref[...], d2_ref[...]
    i1, i2 = i1_ref[...], i2_ref[...]

    # Merge running (d1<=d2) with block (bd1<=bd2). Strict '<' prefers the
    # running value on exact ties; running indices come from earlier tiles,
    # hence lower — this preserves the lowest-index tie-break across tiles.
    take_new1 = bd1 < d1
    nd1 = jnp.where(take_new1, bd1, d1)
    ni1 = jnp.where(take_new1, bi1, i1)
    lf_d = jnp.where(take_new1, d1, bd1)  # loser of the two firsts
    lf_i = jnp.where(take_new1, i1, bi1)
    take_new2 = bd2 < d2
    w2_d = jnp.where(take_new2, bd2, d2)  # winner of the two seconds
    w2_i = jnp.where(take_new2, bi2, i2)
    take_lf = lf_d < w2_d
    nd2 = jnp.where(take_lf, lf_d, w2_d)
    ni2 = jnp.where(take_lf, lf_i, w2_i)

    d1_ref[...] = nd1
    d2_ref[...] = nd2
    i1_ref[...] = ni1
    i2_ref[...] = ni2


def _pad_rows(x, multiple, value):
    rows = x.shape[0]
    target = ((rows + multiple - 1) // multiple) * multiple
    if target == rows:
        return x
    pad = jnp.full((target - rows,) + x.shape[1:], value, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "flavor", "interpret")
)
def find_winners_pallas(
    signals,
    units,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    flavor: str = "exact",
    interpret: bool = True,
):
    """Batched top-2 nearest-unit search.

    signals: f32[m, d]; units: f32[n, d] (padding slots = ``PAD_VALUE``).
    Returns ``(i1 i32[m], i2 i32[m], d1 f32[m], d2 f32[m])``.

    Arbitrary m/n are padded internally up to the block size (signals with
    zeros — their outputs are sliced away; units with ``PAD_VALUE`` — they
    can never win).
    """
    m, d = signals.shape
    n = units.shape[0]
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    sp = _pad_rows(signals.astype(jnp.float32), bm, 0.0)
    up = _pad_rows(units.astype(jnp.float32), bn, PAD_VALUE)
    mp, np_ = sp.shape[0], up.shape[0]

    grid = (mp // bm, np_ // bn)
    kernel = functools.partial(_kernel, block_n=bn, flavor=flavor)
    i1, i2, d1, d2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=interpret,
    )(sp, up)
    return i1[:m], i2[:m], d1[:m], d2[:m]


def vmem_footprint_bytes(block_m: int, block_n: int, d: int = 3) -> int:
    """Estimated VMEM residency of one grid step (DESIGN.md section 9).

    signal tile + unit tile + distance block + masked copy + 4 running [bm]
    vectors. Used by the perf report and by tests that pin the kernel under
    the 16 MiB/core budget.
    """
    tiles = (block_m + block_n) * d * 4
    dist = 2 * block_m * block_n * 4  # d + masked
    running = 4 * block_m * 4
    return tiles + dist + running
