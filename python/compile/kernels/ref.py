"""Pure-jnp oracle for the batched find-winners (top-2 nearest units) kernel.

This is the CORE correctness signal for Layer 1: the Pallas kernel in
``find_winners.py`` and the scan-based XLA flavor in ``model.py`` must agree
with this reference on indices (modulo exact-distance ties, which are
measure-zero on continuous data — see ``ties_possible``) and on distances to
float tolerance.

Semantics (shared with the rust ``findwinners::Scalar`` implementation):

- distance = squared Euclidean distance, computed as ``sum((s - u)**2)`` in
  f32 (the *naive difference form*, NOT the ``|s|^2 - 2 s.u + |u|^2``
  expansion, so that rust scalar code and the kernel can agree bit-for-bit);
- winner   = unit with minimal distance, ties broken toward the LOWEST index;
- second   = unit with minimal distance among the rest, same tie-break;
- invalid (padding) unit slots are pre-filled by the caller with ``PAD_VALUE``
  so their distances overflow to ``+inf`` and they can never win while at
  least two valid units exist.
"""

from __future__ import annotations

import jax.numpy as jnp

# Padding sentinel for unused unit slots. (1e30)**2 overflows f32 -> +inf,
# which guarantees padded slots lose against any valid unit.
PAD_VALUE = 1e30


def pairwise_sq_dist(signals: jnp.ndarray, units: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared distances, naive difference form.

    signals: f32[m, d]; units: f32[n, d] -> f32[m, n]
    """
    diff = signals[:, None, :] - units[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def find_winners_ref(signals: jnp.ndarray, units: jnp.ndarray):
    """Reference top-2 nearest-unit search.

    Returns ``(i1, i2, d1, d2)`` with ``i*`` int32[m], ``d*`` f32[m].
    ``jnp.argmin`` breaks ties toward the lowest index, matching the kernel's
    in-block behavior and the rust scalar implementation.
    """
    d = pairwise_sq_dist(signals, units)
    m = d.shape[0]
    i1 = jnp.argmin(d, axis=1).astype(jnp.int32)
    d1 = jnp.min(d, axis=1)
    masked = d.at[jnp.arange(m), i1].set(jnp.inf)
    i2 = jnp.argmin(masked, axis=1).astype(jnp.int32)
    d2 = jnp.min(masked, axis=1)
    return i1, i2, d1, d2


def ties_possible(signals, units) -> bool:
    """True when the top-2 result is ambiguous under index tie-breaking.

    Used by tests: when hypothesis generates exact-duplicate units (or exact
    equidistance), the kernel's cross-tile merge may legitimately pick a
    different index than the oracle; tests then compare distances only.
    """
    import numpy as np

    d = np.asarray(pairwise_sq_dist(jnp.asarray(signals), jnp.asarray(units)))
    part = np.sort(d, axis=1)
    k = min(3, part.shape[1])
    for col in range(k - 1):
        if np.any(part[:, col] == part[:, col + 1]):
            return True
    return False
