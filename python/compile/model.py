"""Layer-2 JAX compute graph for the batched Find Winners phase.

The paper's L2 is deliberately thin: the multi-signal contribution parallelizes
exactly ONE phase — Find Winners — and leaves Sample and Update on the host
(section 2.5 / Conclusions). Correspondingly this module exposes the batched
top-2 search as a fixed-shape jax function per ``(m, n)`` size bucket, in two
flavors that share exact float semantics:

- ``pallas``: calls the L1 Pallas kernel (``kernels.find_winners``), which
  lowers (interpret mode) into the same HLO module;
- ``scan``:   a pure-XLA formulation that chunks the unit axis with
  ``lax.scan`` and performs the identical running top-2 merge. This is the
  A/B comparator for the perf pass (DESIGN.md section 9) and keeps peak memory
  at ``m * chunk`` instead of ``m * n``.

Both flavors consume units pre-padded with ``PAD_VALUE`` by the rust caller
and return ``(i1, i2, d1, d2)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.find_winners import find_winners_pallas
from .kernels.ref import PAD_VALUE  # noqa: F401  (re-exported for aot/tests)

SCAN_CHUNK = 512


def find_winners_scan(signals, units, *, chunk: int = SCAN_CHUNK):
    """Pure-XLA batched top-2: scan over unit chunks with a running merge.

    Mirrors the Pallas kernel's cross-tile merge exactly (strict ``<`` keeps
    the earlier chunk on ties -> lowest-index tie-break).
    """
    m, d = signals.shape
    n = units.shape[0]
    chunk = min(chunk, n)
    if n % chunk != 0:
        pad = chunk - n % chunk
        units = jnp.concatenate(
            [units, jnp.full((pad, d), PAD_VALUE, units.dtype)], axis=0
        )
        n = units.shape[0]
    tiles = units.reshape(n // chunk, chunk, d)

    def step(carry, tile_with_idx):
        tile, t = tile_with_idx
        d1, d2, i1, i2 = carry
        diff = signals[:, None, :] - tile[None, :, :]
        dist = jnp.sum(diff * diff, axis=-1)
        col = jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
        bi1 = jnp.argmin(dist, axis=1).astype(jnp.int32)
        bd1 = jnp.min(dist, axis=1)
        masked = jnp.where(col == bi1[:, None], jnp.inf, dist)
        bi2 = jnp.argmin(masked, axis=1).astype(jnp.int32)
        bd2 = jnp.min(masked, axis=1)
        base = t * chunk
        bi1, bi2 = bi1 + base, bi2 + base

        take_new1 = bd1 < d1
        nd1 = jnp.where(take_new1, bd1, d1)
        ni1 = jnp.where(take_new1, bi1, i1)
        lf_d = jnp.where(take_new1, d1, bd1)
        lf_i = jnp.where(take_new1, i1, bi1)
        take_new2 = bd2 < d2
        w2_d = jnp.where(take_new2, bd2, d2)
        w2_i = jnp.where(take_new2, bi2, i2)
        take_lf = lf_d < w2_d
        nd2 = jnp.where(take_lf, lf_d, w2_d)
        ni2 = jnp.where(take_lf, lf_i, w2_i)
        return (nd1, nd2, ni1, ni2), None

    init = (
        jnp.full((m,), jnp.inf, jnp.float32),
        jnp.full((m,), jnp.inf, jnp.float32),
        jnp.zeros((m,), jnp.int32),
        jnp.zeros((m,), jnp.int32),
    )
    idx = jnp.arange(tiles.shape[0], dtype=jnp.int32)
    (d1, d2, i1, i2), _ = jax.lax.scan(step, init, (tiles, idx))
    return i1, i2, d1, d2


def find_winners_model(signals, units, *, flavor: str = "pallas",
                       block_m: int = 128, block_n: int = 128):
    """The exported L2 entry point: fixed-shape batched Find Winners.

    ``signals`` f32[m, d]; ``units`` f32[n, d] with padding = ``PAD_VALUE``.
    Output tuple ``(i1 i32[m], i2 i32[m], d1 f32[m], d2 f32[m])``.
    """
    if flavor == "pallas":
        return find_winners_pallas(
            signals, units, block_m=block_m, block_n=block_n
        )
    if flavor == "scan":
        return find_winners_scan(signals, units)
    raise ValueError(f"unknown flavor {flavor!r}")


def lower_bucket(m: int, n: int, d: int = 3, *, flavor: str = "pallas",
                 block_m: int = 128, block_n: int = 128):
    """Lower one ``(m, n)`` bucket to a jax ``Lowered`` object."""
    sig = jax.ShapeDtypeStruct((m, d), jnp.float32)
    uni = jax.ShapeDtypeStruct((n, d), jnp.float32)
    fn = functools.partial(
        find_winners_model, flavor=flavor, block_m=block_m, block_n=block_n
    )
    return jax.jit(fn).lower(sig, uni)
