"""AOT lowering: JAX/Pallas Find-Winners buckets -> HLO text artifacts.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

One artifact per ``(flavor, m, n)`` bucket. The bucket ladder implements the
paper's parallelism schedule (section 3.1): ``m`` = the least power of two
greater than the current unit count, capped at 8192; ``n`` = unit capacity,
padded with ``PAD_VALUE``. The rust ``runtime::Registry`` picks the smallest
bucket that fits and ignores output rows beyond the live batch, which keeps
the algorithm's behavior exactly equal to the unbucketed schedule.

Python runs ONLY here (``make artifacts``); the rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax

from .model import lower_bucket

MIN_N = 128
DEFAULT_MAX_N = 16384
M_CAP = 8192  # paper: "maximum level of parallelism has been set to 8192"
DIM = 3
FLAVORS = ("pallas", "scan")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the rust
    side can unwrap a single tuple result)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def buckets(max_n: int):
    n = MIN_N
    while n <= max_n:
        yield min(n, M_CAP), n
        n *= 2


def artifact_name(flavor: str, m: int, n: int) -> str:
    return f"find_winners_{flavor}_m{m}_n{n}.hlo.txt"


def build(out_dir: str, max_n: int, flavors, block_m: int, block_n: int,
          default_flavor: str, force: bool) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for flavor in flavors:
        for m, n in buckets(max_n):
            name = artifact_name(flavor, m, n)
            path = os.path.join(out_dir, name)
            t0 = time.time()
            if not force and os.path.exists(path):
                text = open(path).read()
                action = "kept"
            else:
                lowered = lower_bucket(
                    m, n, DIM, flavor=flavor,
                    block_m=block_m, block_n=block_n,
                )
                text = to_hlo_text(lowered)
                with open(path, "w") as f:
                    f.write(text)
                action = "wrote"
            sha = hashlib.sha256(text.encode()).hexdigest()[:16]
            entries.append({
                "flavor": flavor, "m": m, "n": n, "dim": DIM,
                "dtype": "f32", "file": name, "sha256_16": sha,
                "inputs": [f"f32[{m},{DIM}]", f"f32[{n},{DIM}]"],
                "outputs": [f"s32[{m}]", f"s32[{m}]", f"f32[{m}]", f"f32[{m}]"],
            })
            print(f"  {action} {name} ({len(text)} chars, "
                  f"{time.time() - t0:.1f}s)", flush=True)

    manifest = {
        "version": 1,
        "jax": jax.__version__,
        "pad_value": 1e30,
        "m_cap": M_CAP,
        "min_n": MIN_N,
        "dim": DIM,
        "block_m": block_m,
        "block_n": block_n,
        "default_flavor": default_flavor,
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument("--max-n", type=int, default=DEFAULT_MAX_N,
                   help="largest unit-capacity bucket to emit")
    p.add_argument("--flavors", default=",".join(FLAVORS),
                   help="comma-separated subset of {pallas,scan}")
    p.add_argument("--block-m", type=int, default=128)
    p.add_argument("--block-n", type=int, default=128)
    p.add_argument("--default-flavor", default="pallas",
                   help="flavor the rust runtime uses unless overridden")
    p.add_argument("--force", action="store_true",
                   help="re-lower even if the artifact file exists")
    args = p.parse_args(argv)

    flavors = [f.strip() for f in args.flavors.split(",") if f.strip()]
    for f in flavors:
        if f not in FLAVORS:
            p.error(f"unknown flavor {f!r}")
    print(f"AOT lowering find-winners buckets -> {args.out}", flush=True)
    manifest = build(args.out, args.max_n, flavors, args.block_m,
                     args.block_n, args.default_flavor, args.force)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
