"""AOT pipeline tests: bucket ladder, manifest contract, HLO-text format."""

import json
import os

import pytest

from compile import aot


class TestBucketLadder:
    def test_ladder_shape(self):
        got = list(aot.buckets(16384))
        assert got[0] == (128, 128)
        assert (8192, 8192) in got
        assert got[-1] == (8192, 16384)

    def test_m_capped_at_8192(self):
        for m, n in aot.buckets(32768):
            assert m == min(n, aot.M_CAP)

    def test_powers_of_two(self):
        for m, n in aot.buckets(16384):
            assert n & (n - 1) == 0 and m & (m - 1) == 0


class TestEmission:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = aot.build(
            str(out), max_n=256, flavors=("pallas", "scan"),
            block_m=64, block_n=64, default_flavor="pallas", force=True,
        )
        return out, manifest

    def test_all_files_exist(self, built):
        out, manifest = built
        assert len(manifest["artifacts"]) == 4  # 2 buckets x 2 flavors
        for e in manifest["artifacts"]:
            assert (out / e["file"]).exists()

    def test_hlo_text_not_proto(self, built):
        """The interchange MUST be HLO text (xla_extension 0.5.1 rejects
        jax>=0.5 serialized protos)."""
        out, manifest = built
        for e in manifest["artifacts"]:
            head = (out / e["file"]).read_text()[:200]
            assert "HloModule" in head

    def test_manifest_contract(self, built):
        out, _ = built
        m = json.loads((out / "manifest.json").read_text())
        assert m["pad_value"] == 1e30
        assert m["dim"] == 3
        assert m["default_flavor"] in ("pallas", "scan")
        for e in m["artifacts"]:
            assert e["inputs"] == [f"f32[{e['m']},3]", f"f32[{e['n']},3]"]
            assert e["outputs"][0] == f"s32[{e['m']}]"

    def test_incremental_noop(self, built):
        """Re-running without --force keeps existing files (mtime unchanged)."""
        out, _ = built
        target = out / aot.artifact_name("scan", 128, 128)
        before = target.stat().st_mtime_ns
        aot.build(str(out), max_n=128, flavors=("scan",), block_m=64,
                  block_n=64, default_flavor="scan", force=False)
        assert target.stat().st_mtime_ns == before

    def test_entry_point_is_tuple(self, built):
        """return_tuple=True: ENTRY computation must return a 4-tuple so the
        rust side can to_tuple() it."""
        out, manifest = built
        for e in manifest["artifacts"]:
            text = (out / e["file"]).read_text()
            roots = [l for l in text.splitlines() if "ROOT" in l]
            assert any(
                f"(s32[{e['m']}]" in l and f"f32[{e['m']}]" in l
                for l in roots
            ), e["file"]


class TestRepoArtifacts:
    """Sanity over the artifacts/ directory actually shipped to rust
    (skipped when `make artifacts` has not run yet)."""

    MANIFEST = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )

    @pytest.fixture()
    def manifest(self):
        if not os.path.exists(self.MANIFEST):
            pytest.skip("make artifacts not run")
        return json.load(open(self.MANIFEST))

    def test_covers_paper_sizes(self, manifest):
        """The ladder must cover every network size in Tables 1-4
        (347 .. 15,638 units) and the m cap of 8192."""
        ns = {e["n"] for e in manifest["artifacts"]}
        for units in (347, 658, 8884, 15638):
            assert any(n >= units + 1 for n in ns)
        assert any(e["m"] == 8192 for e in manifest["artifacts"])

    def test_files_present(self, manifest):
        base = os.path.dirname(self.MANIFEST)
        for e in manifest["artifacts"]:
            assert os.path.exists(os.path.join(base, e["file"]))


class TestTpuModel:
    """The §TPU-model roofline estimator (compile.tpu_model)."""

    def test_vpu_bound_at_default_blocks(self):
        from compile import tpu_model

        _, _, t, bound = tpu_model.bucket_estimate(8192, 8192)
        assert bound == "vpu"
        assert 1e-6 < t < 1e-3

    def test_time_scales_with_work(self):
        from compile import tpu_model

        small = tpu_model.bucket_estimate(128, 128)[2]
        big = tpu_model.bucket_estimate(8192, 8192)[2]
        assert big > 100 * small

    def test_vmem_matches_kernel_model(self):
        from compile import tpu_model
        from compile.kernels.find_winners import vmem_footprint_bytes

        assert tpu_model.vmem_bytes(128, 128) == vmem_footprint_bytes(128, 128)
