"""Layer-1 correctness: Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path: every artifact the
rust runtime executes is lowered from the function under test here.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.find_winners import (
    find_winners_pallas,
    vmem_footprint_bytes,
)
from compile.kernels.ref import PAD_VALUE, find_winners_ref, ties_possible


def _run_both(s, u, **kw):
    out = find_winners_pallas(jnp.asarray(s), jnp.asarray(u), **kw)
    ref = find_winners_ref(jnp.asarray(s), jnp.asarray(u))
    return [np.asarray(x) for x in out], [np.asarray(x) for x in ref]


def _assert_match(s, u, out, ref):
    i1, i2, d1, d2 = out
    ri1, ri2, rd1, rd2 = ref
    np.testing.assert_allclose(d1, rd1, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(d2, rd2, rtol=1e-6, atol=1e-6)
    if not ties_possible(s, u):
        np.testing.assert_array_equal(i1, ri1)
        np.testing.assert_array_equal(i2, ri2)


def _random_case(seed, m, n, pad=0):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=(m, 3)).astype(np.float32)
    u = rng.normal(size=(n, 3)).astype(np.float32)
    if pad:
        u[n - pad:] = PAD_VALUE
    return s, u


class TestKernelVsRef:
    @pytest.mark.parametrize("m,n", [(1, 2), (3, 7), (16, 16), (37, 211),
                                     (128, 128), (200, 1000), (64, 4096)])
    def test_random_clouds(self, m, n):
        s, u = _random_case(42 + m * n, m, n)
        out, ref = _run_both(s, u, block_m=32, block_n=64)
        _assert_match(s, u, out, ref)

    @pytest.mark.parametrize("pad", [1, 5, 100])
    def test_padded_units_never_win(self, pad):
        s, u = _random_case(7, 33, 128, pad=pad)
        out, ref = _run_both(s, u, block_m=16, block_n=32)
        _assert_match(s, u, out, ref)
        assert np.all(out[0] < 128 - pad)
        assert np.all(out[1] < 128 - pad)

    def test_winner_not_equal_second(self):
        s, u = _random_case(3, 50, 300)
        out, _ = _run_both(s, u)
        assert np.all(out[0] != out[1])

    def test_signal_on_unit_gives_zero_distance(self):
        _, u = _random_case(11, 1, 64)
        s = u[17:18].copy()
        out, _ = _run_both(s, u, block_m=8, block_n=16)
        assert out[0][0] == 17
        assert out[2][0] == 0.0

    @pytest.mark.parametrize("bm,bn", [(8, 8), (16, 64), (128, 128),
                                       (64, 256)])
    def test_block_shape_invariance(self, bm, bn):
        """The running cross-tile merge must be block-shape independent."""
        s, u = _random_case(5, 96, 640)
        base, _ = _run_both(s, u, block_m=8, block_n=8)
        out, _ = _run_both(s, u, block_m=bm, block_n=bn)
        for a, b in zip(base, out):
            np.testing.assert_array_equal(a, b)

    def test_mxu_flavor_close_to_exact(self):
        """The |s|^2-2su+|u|^2 expansion changes rounding but not winners on
        well-separated data."""
        s, u = _random_case(13, 64, 512)
        exact = find_winners_pallas(jnp.asarray(s), jnp.asarray(u))
        mxu = find_winners_pallas(jnp.asarray(s), jnp.asarray(u), flavor="mxu")
        np.testing.assert_array_equal(np.asarray(exact[0]), np.asarray(mxu[0]))
        np.testing.assert_allclose(
            np.asarray(exact[2]), np.asarray(mxu[2]), rtol=1e-3, atol=1e-3
        )

    def test_two_units_only(self):
        """Smallest legal network: top-2 must be the two units, ordered."""
        u = np.array([[0, 0, 0], [10, 0, 0]], np.float32)
        s = np.array([[1, 0, 0], [9, 0, 0]], np.float32)
        out, _ = _run_both(s, u, block_m=8, block_n=8)
        np.testing.assert_array_equal(out[0], [0, 1])
        np.testing.assert_array_equal(out[1], [1, 0])


class TestHypothesisSweep:
    """Property sweep over shapes and values (DESIGN.md section 10)."""

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 70),
        n=st.integers(2, 300),
        bm=st.sampled_from([8, 16, 32, 128]),
        bn=st.sampled_from([8, 32, 64, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, n, bm, bn, seed):
        s, u = _random_case(seed, m, n)
        out, ref = _run_both(s, u, block_m=bm, block_n=bn)
        _assert_match(s, u, out, ref)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 40),
        n=st.integers(2, 120),
        dup=st.integers(0, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_duplicate_units_distances_still_exact(self, m, n, dup, seed):
        """Duplicated units force ties: indices may differ, distances not."""
        s, u = _random_case(seed, m, n)
        if dup and n > dup:
            u[:dup] = u[dup:2 * dup] if 2 * dup <= n else u[n - dup:]
        out, ref = _run_both(s, u, block_m=16, block_n=16)
        np.testing.assert_allclose(out[2], ref[2], rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(out[3], ref[3], rtol=1e-6, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        scale=st.floats(1e-3, 1e3),
        shift=st.floats(-100.0, 100.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_scale_shift_robust(self, scale, shift, seed):
        """Winner indices are invariant to similarity transforms of the
        cloud (applied to both signals and units)."""
        s, u = _random_case(seed, 24, 96)
        out0, _ = _run_both(s, u, block_m=8, block_n=32)
        s2 = (s * scale + shift).astype(np.float32)
        u2 = (u * scale + shift).astype(np.float32)
        out1, _ = _run_both(s2, u2, block_m=8, block_n=32)
        if not (ties_possible(s, u) or ties_possible(s2, u2)):
            np.testing.assert_array_equal(out0[0], out1[0])


class TestVmemModel:
    def test_default_blocks_fit_budget(self):
        assert vmem_footprint_bytes(128, 128) < 16 * 2**20

    def test_footprint_monotone(self):
        assert vmem_footprint_bytes(256, 256) > vmem_footprint_bytes(128, 128)

    @pytest.mark.parametrize("bm,bn", [(128, 128), (256, 256), (512, 512)])
    def test_perf_plan_blocks_fit(self, bm, bn):
        """Every block shape in the DESIGN.md section 9 sweep fits VMEM."""
        assert vmem_footprint_bytes(bm, bn) < 16 * 2**20
