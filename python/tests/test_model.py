"""Layer-2 correctness: scan flavor vs Pallas flavor vs oracle, plus the
exported-bucket contract the rust runtime relies on."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import (
    SCAN_CHUNK,
    find_winners_model,
    find_winners_scan,
    lower_bucket,
)
from compile.kernels.ref import PAD_VALUE, find_winners_ref, ties_possible


def _cloud(seed, m, n, live=None):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=(m, 3)).astype(np.float32)
    u = rng.normal(size=(n, 3)).astype(np.float32)
    if live is not None:
        u[live:] = PAD_VALUE
    return jnp.asarray(s), jnp.asarray(u)


class TestScanFlavor:
    @pytest.mark.parametrize("m,n", [(4, 8), (128, 128), (77, 1000),
                                     (128, 2048)])
    def test_scan_matches_ref(self, m, n):
        s, u = _cloud(m * n, m, n)
        out = find_winners_scan(s, u)
        ref = find_winners_ref(s, u)
        for a, b in zip(out, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_scan_chunk_invariance(self):
        s, u = _cloud(1, 32, 700)
        base = find_winners_scan(s, u, chunk=700)
        for chunk in (1, 7, 64, 256, SCAN_CHUNK):
            out = find_winners_scan(s, u, chunk=chunk)
            for a, b in zip(out, base):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 50), n=st.integers(2, 260),
           chunk=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
    def test_scan_hypothesis(self, m, n, chunk, seed):
        s, u = _cloud(seed, m, n)
        out = find_winners_scan(s, u, chunk=chunk)
        ref = find_winners_ref(s, u)
        np.testing.assert_allclose(np.asarray(out[2]), np.asarray(ref[2]),
                                   rtol=1e-6, atol=1e-6)
        if not ties_possible(np.asarray(s), np.asarray(u)):
            np.testing.assert_array_equal(np.asarray(out[0]),
                                          np.asarray(ref[0]))


class TestFlavorParity:
    """pallas and scan flavors share exact semantics — the rust runtime may
    pick either artifact per bucket without changing algorithm behavior."""

    @pytest.mark.parametrize("m,n,live", [(128, 128, 5), (128, 128, 128),
                                          (128, 256, 200), (64, 512, 300)])
    def test_bitwise_equal_outputs(self, m, n, live):
        s, u = _cloud(99, m, n, live=live)
        a = find_winners_model(s, u, flavor="pallas")
        b = find_winners_model(s, u, flavor="scan")
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_unknown_flavor_raises(self):
        s, u = _cloud(0, 8, 8)
        with pytest.raises(ValueError):
            find_winners_model(s, u, flavor="cuda")


class TestBucketContract:
    """What rust (runtime/registry.rs) assumes about every artifact."""

    @pytest.mark.parametrize("flavor", ["pallas", "scan"])
    def test_lowered_signature(self, flavor):
        low = lower_bucket(128, 256, flavor=flavor)
        text = low.as_text()
        assert "128x3" in text and "256x3" in text

    def test_live_prefix_semantics(self):
        """Only the first `live` unit slots are real; results must be
        identical to a dense call on the live prefix."""
        m, n, live = 64, 256, 37
        s, u = _cloud(5, m, n, live=live)
        out = find_winners_model(s, u, flavor="scan")
        ref = find_winners_ref(s, u[:live])
        for a, b in zip(out, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_signal_rows_independent(self):
        """Row i of the batch output depends only on signal i — the implicit
        contract behind 'ignore output rows beyond the live batch'."""
        s, u = _cloud(21, 32, 128)
        full = find_winners_model(s, u, flavor="scan")
        half = find_winners_model(s[:16], u, flavor="scan")
        for a, b in zip(full, half):
            np.testing.assert_array_equal(np.asarray(a)[:16], np.asarray(b))
