//! Microbenchmark of the Find Winners implementations vs network size —
//! the per-phase counterpart of the paper's Fig. 9 ("times per signal in
//! the Find Winners phase" and speed-ups vs Single-signal).
//!
//! Custom harness (`harness = false`): the vendored crate set has no
//! criterion. Methodology: warm up, then repeat each measurement until
//! ≥ `MIN_TIME` elapsed, report the best-of-`REPS` per-signal time (best-of
//! resists scheduler noise on the single-CPU testbed).

use std::path::Path;
use std::time::{Duration, Instant};

use msgsn::findwinners::{BatchRust, FindWinners, Indexed, Scalar};
use msgsn::geometry::Vec3;
use msgsn::rng::Rng;
use msgsn::runtime::{PjrtFindWinners, Registry};
use msgsn::som::Network;

const REPS: usize = 5;
const MIN_TIME: Duration = Duration::from_millis(120);

fn random_net(n: usize, seed: u64) -> Network {
    let mut rng = Rng::seed_from(seed);
    let mut net = Network::new();
    for _ in 0..n {
        net.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1);
    }
    net
}

fn random_signals(m: usize, seed: u64) -> Vec<Vec3> {
    let mut rng = Rng::seed_from(seed);
    (0..m).map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32())).collect()
}

/// Best-of-REPS seconds per signal for one batched implementation.
fn bench_batch(fw: &mut dyn FindWinners, net: &Network, signals: &[Vec3]) -> f64 {
    let mut out = Vec::new();
    fw.find2_batch(net, signals, &mut out); // warmup (+ PJRT compile)
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let mut iters = 0u32;
        let t0 = Instant::now();
        while t0.elapsed() < MIN_TIME {
            fw.find2_batch(net, signals, &mut out);
            iters += 1;
        }
        let per_signal = t0.elapsed().as_secs_f64() / (iters as f64 * signals.len() as f64);
        best = best.min(per_signal);
    }
    best
}

/// Best-of-REPS seconds per signal for the per-signal (single) path.
fn bench_single(fw: &mut dyn FindWinners, net: &Network, signals: &[Vec3]) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let mut done = 0usize;
        let t0 = Instant::now();
        while t0.elapsed() < MIN_TIME {
            let s = signals[done % signals.len()];
            std::hint::black_box(fw.find2(net, s));
            done += 1;
        }
        best = best.min(t0.elapsed().as_secs_f64() / done as f64);
    }
    best
}

fn main() {
    let pjrt_ready = Path::new("artifacts/manifest.json").exists();
    println!("find_winners microbenchmark (best-of-{REPS}, per-signal seconds)");
    println!(
        "{:>7} {:>7} {:>12} {:>12} {:>12} {:>12} {:>9} {:>9}",
        "units", "batch", "single", "indexed", "multi", "pjrt", "idx x", "pjrt x"
    );
    for n in [128usize, 512, 2048, 8192] {
        let net = random_net(n, 1);
        let m = (n + 1).next_power_of_two().min(8192);
        let signals = random_signals(m, 2);

        let single = bench_single(&mut Scalar::new(), &net, &signals);
        let mut idx = Indexed::new(0.08);
        idx.rebuild(&net);
        let indexed = bench_single(&mut idx, &net, &signals);
        let multi = bench_batch(&mut BatchRust::default(), &net, &signals);
        let pjrt = if pjrt_ready {
            // Flavor override for A/B runs: MSGSN_FLAVOR=pallas|scan.
            let flavor = std::env::var("MSGSN_FLAVOR").ok();
            let reg = Registry::open(Path::new("artifacts"), flavor.as_deref()).unwrap();
            bench_batch(&mut PjrtFindWinners::new(reg), &net, &signals)
        } else {
            f64::NAN
        };
        println!(
            "{:>7} {:>7} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>9.1} {:>9.1}",
            n,
            m,
            single,
            indexed,
            multi,
            pjrt,
            single / indexed,
            single / pjrt
        );
    }
    if !pjrt_ready {
        println!("(pjrt column skipped: run `make artifacts`)");
    }
    println!(
        "\npaper shape (Fig 9b): speedups grow with the unit count; the \
         batched implementations win by orders of magnitude at n=8192."
    );
}
