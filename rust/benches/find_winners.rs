//! Microbenchmark of the Find Winners implementations vs network size —
//! the per-phase counterpart of the paper's Fig. 9 ("times per signal in
//! the Find Winners phase" and speed-ups vs Single-signal).
//!
//! Custom harness (`harness = false`): the vendored crate set has no
//! criterion. Methodology: warm up, then repeat each measurement until
//! ≥ `MIN_TIME` elapsed, report the best-of-`REPS` per-signal time (best-of
//! resists scheduler noise on the single-CPU testbed).
//!
//! Columns: `exhaust` is the scalar reference scan (`exhaustive_top2`,
//! pre-PR-2 `single`), `lane` is the lane-blocked SoA kernel (the current
//! `single`), `multi` the SoA-tiled batch, `multi@N` the same batch sharded
//! across N pool workers (`find_threads`), `regionR` the batch with the
//! region-neighborhood scan over an R-region grid (`regions` knob — exact,
//! falls back to the tiles near boundaries), `pjrt` the AOT artifact.
//! Results are written to `BENCH_find_winners.json` for the trajectory.
//!
//! Additionally one `multi` row per *supported* SIMD dispatch tier
//! (`findwinners::simd`) is recorded, forced through the same
//! `set_override` path the `fw_isa` knob uses. Those JSON rows carry an
//! `"isa"` field that is part of the `compare_bench.py` row key, so
//! baselines recorded on hosts with different ISA support never
//! cross-diff (an absent tier is a skipped row, not a regression).

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use msgsn::findwinners::{exhaustive_top2, simd, BatchRust, FindWinners, FwIsa, Indexed, Scalar};
use msgsn::geometry::{Aabb, Vec3};
use msgsn::rng::Rng;
use msgsn::runtime::{PjrtFindWinners, Registry, WorkerPool};
use msgsn::som::{Network, RegionMap};

const REPS: usize = 5;
const MIN_TIME: Duration = Duration::from_millis(120);
const POOL_SHARDS: usize = 4;
/// Region count for the region-neighborhood scan row (the `regions` knob).
const REGIONS: usize = 64;

fn random_net(n: usize, seed: u64) -> Network {
    let mut rng = Rng::seed_from(seed);
    let mut net = Network::new();
    for _ in 0..n {
        net.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1);
    }
    net
}

fn random_signals(m: usize, seed: u64) -> Vec<Vec3> {
    let mut rng = Rng::seed_from(seed);
    (0..m).map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32())).collect()
}

/// Best-of-REPS seconds per signal for one batched implementation.
fn bench_batch(fw: &mut dyn FindWinners, net: &Network, signals: &[Vec3]) -> f64 {
    let mut out = Vec::new();
    fw.find2_batch(net, signals, &mut out); // warmup (+ PJRT compile / gather)
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let mut iters = 0u32;
        let t0 = Instant::now();
        while t0.elapsed() < MIN_TIME {
            fw.find2_batch(net, signals, &mut out);
            iters += 1;
        }
        let per_signal = t0.elapsed().as_secs_f64() / (iters as f64 * signals.len() as f64);
        best = best.min(per_signal);
    }
    best
}

/// Best-of-REPS seconds per signal for a per-signal closure.
fn bench_single(mut f: impl FnMut(Vec3), signals: &[Vec3]) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let mut done = 0usize;
        let t0 = Instant::now();
        while t0.elapsed() < MIN_TIME {
            f(signals[done % signals.len()]);
            done += 1;
        }
        best = best.min(t0.elapsed().as_secs_f64() / done as f64);
    }
    best
}

fn main() {
    let pjrt_ready = Path::new("artifacts/manifest.json").exists();
    println!("find_winners microbenchmark (best-of-{REPS}, per-signal seconds)");
    println!(
        "{:>7} {:>7} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12} {:>7} {:>7}",
        "units",
        "batch",
        "exhaust",
        "lane",
        "indexed",
        "multi",
        format!("multi@{POOL_SHARDS}"),
        format!("region{REGIONS}"),
        "pjrt",
        "lane x",
        "pool x"
    );
    let mut json_rows = Vec::new();
    for n in [128usize, 512, 2048, 8192] {
        let net = random_net(n, 1);
        let m = (n + 1).next_power_of_two().min(8192);
        let signals = random_signals(m, 2);

        let exhaust = bench_single(
            |s| {
                std::hint::black_box(exhaustive_top2(&net, s));
            },
            &signals,
        );
        let mut scalar = Scalar::new();
        let lane = bench_single(
            |s| {
                std::hint::black_box(scalar.find2(&net, s));
            },
            &signals,
        );
        let mut idx = Indexed::new(0.08);
        idx.rebuild(&net);
        let indexed = bench_single(
            |s| {
                std::hint::black_box(idx.find2(&net, s));
            },
            &signals,
        );
        let multi = bench_batch(&mut BatchRust::default(), &net, &signals);
        let pooled = {
            let mut fw = BatchRust::default();
            fw.attach_pool(Arc::new(WorkerPool::new(POOL_SHARDS)), POOL_SHARDS);
            bench_batch(&mut fw, &net, &signals)
        };
        let region = {
            // Units and signals live in the unit cube, so the region grid
            // covers it (the engine derives the same box from the mesh).
            let mut fw = BatchRust::default();
            fw.attach_regions(RegionMap::new(Aabb::new(Vec3::ZERO, Vec3::ONE), REGIONS));
            fw.rebuild(&net);
            bench_batch(&mut fw, &net, &signals)
        };
        let pjrt = if pjrt_ready {
            // Flavor override for A/B runs: MSGSN_FLAVOR=pallas|scan.
            let flavor = std::env::var("MSGSN_FLAVOR").ok();
            let reg = Registry::open(Path::new("artifacts"), flavor.as_deref()).unwrap();
            bench_batch(&mut PjrtFindWinners::new(reg), &net, &signals)
        } else {
            f64::NAN
        };
        // One dispatched-batch measurement per supported SIMD tier, forced
        // through the same `set_override` path the `fw_isa` knob uses.
        // Every tier is bit-identical; only the wall time differs.
        let mut isa_times = Vec::new();
        for isa in FwIsa::ALL {
            if !isa.is_supported() {
                continue;
            }
            simd::set_override(Some(isa)).unwrap();
            isa_times.push((isa, bench_batch(&mut BatchRust::default(), &net, &signals)));
        }
        simd::set_override(None).unwrap();
        println!(
            "{:>7} {:>7} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>7.1} {:>7.1}",
            n,
            m,
            exhaust,
            lane,
            indexed,
            multi,
            pooled,
            region,
            pjrt,
            exhaust / lane,
            multi / pooled,
        );
        let isa_cols: Vec<String> = isa_times
            .iter()
            .map(|(isa, t)| format!("{}={t:.3e}", isa.name()))
            .collect();
        println!("{:>15} isa-forced multi: {}", "", isa_cols.join("  "));
        json_rows.push(format!(
            "    {{\"units\": {n}, \"m\": {m}, \"exhaustive_s\": {exhaust:e}, \
             \"lane_s\": {lane:e}, \"indexed_s\": {indexed:e}, \"multi_s\": {multi:e}, \
             \"multi_pool{POOL_SHARDS}_s\": {pooled:e}, \
             \"region{REGIONS}_s\": {region:e}, \"pjrt_s\": {}}}",
            if pjrt.is_nan() { "null".to_string() } else { format!("{pjrt:e}") }
        ));
        for (isa, t) in &isa_times {
            // The "isa" field is part of the compare_bench.py row key:
            // hosts with different ISA support never cross-diff.
            json_rows.push(format!(
                "    {{\"units\": {n}, \"m\": {m}, \"isa\": \"{}\", \"multi_s\": {t:e}}}",
                isa.name()
            ));
        }
    }
    if !pjrt_ready {
        println!("(pjrt column skipped: run `make artifacts`)");
    }
    let json = format!(
        "{{\n  \"bench\": \"find_winners\",\n  \"per_signal_seconds\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    if let Err(e) = std::fs::write("BENCH_find_winners.json", &json) {
        eprintln!("(could not write BENCH_find_winners.json: {e})");
    } else {
        println!("\nwrote BENCH_find_winners.json");
    }
    println!(
        "\npaper shape (Fig 9b): speedups grow with the unit count; the \
         batched implementations win by orders of magnitude at n=8192."
    );
}
