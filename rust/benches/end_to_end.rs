//! End-to-end driver comparison at smoke scale — the bench-sized analogue
//! of the paper's Tables 1–4 / Fig. 10 (who wins end-to-end, by what
//! factor). Full paper-shaped runs: `msgsn reproduce --scale quick`.

use std::path::Path;

use msgsn::bench::{grid::run_grid, render::render_figure10, Scale};
use msgsn::config::Driver;
use msgsn::mesh::BenchmarkShape;

fn main() -> anyhow::Result<()> {
    let mut drivers = vec![Driver::Single, Driver::Indexed, Driver::Multi];
    if Path::new("artifacts/manifest.json").exists() {
        drivers.push(Driver::Pjrt);
    } else {
        eprintln!("note: artifacts/ missing — pjrt column skipped");
    }
    // The Update-phase drivers (same semantics as `multi`; the interesting
    // columns are Update wall time and, for pipelined, residual Sample).
    drivers.push(Driver::Pipelined);
    drivers.push(Driver::Parallel);

    println!("end-to-end smoke grid (blob + eight):");
    let grid = run_grid(
        &[BenchmarkShape::Blob, BenchmarkShape::Eight],
        &drivers,
        &Scale::SMOKE,
        42,
        None,
        |line| println!("{line}"),
    )?;

    for shape in grid.shapes() {
        println!("\n[{}] time to convergence / cap:", shape.name());
        for &d in &drivers {
            let r = grid.get(shape, d).unwrap();
            println!(
                "  {:9} {:>9.3}s  (update {:>7.3}s, {} units, find {:.0}% of time)",
                d.name(),
                r.total.as_secs_f64(),
                r.phase.update.as_secs_f64(),
                r.units,
                100.0 * r.phase.find_fraction(),
            );
        }
    }

    if drivers.contains(&Driver::Pjrt) {
        let (text, _) = render_figure10(&grid)?;
        println!("\n{text}");
    }

    // Pooled-vs-sequential end-to-end: the same parallel driver with the
    // worker pool off (update_threads=1, find_threads=1) and fully on
    // (auto plan workers + sharded Find Winners on the shared pool).
    // Results are bit-identical; only wall time may move.
    println!("\nworker-pool end-to-end (blob, smoke scale):");
    let mesh = msgsn::mesh::benchmark_mesh(BenchmarkShape::Blob, Scale::SMOKE.mesh_resolution);
    let mut pool_rows = Vec::new();
    let pool_runs = [
        ("sequential", 1usize, 1usize, 1usize),
        ("pooled", 0usize, 0usize, 1usize),
        // PR 4: the full region-sharded path (region Find Winners + the
        // region-aware executor schedule) on top of the pool. Identical
        // results to the rows above by construction.
        ("pooled+regions", 0usize, 0usize, 64usize),
    ];
    for (name, update_threads, find_threads, regions) in pool_runs {
        let mut cfg = Scale::SMOKE.configure(BenchmarkShape::Blob);
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        cfg.regions = regions;
        let mut rng = msgsn::rng::Rng::seed_from(42);
        let t0 = std::time::Instant::now();
        let r = msgsn::engine::run(&mesh, Driver::Parallel, &cfg, &mut rng)?;
        let total = t0.elapsed().as_secs_f64();
        println!(
            "  {:10} {:>8.3}s  (find {:>7.3}s, update {:>7.3}s, {} units, {} discarded)",
            name,
            total,
            r.phase.find.as_secs_f64(),
            r.phase.update.as_secs_f64(),
            r.units,
            r.discarded,
        );
        pool_rows.push(format!(
            "    {{\"row\": \"{name}\", \"update_threads\": {update_threads}, \
             \"find_threads\": {find_threads}, \"regions\": {regions}, \
             \"total_s\": {total:.6}, \
             \"find_s\": {:.6}, \"update_s\": {:.6}, \"units\": {}, \"discarded\": {}}}",
            r.phase.find.as_secs_f64(),
            r.phase.update.as_secs_f64(),
            r.units,
            r.discarded,
        ));
    }

    // Fleet: N independent networks multiplexed over ONE shared worker
    // pool (round-robin at batch granularity) vs the same N specs run
    // back-to-back through the classic blocking path. Results are
    // bit-identical (rust/tests/fleet.rs); this row pair measures the
    // orchestration overhead / interleaving benefit. The rows carry a
    // "jobs" field so scripts/compare_bench.py keys them per fleet size.
    println!("\nfleet end-to-end ({} jobs, smoke scale):", 2);
    let fleet_specs = || {
        [BenchmarkShape::Blob, BenchmarkShape::Eight]
            .into_iter()
            .enumerate()
            .map(|(k, shape)| {
                let mut cfg = Scale::SMOKE.configure(shape);
                cfg.driver = Driver::Parallel;
                cfg.update_threads = 0;
                cfg.seed = 42 + k as u64;
                msgsn::fleet::JobSpec::from_config(format!("{}-{k}", shape.name()), cfg)
            })
            .collect::<Vec<_>>()
    };
    let mut fleet_rows = Vec::new();
    {
        let t0 = std::time::Instant::now();
        let mut fleet = msgsn::fleet::Fleet::new(fleet_specs())?;
        let report = fleet.run(&msgsn::fleet::FleetOptions::default(), |_| {})?;
        let total = t0.elapsed().as_secs_f64();
        let signals: u64 =
            report.rows.iter().filter_map(|row| row.report.as_ref()).map(|r| r.signals).sum();
        println!("  {:18} {total:>8.3}s  ({signals} signals total)", "fleet-concurrent");
        fleet_rows.push(format!(
            "    {{\"row\": \"fleet-concurrent\", \"jobs\": 2, \"total_s\": {total:.6}, \
             \"signals_total\": {signals}}}"
        ));
    }
    {
        let t0 = std::time::Instant::now();
        let mut signals = 0u64;
        for spec in fleet_specs() {
            let mesh =
                msgsn::mesh::benchmark_mesh(spec.cfg.shape, spec.cfg.mesh_resolution);
            let mut rng = msgsn::rng::Rng::seed_from(spec.cfg.seed);
            let r = msgsn::engine::run(&mesh, spec.cfg.driver, &spec.cfg, &mut rng)?;
            signals += r.signals;
        }
        let total = t0.elapsed().as_secs_f64();
        println!("  {:18} {total:>8.3}s  ({signals} signals total)", "fleet-sequential");
        fleet_rows.push(format!(
            "    {{\"row\": \"fleet-sequential\", \"jobs\": 2, \"total_s\": {total:.6}, \
             \"signals_total\": {signals}}}"
        ));
    }

    // Dist: the same two-job shape driven through the PR 8
    // coordinator/worker split over the in-process channel transport —
    // measures the protocol + snapshot-shipping overhead on top of the
    // fleet-concurrent row. The row carries a "transport" field so
    // scripts/compare_bench.py keys channel and tcp numbers separately
    // (never a cross-transport diff).
    println!("\ndist end-to-end (2 jobs, channel transport, smoke scale):");
    let mut dist_rows = Vec::new();
    {
        let jobs: Vec<String> = [BenchmarkShape::Blob, BenchmarkShape::Eight]
            .into_iter()
            .enumerate()
            .map(|(k, shape)| {
                let cfg = Scale::SMOKE.configure(shape);
                format!(
                    "{{\"name\": \"{name}-{k}\", \"mesh\": \"{name}\", \
                     \"driver\": \"parallel\", \"seed\": {seed}, \
                     \"config\": {{\"mesh_resolution\": {res}, \"max_signals\": {cap}, \
                     \"update_threads\": 0}}}}",
                    name = shape.name(),
                    seed = 42 + k as u64,
                    res = cfg.mesh_resolution,
                    cap = cfg.limits.max_signals,
                )
            })
            .collect();
        let manifest = format!("{{\"version\": 1, \"jobs\": [{}]}}", jobs.join(","));
        let payloads = msgsn::fleet::manifest_job_payloads(&manifest)?;
        let t0 = std::time::Instant::now();
        let mut coordinator =
            msgsn::dist::Coordinator::new(payloads, msgsn::dist::DistOptions::default());
        let mut handles = Vec::new();
        for name in ["bench-dist-w0", "bench-dist-w1"] {
            let (coord_end, mut worker_end) = msgsn::dist::channel_transport_pair(name);
            coordinator.add_worker(name, Box::new(coord_end));
            let opts = msgsn::dist::WorkerOptions {
                name: name.to_string(),
                ..msgsn::dist::WorkerOptions::default()
            };
            handles.push(std::thread::spawn(move || {
                let _ = msgsn::dist::run_worker(&mut worker_end, &opts, |_| {});
            }));
        }
        let report = coordinator.run(|_| {});
        let total = t0.elapsed().as_secs_f64();
        for h in handles {
            let _ = h.join();
        }
        let signals: u64 = report.rows.iter().map(|r| r.signals).sum();
        println!(
            "  {:18} {total:>8.3}s  ({signals} signals total, outcome {:?})",
            "dist-fleet",
            report.outcome(),
        );
        dist_rows.push(format!(
            "    {{\"row\": \"dist-fleet\", \"jobs\": 2, \"transport\": \"channel\", \
             \"total_s\": {total:.6}, \"signals_total\": {signals}}}"
        ));
    }

    // Serve: the same two-job shape preloaded into the `msgsn serve`
    // daemon over a real TCP loopback socket, with one client requesting
    // shutdown so the daemon drains and reports. Measures the line-JSON
    // protocol + QoS scheduling overhead on top of the fleet-concurrent
    // row. The row carries "serve": true so scripts/compare_bench.py
    // keys daemon-path numbers separately from batch-fleet rows.
    println!("\nserve end-to-end (2 jobs, tcp loopback, smoke scale):");
    let mut serve_rows = Vec::new();
    {
        use std::io::{BufRead, BufReader, Write};
        let t0 = std::time::Instant::now();
        let mut server = msgsn::serve::Server::bind("127.0.0.1:0", fleet_specs())?;
        let addr = server.local_addr()?;
        let client = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr)?;
            stream.write_all(b"{\"cmd\": \"watch\"}\n{\"cmd\": \"shutdown\"}\n")?;
            let mut lines = BufReader::new(stream).lines();
            let mut seen = 0usize;
            for line in &mut lines {
                seen += 1;
                if line?.contains("\"bye\"") {
                    break;
                }
            }
            Ok::<usize, std::io::Error>(seen)
        });
        let opts = msgsn::serve::ServeOptions {
            idle_poll: std::time::Duration::from_millis(1),
            ..msgsn::serve::ServeOptions::default()
        };
        let report = server.run(&opts, &mut |_| {})?;
        let lines = client.join().expect("serve bench client panicked")?;
        let total = t0.elapsed().as_secs_f64();
        let signals: u64 =
            report.rows.iter().filter_map(|row| row.report.as_ref()).map(|r| r.signals).sum();
        println!(
            "  {:18} {total:>8.3}s  ({signals} signals total, {lines} protocol lines, outcome {:?})",
            "serve-fleet",
            report.outcome(),
        );
        serve_rows.push(format!(
            "    {{\"row\": \"serve-fleet\", \"jobs\": 2, \"serve\": true, \
             \"total_s\": {total:.6}, \"signals_total\": {signals}}}"
        ));
    }

    // Telemetry: the same solo smoke run with the instrument registry off
    // vs fully on (counters + phase timers + trace). The contract says the
    // results are bit-identical and the overhead is one relaxed load per
    // hot-path site; this row pair puts a wall-clock number on it. Rows
    // carry a "telemetry" field so scripts/compare_bench.py keys them
    // separately (an on-row never regression-diffs against an off-row).
    println!("\ntelemetry overhead end-to-end (blob, smoke scale):");
    let mut telemetry_rows = Vec::new();
    for (name, enabled) in [("off", false), ("on", true)] {
        msgsn::telemetry::set_enabled(enabled);
        let mut cfg = Scale::SMOKE.configure(BenchmarkShape::Blob);
        cfg.update_threads = 0;
        cfg.find_threads = 0;
        let mut rng = msgsn::rng::Rng::seed_from(42);
        let t0 = std::time::Instant::now();
        let r = msgsn::engine::run(&mesh, Driver::Parallel, &cfg, &mut rng)?;
        let total = t0.elapsed().as_secs_f64();
        println!(
            "  telemetry-{:3} {total:>8.3}s  ({} units, {} discarded)",
            name, r.units, r.discarded,
        );
        telemetry_rows.push(format!(
            "    {{\"row\": \"telemetry-overhead\", \"telemetry\": \"{name}\", \
             \"total_s\": {total:.6}, \"units\": {}, \"discarded\": {}}}",
            r.units, r.discarded,
        ));
    }
    msgsn::telemetry::set_enabled(false);

    let csv = grid.to_csv();
    let json = format!(
        "{{\n  \"bench\": \"end_to_end\",\n  \"worker_pool\": [\n{}\n  ],\n  \
         \"fleet\": [\n{}\n  ],\n  \"dist\": [\n{}\n  ],\n  \
         \"serve\": [\n{}\n  ],\n  \"telemetry\": [\n{}\n  ],\n  \"grid_csv\": {:?}\n}}\n",
        pool_rows.join(",\n"),
        fleet_rows.join(",\n"),
        dist_rows.join(",\n"),
        serve_rows.join(",\n"),
        telemetry_rows.join(",\n"),
        csv,
    );
    if let Err(e) = std::fs::write("BENCH_end_to_end.json", &json) {
        eprintln!("(could not write BENCH_end_to_end.json: {e})");
    } else {
        println!("wrote BENCH_end_to_end.json");
    }
    Ok(())
}
