//! End-to-end driver comparison at smoke scale — the bench-sized analogue
//! of the paper's Tables 1–4 / Fig. 10 (who wins end-to-end, by what
//! factor). Full paper-shaped runs: `msgsn reproduce --scale quick`.

use std::path::Path;

use msgsn::bench::{grid::run_grid, render::render_figure10, Scale};
use msgsn::config::Driver;
use msgsn::mesh::BenchmarkShape;

fn main() -> anyhow::Result<()> {
    let mut drivers = vec![Driver::Single, Driver::Indexed, Driver::Multi];
    if Path::new("artifacts/manifest.json").exists() {
        drivers.push(Driver::Pjrt);
    } else {
        eprintln!("note: artifacts/ missing — pjrt column skipped");
    }
    // The Update-phase drivers (same semantics as `multi`; the interesting
    // columns are Update wall time and, for pipelined, residual Sample).
    drivers.push(Driver::Pipelined);
    drivers.push(Driver::Parallel);

    println!("end-to-end smoke grid (blob + eight):");
    let grid = run_grid(
        &[BenchmarkShape::Blob, BenchmarkShape::Eight],
        &drivers,
        &Scale::SMOKE,
        42,
        None,
        |line| println!("{line}"),
    )?;

    for shape in grid.shapes() {
        println!("\n[{}] time to convergence / cap:", shape.name());
        for &d in &drivers {
            let r = grid.get(shape, d).unwrap();
            println!(
                "  {:9} {:>9.3}s  (update {:>7.3}s, {} units, find {:.0}% of time)",
                d.name(),
                r.total.as_secs_f64(),
                r.phase.update.as_secs_f64(),
                r.units,
                100.0 * r.phase.find_fraction(),
            );
        }
    }

    if drivers.contains(&Driver::Pjrt) {
        let (text, _) = render_figure10(&grid)?;
        println!("\n{text}");
    }
    Ok(())
}
