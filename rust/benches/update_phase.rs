//! Update-phase throughput: the paper's Conclusions call Update "the most
//! time-consuming" phase once Find Winners is accelerated, and leave its
//! parallelization as future work. This bench quantifies the Update rule
//! itself (SOAM adapt/insert/prune path) and the winner-lock overhead, and
//! measures the pipelined overlap (now composed with the pooled Update
//! split), the pooled plan pass + concurrent commit, and the
//! `find_threads` sharding on the shared pool. Driver rows are written to
//! `BENCH_update_phase.json`; the PR 3 additions — the eager-vs-lazy GNG
//! decay microbench and the GNG driver rows the lazy decay made possible —
//! go to `BENCH_PR3.json`.
//!
//! `MSGSN_BENCH_SIGNALS` scales the driver-row workloads (default
//! 300_000) so CI can run a shortened pass for the regression diff.

use std::time::{Duration, Instant};

use msgsn::config::{Algorithm, Driver, Limits, RunConfig};
use msgsn::coordinator::LockTable;
use msgsn::engine::run_multi_signal;
use msgsn::findwinners::{BatchRust, FindWinners, Scalar};
use msgsn::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::som::{ChangeLog, GrowingNetwork, Soam, SoamParams};

fn bench_signals() -> u64 {
    std::env::var("MSGSN_BENCH_SIGNALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300_000)
}

fn grown_soam(sampler: &SurfaceSampler, threshold: f32, grow_signals: u64) -> Soam {
    let mut rng = Rng::seed_from(3);
    let mut soam = Soam::new(SoamParams {
        insertion_threshold: threshold,
        ..SoamParams::default()
    });
    soam.init(sampler, &mut rng);
    let mut fw = Scalar::new();
    let mut log = ChangeLog::default();
    for _ in 0..grow_signals {
        let s = sampler.sample(&mut rng);
        let w = fw.find2(soam.net(), s).unwrap();
        log.clear();
        soam.update(s, &w, &mut log);
    }
    soam
}

fn main() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 48);
    let sampler = SurfaceSampler::new(&mesh);

    // 1. Raw update-rule throughput on a mature network.
    println!("update rule throughput (mature network, winners precomputed):");
    for (threshold, grow) in [(0.15f32, 150_000u64), (0.075, 600_000)] {
        let mut soam = grown_soam(&sampler, threshold, grow);
        let units = soam.net().len();
        let mut rng = Rng::seed_from(9);
        let mut fw = Scalar::new();
        // Precompute a pool of (signal, winners).
        let pool: Vec<_> = (0..4096)
            .map(|_| {
                let s = sampler.sample(&mut rng);
                (s, fw.find2(soam.net(), s).unwrap())
            })
            .collect();
        let mut log = ChangeLog::default();
        let t0 = Instant::now();
        let mut done = 0usize;
        while t0.elapsed() < Duration::from_millis(400) {
            let (s, w) = pool[done % pool.len()];
            log.clear();
            soam.update(s, &w, &mut log);
            done += 1;
        }
        let per = t0.elapsed().as_secs_f64() / done as f64;
        println!(
            "  {:>5} units: {:>10.1} ns/update ({:.2} M updates/s)",
            units,
            per * 1e9,
            1e-6 / per
        );
    }

    // 2. Lock-table overhead (the §2.2 collision mechanism).
    {
        let mut locks = LockTable::new();
        locks.ensure_capacity(100_000);
        let mut rng = Rng::seed_from(1);
        let winners: Vec<u32> = (0..8192).map(|_| rng.below(3000) as u32).collect();
        let t0 = Instant::now();
        let mut rounds = 0u64;
        while t0.elapsed() < Duration::from_millis(300) {
            locks.next_batch();
            for &w in &winners {
                std::hint::black_box(locks.try_lock(w));
            }
            rounds += 1;
        }
        let per = t0.elapsed().as_secs_f64() / (rounds as f64 * winners.len() as f64);
        println!("\nlock table: {:.2} ns per try_lock (batch of 8192)", per * 1e9);
    }

    // 3. Update-phase drivers: plain multi vs pipelined (Sample/Update
    //    overlap) vs parallel with a sequential plan (update_threads=1) vs
    //    the pooled plan pass (auto threads) vs pooled plan + sharded Find
    //    Winners on the same pool. The parallel rows are bit-identical to
    //    multi by construction — only the time columns may move.
    let signals = bench_signals();
    println!("\nupdate-phase drivers ({signals} signals, blob):");
    let rows: [(&str, Driver, usize, usize, usize); 8] = [
        ("multi", Driver::Multi, 1, 1, 1),
        ("pipelined", Driver::Pipelined, 1, 1, 1),
        ("pipe pooled", Driver::Pipelined, 0, 1, 1),
        ("par seq-plan", Driver::Parallel, 1, 1, 1),
        ("par pooled", Driver::Parallel, 0, 1, 1),
        ("par pool+find", Driver::Parallel, 0, 0, 1),
        // PR 4: region-sharded convergence (region-neighborhood Find
        // Winners + region-aware schedule with deferred insert commits).
        ("multi regions", Driver::Multi, 1, 1, 64),
        ("par regions", Driver::Parallel, 0, 0, 64),
    ];
    let mut json_rows = Vec::new();
    for (name, driver, update_threads, find_threads, regions) in rows {
        let mut rng = Rng::seed_from(5);
        let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
        cfg.soam.insertion_threshold = 0.1;
        cfg.driver = driver;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        cfg.regions = regions;
        cfg.limits = Limits { max_signals: signals, ..Limits::default() };
        let mut soam = Soam::new(cfg.soam);
        let mut fw = BatchRust::default();
        let t0 = Instant::now();
        // Everything except the bare multi reference goes through
        // run_convergence: it resolves the thread knobs and builds the
        // pipelined/parallel executors exactly as production runs do
        // (queue_depth comes from the preset, 2).
        let r = match driver {
            // The bare multi reference row bypasses run_convergence; the
            // region row must go through it (that is where the region map
            // is built and attached).
            Driver::Multi if regions == 1 => {
                run_multi_signal(&mut soam, &sampler, &mut fw, &cfg.limits, &mut rng)
            }
            _ => msgsn::engine::run_convergence(&mut soam, &sampler, &mut fw, &cfg, &mut rng),
        };
        let total = t0.elapsed().as_secs_f64();
        println!(
            "  {:14} {:>8.3}s total  sample {:>7.3}s  find {:>7.3}s  update {:>7.3}s ({} units, {} discarded)",
            name,
            total,
            r.phase.sample.as_secs_f64(),
            r.phase.find.as_secs_f64(),
            r.phase.update.as_secs_f64(),
            r.units,
            r.discarded,
        );
        json_rows.push(format!(
            "    {{\"row\": \"{name}\", \"driver\": \"{}\", \"update_threads\": {update_threads}, \
             \"find_threads\": {find_threads}, \"regions\": {regions}, \"total_s\": {total:.6}, \
             \"sample_s\": {:.6}, \"find_s\": {:.6}, \"update_s\": {:.6}, \
             \"units\": {}, \"discarded\": {}}}",
            driver.name(),
            r.phase.sample.as_secs_f64(),
            r.phase.find.as_secs_f64(),
            r.phase.update.as_secs_f64(),
            r.units,
            r.discarded,
        ));
    }
    println!("\n(pipelined: the Sample row is residual wait time — overlap hides the rest;");
    println!(" parallel rows: identical units/discards to multi by construction)");
    let json = format!(
        "{{\n  \"bench\": \"update_phase\",\n  \"signals\": {signals},\n  \"drivers\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    if let Err(e) = std::fs::write("BENCH_update_phase.json", &json) {
        eprintln!("(could not write BENCH_update_phase.json: {e})");
    } else {
        println!("wrote BENCH_update_phase.json");
    }

    // 4. GNG error-decay bookkeeping: the eager per-signal O(N) sweep vs
    //    the lazy epoch scheme (one counter bump per signal + a
    //    repeated-multiply ladder on the ~|N(w1)|+1 units actually read).
    //    This is the sequential tail the lazy decay removed; the sweep
    //    cost grows linearly with the network while the lazy cost is flat.
    println!("\nGNG decay bookkeeping (ns/signal, winner + 6 neighbors touched per signal):");
    let mut decay_rows = Vec::new();
    for n in [1_000usize, 10_000, 100_000] {
        let d = 1.0f32 - 0.0005;
        let mut rng = Rng::seed_from(17);
        let touched: Vec<usize> = (0..4096).map(|_| rng.index(n)).collect();

        // Eager: multiply every unit's error once per signal.
        let mut errors = vec![1.0f32; n];
        let t0 = Instant::now();
        let mut eager_signals = 0u64;
        while t0.elapsed() < Duration::from_millis(250) {
            for e in errors.iter_mut() {
                *e *= d;
            }
            eager_signals += 1;
        }
        std::hint::black_box(&errors);
        let eager_ns = t0.elapsed().as_secs_f64() / eager_signals as f64 * 1e9;

        // Lazy: bump the epoch; materialize only the touched units.
        let mut errors = vec![1.0f32; n];
        let mut epochs = vec![0u64; n];
        let mut epoch = 0u64;
        let t0 = Instant::now();
        let mut lazy_signals = 0u64;
        let mut cursor = 0usize;
        while t0.elapsed() < Duration::from_millis(250) {
            epoch += 1;
            // A winner read-modify-write plus six neighbor reads.
            for k in 0..7 {
                let i = touched[(cursor + k) % touched.len()];
                let mut e = errors[i];
                let mut steps = epoch - epochs[i];
                while steps > 0 {
                    let next = e * d;
                    if next.to_bits() == e.to_bits() {
                        break;
                    }
                    e = next;
                    steps -= 1;
                }
                errors[i] = e;
                epochs[i] = epoch;
            }
            errors[touched[cursor % touched.len()]] += 0.01;
            cursor += 7;
            lazy_signals += 1;
        }
        let lazy_ns = t0.elapsed().as_secs_f64() / lazy_signals as f64 * 1e9;
        std::hint::black_box(&errors);

        println!(
            "  n={n:>6}: eager sweep {eager_ns:>10.1} ns/signal   lazy epochs {lazy_ns:>8.1} ns/signal   ({:.1}x)",
            eager_ns / lazy_ns
        );
        decay_rows.push(format!(
            "    {{\"units\": {n}, \"eager_ns_per_signal\": {eager_ns:.2}, \
             \"lazy_ns_per_signal\": {lazy_ns:.2}}}"
        ));
    }

    // 5. GNG through the drivers — rows that were meaningless before the
    //    lazy decay (GNG always classified Structural, so `parallel`
    //    degenerated to sequential by definition).
    println!("\nGNG drivers ({signals} signals, eight):");
    let gng_mesh = benchmark_mesh(BenchmarkShape::Eight, 48);
    let mut gng_rows = Vec::new();
    for (name, driver, update_threads, find_threads) in [
        ("gng multi", Driver::Multi, 1usize, 1usize),
        ("gng par pooled", Driver::Parallel, 0, 1),
        ("gng pool+find", Driver::Parallel, 0, 0),
    ] {
        let mut cfg = RunConfig::preset(BenchmarkShape::Eight);
        cfg.algorithm = Algorithm::Gng;
        cfg.driver = driver;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        cfg.limits = Limits { max_signals: signals, ..Limits::default() };
        let mut rng = Rng::seed_from(5);
        let t0 = Instant::now();
        let r = msgsn::engine::run(&gng_mesh, driver, &cfg, &mut rng).expect("gng bench run");
        let total = t0.elapsed().as_secs_f64();
        println!(
            "  {:14} {:>8.3}s total  find {:>7.3}s  update {:>7.3}s ({} units, {} discarded)",
            name,
            total,
            r.phase.find.as_secs_f64(),
            r.phase.update.as_secs_f64(),
            r.units,
            r.discarded,
        );
        gng_rows.push(format!(
            "    {{\"row\": \"{name}\", \"driver\": \"{}\", \"update_threads\": {update_threads}, \
             \"find_threads\": {find_threads}, \"total_s\": {total:.6}, \
             \"find_s\": {:.6}, \"update_s\": {:.6}, \"units\": {}, \"discarded\": {}}}",
            driver.name(),
            r.phase.find.as_secs_f64(),
            r.phase.update.as_secs_f64(),
            r.units,
            r.discarded,
        ));
    }
    println!("(gng parallel rows: identical units/discards to gng multi by construction)");

    let pr3 = format!(
        "{{\n  \"bench\": \"pr3\",\n  \"signals\": {signals},\n  \"decay_microbench\": [\n{}\n  ],\n  \"gng_drivers\": [\n{}\n  ]\n}}\n",
        decay_rows.join(",\n"),
        gng_rows.join(",\n")
    );
    if let Err(e) = std::fs::write("BENCH_PR3.json", &pr3) {
        eprintln!("(could not write BENCH_PR3.json: {e})");
    } else {
        println!("wrote BENCH_PR3.json");
    }
}
