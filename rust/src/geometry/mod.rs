//! Geometric primitives: [`Vec3`], [`Aabb`], [`Triangle`].
//!
//! Everything downstream (implicit fields, marching tetrahedra, meshes, the
//! SOAM reference vectors, the hash index) is built on these three types.

mod aabb;
mod triangle;
mod vec3;

pub use aabb::Aabb;
pub use triangle::Triangle;
pub use vec3::Vec3;
