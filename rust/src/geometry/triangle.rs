//! Triangle primitive: area, normal, uniform sampling.
//!
//! The paper samples input signals "with uniform probability distribution
//! P(ξ)" from a triangular mesh (§3.1); [`Triangle::sample_uniform`] is the
//! per-face half of that sampler (the area-weighted face choice lives in
//! `mesh::sampler`).

use super::Vec3;
use crate::rng::Rng;

/// A triangle given by its three corners.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Triangle {
    pub a: Vec3,
    pub b: Vec3,
    pub c: Vec3,
}

impl Triangle {
    pub fn new(a: Vec3, b: Vec3, c: Vec3) -> Self {
        Self { a, b, c }
    }

    #[inline]
    pub fn area(&self) -> f32 {
        (self.b - self.a).cross(self.c - self.a).norm() * 0.5
    }

    /// Unit normal with right-hand orientation `(b-a) × (c-a)`; `None` for
    /// degenerate triangles.
    pub fn normal(&self) -> Option<Vec3> {
        (self.b - self.a).cross(self.c - self.a).normalized()
    }

    #[inline]
    pub fn centroid(&self) -> Vec3 {
        (self.a + self.b + self.c) / 3.0
    }

    /// Uniform point on the triangle via the square-root parametrization
    /// (Osada et al.): `p = (1-√r1)·a + √r1(1-r2)·b + √r1·r2·c`.
    pub fn sample_uniform(&self, rng: &mut Rng) -> Vec3 {
        let r1 = (rng.f64() as f32).sqrt();
        let r2 = rng.f64() as f32;
        self.a * (1.0 - r1) + self.b * (r1 * (1.0 - r2)) + self.c * (r1 * r2)
    }

    /// Barycentric coordinates of `p` projected onto the triangle plane.
    pub fn barycentric(&self, p: Vec3) -> (f32, f32, f32) {
        let v0 = self.b - self.a;
        let v1 = self.c - self.a;
        let v2 = p - self.a;
        let d00 = v0.dot(v0);
        let d01 = v0.dot(v1);
        let d11 = v1.dot(v1);
        let d20 = v2.dot(v0);
        let d21 = v2.dot(v1);
        let denom = d00 * d11 - d01 * d01;
        if denom.abs() < 1e-20 {
            return (1.0, 0.0, 0.0);
        }
        let v = (d11 * d20 - d01 * d21) / denom;
        let w = (d00 * d21 - d01 * d20) / denom;
        (1.0 - v - w, v, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_right() -> Triangle {
        Triangle::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0), Vec3::new(0.0, 1.0, 0.0))
    }

    #[test]
    fn area_and_normal() {
        let t = unit_right();
        assert!((t.area() - 0.5).abs() < 1e-7);
        assert_eq!(t.normal().unwrap(), Vec3::new(0.0, 0.0, 1.0));
    }

    #[test]
    fn degenerate_normal_is_none() {
        let t = Triangle::new(Vec3::ZERO, Vec3::ONE, Vec3::ONE * 2.0);
        assert!(t.normal().is_none());
        assert_eq!(t.area(), 0.0);
    }

    #[test]
    fn samples_inside_triangle() {
        let t = unit_right();
        let mut rng = Rng::seed_from(5);
        for _ in 0..2000 {
            let p = t.sample_uniform(&mut rng);
            let (u, v, w) = t.barycentric(p);
            for c in [u, v, w] {
                assert!((-1e-4..=1.0 + 1e-4).contains(&c), "bary {c}");
            }
            assert!(p.z.abs() < 1e-6);
        }
    }

    #[test]
    fn sampling_is_roughly_uniform() {
        // Split the unit right triangle along x+y=0.5: the small corner
        // triangle holds 1/4 of the area.
        let t = unit_right();
        let mut rng = Rng::seed_from(11);
        let n = 20_000;
        let corner = (0..n)
            .filter(|_| {
                let p = t.sample_uniform(&mut rng);
                p.x + p.y < 0.5
            })
            .count();
        let frac = corner as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "corner fraction {frac}");
    }

    #[test]
    fn barycentric_roundtrip() {
        let t = Triangle::new(
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(2.0, 0.0, 1.0),
            Vec3::new(0.0, 3.0, 1.0),
        );
        let p = t.a * 0.2 + t.b * 0.3 + t.c * 0.5;
        let (u, v, w) = t.barycentric(p);
        assert!((u - 0.2).abs() < 1e-5);
        assert!((v - 0.3).abs() < 1e-5);
        assert!((w - 0.5).abs() < 1e-5);
    }
}
