//! Axis-aligned bounding box — used by the marching grid, the hash index
//! (cell addressing) and mesh normalization.

use super::Vec3;

/// Axis-aligned bounding box `[min, max]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub min: Vec3,
    pub max: Vec3,
}

impl Aabb {
    /// The empty box (inverted bounds); grows under [`Aabb::expand`].
    pub const EMPTY: Aabb = Aabb {
        min: Vec3::splat(f32::INFINITY),
        max: Vec3::splat(f32::NEG_INFINITY),
    };

    pub fn new(min: Vec3, max: Vec3) -> Self {
        Self { min, max }
    }

    /// Box enclosing a point set.
    pub fn from_points<'a>(pts: impl IntoIterator<Item = &'a Vec3>) -> Self {
        let mut b = Self::EMPTY;
        for p in pts {
            b.expand(*p);
        }
        b
    }

    #[inline]
    pub fn expand(&mut self, p: Vec3) {
        self.min = self.min.min(p);
        self.max = self.max.max(p);
    }

    /// Uniformly inflate by `pad` on all sides.
    pub fn inflated(&self, pad: f32) -> Aabb {
        Aabb::new(self.min - Vec3::splat(pad), self.max + Vec3::splat(pad))
    }

    #[inline]
    pub fn extent(&self) -> Vec3 {
        self.max - self.min
    }

    #[inline]
    pub fn center(&self) -> Vec3 {
        (self.min + self.max) * 0.5
    }

    /// Longest edge of the box.
    #[inline]
    pub fn max_extent(&self) -> f32 {
        let e = self.extent();
        e.x.max(e.y).max(e.z)
    }

    #[inline]
    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.min.x
            && p.y >= self.min.y
            && p.z >= self.min.z
            && p.x <= self.max.x
            && p.y <= self.max.y
            && p.z <= self.max.z
    }

    pub fn is_empty(&self) -> bool {
        self.min.x > self.max.x
    }

    /// Squared distance from `p` to the box (0 inside, `+inf` for empty).
    ///
    /// Monotonicity note: each per-axis clamp is computed with the same
    /// correctly-rounded f32 subtractions as a point-to-point `dist2`, so
    /// `self.dist2(p) <= p.dist2(q)` holds in f32 for every `q` inside the
    /// box — the property the batch staleness guard's early exit relies on.
    #[inline]
    pub fn dist2(&self, p: Vec3) -> f32 {
        if self.is_empty() {
            return f32::INFINITY;
        }
        let dx = (self.min.x - p.x).max(0.0).max(p.x - self.max.x);
        let dy = (self.min.y - p.y).max(0.0).max(p.y - self.max.y);
        let dz = (self.min.z - p.z).max(0.0).max(p.z - self.max.z);
        dx * dx + dy * dy + dz * dz
    }

    /// Surface area (0 for empty).
    pub fn area(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        let e = self.extent();
        2.0 * (e.x * e.y + e.y * e.z + e.z * e.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_points_and_contains() {
        let pts = [
            Vec3::new(-1.0, 0.0, 2.0),
            Vec3::new(3.0, -2.0, 0.0),
            Vec3::new(0.0, 5.0, 1.0),
        ];
        let b = Aabb::from_points(pts.iter());
        assert_eq!(b.min, Vec3::new(-1.0, -2.0, 0.0));
        assert_eq!(b.max, Vec3::new(3.0, 5.0, 2.0));
        for p in &pts {
            assert!(b.contains(*p));
        }
        assert!(!b.contains(Vec3::new(10.0, 0.0, 0.0)));
    }

    #[test]
    fn empty_box_behaviour() {
        let b = Aabb::EMPTY;
        assert!(b.is_empty());
        assert_eq!(b.area(), 0.0);
        assert!(!b.contains(Vec3::ZERO));
    }

    #[test]
    fn inflate_grows_symmetrically() {
        let b = Aabb::new(Vec3::ZERO, Vec3::ONE).inflated(0.5);
        assert_eq!(b.min, Vec3::splat(-0.5));
        assert_eq!(b.max, Vec3::splat(1.5));
    }

    #[test]
    fn dist2_inside_edge_outside() {
        let b = Aabb::new(Vec3::ZERO, Vec3::ONE);
        assert_eq!(b.dist2(Vec3::new(0.5, 0.5, 0.5)), 0.0);
        assert_eq!(b.dist2(Vec3::new(1.0, 1.0, 1.0)), 0.0);
        assert_eq!(b.dist2(Vec3::new(2.0, 0.5, 0.5)), 1.0);
        let d = b.dist2(Vec3::new(-1.0, -1.0, 2.0));
        assert!((d - 3.0).abs() < 1e-6);
        assert_eq!(Aabb::EMPTY.dist2(Vec3::ZERO), f32::INFINITY);
    }

    #[test]
    fn dist2_lower_bounds_member_points() {
        let pts = [
            Vec3::new(0.1, 0.9, 0.4),
            Vec3::new(0.7, 0.2, 0.8),
            Vec3::new(0.3, 0.3, 0.1),
        ];
        let b = Aabb::from_points(pts.iter());
        for q in [
            Vec3::new(-0.5, 0.5, 0.5),
            Vec3::new(0.5, 0.5, 0.5),
            Vec3::new(2.0, -1.0, 0.3),
        ] {
            for p in &pts {
                assert!(b.dist2(q) <= q.dist2(*p));
            }
        }
    }

    #[test]
    fn extent_center_area() {
        let b = Aabb::new(Vec3::ZERO, Vec3::new(2.0, 3.0, 4.0));
        assert_eq!(b.extent(), Vec3::new(2.0, 3.0, 4.0));
        assert_eq!(b.center(), Vec3::new(1.0, 1.5, 2.0));
        assert_eq!(b.max_extent(), 4.0);
        assert_eq!(b.area(), 2.0 * (6.0 + 12.0 + 8.0));
    }
}
