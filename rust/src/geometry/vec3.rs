//! Minimal 3-vector in `f32` — the coordinate type of signals and reference
//! vectors. `f32` (not `f64`) on purpose: it is the dtype of the AOT
//! artifacts, and the rust scalar Find-Winners path must match the kernel's
//! arithmetic bit-for-bit (DESIGN.md §7, invariant 5).

use std::ops::{Add, AddAssign, Div, Index, Mul, Neg, Sub, SubAssign};

/// A 3-component `f32` vector / point.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };
    pub const ONE: Vec3 = Vec3 { x: 1.0, y: 1.0, z: 1.0 };

    #[inline]
    pub const fn new(x: f32, y: f32, z: f32) -> Self {
        Self { x, y, z }
    }

    #[inline]
    pub const fn splat(v: f32) -> Self {
        Self { x: v, y: v, z: v }
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    /// Squared Euclidean distance, evaluated as `dx*dx + dy*dy + dz*dz` in
    /// `f32` — the exact expression the L1 kernel computes per unit, so both
    /// sides agree bitwise on untied data.
    #[inline]
    pub fn dist2(self, o: Vec3) -> f32 {
        let dx = self.x - o.x;
        let dy = self.y - o.y;
        let dz = self.z - o.z;
        dx * dx + dy * dy + dz * dz
    }

    #[inline]
    pub fn dist(self, o: Vec3) -> f32 {
        self.dist2(o).sqrt()
    }

    #[inline]
    pub fn norm2(self) -> f32 {
        self.dot(self)
    }

    #[inline]
    pub fn norm(self) -> f32 {
        self.norm2().sqrt()
    }

    /// Unit vector; `None` for (near-)zero input.
    #[inline]
    pub fn normalized(self) -> Option<Vec3> {
        let n = self.norm();
        if n > 1e-20 {
            Some(self / n)
        } else {
            None
        }
    }

    #[inline]
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    #[inline]
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    /// Componentwise linear interpolation `self + t * (o - self)`.
    #[inline]
    pub fn lerp(self, o: Vec3, t: f32) -> Vec3 {
        self + (o - self) * t
    }

    #[inline]
    pub fn is_finite(self) -> bool {
        self.x.is_finite() && self.y.is_finite() && self.z.is_finite()
    }

    #[inline]
    pub fn to_array(self) -> [f32; 3] {
        [self.x, self.y, self.z]
    }

    #[inline]
    pub fn from_array(a: [f32; 3]) -> Self {
        Vec3::new(a[0], a[1], a[2])
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    #[inline]
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl AddAssign for Vec3 {
    #[inline]
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    #[inline]
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl SubAssign for Vec3 {
    #[inline]
    fn sub_assign(&mut self, o: Vec3) {
        *self = *self - o;
    }
}

impl Mul<f32> for Vec3 {
    type Output = Vec3;
    #[inline]
    fn mul(self, s: f32) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f32> for Vec3 {
    type Output = Vec3;
    #[inline]
    fn div(self, s: f32) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    #[inline]
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

impl Index<usize> for Vec3 {
    type Output = f32;
    #[inline]
    fn index(&self, i: usize) -> &f32 {
        match i {
            0 => &self.x,
            1 => &self.y,
            2 => &self.z,
            _ => panic!("Vec3 index {i} out of range"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_cross_orthogonality() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-2.0, 0.5, 4.0);
        let c = a.cross(b);
        assert!(c.dot(a).abs() < 1e-4);
        assert!(c.dot(b).abs() < 1e-4);
    }

    #[test]
    fn dist2_matches_manual() {
        let a = Vec3::new(1.0, 0.0, -1.0);
        let b = Vec3::new(4.0, 4.0, -1.0);
        assert_eq!(a.dist2(b), 25.0);
        assert_eq!(a.dist(b), 5.0);
    }

    #[test]
    fn lerp_endpoints() {
        let a = Vec3::new(0.0, 1.0, 2.0);
        let b = Vec3::new(10.0, -1.0, 0.0);
        assert_eq!(a.lerp(b, 0.0), a);
        assert_eq!(a.lerp(b, 1.0), b);
        assert_eq!(a.lerp(b, 0.5), Vec3::new(5.0, 0.0, 1.0));
    }

    #[test]
    fn normalized_unit_and_zero() {
        assert!(Vec3::new(0.0, 3.0, 4.0).normalized().unwrap().norm() - 1.0 < 1e-6);
        assert!(Vec3::ZERO.normalized().is_none());
    }

    #[test]
    fn index_access() {
        let v = Vec3::new(7.0, 8.0, 9.0);
        assert_eq!((v[0], v[1], v[2]), (7.0, 8.0, 9.0));
    }
}
