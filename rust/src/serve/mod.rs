//! `msgsn serve`: the fleet as a long-running service.
//!
//! The batch CLI runs a manifest to completion and exits; the daemon
//! keeps the same [`Fleet`] resident and interleaves its scheduler
//! rounds with a line-delimited JSON protocol over TCP (see
//! [`protocol`]). The structure mirrors the dist worker's round loop —
//! drain a bounded budget of protocol traffic, then advance every live
//! job exactly one [`Fleet::step_round`] — so all the batch-path
//! invariants carry over unchanged:
//!
//! - **Bit-parity with the batch path.** The daemon calls the very same
//!   `step_round`; stride invariance (a chunked run is bit-identical to
//!   a blocking run) means a job submitted over the wire converges to
//!   the same bits as `msgsn fleet` on the same spec. `rust/tests/serve.rs`
//!   pins this over real TCP.
//! - **Batch-boundary read views.** Requests are only handled *between*
//!   rounds, and [`view`] builds every answer from immutable accessors —
//!   a `query` observes the exact state the next round resumes from and
//!   cannot perturb convergence.
//! - **Crash safety.** `--checkpoint-secs`/`--checkpoint-every` pass
//!   straight into [`FleetOptions`]; the daemon runs the same
//!   [`CheckpointWriter`] protocol as the batch path, so a killed daemon
//!   resumes from last-good generations like a killed fleet run.
//! - **Failure isolation.** A client is to the daemon what a job is to
//!   the fleet: a torn, slow, or malicious connection degrades to a
//!   closed socket ([`conn`]), never a stalled or dead daemon. The
//!   `serve_conn` fault point injects exactly those failures in tests
//!   and the CI chaos cell.
//!
//! Lifecycle: the daemon idles when no jobs are live (it stays resident
//! for future submits), and `shutdown` flips it into draining — new
//! submits are refused, live jobs run to completion, every open
//! connection receives the final report and a `bye` event carrying the
//! fleet exit code, and [`Server::run`] returns the [`FleetReport`].

pub mod conn;
pub mod protocol;
pub mod view;

use std::collections::BTreeSet;
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::fleet::{
    parse_job_payload, CheckpointWriter, Fleet, FleetOptions, FleetReport, JobSpec,
};
use crate::runtime::fault::{self, FaultAction, FaultPoint};
use crate::runtime::{render_json, Json};

use conn::ClientConn;
use protocol::{err_response, event, ok_response, parse_request, Request};
use view::{mesh_view, snapshot_view, status_row, units_view};

/// Most request lines handled per scheduler round, across all
/// connections — the same bounded-drain idea as the dist worker's
/// message budget: protocol traffic must not starve convergence.
const REQUEST_BUDGET: usize = 64;

/// How many trace events a `metrics` response carries. The full ring is
/// for `--trace-file`; over the wire a bounded tail keeps the response a
/// single sane line.
const METRICS_TRACE_TAIL: usize = 64;

#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Scheduler/checkpoint knobs, shared verbatim with the batch path.
    pub fleet: FleetOptions,
    /// Broadcast a `progress` event to watchers every this many rounds
    /// (job completions are always announced immediately).
    pub watch_every: u64,
    /// How long to sleep per poll when nothing is live and no traffic is
    /// arriving — the daemon's idle heartbeat.
    pub idle_poll: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            fleet: FleetOptions::default(),
            watch_every: 8,
            idle_poll: Duration::from_millis(10),
        }
    }
}

pub struct Server {
    listener: TcpListener,
    fleet: Fleet,
    conns: Vec<ClientConn>,
    next_conn_id: u64,
    draining: bool,
    /// Jobs whose completion has already been broadcast to watchers.
    announced_done: BTreeSet<String>,
}

impl Server {
    /// Bind the listener and build the resident fleet. `specs` may be
    /// empty — an idle daemon waiting for its first `submit` is the
    /// normal cold start.
    pub fn bind(addr: &str, specs: Vec<JobSpec>) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("setting serve listener non-blocking")?;
        Ok(Server {
            listener,
            fleet: Fleet::new(specs)?,
            conns: Vec::new(),
            next_conn_id: 0,
            draining: false,
            announced_done: BTreeSet::new(),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading serve listener address")
    }

    /// The resident fleet (read-only — tests assert parity on the final
    /// sessions after [`Server::run`] returns).
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Resume preloaded jobs from their checkpoints before serving
    /// (delegates to [`Fleet::resume_from`]; `--resume` on the CLI).
    pub fn resume_from(&mut self, dir: &std::path::Path) -> Result<Vec<crate::fleet::ResumeOutcome>> {
        self.fleet.resume_from(dir)
    }

    /// Serve until a `shutdown` request has been honoured and every live
    /// job drained. Returns the final report (also broadcast to every
    /// connection still open).
    pub fn run(
        &mut self,
        opts: &ServeOptions,
        mut progress: impl FnMut(&str),
    ) -> Result<FleetReport> {
        let checkpointing = opts.fleet.checkpoint_dir.is_some()
            && (opts.fleet.checkpoint_every > 0 || opts.fleet.checkpoint_secs.is_some());
        let mut ckpt = None;
        if checkpointing {
            let dir = opts.fleet.checkpoint_dir.as_deref().expect("checkpointing implies a dir");
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
            ckpt = Some(CheckpointWriter::new());
        }

        let mut round = 0u64;
        loop {
            self.accept_new(&mut progress);
            let live_before = self
                .fleet
                .jobs()
                .iter()
                .filter(|j| !j.is_done())
                .count();
            // Busy fleet: skim traffic with a near-zero poll. Idle
            // daemon: the poll timeout *is* the heartbeat.
            let poll = if live_before > 0 {
                Duration::from_millis(1)
            } else {
                opts.idle_poll
            };
            let handled = self.drain_requests(poll, &mut progress);
            if self.conns.is_empty() && live_before == 0 && !self.draining {
                std::thread::sleep(opts.idle_poll);
            }

            let live = self.fleet.step_round(&opts.fleet, round, ckpt.as_mut(), &mut progress);
            self.broadcast_progress(round, opts.watch_every, handled);
            if self.draining && live == 0 {
                break;
            }
            round += 1;
        }

        if let Some(w) = ckpt.as_mut() {
            self.fleet.drain_checkpoints(w, &mut progress);
        }
        let report = self.fleet.report();
        let rows = Json::Arr(report.rows.iter().map(|r| r.to_json()).collect());
        let exit = report.outcome().exit_code();
        self.broadcast(&event("report", vec![("rows", rows)]), false);
        self.broadcast(
            &event("bye", vec![("exit", Json::Num(f64::from(exit)))]),
            false,
        );
        progress(&format!("serve: drained, outcome {}", report.outcome().name()));
        Ok(report)
    }

    fn accept_new(&mut self, progress: &mut impl FnMut(&str)) {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    let c = ClientConn::new(stream, self.next_conn_id);
                    progress(&format!("serve: accepted {} from {peer}", c.label()));
                    self.next_conn_id += 1;
                    self.conns.push(c);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        crate::telemetry::set_gauge(
            crate::telemetry::Gauge::ServeConnsOpen,
            self.conns.len() as u64,
        );
    }

    /// Read and handle up to [`REQUEST_BUDGET`] request lines across all
    /// connections, firing the `serve_conn` fault point once per
    /// completed line. Returns how many requests were handled.
    fn drain_requests(&mut self, poll: Duration, progress: &mut impl FnMut(&str)) -> usize {
        let mut handled = 0;
        'budget: while handled < REQUEST_BUDGET {
            let mut any = false;
            for i in 0..self.conns.len() {
                let Some(line) = self.conns[i].poll_line(poll) else { continue };
                any = true;
                let label = self.conns[i].label().to_string();
                match fault::fire(FaultPoint::ServeConn, Some(&label), None) {

                    Some(FaultAction::Drop) => {
                        // The mid-request client vanish: request discarded,
                        // connection gone, daemon and jobs untouched.
                        progress(&format!("serve: injected drop on {label}"));
                        self.conns[i].close();
                    }
                    Some(FaultAction::Error) => {
                        let resp = err_response("injected", "injected connection error");
                        self.conns[i].write_line(&resp);
                        self.conns[i].close();
                    }
                    Some(FaultAction::Delay(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                        self.handle_line(i, &line, progress);
                    }
                    Some(FaultAction::Dup) => {
                        self.handle_line(i, &line, progress);
                        self.handle_line(i, &line, progress);
                    }
                    Some(FaultAction::Truncate(n)) => {
                        let cut: String = line.chars().take(n as usize).collect();
                        self.handle_line(i, &cut, progress);
                    }
                    Some(FaultAction::Panic) => panic!("injected serve_conn panic"),
                    None => self.handle_line(i, &line, progress),
                }
                handled += 1;
                if handled >= REQUEST_BUDGET {
                    break 'budget;
                }
            }
            self.conns.retain(|c| !c.is_closed());
            if !any {
                break;
            }
        }
        self.conns.retain(|c| !c.is_closed());
        crate::telemetry::set_gauge(
            crate::telemetry::Gauge::ServeConnsOpen,
            self.conns.len() as u64,
        );
        crate::telemetry::add(crate::telemetry::Counter::ServeRequests, handled as u64);
        handled
    }

    fn handle_line(&mut self, i: usize, line: &str, progress: &mut impl FnMut(&str)) {
        let req = match parse_request(line) {
            Ok(r) => r,
            Err(e) => {
                let resp = err_response("bad-request", e);
                self.conns[i].write_line(&resp);
                return;
            }
        };
        let resp = match req {
            Request::Submit { job } => self.handle_submit(&job, progress),
            Request::Status => {
                let rows: Vec<Json> = self.fleet.jobs().iter().map(status_row).collect();
                ok_response(vec![
                    ("jobs", Json::Arr(rows)),
                    ("draining", Json::Bool(self.draining)),
                ])
            }
            Request::Watch => {
                self.conns[i].watching = true;
                ok_response(vec![("watching", Json::Bool(true))])
            }
            Request::Query { job, what } => self.handle_query(&job, what),
            Request::Cancel { job } => {
                if self.fleet.remove_job(&job) {
                    progress(&format!("serve: cancelled job {job:?}"));
                    ok_response(vec![("cancelled", Json::Str(job))])
                } else {
                    err_response("no-such-job", format!("no job named {job:?}"))
                }
            }
            // Answered entirely from the telemetry registry and trace
            // ring — no session, job, or fleet state is touched, so a
            // `metrics` poll can never perturb convergence (pinned by
            // the byte-equal snapshot test in `rust/tests/telemetry.rs`).
            Request::Metrics => ok_response(vec![
                (
                    "metrics",
                    crate::telemetry::metrics_json(METRICS_TRACE_TAIL),
                ),
                (
                    "text",
                    Json::Str(crate::telemetry::snapshot().render_prometheus()),
                ),
            ]),
            Request::Shutdown => {
                self.draining = true;
                progress("serve: shutdown requested, draining");
                ok_response(vec![("draining", Json::Bool(true))])
            }
        };
        self.conns[i].write_line(&resp);
    }

    fn handle_submit(&mut self, job: &Json, progress: &mut impl FnMut(&str)) -> Json {
        if self.draining {
            return err_response("draining", "daemon is draining; submit refused");
        }
        // Re-wrap the inline job object as a one-job manifest so the
        // daemon validates submissions with exactly the batch parser.
        let payload = format!("{{\"version\": 1, \"jobs\": [{}]}}", render_json(job));
        let spec = match parse_job_payload(&payload) {
            Ok(s) => s,
            Err(e) => return err_response("bad-request", format!("invalid job payload: {e:#}")),
        };
        let name = spec.name.clone();
        if self.fleet.jobs().iter().any(|j| j.spec().name == name) {
            return err_response("exists", format!("job {name:?} already admitted"));
        }
        match self.fleet.add_job(spec) {
            Ok(()) => {
                progress(&format!("serve: admitted job {name:?}"));
                ok_response(vec![("job", Json::Str(name))])
            }
            Err(e) => err_response("bad-request", format!("{e:#}")),
        }
    }

    fn handle_query(&self, name: &str, what: protocol::QueryWhat) -> Json {
        let Some(job) = self.fleet.jobs().iter().find(|j| j.spec().name == name) else {
            return err_response("no-such-job", format!("no job named {name:?}"));
        };
        let body = match what {
            protocol::QueryWhat::Units => units_view(job),
            protocol::QueryWhat::Mesh => mesh_view(job),
            protocol::QueryWhat::Snapshot => snapshot_view(job),
        };
        match body {
            Some(view) => ok_response(vec![
                ("job", Json::Str(name.to_string())),
                ("what", Json::Str(what.name().to_string())),
                ("view", view),
            ]),
            None => err_response(
                "no-session",
                format!("job {name:?} has no live session (status {})", job.status().name()),
            ),
        }
    }

    /// Stream per-round progress to watchers: completions immediately,
    /// the full row set every `watch_every` rounds.
    fn broadcast_progress(&mut self, round: u64, watch_every: u64, handled: usize) {
        let mut newly_done = Vec::new();
        for job in self.fleet.jobs() {
            if job.is_done() && !self.announced_done.contains(&job.spec().name) {
                newly_done.push(status_row(job));
                self.announced_done.insert(job.spec().name.clone());
            }
        }
        for row in newly_done {
            self.broadcast(&event("done", vec![("job", row)]), true);
        }
        let cadence = watch_every.max(1);
        let live = self.fleet.jobs().iter().any(|j| !j.is_done());
        if (live || handled > 0) && round % cadence == 0 {
            let rows: Vec<Json> = self.fleet.jobs().iter().map(status_row).collect();
            self.broadcast(
                &event(
                    "progress",
                    vec![("round", Json::Num(round as f64)), ("jobs", Json::Arr(rows))],
                ),
                true,
            );
        }
    }

    fn broadcast(&mut self, doc: &Json, watchers_only: bool) {
        for c in &mut self.conns {
            if !watchers_only || c.watching {
                c.write_line(doc);
            }
        }
    }
}
