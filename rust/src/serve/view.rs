//! Batch-boundary read views over live fleet jobs.
//!
//! Every function here takes `&FleetJob` / `&ConvergenceSession` and only
//! calls immutable accessors (`report_so_far`, `algo().net()`,
//! `snapshot_session`) — a query can therefore never perturb convergence,
//! by construction rather than by care. The daemon calls these between
//! `step_round` batches, so the numbers a client sees are exactly the
//! state the next round resumes from: the same consistency point the
//! checkpoint writer snapshots at.

use crate::fleet::snapshot::snapshot_session;
use crate::fleet::FleetJob;
use crate::runtime::{bytes::crc32, Json};

use super::protocol::obj;

/// One job's live counters, as a `status` row / `watch` progress row.
pub fn status_row(job: &FleetJob) -> Json {
    let mut fields = vec![
        ("name", Json::Str(job.spec().name.clone())),
        ("status", Json::Str(job.status().name().to_string())),
        ("qos", Json::Str(job.spec().qos.name().to_string())),
        ("attempts", Json::Num(job.attempts() as f64)),
    ];
    // Prefer the final report (survives session teardown on failure);
    // fall back to the live session's running totals.
    let live = job.session().map(|s| s.report_so_far());
    if let Some(r) = job.report().or(live) {
        fields.push(("signals", Json::Num(r.signals as f64)));
        fields.push(("units", Json::Num(r.units.max(units_of(job)) as f64)));
        fields.push(("connections", Json::Num(r.connections.max(connections_of(job)) as f64)));
        fields.push(("qe", Json::Num(qe_of(job).unwrap_or(r.qe) as f64)));
        fields.push(("converged", Json::Bool(r.converged)));
    }
    if let Some(e) = job.last_error() {
        fields.push(("error", Json::Str(e.to_string())));
    }
    obj(fields)
}

/// `query what=units`: counts + QE straight off the live network.
pub fn units_view(job: &FleetJob) -> Option<Json> {
    let session = job.session()?;
    let net = session.algo().net();
    Some(obj(vec![
        ("units", Json::Num(net.len() as f64)),
        ("connections", Json::Num(net.edge_count() as f64)),
        ("qe", Json::Num(session.algo().quantization_error() as f64)),
        ("signals", Json::Num(session.report_so_far().signals as f64)),
        ("done", Json::Bool(session.is_done())),
    ]))
}

/// `query what=mesh`: triangulate the current network and summarise it.
pub fn mesh_view(job: &FleetJob) -> Option<Json> {
    let session = job.session()?;
    let stats = session.algo().net().to_mesh().stats();
    Some(obj(vec![
        ("vertices", Json::Num(stats.vertices as f64)),
        ("edges", Json::Num(stats.edges as f64)),
        ("faces", Json::Num(stats.faces as f64)),
        ("euler_characteristic", Json::Num(stats.euler_characteristic as f64)),
        (
            "genus",
            stats.genus.map_or(Json::Null, |g| Json::Num(g as f64)),
        ),
        ("components", Json::Num(stats.components as f64)),
        ("watertight", Json::Bool(stats.watertight)),
        ("total_area", Json::Num(stats.total_area)),
    ]))
}

/// `query what=snapshot`: length + CRC-32 of the encoded session. Two
/// runs that answer the same pair here hold bit-identical state — the
/// cheapest parity probe that fits on one line.
pub fn snapshot_view(job: &FleetJob) -> Option<Json> {
    let session = job.session()?;
    let bytes = snapshot_session(session);
    Some(obj(vec![
        ("len", Json::Num(bytes.len() as f64)),
        ("crc32", Json::Str(format!("{:08x}", crc32(&bytes)))),
        ("fingerprint", Json::Str(format!("{:016x}", session.fingerprint()))),
    ]))
}

fn units_of(job: &FleetJob) -> usize {
    job.session().map_or(0, |s| s.algo().net().len())
}

fn connections_of(job: &FleetJob) -> usize {
    job.session().map_or(0, |s| s.algo().net().edge_count())
}

fn qe_of(job: &FleetJob) -> Option<f32> {
    job.session().map(|s| s.algo().quantization_error())
}
