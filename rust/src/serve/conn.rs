//! One client connection: non-blocking line framing over a `TcpStream`.
//!
//! The daemon's round loop cannot afford to block on a slow or silent
//! client — convergence is the product, the sockets are a side channel.
//! Reads use the same bounded-timeout pattern as `dist::transport::TcpPipe`
//! (a short `set_read_timeout`, `WouldBlock`/`TimedOut` meaning "nothing
//! yet", `Ok(0)` meaning the peer hung up), and writes carry their own
//! short timeout so a stalled watcher degrades to a closed connection
//! instead of a stalled fleet.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::runtime::{render_json, Json};

/// Longest accepted request line. Anything bigger is a protocol error
/// (or an attack), not a job submission — 1 MiB comfortably fits any
/// manifest job object.
pub const MAX_LINE: usize = 1 << 20;

/// How long a single response write may stall before the connection is
/// declared dead. Watch streams are best-effort; the fleet never waits.
const WRITE_TIMEOUT: Duration = Duration::from_millis(50);

pub struct ClientConn {
    stream: TcpStream,
    /// Fault-scope label: `c<id>` in accept order, matching the
    /// `serve_conn/c<id>` grammar in `runtime::fault`.
    label: String,
    /// Bytes received but not yet terminated by `\n`.
    partial: Vec<u8>,
    /// Whether this connection subscribed to streamed events.
    pub watching: bool,
    closed: bool,
}

impl ClientConn {
    pub fn new(stream: TcpStream, id: u64) -> Self {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
        crate::telemetry::add(crate::telemetry::Counter::ServeConnsOpened, 1);
        ClientConn {
            stream,
            label: format!("c{id}"),
            partial: Vec::new(),
            watching: false,
            closed: false,
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Mark the connection dead; the registry sweeps it after the round.
    pub fn close(&mut self) {
        if !self.closed {
            crate::telemetry::add(crate::telemetry::Counter::ServeConnsSevered, 1);
            crate::telemetry::emit(
                "conn_severed",
                None,
                vec![("conn", Json::Str(self.label.clone()))],
            );
        }
        self.closed = true;
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    /// Try to read one complete request line, waiting at most `timeout`.
    /// Returns `None` when no full line is available yet (or the
    /// connection is gone — check [`is_closed`](Self::is_closed)).
    pub fn poll_line(&mut self, timeout: Duration) -> Option<String> {
        if self.closed {
            return None;
        }
        if let Some(line) = self.take_line() {
            return Some(line);
        }
        // Zero-duration read timeouts mean "block forever" on most
        // platforms; clamp to 1ms like TcpPipe does.
        let _ = self.stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))));
        let mut buf = [0u8; 4096];
        match self.stream.read(&mut buf) {
            Ok(0) => {
                self.closed = true;
                None
            }
            Ok(n) => {
                self.partial.extend_from_slice(&buf[..n]);
                if self.partial.len() > MAX_LINE && !self.partial.contains(&b'\n') {
                    // A line that long is never a legal request; cut the
                    // peer loose rather than buffering without bound.
                    self.close();
                    return None;
                }
                self.take_line()
            }
            Err(e) if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut | std::io::ErrorKind::Interrupted
            ) => None,
            Err(_) => {
                self.closed = true;
                None
            }
        }
    }

    fn take_line(&mut self) -> Option<String> {
        let nl = self.partial.iter().position(|&b| b == b'\n')?;
        let mut line: Vec<u8> = self.partial.drain(..=nl).collect();
        line.pop(); // the \n
        if line.last() == Some(&b'\r') {
            line.pop();
        }
        match String::from_utf8(line) {
            Ok(s) => Some(s),
            // Not UTF-8, not a request we can parse — surface it as a
            // line the protocol layer will reject with bad-request.
            Err(_) => Some("\u{fffd}".to_string()),
        }
    }

    /// Send one JSON line. A failed or stalled write closes the
    /// connection; it never propagates into the fleet loop.
    pub fn write_line(&mut self, doc: &Json) {
        if self.closed {
            return;
        }
        let mut line = render_json(doc);
        line.push('\n');
        if self.stream.write_all(line.as_bytes()).is_err() || self.stream.flush().is_err() {
            self.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, ClientConn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (served, _) = listener.accept().unwrap();
        (client, ClientConn::new(served, 0))
    }

    #[test]
    fn frames_lines_across_partial_reads() {
        let (mut client, mut conn) = pair();
        client.write_all(b"{\"cmd\": \"sta").unwrap();
        assert_eq!(conn.poll_line(Duration::from_millis(20)), None);
        client.write_all(b"tus\"}\r\n{\"cmd\": \"watch\"}\n").unwrap();
        let mut lines = Vec::new();
        for _ in 0..20 {
            if let Some(l) = conn.poll_line(Duration::from_millis(20)) {
                lines.push(l);
            }
            if lines.len() == 2 {
                break;
            }
        }
        assert_eq!(lines, ["{\"cmd\": \"status\"}", "{\"cmd\": \"watch\"}"]);
        assert!(!conn.is_closed());
    }

    #[test]
    fn peer_hangup_marks_closed_without_error() {
        let (client, mut conn) = pair();
        drop(client);
        for _ in 0..50 {
            if conn.poll_line(Duration::from_millis(10)).is_some() {
                panic!("no line was ever sent");
            }
            if conn.is_closed() {
                return;
            }
        }
        panic!("hangup never detected");
    }

    #[test]
    fn oversized_line_closes_the_connection() {
        let (mut client, mut conn) = pair();
        let blob = vec![b'x'; MAX_LINE + 4096];
        // The daemon may close mid-send; ignore the client-side error.
        let _ = client.write_all(&blob);
        for _ in 0..200 {
            let _ = conn.poll_line(Duration::from_millis(5));
            if conn.is_closed() {
                return;
            }
        }
        panic!("oversized line was buffered without bound");
    }
}
