//! The serve wire protocol: line-delimited JSON requests and responses.
//!
//! One request per line, one JSON object per request, `"cmd"` selects the
//! verb. Responses are single lines too: request/response pairs carry
//! `"ok"` (with `"code"` naming the failure class on `"ok": false`),
//! streamed lines carry `"event"` instead — a client can always tell a
//! reply from a broadcast. The vocabulary:
//!
//! ```text
//! {"cmd": "submit", "job": { ...manifest job object... }}
//! {"cmd": "status"}
//! {"cmd": "watch"}
//! {"cmd": "query", "job": "name", "what": "units" | "mesh" | "snapshot"}
//! {"cmd": "cancel", "job": "name"}
//! {"cmd": "metrics"}
//! {"cmd": "shutdown"}
//! ```
//!
//! `submit`'s `"job"` payload is exactly one entry of the jobs-manifest
//! schema (`fleet::parse_manifest` — mesh/algorithm/driver/seed/retries/
//! qos plus any config key): the daemon wraps it in a single-job manifest
//! and re-parses it through [`crate::fleet::parse_job_payload`], so the
//! batch CLI and the daemon validate submissions with the same code and
//! reject the same typos.
//!
//! Error codes: `bad-request` (unparseable line / unknown cmd / invalid
//! job payload), `exists` (submit of a name already admitted — the
//! idempotent-resubmit signal a reconnecting client treats as success),
//! `no-such-job`, `no-session` (query against a crashed/quarantined job),
//! `draining` (submit after shutdown was requested).

use std::collections::BTreeMap;

use crate::runtime::{parse_json, Json};

/// One parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Admit one job (inline manifest-job object).
    Submit { job: Json },
    /// One-shot snapshot of every job's live counters.
    Status,
    /// Subscribe this connection to streamed progress/report events.
    Watch,
    /// Read one job's live state (batch-boundary read view).
    Query { job: String, what: QueryWhat },
    /// Remove a job (any status).
    Cancel { job: String },
    /// One-shot snapshot of the telemetry registry + trace tail. Answered
    /// entirely from `crate::telemetry` — never touches a session.
    Metrics,
    /// Stop admitting work, drain to completion, report, exit.
    Shutdown,
}

/// What a `query` extracts from the read view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryWhat {
    /// Unit/connection counts + QE (the cheap poll).
    Units,
    /// Full mesh-extraction statistics of the network triangulation.
    Mesh,
    /// Snapshot length + CRC-32 of the encoded session — a bit-exactness
    /// probe cheap enough to answer over the wire.
    Snapshot,
}

impl QueryWhat {
    pub fn name(self) -> &'static str {
        match self {
            QueryWhat::Units => "units",
            QueryWhat::Mesh => "mesh",
            QueryWhat::Snapshot => "snapshot",
        }
    }

    fn from_name(s: &str) -> Option<Self> {
        match s {
            "units" => Some(QueryWhat::Units),
            "mesh" => Some(QueryWhat::Mesh),
            "snapshot" => Some(QueryWhat::Snapshot),
            _ => None,
        }
    }
}

/// Parse one request line. `Err` carries the diagnostic the daemon wraps
/// in a `bad-request` response — a malformed line must never kill the
/// connection, let alone the daemon.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let doc = parse_json(line).map_err(|e| format!("request is not valid JSON: {e}"))?;
    let Json::Obj(_) = &doc else {
        return Err("request must be a JSON object".to_string());
    };
    let cmd = doc
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| "request needs a string \"cmd\"".to_string())?;
    let job_name = |what: &str| -> Result<String, String> {
        doc.get("job")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("{what} needs a string \"job\""))
    };
    match cmd {
        "submit" => match doc.get("job") {
            Some(job @ Json::Obj(_)) => Ok(Request::Submit { job: job.clone() }),
            _ => Err("submit needs a \"job\" object (one manifest job entry)".to_string()),
        },
        "status" => Ok(Request::Status),
        "watch" => Ok(Request::Watch),
        "query" => {
            let what = doc
                .get("what")
                .and_then(Json::as_str)
                .unwrap_or("units");
            let what = QueryWhat::from_name(what)
                .ok_or_else(|| format!("unknown query {what:?} (expected units|mesh|snapshot)"))?;
            Ok(Request::Query { job: job_name("query")?, what })
        }
        "cancel" => Ok(Request::Cancel { job: job_name("cancel")? }),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!(
            "unknown cmd {other:?} (expected submit|status|watch|query|cancel|metrics|shutdown)"
        )),
    }
}

/// Build a JSON object from field pairs (the response-builder spine).
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// A success response with extra fields.
pub fn ok_response(fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    obj(all)
}

/// A failure response: `{"ok": false, "code": ..., "error": ...}`.
pub fn err_response(code: &str, error: impl Into<String>) -> Json {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::Str(code.to_string())),
        ("error", Json::Str(error.into())),
    ])
}

/// A streamed event line: `{"event": ..., ...fields}`.
pub fn event(name: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("event", Json::Str(name.to_string()))];
    all.extend(fields);
    obj(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::render_json;

    #[test]
    fn parses_every_verb() {
        let r = parse_request(r#"{"cmd": "submit", "job": {"name": "a", "mesh": "blob"}}"#);
        assert!(matches!(r, Ok(Request::Submit { .. })), "{r:?}");
        assert_eq!(parse_request(r#"{"cmd": "status"}"#), Ok(Request::Status));
        assert_eq!(parse_request(r#"{"cmd": "watch"}"#), Ok(Request::Watch));
        assert_eq!(
            parse_request(r#"{"cmd": "query", "job": "a", "what": "mesh"}"#),
            Ok(Request::Query { job: "a".to_string(), what: QueryWhat::Mesh })
        );
        assert_eq!(
            parse_request(r#"{"cmd": "query", "job": "a"}"#),
            Ok(Request::Query { job: "a".to_string(), what: QueryWhat::Units }),
            "query defaults to the cheap units probe"
        );
        assert_eq!(
            parse_request(r#"{"cmd": "cancel", "job": "a"}"#),
            Ok(Request::Cancel { job: "a".to_string() })
        );
        assert_eq!(parse_request(r#"{"cmd": "metrics"}"#), Ok(Request::Metrics));
        assert_eq!(parse_request(r#"{"cmd": "shutdown"}"#), Ok(Request::Shutdown));
    }

    #[test]
    fn rejects_malformed_requests_with_diagnostics() {
        for (bad, needle) in [
            ("not json", "not valid JSON"),
            ("[1,2]", "must be a JSON object"),
            (r#"{"verb": "status"}"#, "needs a string \"cmd\""),
            (r#"{"cmd": "frobnicate"}"#, "unknown cmd"),
            (r#"{"cmd": "submit"}"#, "needs a \"job\" object"),
            (r#"{"cmd": "submit", "job": "a"}"#, "needs a \"job\" object"),
            (r#"{"cmd": "query"}"#, "needs a string \"job\""),
            (r#"{"cmd": "query", "job": "a", "what": "vibes"}"#, "unknown query"),
            (r#"{"cmd": "cancel"}"#, "needs a string \"job\""),
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains(needle), "{bad:?}: {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn responses_render_with_stable_discriminators() {
        let ok = render_json(&ok_response(vec![("job", Json::Str("a".to_string()))]));
        assert!(ok.contains("\"ok\":true") && ok.contains("\"job\":\"a\""), "{ok}");
        let err = render_json(&err_response("exists", "job \"a\" already admitted"));
        assert!(err.contains("\"ok\":false") && err.contains("\"code\":\"exists\""), "{err}");
        let ev = render_json(&event("bye", vec![("exit", Json::Num(0.0))]));
        assert!(ev.contains("\"event\":\"bye\"") && ev.contains("\"exit\":0"), "{ev}");
    }
}
