//! Deterministic pseudo-random number generation.
//!
//! The image vendors no `rand` crate, so the PRNG substrate is in-repo:
//! [`SplitMix64`] for seeding / cheap streams and [`Rng`] (Xoshiro256**) for
//! the algorithm hot paths. Determinism matters more than statistical
//! perfection here: the multi-signal ⇄ batched-PJRT replication invariant
//! (DESIGN.md §7) requires every driver to draw *identical* signal sequences
//! from the same seed.

/// SplitMix64: tiny, solid stream splitter (Steele et al., 2014).
///
/// Used to expand one user seed into independent sub-streams (sampler,
/// shuffles, index salts, …) without correlation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator (Blackman & Vigna, 2018).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors (avoids
    /// the all-zero state and decorrelates close seeds).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (used to give each pipeline stage
    /// its own generator while keeping runs reproducible).
    pub fn fork(&mut self) -> Self {
        Rng::seed_from(self.next_u64())
    }

    /// Export the full generator state — the fleet snapshot format stores
    /// this so a resumed session draws the *exact* continuation of the
    /// interrupted stream (bit-identical signals, permutations, forks).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from an exported [`Self::state`]. The all-zero
    /// state is xoshiro's one invalid fixed point (the stream would be
    /// constant zero); it cannot be produced by `seed_from`/`state`, so a
    /// snapshot carrying it is corrupt.
    pub fn from_state(s: [u64; 4]) -> Result<Self, &'static str> {
        if s == [0; 4] {
            return Err("all-zero xoshiro state");
        }
        Ok(Self { s })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection-free-ish method;
    /// exact and unbiased via 128-bit multiply + rejection).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform index in `[0, len)`.
    #[inline]
    pub fn index(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Fisher–Yates shuffle. The multi-signal Update phase processes each
    /// batch "in a random order" (paper §2.2) — this is that order.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n` (allocation reused by callers that
    /// shuffle every iteration).
    pub fn permutation(&mut self, n: usize, out: &mut Vec<u32>) {
        out.clear();
        out.extend(0..n as u32);
        self.shuffle(out);
    }

    /// Standard normal via Box–Muller (used by tests and synthetic clouds).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (cross-checked against the reference
        // implementation in the SplitMix64 paper's public domain C code).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(123);
        let mut b = Rng::seed_from(123);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_diverge() {
        let mut a = Rng::seed_from(9);
        let mut fork = a.fork();
        let h: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let g: Vec<u64> = (0..8).map(|_| fork.next_u64()).collect();
        assert_ne!(h, g);
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::seed_from(77);
        for _ in 0..100 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state()).unwrap();
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert!(Rng::from_state([0; 4]).is_err(), "all-zero state is invalid");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_bound() {
        let mut r = Rng::seed_from(42);
        let mut counts = [0u32; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 5.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(1);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn permutation_reuses_buffer() {
        let mut r = Rng::seed_from(2);
        let mut buf = Vec::new();
        r.permutation(16, &mut buf);
        assert_eq!(buf.len(), 16);
        r.permutation(4, &mut buf);
        assert_eq!(buf.len(), 4);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
