//! Fleet — multi-network orchestration: N independent growing-network
//! reconstructions multiplexed over **one** shared [`WorkerPool`], with
//! resumable sessions, durable bit-exact checkpoint/restore, and per-job
//! failure isolation.
//!
//! The ROADMAP's step after PR 4's region sharding is "multiple *networks*
//! per process (one region grid each)": a serving system runs many
//! concurrent reconstruction workloads, and restarting a half-converged
//! network from scratch is not acceptable. The fleet is that seam:
//!
//! - [`JobSpec`] (`spec`): one job = point-cloud source + full
//!   [`crate::config::RunConfig`], parsed from a JSON jobs manifest;
//! - [`Fleet`]: builds one [`ConvergenceSession`] per job — each with its
//!   own sampler, Find-Winners backend, region grid, RNG stream and
//!   executor — and schedules them **work-conserving round-robin at batch
//!   granularity** over a single worker pool sized for the widest job.
//!   Jobs share only compute, never state, so a fleet-of-N is
//!   bit-identical to N solo runs (`rust/tests/fleet.rs`);
//! - [`snapshot`]: the versioned, CRC-trailed checkpoint format with
//!   durable two-generation writes (tmp + fsync + rename, `.prev`
//!   retained); kill-and-resume is bit-identical to an uninterrupted run
//!   (`rust/tests/executor_parity.rs` covers the full knob matrix,
//!   `rust/tests/fleet.rs` the torn-write recovery at every byte offset);
//! - [`writer`]: the background checkpoint writer — encoding stays on the
//!   scheduler thread, fsync + rotation + rename happen off it.
//!
//! Scheduling is deliberately cooperative and deterministic: one round
//! steps every live job `stride` iterations in manifest order. The pool's
//! caller gate serializes the *parallel sections* of different jobs
//! anyway (plan/commit/find shards), so interleaving at batch granularity
//! is work-conserving — whenever any job has work, the pool has work —
//! while per-job results stay a pure function of the job's own spec.
//!
//! ## Failure isolation
//!
//! Every `step` runs under `catch_unwind`: a panicking job (a poison
//! input, an injected `session_step` fault) is marked [`JobStatus::Failed`]
//! and its session discarded, while the other N−1 jobs keep converging
//! bit-identically to a fleet that never contained it. A failed job is
//! retried after a turn-based exponential backoff by rebuilding its
//! session and restoring the **last good checkpoint generation** (latest,
//! then `.prev`, then from scratch); because restore is bit-exact, a
//! retry that succeeds is indistinguishable from a run that never
//! crashed. After `max_retries` failed attempts (per-job override:
//! [`JobSpec::retries`]) the job is [`JobStatus::Quarantined`] — reported,
//! counted, never silently dropped. [`FleetReport::outcome`] folds the
//! statuses into the process exit code: all succeeded ≠ partial failure ≠
//! total failure.

pub mod snapshot;
mod spec;
mod writer;

pub use spec::{
    manifest_job_payloads, parse_job_payload, parse_manifest, JobSpec, QosClass, MANIFEST_VERSION,
};
pub use writer::{CheckpointWriter, WriteOutcome, DEFAULT_QUEUE_CAPACITY};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::engine::{resolve_run_threads, ConvergenceSession, RunReport};
use crate::mesh::Mesh;
use crate::metrics::{fmt_secs, PhaseTimes, Table};
use crate::runtime::{Json, WorkerPool};
use crate::telemetry::{self, Counter};

use writer::panic_message;

/// Scheduler options.
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Iterations (batches; signals for single-signal drivers) each live
    /// job advances per round-robin turn.
    pub stride: u64,
    /// Checkpoint a job every this many of its own turns (0 = never).
    pub checkpoint_every: u64,
    /// Checkpoint a job when this much wall-clock time has passed since
    /// its last checkpoint (fractional seconds; `None` = turns only).
    /// Either cadence being due queues a write; both compose.
    pub checkpoint_secs: Option<f64>,
    /// Where checkpoint files (`<job>.msgsnap` + `.prev`) live.
    pub checkpoint_dir: Option<PathBuf>,
    /// Restore-from-last-good retries a crashed job gets before it is
    /// quarantined (see module docs). Per-job override: [`JobSpec::retries`].
    pub max_retries: u32,
    /// Base of the turn-based exponential backoff: a job's k-th failure
    /// delays its retry by `backoff_rounds · 2^(k−1)` scheduler rounds
    /// (deterministic — rounds, not wall clock).
    pub backoff_rounds: u64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self {
            stride: 1,
            checkpoint_every: 0,
            checkpoint_secs: None,
            checkpoint_dir: None,
            max_retries: 2,
            backoff_rounds: 2,
        }
    }
}

/// Lifecycle state of a fleet job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Converging (or waiting for its next round-robin turn).
    Running,
    /// Terminated normally; its [`RunReport`] is final.
    Done,
    /// Crashed; waiting out its backoff before a restore-and-retry.
    Failed,
    /// Crashed more than its retry budget allows; permanently stopped.
    Quarantined,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Quarantined => "quarantined",
        }
    }
}

impl std::fmt::Display for JobStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One scheduled job: its spec, its (possibly discarded) session, and
/// checkpoint/failure bookkeeping.
pub struct FleetJob {
    spec: JobSpec,
    /// The materialized point cloud, kept so a crashed session can be
    /// rebuilt without re-reading mesh files mid-run.
    mesh: Mesh,
    /// `None` after a crash (a panicking step may leave the session in a
    /// torn state — it is discarded, never reused) until the retry
    /// rebuilds it.
    session: Option<ConvergenceSession>,
    status: JobStatus,
    turns_since_checkpoint: u64,
    last_checkpoint: Instant,
    /// Failures so far (== restore attempts consumed).
    attempts: u32,
    /// Scheduler round at which a Failed job may retry.
    retry_at_round: u64,
    last_error: Option<String>,
    report: Option<RunReport>,
    /// Non-fatal incidents surfaced per job in the [`FleetReport`]:
    /// dropped (queue-full) and failed checkpoint write-outs.
    notes: Vec<String>,
}

impl FleetJob {
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// The live session (`None` while crashed/quarantined).
    pub fn session(&self) -> Option<&ConvergenceSession> {
        self.session.as_ref()
    }

    pub fn status(&self) -> JobStatus {
        self.status
    }

    pub fn is_done(&self) -> bool {
        self.status == JobStatus::Done
    }

    /// Failures so far (retry attempts consumed).
    pub fn attempts(&self) -> u32 {
        self.attempts
    }

    /// The most recent crash/restore error, if any.
    pub fn last_error(&self) -> Option<&str> {
        self.last_error.as_deref()
    }

    /// The finalized report (None while the job is still running — or
    /// quarantined before finishing).
    pub fn report(&self) -> Option<&RunReport> {
        self.report.as_ref()
    }

    /// Non-fatal incidents recorded against this job (dropped / failed
    /// checkpoint write-outs).
    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    fn checkpoint_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.msgsnap", self.spec.file_stem()))
    }
}

/// Where a rebuilt job's state came from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RestoreSource {
    /// The latest checkpoint generation restored cleanly.
    Latest,
    /// The latest was torn/corrupt/unreadable; the retained `.prev`
    /// generation restored (and was promoted back to the latest name).
    Previous,
    /// No usable checkpoint; started from scratch. Carries the restore
    /// errors when checkpoints existed but were rejected.
    Scratch(Option<String>),
}

impl RestoreSource {
    pub fn describe(&self) -> String {
        match self {
            RestoreSource::Latest => "latest checkpoint".to_string(),
            RestoreSource::Previous => "previous checkpoint generation".to_string(),
            RestoreSource::Scratch(None) => "scratch (no checkpoint)".to_string(),
            RestoreSource::Scratch(Some(why)) => {
                format!("scratch (checkpoints unusable: {why})")
            }
        }
    }
}

/// One [`Fleet::resume_from`] result: which job resumed from what.
#[derive(Clone, Debug)]
pub struct ResumeOutcome {
    pub name: String,
    pub source: RestoreSource,
}

/// Final state of one job in the [`FleetReport`].
#[derive(Clone, Debug)]
pub struct FleetRow {
    pub name: String,
    pub status: JobStatus,
    /// Failures/restore attempts the job consumed (0 = clean run).
    pub attempts: u32,
    /// Last crash/restore error (quarantined jobs always carry one).
    pub error: Option<String>,
    /// `None` for jobs quarantined before finishing.
    pub report: Option<RunReport>,
    /// Non-fatal incidents (dropped / failed checkpoint write-outs) —
    /// surfaced here so a degraded-durability run is visible in the
    /// report, not only in scrollback progress lines.
    pub notes: Vec<String>,
}

/// Process-level outcome of a fleet run, for the CLI exit code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetOutcome {
    AllSucceeded,
    /// Some — not all — jobs were quarantined: the survivors' reports are
    /// valid, but the run is not a success.
    PartialFailure,
    AllFailed,
}

impl FleetOutcome {
    /// `msgsn fleet` exit code: 0 success, 2 partial failure, 3 total
    /// failure (1 is the generic CLI error path).
    pub fn exit_code(self) -> u8 {
        match self {
            FleetOutcome::AllSucceeded => 0,
            FleetOutcome::PartialFailure => 2,
            FleetOutcome::AllFailed => 3,
        }
    }

    /// Stable machine-readable name (the `--report-json` payload).
    pub fn name(self) -> &'static str {
        match self {
            FleetOutcome::AllSucceeded => "all-succeeded",
            FleetOutcome::PartialFailure => "partial-failure",
            FleetOutcome::AllFailed => "all-failed",
        }
    }
}

/// Aggregated result of a fleet run: one [`FleetRow`] per job, in
/// manifest order.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub rows: Vec<FleetRow>,
}

impl FleetReport {
    /// Per-phase time totals aggregated across every job that produced a
    /// report — the fleet-level view of the paper's Sample / Find Winners
    /// / Update axes ([`PhaseTimes::merge`]).
    pub fn phase_totals(&self) -> PhaseTimes {
        let mut totals = PhaseTimes::default();
        for row in &self.rows {
            if let Some(r) = &row.report {
                totals.merge(&r.phase);
            }
        }
        totals
    }

    /// Fold job statuses into the process-level outcome.
    pub fn outcome(&self) -> FleetOutcome {
        let quarantined =
            self.rows.iter().filter(|r| r.status == JobStatus::Quarantined).count();
        if quarantined == 0 {
            FleetOutcome::AllSucceeded
        } else if quarantined == self.rows.len() {
            FleetOutcome::AllFailed
        } else {
            FleetOutcome::PartialFailure
        }
    }

    /// One summary row per job (name, status, attempts, algorithm, driver,
    /// signals, units, connections, converged, wall time, per-phase times,
    /// notes count). Quarantined jobs without a report render `-` in the
    /// report columns; the `notes` column counts per-job incidents
    /// (details in [`FleetRow::notes`]).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "job",
            "status",
            "attempts",
            "algorithm",
            "driver",
            "signals",
            "discarded",
            "units",
            "connections",
            "converged",
            "time",
            "sample",
            "find",
            "update",
            "notes",
        ]);
        for row in &self.rows {
            let notes = if row.notes.is_empty() {
                "-".to_string()
            } else {
                row.notes.len().to_string()
            };
            let mut cells = match &row.report {
                Some(r) => vec![
                    row.name.clone(),
                    row.status.to_string(),
                    row.attempts.to_string(),
                    r.algorithm.clone(),
                    r.implementation.clone(),
                    r.signals.to_string(),
                    r.discarded.to_string(),
                    r.units.to_string(),
                    r.connections.to_string(),
                    r.converged.to_string(),
                    fmt_secs(r.total),
                    fmt_secs(r.phase.sample),
                    fmt_secs(r.phase.find),
                    fmt_secs(r.phase.update),
                ],
                None => {
                    let mut cells =
                        vec![row.name.clone(), row.status.to_string(), row.attempts.to_string()];
                    cells.extend(std::iter::repeat("-".to_string()).take(11));
                    cells
                }
            };
            cells.push(notes);
            t.row(cells);
        }
        t
    }

    /// Machine-readable form of the report — the `--report-json` payload
    /// CI asserts on instead of scraping the rendered table:
    /// `{"rows": [...], "outcome": "...", "exit_code": N}`, one object per
    /// job carrying name/status/attempts/error/notes plus the numeric
    /// report columns (`null` report for jobs quarantined before
    /// finishing). The serve daemon streams the same row objects in its
    /// final `report` event, so batch and daemon consumers parse one
    /// schema.
    pub fn to_json(&self) -> Json {
        let mut top = BTreeMap::new();
        top.insert("rows".to_string(), Json::Arr(self.rows.iter().map(FleetRow::to_json).collect()));
        let outcome = self.outcome();
        top.insert("outcome".to_string(), Json::Str(outcome.name().to_string()));
        top.insert("exit_code".to_string(), Json::Num(f64::from(outcome.exit_code())));
        let totals = self.phase_totals();
        let mut pt = BTreeMap::new();
        pt.insert("sample_s".to_string(), Json::Num(totals.sample.as_secs_f64()));
        pt.insert("find_s".to_string(), Json::Num(totals.find.as_secs_f64()));
        pt.insert("update_s".to_string(), Json::Num(totals.update.as_secs_f64()));
        top.insert("phase_totals".to_string(), Json::Obj(pt));
        Json::Obj(top)
    }
}

impl FleetRow {
    /// One row of [`FleetReport::to_json`].
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("status".to_string(), Json::Str(self.status.name().to_string()));
        m.insert("attempts".to_string(), Json::Num(f64::from(self.attempts)));
        m.insert(
            "error".to_string(),
            self.error.clone().map_or(Json::Null, Json::Str),
        );
        m.insert(
            "notes".to_string(),
            Json::Arr(self.notes.iter().cloned().map(Json::Str).collect()),
        );
        let report = match &self.report {
            None => Json::Null,
            Some(r) => {
                let mut rm = BTreeMap::new();
                rm.insert("algorithm".to_string(), Json::Str(r.algorithm.clone()));
                rm.insert("driver".to_string(), Json::Str(r.implementation.clone()));
                rm.insert("signals".to_string(), Json::Num(r.signals as f64));
                rm.insert("discarded".to_string(), Json::Num(r.discarded as f64));
                rm.insert("units".to_string(), Json::Num(r.units as f64));
                rm.insert("connections".to_string(), Json::Num(r.connections as f64));
                rm.insert("converged".to_string(), Json::Bool(r.converged));
                rm.insert("qe".to_string(), Json::Num(f64::from(r.qe)));
                rm.insert("total_s".to_string(), Json::Num(r.total.as_secs_f64()));
                rm.insert("sample_s".to_string(), Json::Num(r.phase.sample.as_secs_f64()));
                rm.insert("find_s".to_string(), Json::Num(r.phase.find.as_secs_f64()));
                rm.insert("update_s".to_string(), Json::Num(r.phase.update.as_secs_f64()));
                Json::Obj(rm)
            }
        };
        m.insert("report".to_string(), report);
        Json::Obj(m)
    }
}

/// The multi-network scheduler (see module docs).
pub struct Fleet {
    jobs: Vec<FleetJob>,
    /// The one shared pool (None when every job is single-threaded).
    pool: Option<Arc<WorkerPool>>,
    /// Checkpoint generations dropped run-wide (writer queue full) —
    /// summarized loudly at end of run, not only in per-job notes.
    ckpt_dropped: u64,
    /// Checkpoint write-outs that failed run-wide (I/O error / panic).
    ckpt_failed: u64,
}

/// Build a fresh session for `spec` over `mesh` and restore the best
/// available checkpoint generation: latest, then `.prev` (promoting it
/// back to the latest name so the next rotation cannot shift the corrupt
/// file over it), else scratch. A fresh session is built **per attempt**
/// — a failed restore may leave the session partially overwritten
/// ([`ConvergenceSession::read_state`]'s contract), so it is never
/// reused. `Err` only on session *build* failure.
fn rebuild_and_restore(
    spec: &JobSpec,
    mesh: &Mesh,
    pool: &Option<Arc<WorkerPool>>,
    dir: Option<&Path>,
) -> Result<(ConvergenceSession, RestoreSource)> {
    let fresh = || -> Result<ConvergenceSession> {
        let mut s = ConvergenceSession::new(&spec.cfg, mesh, pool.clone())
            .with_context(|| format!("job {:?}", spec.name))?;
        s.set_label(&spec.name);
        Ok(s)
    };
    let Some(dir) = dir else {
        return Ok((fresh()?, RestoreSource::Scratch(None)));
    };
    let latest = dir.join(format!("{}.msgsnap", spec.file_stem()));
    let prev = snapshot::prev_path(&latest);
    let mut errors = Vec::new();
    if latest.exists() {
        let mut s = fresh()?;
        match snapshot::load_from(&latest, &mut s) {
            Ok(()) => return Ok((s, RestoreSource::Latest)),
            Err(e) => errors.push(e),
        }
    }
    if prev.exists() {
        let mut s = fresh()?;
        match snapshot::load_from(&prev, &mut s) {
            Ok(()) => {
                // Promote the good generation: if the corrupt latest stayed
                // in place, the *next* checkpoint write would rotate it over
                // this file and destroy the only good state on disk.
                std::fs::remove_file(&latest).ok();
                std::fs::rename(&prev, &latest).ok();
                return Ok((s, RestoreSource::Previous));
            }
            Err(e) => errors.push(e),
        }
    }
    let detail = (!errors.is_empty()).then(|| errors.join("; "));
    Ok((fresh()?, RestoreSource::Scratch(detail)))
}

impl Fleet {
    /// Build every job's session. One worker pool is created, sized for
    /// the **widest** job (`max` over each job's resolved
    /// `find_threads`/`update_threads`), and shared by all of them — a
    /// narrower job simply activates fewer workers per handoff.
    pub fn new(specs: Vec<JobSpec>) -> Result<Fleet> {
        // Checkpoint files are named by the sanitized stem, so two jobs
        // whose *names* differ but whose stems collide (e.g. "scan a" and
        // "scan_a") would silently share — and cross-restore — one
        // checkpoint file. Reject up front.
        for i in 0..specs.len() {
            for j in i + 1..specs.len() {
                if specs[i].file_stem() == specs[j].file_stem() {
                    bail!(
                        "jobs {:?} and {:?} both checkpoint as {:?} — rename one",
                        specs[i].name,
                        specs[j].name,
                        specs[i].file_stem()
                    );
                }
            }
        }
        let width = specs.iter().map(pool_width).max().unwrap_or(1);
        let pool = (width > 1).then(|| Arc::new(WorkerPool::new(width)));
        let mut fleet = Fleet {
            jobs: Vec::with_capacity(specs.len()),
            pool,
            ckpt_dropped: 0,
            ckpt_failed: 0,
        };
        for spec in specs {
            fleet.push_job(spec)?;
        }
        Ok(fleet)
    }

    fn push_job(&mut self, spec: JobSpec) -> Result<()> {
        let mesh = spec
            .build_mesh()
            .with_context(|| format!("job {:?}: building mesh", spec.name))?;
        // A job wider than the shared pool (or added to a pool-less
        // fleet) self-provisions: the session builds its own pool when
        // handed `None` (see `ConvergenceSession::new`).
        let mut session = ConvergenceSession::new(&spec.cfg, &mesh, self.pool.clone())
            .with_context(|| format!("job {:?}", spec.name))?;
        session.set_label(&spec.name);
        telemetry::add(Counter::JobsAdmitted, 1);
        telemetry::emit(
            "job_admitted",
            Some(&spec.name),
            vec![("driver", Json::Str(spec.cfg.driver.name().to_string()))],
        );
        self.jobs.push(FleetJob {
            spec,
            mesh,
            session: Some(session),
            status: JobStatus::Running,
            turns_since_checkpoint: 0,
            last_checkpoint: Instant::now(),
            attempts: 0,
            retry_at_round: 0,
            last_error: None,
            report: None,
            notes: Vec::new(),
        });
        Ok(())
    }

    /// Add a job to a (possibly running) fleet — the dynamic-admission
    /// primitive the dist worker is built on. Same stem-collision rule as
    /// [`Fleet::new`].
    pub fn add_job(&mut self, spec: JobSpec) -> Result<()> {
        for existing in &self.jobs {
            if existing.spec.file_stem() == spec.file_stem() {
                bail!(
                    "jobs {:?} and {:?} both checkpoint as {:?} — rename one",
                    existing.spec.name,
                    spec.name,
                    spec.file_stem()
                );
            }
        }
        self.push_job(spec)
    }

    /// Remove a job (any status) by name. Returns whether it existed.
    pub fn remove_job(&mut self, name: &str) -> bool {
        let before = self.jobs.len();
        self.jobs.retain(|j| j.spec.name != name);
        self.jobs.len() != before
    }

    /// Restore a job's session from snapshot bytes (the dist migration
    /// path: the coordinator ships the last good checkpoint generation,
    /// the worker restores it into the freshly built session). On `Err`
    /// the session may be torn — the caller must remove the job.
    pub fn restore_job(&mut self, name: &str, bytes: &[u8]) -> Result<(), String> {
        let job = self
            .jobs
            .iter_mut()
            .find(|j| j.spec.name == name)
            .ok_or_else(|| format!("no job named {name:?}"))?;
        let session = job.session.as_mut().ok_or_else(|| {
            format!("job {name:?} has no live session to restore into")
        })?;
        snapshot::restore_session(session, bytes)?;
        if session.is_done() {
            job.report = Some(session.finish());
            job.status = JobStatus::Done;
        } else {
            job.status = JobStatus::Running;
        }
        Ok(())
    }

    pub fn jobs(&self) -> &[FleetJob] {
        &self.jobs
    }

    /// Width of the shared pool (1 = no pool).
    pub fn pool_width(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.size())
    }

    /// Resume every job that has a checkpoint (either generation) in
    /// `dir`; jobs without one start fresh and are not listed. A torn or
    /// corrupt latest falls back **per job** to the retained `.prev`
    /// generation instead of aborting the whole fleet; a job whose
    /// generations are all unusable restarts from scratch (reported as
    /// [`RestoreSource::Scratch`] with the errors). `Err` only on session
    /// build failure.
    pub fn resume_from(&mut self, dir: &Path) -> Result<Vec<ResumeOutcome>> {
        let mut outcomes = Vec::new();
        let pool = self.pool.clone();
        for job in &mut self.jobs {
            let latest = job.checkpoint_path(dir);
            if !latest.exists() && !snapshot::prev_path(&latest).exists() {
                continue;
            }
            let (mut session, source) =
                rebuild_and_restore(&job.spec, &job.mesh, &pool, Some(dir))?;
            if session.is_done() {
                job.report = Some(session.finish());
                job.status = JobStatus::Done;
            } else {
                job.status = JobStatus::Running;
            }
            job.session = Some(session);
            outcomes.push(ResumeOutcome { name: job.spec.name.clone(), source });
        }
        Ok(outcomes)
    }

    /// Run every job to termination or quarantine, round-robin (see
    /// module docs). `progress` receives one line per job completion,
    /// queued checkpoint, failure, retry, and failed checkpoint write.
    pub fn run(
        &mut self,
        opts: &FleetOptions,
        mut progress: impl FnMut(&str),
    ) -> Result<FleetReport> {
        let checkpointing = opts.checkpoint_dir.is_some()
            && (opts.checkpoint_every > 0 || opts.checkpoint_secs.is_some());
        let mut ckpt = None;
        if checkpointing {
            let dir = opts.checkpoint_dir.as_deref().expect("checkpointing implies a dir");
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
            ckpt = Some(CheckpointWriter::new());
        }

        let mut round = 0u64;
        loop {
            let live = self.step_round(opts, round, ckpt.as_mut(), &mut progress);
            if live == 0 {
                break;
            }
            round += 1;
        }
        // Every queued write must land before the run reports back (the
        // "last good generation" durability statement is about disk).
        if let Some(w) = ckpt.as_mut() {
            self.drain_checkpoints(w, &mut progress);
        }
        // Degraded durability must be loud at end of run, not only buried
        // in per-job notes: every drop/fail widens some job's resume
        // window back to its previous checkpoint generation.
        if self.ckpt_dropped > 0 || self.ckpt_failed > 0 {
            eprintln!(
                "msgsn fleet: WARNING: degraded checkpoint durability — \
                 {} write-out(s) dropped (writer queue full), {} failed; \
                 affected jobs resume from an older generation",
                self.ckpt_dropped, self.ckpt_failed
            );
        }
        Ok(self.report())
    }

    /// Advance every live job one scheduler round (the body of [`Fleet::run`],
    /// exposed so the dist worker can interleave scheduling with protocol
    /// traffic). Returns the number of jobs still live (Running or Failed
    /// awaiting retry); 0 = the fleet is finished.
    pub fn step_round(
        &mut self,
        opts: &FleetOptions,
        round: u64,
        mut ckpt: Option<&mut CheckpointWriter>,
        progress: &mut impl FnMut(&str),
    ) -> usize {
        let stride = opts.stride.max(1);
        let checkpointing = ckpt.is_some()
            && opts.checkpoint_dir.is_some()
            && (opts.checkpoint_every > 0 || opts.checkpoint_secs.is_some());
        // Surface landed checkpoint outcomes (failures are progress
        // lines + per-job notes, not fleet errors: a failed write costs
        // at most one recovery generation).
        if let Some(w) = ckpt.as_deref_mut() {
            for o in w.poll() {
                self.note_write(&o, progress);
            }
        }
        let mut live = 0usize;
        for idx in 0..self.jobs.len() {
            match self.jobs[idx].status {
                JobStatus::Done | JobStatus::Quarantined => continue,
                JobStatus::Failed => {
                    live += 1;
                    if round >= self.jobs[idx].retry_at_round {
                        self.retry_job(idx, opts, ckpt.as_deref_mut(), progress);
                    }
                    continue;
                }
                JobStatus::Running => {}
            }
            live += 1;
            let job = &mut self.jobs[idx];
            // QoS: an interactive job advances weight× the batches of a
            // batch-class job per turn. Stride-invariance (chunked
            // stepping ≡ a blocking run, proven in rust/tests/fleet.rs)
            // makes the weight a pure latency knob — it reorders turn
            // interleaving, never results.
            let stride = stride.saturating_mul(job.spec.qos.weight());
            let session = job.session.as_mut().expect("running job has a session");
            let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                session.step(stride)
            }));
            let running = match stepped {
                Ok(running) => running,
                Err(payload) => {
                    fail_job(job, payload, round, opts, progress);
                    continue;
                }
            };
            job.turns_since_checkpoint += 1;
            // Checkpoint on either cadence and once more at termination
            // (a kill right after the final batch must also resume to
            // the finished state, not re-run the tail).
            let turns_due = opts.checkpoint_every > 0
                && job.turns_since_checkpoint >= opts.checkpoint_every;
            let wall_due = opts
                .checkpoint_secs
                .is_some_and(|s| job.last_checkpoint.elapsed().as_secs_f64() >= s);
            if checkpointing && (turns_due || wall_due || !running) {
                let dir = opts.checkpoint_dir.as_deref().expect("checkpointing dir");
                // Encode on the scheduler thread (the bytes are the
                // boundary), write durably on the writer thread.
                let bytes = snapshot::snapshot_session(session);
                let path = job.checkpoint_path(dir);
                let writer = ckpt.as_deref_mut().expect("writer exists while checkpointing");
                if writer.enqueue(&job.spec.name, path.clone(), bytes) {
                    progress(&format!(
                        "checkpoint {} @ {} signals",
                        path.display(),
                        session.report_so_far().signals
                    ));
                } else {
                    // Queue full: drop this generation rather than stall
                    // convergence — recorded per job, visible in the
                    // report (satellite: bounded writer queue).
                    let note = format!(
                        "checkpoint {} DROPPED: writer queue full",
                        path.display()
                    );
                    progress(&note);
                    job.notes.push(note);
                    self.ckpt_dropped += 1;
                    telemetry::add(Counter::CheckpointsDropped, 1);
                }
                job.turns_since_checkpoint = 0;
                job.last_checkpoint = Instant::now();
            }
            if !running {
                let report = session.finish();
                progress(&format!(
                    "job {} finished: {} units, {} signals, converged={}",
                    job.spec.name, report.units, report.signals, report.converged
                ));
                telemetry::emit(
                    "job_done",
                    Some(&job.spec.name),
                    vec![
                        ("signals", Json::Num(report.signals as f64)),
                        ("units", Json::Num(report.units as f64)),
                        ("converged", Json::Bool(report.converged)),
                    ],
                );
                job.report = Some(report);
                job.status = JobStatus::Done;
            }
        }
        live
    }

    /// Snapshot the fleet's current state as a [`FleetReport`] (finalizes
    /// the report of any Done job that still holds one).
    pub fn report(&mut self) -> FleetReport {
        FleetReport {
            rows: self
                .jobs
                .iter_mut()
                .map(|j| {
                    if j.status == JobStatus::Done && j.report.is_none() {
                        if let Some(s) = j.session.as_mut() {
                            j.report = Some(s.finish());
                        }
                    }
                    FleetRow {
                        name: j.spec.name.clone(),
                        status: j.status,
                        attempts: j.attempts,
                        error: j.last_error.clone(),
                        report: j.report.clone(),
                        notes: j.notes.clone(),
                    }
                })
                .collect(),
        }
    }

    /// Block until every queued checkpoint write has landed and record
    /// the outcomes — the end-of-run durability barrier [`Fleet::run`]
    /// uses, exposed for callers that drive [`Fleet::step_round`]
    /// themselves (the serve daemon's drain path).
    pub fn drain_checkpoints(
        &mut self,
        ckpt: &mut CheckpointWriter,
        progress: &mut impl FnMut(&str),
    ) {
        for o in ckpt.drain() {
            self.note_write(&o, progress);
        }
    }

    /// Record a landed checkpoint write-out; failures become progress
    /// lines *and* per-job notes.
    fn note_write(&mut self, o: &WriteOutcome, progress: &mut impl FnMut(&str)) {
        if let Err(e) = &o.result {
            let note = format!("checkpoint {} FAILED: {e}", o.path.display());
            progress(&format!("checkpoint {} FAILED for job {}: {e}", o.path.display(), o.job));
            if let Some(job) = self.jobs.iter_mut().find(|j| j.spec.name == o.job) {
                job.notes.push(note);
            }
            self.ckpt_failed += 1;
            telemetry::add(Counter::CheckpointsFailed, 1);
        }
    }

    /// Restore a Failed job whose backoff has elapsed: drain pending
    /// checkpoint writes (the last good generation must be *on disk*
    /// before we look for it), rebuild the session, restore the best
    /// generation. A session build failure quarantines the job rather
    /// than aborting the fleet.
    fn retry_job(
        &mut self,
        idx: usize,
        opts: &FleetOptions,
        mut ckpt: Option<&mut CheckpointWriter>,
        progress: &mut impl FnMut(&str),
    ) {
        if let Some(w) = ckpt.take() {
            for o in w.drain() {
                self.note_write(&o, progress);
            }
        }
        let pool = self.pool.clone();
        let job = &mut self.jobs[idx];
        match rebuild_and_restore(&job.spec, &job.mesh, &pool, opts.checkpoint_dir.as_deref()) {
            Ok((mut session, source)) => {
                progress(&format!(
                    "job {} retrying from {} (attempt {})",
                    job.spec.name,
                    source.describe(),
                    job.attempts
                ));
                telemetry::add(Counter::JobsRetried, 1);
                telemetry::emit(
                    "job_retried",
                    Some(&job.spec.name),
                    vec![
                        ("attempt", Json::Num(f64::from(job.attempts))),
                        ("source", Json::Str(source.describe())),
                    ],
                );
                if session.is_done() {
                    job.report = Some(session.finish());
                    job.status = JobStatus::Done;
                } else {
                    job.status = JobStatus::Running;
                }
                job.session = Some(session);
            }
            Err(e) => {
                job.status = JobStatus::Quarantined;
                job.last_error = Some(e.to_string());
                progress(&format!(
                    "job {} QUARANTINED: session rebuild failed: {e}",
                    job.spec.name
                ));
                telemetry::add(Counter::JobsQuarantined, 1);
                telemetry::emit(
                    "job_quarantined",
                    Some(&job.spec.name),
                    vec![("error", Json::Str(e.to_string()))],
                );
            }
        }
    }
}

/// Mark a crashed job Failed (with backoff) or Quarantined (budget
/// exhausted). The torn session is discarded — a panicking step may leave
/// it in any state.
fn fail_job(
    job: &mut FleetJob,
    payload: Box<dyn std::any::Any + Send>,
    round: u64,
    opts: &FleetOptions,
    progress: &mut impl FnMut(&str),
) {
    job.session = None;
    job.attempts += 1;
    let msg = panic_message(payload.as_ref());
    job.last_error = Some(msg.clone());
    telemetry::emit(
        "job_failed",
        Some(&job.spec.name),
        vec![
            ("attempt", Json::Num(f64::from(job.attempts))),
            ("error", Json::Str(msg.clone())),
        ],
    );
    let budget = job.spec.retries.unwrap_or(opts.max_retries);
    if job.attempts > budget {
        job.status = JobStatus::Quarantined;
        progress(&format!(
            "job {} QUARANTINED after {} attempts: {msg}",
            job.spec.name, job.attempts
        ));
        telemetry::add(Counter::JobsQuarantined, 1);
        telemetry::emit(
            "job_quarantined",
            Some(&job.spec.name),
            vec![("attempts", Json::Num(f64::from(job.attempts)))],
        );
    } else {
        job.status = JobStatus::Failed;
        let backoff = opts
            .backoff_rounds
            .max(1)
            .saturating_mul(1u64 << u64::from((job.attempts - 1).min(16)));
        job.retry_at_round = round.saturating_add(backoff);
        progress(&format!(
            "job {} failed (attempt {}/{}): {msg} — retry in {backoff} rounds",
            job.spec.name,
            job.attempts,
            budget + 1
        ));
    }
}

/// Worker threads a job's spec can put to use — the engine's own
/// resolution rules ([`resolve_run_threads`], the single source of the
/// driver → thread mapping), collapsed to a width for pool sizing.
fn pool_width(spec: &JobSpec) -> usize {
    let (find, update) = resolve_run_threads(&spec.cfg);
    find.max(update)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Driver, RunConfig};
    use crate::mesh::BenchmarkShape;

    fn quick_spec(name: &str, shape: BenchmarkShape, algorithm: Algorithm, seed: u64) -> JobSpec {
        let mut cfg = RunConfig::preset(shape);
        cfg.driver = Driver::Multi;
        cfg.algorithm = algorithm;
        cfg.seed = seed;
        cfg.soam.insertion_threshold = 0.16;
        cfg.gwr.insertion_threshold = 0.16;
        cfg.limits.max_signals = 8_000;
        JobSpec::from_config(name, cfg)
    }

    /// Unique per-test checkpoint dir: parallel `cargo test` processes
    /// (and parallel tests within one) must never share on-disk state.
    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("msgsn_{}_{}", std::process::id(), name));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn fleet_runs_all_jobs_to_completion() {
        let specs = vec![
            quick_spec("a", BenchmarkShape::Blob, Algorithm::Soam, 1),
            quick_spec("b", BenchmarkShape::Eight, Algorithm::Gng, 2),
        ];
        let mut fleet = Fleet::new(specs).unwrap();
        assert_eq!(fleet.pool_width(), 1, "multi driver, no threads: no pool");
        let mut events = Vec::new();
        let report = fleet.run(&FleetOptions::default(), |line| events.push(line.to_string()))
            .unwrap();
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.rows[0].name, "a");
        assert_eq!(report.rows[0].status, JobStatus::Done);
        assert_eq!(report.rows[0].attempts, 0);
        let a = report.rows[0].report.as_ref().unwrap();
        assert!(a.signals >= 8_000);
        assert_eq!(report.rows[1].report.as_ref().unwrap().algorithm, "gng");
        assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);
        assert_eq!(report.outcome().exit_code(), 0);
        assert_eq!(events.len(), 2, "one completion line per job");
        let rendered = report.to_table().render();
        assert!(rendered.contains("gng") && rendered.contains("soam"), "{rendered}");
        assert!(rendered.contains("done"), "{rendered}");
    }

    #[test]
    fn report_json_round_trips_with_status_and_outcome() {
        let specs = vec![quick_spec("j", BenchmarkShape::Blob, Algorithm::Soam, 11)];
        let mut fleet = Fleet::new(specs).unwrap();
        let report = fleet.run(&FleetOptions::default(), |_| {}).unwrap();
        let text = crate::runtime::render_json(&report.to_json());
        let doc = crate::runtime::parse_json(&text).unwrap();
        assert_eq!(doc.get("outcome").and_then(Json::as_str), Some("all-succeeded"));
        assert_eq!(doc.get("exit_code").and_then(Json::as_u64), Some(0));
        let rows = doc.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").and_then(Json::as_str), Some("j"));
        assert_eq!(rows[0].get("status").and_then(Json::as_str), Some("done"));
        assert_eq!(rows[0].get("attempts").and_then(Json::as_u64), Some(0));
        assert_eq!(rows[0].get("error"), Some(&Json::Null));
        let r = rows[0].get("report").unwrap();
        assert!(r.get("signals").and_then(Json::as_u64).unwrap() >= 8_000);
        assert_eq!(
            r.get("algorithm").and_then(Json::as_str),
            Some("soam"),
            "{text}"
        );
    }

    #[test]
    fn qos_weight_changes_scheduling_not_results() {
        // Same two jobs, once all-batch and once with one interactive:
        // the interactive job's 4× stride reorders turn interleaving but
        // every per-job result must be bit-identical (stride invariance).
        let base = || {
            vec![
                quick_spec("fg", BenchmarkShape::Blob, Algorithm::Soam, 21),
                quick_spec("bg", BenchmarkShape::Eight, Algorithm::Gng, 22),
            ]
        };
        let mut plain = Fleet::new(base()).unwrap();
        let a = plain.run(&FleetOptions::default(), |_| {}).unwrap();
        let mut specs = base();
        specs[0].qos = crate::fleet::QosClass::Interactive;
        let mut weighted = Fleet::new(specs).unwrap();
        let b = weighted.run(&FleetOptions::default(), |_| {}).unwrap();
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            let (pa, pb) = (ra.report.as_ref().unwrap(), rb.report.as_ref().unwrap());
            assert_eq!(pa.signals, pb.signals, "{}", ra.name);
            assert_eq!(pa.units, pb.units, "{}", ra.name);
            assert_eq!(pa.connections, pb.connections, "{}", ra.name);
            assert_eq!(pa.qe.to_bits(), pb.qe.to_bits(), "{}", ra.name);
        }
    }

    #[test]
    fn colliding_checkpoint_stems_rejected() {
        // Distinct names, same sanitized checkpoint stem: must not build
        // (the jobs would silently share one .msgsnap file).
        let a = quick_spec("scan a", BenchmarkShape::Blob, Algorithm::Soam, 1);
        let b = quick_spec("scan_a", BenchmarkShape::Blob, Algorithm::Soam, 2);
        let err = Fleet::new(vec![a, b]).unwrap_err().to_string();
        assert!(err.contains("scan_a"), "{err}");
    }

    #[test]
    fn pool_sized_for_the_widest_job() {
        let mut wide = quick_spec("wide", BenchmarkShape::Blob, Algorithm::Soam, 3);
        wide.cfg.driver = Driver::Parallel;
        wide.cfg.update_threads = 3;
        wide.cfg.limits.max_signals = 2_000;
        let narrow = quick_spec("narrow", BenchmarkShape::Blob, Algorithm::Soam, 4);
        let fleet = Fleet::new(vec![wide, narrow]).unwrap();
        assert_eq!(fleet.pool_width(), 3);
    }

    #[test]
    fn checkpoint_files_are_written_and_resumable() {
        let dir = scratch_dir("fleet_ckpt");
        let spec = quick_spec("ckpt-job", BenchmarkShape::Blob, Algorithm::Soam, 5);
        let mut fleet = Fleet::new(vec![spec.clone()]).unwrap();
        let opts = FleetOptions {
            stride: 1,
            checkpoint_every: 3,
            checkpoint_dir: Some(dir.clone()),
            ..FleetOptions::default()
        };
        let a = fleet.run(&opts, |_| {}).unwrap();
        let path = dir.join("ckpt-job.msgsnap");
        assert!(path.exists(), "checkpoint file missing");
        assert!(
            snapshot::prev_path(&path).exists(),
            "previous generation retained after ≥2 checkpoints"
        );

        // A brand-new fleet resuming from the final checkpoint reports the
        // finished run without redoing it.
        let mut fleet2 = Fleet::new(vec![spec]).unwrap();
        let resumed = fleet2.resume_from(&dir).unwrap();
        assert_eq!(resumed.len(), 1);
        assert_eq!(resumed[0].name, "ckpt-job");
        assert_eq!(resumed[0].source, RestoreSource::Latest);
        let b = fleet2.run(&opts, |_| {}).unwrap();
        let (ra, rb) =
            (a.rows[0].report.as_ref().unwrap(), b.rows[0].report.as_ref().unwrap());
        assert_eq!(ra.signals, rb.signals);
        assert_eq!(ra.units, rb.units);
        assert_eq!(ra.qe.to_bits(), rb.qe.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wall_clock_cadence_checkpoints_without_turn_cadence() {
        let dir = scratch_dir("fleet_wallclock");
        let spec = quick_spec("wall-job", BenchmarkShape::Blob, Algorithm::Soam, 6);
        let mut fleet = Fleet::new(vec![spec]).unwrap();
        let opts = FleetOptions {
            stride: 1,
            checkpoint_every: 0,
            // Zero interval: every turn is wall-due — the cadence works
            // without any turn-based checkpointing configured.
            checkpoint_secs: Some(0.0),
            checkpoint_dir: Some(dir.clone()),
            ..FleetOptions::default()
        };
        let mut checkpoints = 0usize;
        fleet.run(&opts, |line| {
            if line.starts_with("checkpoint ") {
                checkpoints += 1;
            }
        })
        .unwrap();
        assert!(checkpoints > 1, "wall-clock cadence must checkpoint repeatedly");
        assert!(dir.join("wall-job.msgsnap").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_from_ignores_jobs_without_checkpoints() {
        let dir = scratch_dir("fleet_no_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let spec = quick_spec("fresh", BenchmarkShape::Blob, Algorithm::Soam, 7);
        let mut fleet = Fleet::new(vec![spec]).unwrap();
        let resumed = fleet.resume_from(&dir).unwrap();
        assert!(resumed.is_empty());
        assert_eq!(fleet.jobs()[0].status(), JobStatus::Running);
        std::fs::remove_dir_all(&dir).ok();
    }
}
