//! Fleet — multi-network orchestration: N independent growing-network
//! reconstructions multiplexed over **one** shared [`WorkerPool`], with
//! resumable sessions and bit-exact checkpoint/restore.
//!
//! The ROADMAP's step after PR 4's region sharding is "multiple *networks*
//! per process (one region grid each)": a serving system runs many
//! concurrent reconstruction workloads, and restarting a half-converged
//! network from scratch is not acceptable. The fleet is that seam:
//!
//! - [`JobSpec`] (`spec`): one job = point-cloud source + full
//!   [`crate::config::RunConfig`], parsed from a JSON jobs manifest;
//! - [`Fleet`]: builds one [`ConvergenceSession`] per job — each with its
//!   own sampler, Find-Winners backend, region grid, RNG stream and
//!   executor — and schedules them **work-conserving round-robin at batch
//!   granularity** over a single worker pool sized for the widest job.
//!   Jobs share only compute, never state, so a fleet-of-N is
//!   bit-identical to N solo runs (`rust/tests/fleet.rs`);
//! - [`snapshot`]: the versioned checkpoint format; kill-and-resume is
//!   bit-identical to an uninterrupted run (`rust/tests/executor_parity.rs`
//!   covers the full knob matrix).
//!
//! Scheduling is deliberately cooperative and deterministic: one round
//! steps every live job `stride` iterations in manifest order. The pool's
//! caller gate serializes the *parallel sections* of different jobs
//! anyway (plan/commit/find shards), so interleaving at batch granularity
//! is work-conserving — whenever any job has work, the pool has work —
//! while per-job results stay a pure function of the job's own spec.

pub mod snapshot;
mod spec;

pub use spec::{parse_manifest, JobSpec, MANIFEST_VERSION};

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::engine::{resolve_run_threads, ConvergenceSession, RunReport};
use crate::metrics::{fmt_secs, Table};
use crate::runtime::WorkerPool;

/// Scheduler options.
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Iterations (batches; signals for single-signal drivers) each live
    /// job advances per round-robin turn.
    pub stride: u64,
    /// Checkpoint a job every this many of its own turns (0 = never).
    pub checkpoint_every: u64,
    /// Where checkpoint files (`<job>.msgsnap`) live.
    pub checkpoint_dir: Option<PathBuf>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self { stride: 1, checkpoint_every: 0, checkpoint_dir: None }
    }
}

/// One scheduled job: its spec, its session, and checkpoint bookkeeping.
pub struct FleetJob {
    spec: JobSpec,
    session: ConvergenceSession,
    turns_since_checkpoint: u64,
    report: Option<RunReport>,
}

impl FleetJob {
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    pub fn session(&self) -> &ConvergenceSession {
        &self.session
    }

    pub fn is_done(&self) -> bool {
        self.session.is_done()
    }

    /// The finalized report (None while the job is still running).
    pub fn report(&self) -> Option<&RunReport> {
        self.report.as_ref()
    }

    fn checkpoint_path(&self, dir: &std::path::Path) -> PathBuf {
        dir.join(format!("{}.msgsnap", self.spec.file_stem()))
    }
}

/// Aggregated result of a fleet run: one [`RunReport`] per job, in
/// manifest order.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub jobs: Vec<(String, RunReport)>,
}

impl FleetReport {
    /// One summary row per job (name, algorithm, driver, signals, units,
    /// connections, converged, wall time).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "job", "algorithm", "driver", "signals", "discarded", "units", "connections",
            "converged", "time",
        ]);
        for (name, r) in &self.jobs {
            t.row(vec![
                name.clone(),
                r.algorithm.clone(),
                r.implementation.clone(),
                r.signals.to_string(),
                r.discarded.to_string(),
                r.units.to_string(),
                r.connections.to_string(),
                r.converged.to_string(),
                fmt_secs(r.total),
            ]);
        }
        t
    }
}

/// The multi-network scheduler (see module docs).
pub struct Fleet {
    jobs: Vec<FleetJob>,
    /// The one shared pool (None when every job is single-threaded).
    pool: Option<Arc<WorkerPool>>,
}

impl Fleet {
    /// Build every job's session. One worker pool is created, sized for
    /// the **widest** job (`max` over each job's resolved
    /// `find_threads`/`update_threads`), and shared by all of them — a
    /// narrower job simply activates fewer workers per handoff.
    pub fn new(specs: Vec<JobSpec>) -> Result<Fleet> {
        // Checkpoint files are named by the sanitized stem, so two jobs
        // whose *names* differ but whose stems collide (e.g. "scan a" and
        // "scan_a") would silently share — and cross-restore — one
        // checkpoint file. Reject up front.
        for i in 0..specs.len() {
            for j in i + 1..specs.len() {
                if specs[i].file_stem() == specs[j].file_stem() {
                    bail!(
                        "jobs {:?} and {:?} both checkpoint as {:?} — rename one",
                        specs[i].name,
                        specs[j].name,
                        specs[i].file_stem()
                    );
                }
            }
        }
        let width = specs.iter().map(pool_width).max().unwrap_or(1);
        let pool = (width > 1).then(|| Arc::new(WorkerPool::new(width)));
        let mut jobs = Vec::with_capacity(specs.len());
        for spec in specs {
            let mesh = spec
                .build_mesh()
                .with_context(|| format!("job {:?}: building mesh", spec.name))?;
            let session = ConvergenceSession::new(&spec.cfg, &mesh, pool.clone())
                .with_context(|| format!("job {:?}", spec.name))?;
            jobs.push(FleetJob {
                spec,
                session,
                turns_since_checkpoint: 0,
                report: None,
            });
        }
        Ok(Fleet { jobs, pool })
    }

    pub fn jobs(&self) -> &[FleetJob] {
        &self.jobs
    }

    /// Width of the shared pool (1 = no pool).
    pub fn pool_width(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.size())
    }

    /// Resume every job that has a checkpoint in `dir` (jobs without one
    /// start fresh). Returns the resumed job names.
    pub fn resume_from(&mut self, dir: &std::path::Path) -> Result<Vec<String>> {
        let mut resumed = Vec::new();
        for job in &mut self.jobs {
            let path = job.checkpoint_path(dir);
            if !path.exists() {
                continue;
            }
            snapshot::load_from(&path, &mut job.session)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("job {:?}", job.spec.name))?;
            if job.session.is_done() {
                job.report = Some(job.session.finish());
            }
            resumed.push(job.spec.name.clone());
        }
        Ok(resumed)
    }

    /// Run every job to termination, round-robin (see module docs).
    /// `progress` receives one line per job completion and per checkpoint.
    pub fn run(
        &mut self,
        opts: &FleetOptions,
        mut progress: impl FnMut(&str),
    ) -> Result<FleetReport> {
        let stride = opts.stride.max(1);
        loop {
            let mut live = 0usize;
            for job in &mut self.jobs {
                if job.session.is_done() {
                    continue;
                }
                live += 1;
                let running = job.session.step(stride);
                job.turns_since_checkpoint += 1;
                // Checkpoint on the cadence and once more at termination
                // (a kill right after the final batch must also resume to
                // the finished state, not re-run the tail).
                let due = opts.checkpoint_every > 0
                    && (job.turns_since_checkpoint >= opts.checkpoint_every || !running);
                if let Some(dir) = opts.checkpoint_dir.as_ref().filter(|_| due) {
                    std::fs::create_dir_all(dir)
                        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
                    let path = job.checkpoint_path(dir);
                    snapshot::save_to(&path, &job.session)
                        .with_context(|| format!("writing checkpoint {}", path.display()))?;
                    job.turns_since_checkpoint = 0;
                    progress(&format!(
                        "checkpoint {} @ {} signals",
                        path.display(),
                        job.session.report_so_far().signals
                    ));
                }
                if !running {
                    let report = job.session.finish();
                    progress(&format!(
                        "job {} finished: {} units, {} signals, converged={}",
                        job.spec.name, report.units, report.signals, report.converged
                    ));
                    job.report = Some(report);
                }
            }
            if live == 0 {
                break;
            }
        }
        Ok(FleetReport {
            jobs: self
                .jobs
                .iter_mut()
                .map(|j| {
                    let report =
                        j.report.get_or_insert_with(|| j.session.finish()).clone();
                    (j.spec.name.clone(), report)
                })
                .collect(),
        })
    }
}

/// Worker threads a job's spec can put to use — the engine's own
/// resolution rules ([`resolve_run_threads`], the single source of the
/// driver → thread mapping), collapsed to a width for pool sizing.
fn pool_width(spec: &JobSpec) -> usize {
    let (find, update) = resolve_run_threads(&spec.cfg);
    find.max(update)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Driver, RunConfig};
    use crate::mesh::BenchmarkShape;

    fn quick_spec(name: &str, shape: BenchmarkShape, algorithm: Algorithm, seed: u64) -> JobSpec {
        let mut cfg = RunConfig::preset(shape);
        cfg.driver = Driver::Multi;
        cfg.algorithm = algorithm;
        cfg.seed = seed;
        cfg.soam.insertion_threshold = 0.16;
        cfg.gwr.insertion_threshold = 0.16;
        cfg.limits.max_signals = 8_000;
        JobSpec::from_config(name, cfg)
    }

    #[test]
    fn fleet_runs_all_jobs_to_completion() {
        let specs = vec![
            quick_spec("a", BenchmarkShape::Blob, Algorithm::Soam, 1),
            quick_spec("b", BenchmarkShape::Eight, Algorithm::Gng, 2),
        ];
        let mut fleet = Fleet::new(specs).unwrap();
        assert_eq!(fleet.pool_width(), 1, "multi driver, no threads: no pool");
        let mut events = Vec::new();
        let report = fleet.run(&FleetOptions::default(), |line| events.push(line.to_string()))
            .unwrap();
        assert_eq!(report.jobs.len(), 2);
        assert_eq!(report.jobs[0].0, "a");
        assert!(report.jobs[0].1.signals >= 8_000);
        assert_eq!(report.jobs[1].1.algorithm, "gng");
        assert_eq!(events.len(), 2, "one completion line per job");
        let rendered = report.to_table().render();
        assert!(rendered.contains("gng") && rendered.contains("soam"), "{rendered}");
    }

    #[test]
    fn colliding_checkpoint_stems_rejected() {
        // Distinct names, same sanitized checkpoint stem: must not build
        // (the jobs would silently share one .msgsnap file).
        let a = quick_spec("scan a", BenchmarkShape::Blob, Algorithm::Soam, 1);
        let b = quick_spec("scan_a", BenchmarkShape::Blob, Algorithm::Soam, 2);
        let err = Fleet::new(vec![a, b]).unwrap_err().to_string();
        assert!(err.contains("scan_a"), "{err}");
    }

    #[test]
    fn pool_sized_for_the_widest_job() {
        let mut wide = quick_spec("wide", BenchmarkShape::Blob, Algorithm::Soam, 3);
        wide.cfg.driver = Driver::Parallel;
        wide.cfg.update_threads = 3;
        wide.cfg.limits.max_signals = 2_000;
        let narrow = quick_spec("narrow", BenchmarkShape::Blob, Algorithm::Soam, 4);
        let fleet = Fleet::new(vec![wide, narrow]).unwrap();
        assert_eq!(fleet.pool_width(), 3);
    }

    #[test]
    fn checkpoint_files_are_written_and_resumable() {
        let dir = std::env::temp_dir().join("msgsn_fleet_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        let spec = quick_spec("ckpt-job", BenchmarkShape::Blob, Algorithm::Soam, 5);
        let mut fleet = Fleet::new(vec![spec.clone()]).unwrap();
        let opts = FleetOptions {
            stride: 1,
            checkpoint_every: 3,
            checkpoint_dir: Some(dir.clone()),
        };
        let a = fleet.run(&opts, |_| {}).unwrap();
        let path = dir.join("ckpt-job.msgsnap");
        assert!(path.exists(), "checkpoint file missing");

        // A brand-new fleet resuming from the final checkpoint reports the
        // finished run without redoing it.
        let mut fleet2 = Fleet::new(vec![spec]).unwrap();
        let resumed = fleet2.resume_from(&dir).unwrap();
        assert_eq!(resumed, vec!["ckpt-job".to_string()]);
        let b = fleet2.run(&opts, |_| {}).unwrap();
        assert_eq!(a.jobs[0].1.signals, b.jobs[0].1.signals);
        assert_eq!(a.jobs[0].1.units, b.jobs[0].1.units);
        assert_eq!(a.jobs[0].1.qe.to_bits(), b.jobs[0].1.qe.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }
}
