//! Versioned, bit-exact, **durable** checkpoint format for
//! [`ConvergenceSession`]s.
//!
//! A snapshot captures everything a later process needs to continue a
//! half-converged run **bit-identically to never having stopped**:
//!
//! - the network slab — unit scalars, adjacency **in list order** (it
//!   drives the f32 operation order of later updates), the sharded free
//!   lists with their global-LIFO stamps (allocation order of future unit
//!   ids), via [`crate::som::Network::write_state`];
//! - the algorithm's scalars: the QE EMA, GNG's `signals_seen`,
//!   `decay_epoch` and per-slot `error_epoch` stamps (stored errors are
//!   only meaningful together with their stamps — materializing before
//!   saving would change *when* each decay ladder runs), SOAM's strike
//!   tables;
//! - the driver RNG state (and, for pipelined sessions, the forked
//!   sampler stream) via [`crate::rng::Rng::state`];
//! - the session counters (iterations, signals, discards, the pipelined
//!   m-schedule lag, termination flags).
//!
//! What is deliberately **not** stored: the mesh/sampler (rebuilt
//! deterministically from the [`super::JobSpec`]), the Find-Winners
//! structures (rebuilt from the restored network — they are derived
//! state), the executor (it holds no cross-batch semantic state), phase
//! timings and trace points (reporting only). Restoring therefore
//! requires the *same spec* the snapshot was taken under; the header
//! pins algorithm, driver, seed and a semantic fingerprint of the mesh +
//! every results-affecting parameter, and the restore fails loudly on
//! any mismatch rather than continuing a subtly different run (only
//! `max_signals` — the raise-the-budget knob — and the bit-invisible
//! performance knobs may change across a resume).
//!
//! Snapshots are only taken at iteration boundaries (between two
//! `step` calls), where every transient buffer is empty — the property
//! that makes the captured state complete.
//!
//! ## Durability (format v2)
//!
//! Version 2 appends a CRC-32 trailer (little-endian, over every
//! preceding byte — see [`crate::runtime::bytes::crc32`]), so a torn or
//! bit-rotted file is *detected* at restore instead of mis-parsed.
//! Version 1 files (no trailer) are still restorable.
//!
//! [`write_durable`] makes the on-disk story survive `kill -9` at any
//! byte: the new snapshot goes to a temp file, is fsync'd, and only then
//! renamed over the final name — and the previous generation is retained
//! as `<file>.prev` (rotated immediately before the rename), so even a
//! filesystem that breaks rename atomicity, or a fault-injected torn
//! write, leaves a restorable last-good generation on disk.
//! [`super::Fleet::resume_from`] falls back to it per job.

use std::path::{Path, PathBuf};

use crate::engine::ConvergenceSession;
use crate::runtime::bytes::{crc32, ByteReader, ByteWriter};
use crate::runtime::fault::{self, FaultAction, FaultPoint};

/// File magic ("MSGSN" + "FLT" for fleet).
pub const MAGIC: &[u8; 8] = b"MSGSNFLT";

/// Current snapshot format version (CRC-32 trailer). Bump on any layout
/// change; readers reject unknown versions instead of mis-parsing.
pub const SNAPSHOT_VERSION: u32 = 2;

/// The pre-checksum format (PR 5): same layout, no trailer. Still
/// restorable so existing checkpoint dirs survive the upgrade.
pub const LEGACY_VERSION: u32 = 1;

/// Serialize a session checkpoint. The header pins algorithm, driver,
/// seed AND the session's semantic fingerprint (mesh identity + every
/// results-affecting parameter — see
/// [`ConvergenceSession::fingerprint`]), so a restore under an edited
/// spec fails instead of continuing a subtly different run. `max_signals`
/// and the performance knobs are deliberately outside the fingerprint.
/// The final 4 bytes are the CRC-32 of everything before them.
pub fn snapshot_session(session: &ConvergenceSession) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.raw(MAGIC);
    w.u32(SNAPSHOT_VERSION);
    w.str(session.algo().name());
    w.str(session.driver().name());
    w.u64(session.seed());
    w.u64(session.fingerprint());
    session.write_state(&mut w);
    let mut bytes = w.into_inner();
    let crc = crc32(&bytes);
    bytes.extend_from_slice(&crc.to_le_bytes());
    bytes
}

/// Integrity probe without a session: magic, known version, and (v2) the
/// CRC-32 trailer. This is the *transportable* half of the restore checks
/// — the distributed coordinator runs it on every checkpoint generation a
/// worker ships over the wire before accepting it as "last good", so a
/// migration never resumes from bytes that would fail
/// [`restore_session`]'s own integrity pass. Header/spec agreement
/// (algo, driver, seed, fingerprint) still belongs to `restore_session`,
/// which is the only place a session exists to compare against.
pub fn verify_bytes(bytes: &[u8]) -> Result<(), String> {
    let mut probe = ByteReader::new(bytes);
    probe.expect_raw(MAGIC).map_err(|e| e.to_string())?;
    let version = probe.u32().map_err(|e| e.to_string())?;
    match version {
        LEGACY_VERSION => Ok(()),
        SNAPSHOT_VERSION => {
            if bytes.len() < MAGIC.len() + 8 {
                return Err("snapshot too short for its checksum trailer".to_string());
            }
            let (body, trailer) = bytes.split_at(bytes.len() - 4);
            let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
            let computed = crc32(body);
            if stored != computed {
                return Err(format!(
                    "checksum mismatch (stored {stored:#010x}, computed {computed:#010x}) — \
                     the checkpoint is torn or corrupt"
                ));
            }
            Ok(())
        }
        other => Err(format!(
            "snapshot version {other} (this build reads versions \
             {LEGACY_VERSION} and {SNAPSHOT_VERSION})"
        )),
    }
}

/// Restore a checkpoint into a freshly built session (same spec: same
/// mesh, same `RunConfig`). The checksum (v2) is verified over the whole
/// buffer **before** any state is decoded; the header is then validated
/// against the session before any state is touched. On `Err` the session
/// may be partially overwritten — callers rebuild a fresh one per
/// attempt (see [`super::Fleet::resume_from`]).
pub fn restore_session(session: &mut ConvergenceSession, bytes: &[u8]) -> Result<(), String> {
    // Probe magic + version first: whether a CRC trailer exists depends on
    // the version, and the version bytes sit before the trailer.
    let mut probe = ByteReader::new(bytes);
    probe.expect_raw(MAGIC).map_err(|e| e.to_string())?;
    let version = probe.u32().map_err(|e| e.to_string())?;
    let body: &[u8] = match version {
        LEGACY_VERSION => bytes,
        SNAPSHOT_VERSION => {
            if bytes.len() < MAGIC.len() + 8 {
                return Err("snapshot too short for its checksum trailer".to_string());
            }
            let (body, trailer) = bytes.split_at(bytes.len() - 4);
            let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
            let computed = crc32(body);
            if stored != computed {
                return Err(format!(
                    "checksum mismatch (stored {stored:#010x}, computed {computed:#010x}) — \
                     the checkpoint is torn or corrupt"
                ));
            }
            body
        }
        other => {
            return Err(format!(
                "snapshot version {other} (this build reads versions \
                 {LEGACY_VERSION} and {SNAPSHOT_VERSION})"
            ))
        }
    };
    let mut r = ByteReader::new(body);
    r.expect_raw(MAGIC).map_err(|e| e.to_string())?;
    let _version = r.u32().map_err(|e| e.to_string())?;
    let algo = r.str().map_err(|e| e.to_string())?;
    if algo != session.algo().name() {
        return Err(format!(
            "snapshot is a {algo:?} run, the job spec builds {:?}",
            session.algo().name()
        ));
    }
    let driver = r.str().map_err(|e| e.to_string())?;
    if driver != session.driver().name() {
        return Err(format!(
            "snapshot driver {driver:?} != spec driver {:?}",
            session.driver().name()
        ));
    }
    let seed = r.u64().map_err(|e| e.to_string())?;
    if seed != session.seed() {
        return Err(format!("snapshot seed {seed} != spec seed {}", session.seed()));
    }
    let fingerprint = r.u64().map_err(|e| e.to_string())?;
    if fingerprint != session.fingerprint() {
        return Err(format!(
            "snapshot config fingerprint {fingerprint:#x} != the spec's {:#x} — the mesh \
             or a results-affecting parameter changed since the checkpoint (only \
             max_signals and the performance knobs may differ across a resume)",
            session.fingerprint()
        ));
    }
    session.read_state(&mut r)?;
    r.expect_end().map_err(|e| e.to_string())?;
    Ok(())
}

/// The retained previous generation for a checkpoint path:
/// `a.msgsnap` → `a.msgsnap.prev`. (Note the appended — not replaced —
/// extension: `a.msgsnap.prev`'s file *stem* is therefore `a.msgsnap`,
/// which is also its fault-injection scope at the `snapshot_decode`
/// point, so tests can target latest and previous separately.)
pub fn prev_path(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(".prev");
    PathBuf::from(name)
}

/// Rotate the current latest generation (if any) to its `.prev` name.
fn rotate_to_prev(path: &Path) -> std::io::Result<()> {
    if path.exists() {
        std::fs::rename(path, prev_path(path))?;
    }
    Ok(())
}

/// Durably write checkpoint `bytes` to `path`, retaining the previous
/// generation:
///
/// 1. write to `<path minus extension>.tmp` and **fsync** it (a rename
///    can survive a crash its data didn't);
/// 2. rotate the existing latest to [`prev_path`];
/// 3. atomically rename the temp file over `path`;
/// 4. best-effort fsync of the parent directory (makes the rename itself
///    durable where supported).
///
/// Fault point [`FaultPoint::CheckpointWrite`] (scope = the file stem):
/// `truncate` simulates a kill mid-write of a *non-atomic* writer — the
/// rotation still happens, then the truncated prefix is written directly
/// over the final path, bypassing the temp+rename dance. That is exactly
/// the torn file the two-generation layout must recover from. `err`
/// returns an injected I/O error with nothing written.
pub fn write_durable(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    let scope = path.file_stem().and_then(|s| s.to_str()).map(str::to_owned);
    match fault::fire(FaultPoint::CheckpointWrite, scope.as_deref(), None) {
        Some(FaultAction::Truncate(n)) => {
            rotate_to_prev(path)?;
            let cut = (n as usize).min(bytes.len());
            return std::fs::write(path, &bytes[..cut]);
        }
        Some(FaultAction::Error) => {
            return Err(std::io::Error::other("injected checkpoint write error"));
        }
        Some(FaultAction::Panic) => {
            panic!("injected fault: checkpoint_write panic ({})", path.display())
        }
        None => {}
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    rotate_to_prev(path)?;
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Snapshot a session and write it durably (see [`write_durable`]).
pub fn save_to(path: &Path, session: &ConvergenceSession) -> std::io::Result<()> {
    write_durable(path, &snapshot_session(session))
}

/// Read a checkpoint file into a freshly built session.
///
/// Fault point [`FaultPoint::SnapshotDecode`] (scope = the file stem;
/// `.prev` generations decode under the stem `<job>.msgsnap` — see
/// [`prev_path`]): any armed action injects a decode failure (`panic`
/// panics), simulating corruption the CRC cannot model.
pub fn load_from(path: &Path, session: &mut ConvergenceSession) -> Result<(), String> {
    let scope = path.file_stem().and_then(|s| s.to_str()).map(str::to_owned);
    if let Some(action) = fault::fire(FaultPoint::SnapshotDecode, scope.as_deref(), None) {
        if action == FaultAction::Panic {
            panic!("injected fault: snapshot_decode panic ({})", path.display());
        }
        return Err(format!("checkpoint {}: injected snapshot decode fault", path.display()));
    }
    let bytes = std::fs::read(path)
        .map_err(|e| format!("reading checkpoint {}: {e}", path.display()))?;
    restore_session(session, &bytes)
        .map_err(|e| format!("checkpoint {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Driver, RunConfig};
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    fn cfg(driver: Driver, algorithm: Algorithm, seed: u64) -> RunConfig {
        let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
        cfg.driver = driver;
        cfg.algorithm = algorithm;
        cfg.seed = seed;
        cfg.soam.insertion_threshold = 0.15;
        cfg.gwr.insertion_threshold = 0.15;
        cfg.limits.max_signals = 15_000;
        cfg
    }

    /// Unique per-test scratch path: parallel `cargo test` processes (and
    /// parallel tests within one) must never share on-disk state.
    fn scratch_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("msgsn_{}_{}", std::process::id(), name))
    }

    /// Kill-and-resume must be bit-identical to an uninterrupted session
    /// (the full matrix against the Multi reference lives in
    /// `rust/tests/executor_parity.rs`; this is the format's own test).
    #[test]
    fn roundtrip_resume_matches_uninterrupted() {
        for (driver, algorithm) in [
            (Driver::Multi, Algorithm::Soam),
            (Driver::Multi, Algorithm::Gng),
            (Driver::Pipelined, Algorithm::Soam),
            (Driver::Single, Algorithm::Gwr),
        ] {
            let cfg = cfg(driver, algorithm, 19);
            let mesh = benchmark_mesh(cfg.shape, 20);

            let mut uninterrupted = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            let a = uninterrupted.run_to_end();

            let mut first = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            // Step a prefix (batches for batched modes, signals for single).
            let prefix = if driver == Driver::Single { 4_000 } else { 12 };
            first.step(prefix);
            let bytes = snapshot_session(&first);
            drop(first);

            let mut resumed = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            restore_session(&mut resumed, &bytes).unwrap();
            let b = resumed.run_to_end();

            let label = format!("{}/{}", driver.name(), a.algorithm);
            assert_eq!(a.iterations, b.iterations, "{label}");
            assert_eq!(a.signals, b.signals, "{label}");
            assert_eq!(a.discarded, b.discarded, "{label}");
            assert_eq!(a.units, b.units, "{label}");
            assert_eq!(a.connections, b.connections, "{label}");
            assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
            let (na, nb) = (uninterrupted.algo().net(), resumed.algo().net());
            assert_eq!(na.capacity(), nb.capacity(), "{label}: slab");
            for id in 0..na.capacity() as u32 {
                assert_eq!(na.is_alive(id), nb.is_alive(id), "{label}: unit {id}");
                if !na.is_alive(id) {
                    continue;
                }
                let (ua, ub) = (na.unit(id), nb.unit(id));
                assert_eq!(ua.pos.x.to_bits(), ub.pos.x.to_bits(), "{label}: unit {id}");
                assert_eq!(ua.firing.to_bits(), ub.firing.to_bits(), "{label}: unit {id}");
                assert_eq!(ua.error.to_bits(), ub.error.to_bits(), "{label}: unit {id}");
                let ea: Vec<(u32, u32)> =
                    na.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
                let eb: Vec<(u32, u32)> =
                    nb.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
                assert_eq!(ea, eb, "{label}: edges of {id}");
            }
        }
    }

    #[test]
    fn header_mismatches_are_rejected() {
        let cfg_a = cfg(Driver::Multi, Algorithm::Soam, 1);
        let mesh = benchmark_mesh(cfg_a.shape, 20);
        let mut session = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        session.step(3);
        let bytes = snapshot_session(&session);

        // Wrong algorithm.
        let mut other = ConvergenceSession::new(
            &cfg(Driver::Multi, Algorithm::Gng, 1),
            &mesh,
            None,
        )
        .unwrap();
        assert!(restore_session(&mut other, &bytes).unwrap_err().contains("gng"));

        // Wrong driver.
        let mut other =
            ConvergenceSession::new(&cfg(Driver::Parallel, Algorithm::Soam, 1), &mesh, None)
                .unwrap();
        assert!(restore_session(&mut other, &bytes).unwrap_err().contains("driver"));

        // Wrong seed.
        let mut other =
            ConvergenceSession::new(&cfg(Driver::Multi, Algorithm::Soam, 2), &mesh, None)
                .unwrap();
        assert!(restore_session(&mut other, &bytes).unwrap_err().contains("seed"));

        // Same algorithm/driver/seed but an edited results-affecting
        // parameter: the fingerprint must reject it.
        let mut edited_cfg = cfg(Driver::Multi, Algorithm::Soam, 1);
        edited_cfg.soam.insertion_threshold = 0.11;
        let mut other = ConvergenceSession::new(&edited_cfg, &mesh, None).unwrap();
        assert!(
            restore_session(&mut other, &bytes).unwrap_err().contains("fingerprint"),
            "edited insertion_threshold must be rejected"
        );

        // …while raising only max_signals (the resume-budget knob) passes
        // the header and restores cleanly.
        let mut raised_cfg = cfg(Driver::Multi, Algorithm::Soam, 1);
        raised_cfg.limits.max_signals *= 2;
        let mut other = ConvergenceSession::new(&raised_cfg, &mesh, None).unwrap();
        restore_session(&mut other, &bytes).unwrap();

        // Truncation anywhere errors, never panics (the cuts past the
        // header land in the CRC check: a truncated v2 body can never
        // carry a matching trailer).
        let mut fresh =
            ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        for cut in [0, 4, MAGIC.len(), bytes.len() / 2, bytes.len() - 1] {
            assert!(
                restore_session(&mut fresh, &bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
            fresh = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        }

        // Bad version byte: rejected by the version probe (before any CRC
        // interpretation — an unknown version's trailer layout is unknown).
        let mut bad = bytes.clone();
        bad[8] = 0xFF;
        assert!(restore_session(&mut fresh, &bad).unwrap_err().contains("version"));

        // Trailing garbage appended after a valid file shifts the trailer:
        // the checksum catches it.
        let mut bad = bytes.clone();
        bad.push(0);
        let mut fresh = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        assert!(restore_session(&mut fresh, &bad).unwrap_err().contains("checksum"));

        // Trailing garbage *inside* a correctly-checksummed envelope (a
        // buggy writer, not corruption) is still flagged by the body parse.
        let mut forged = bytes[..bytes.len() - 4].to_vec();
        forged.push(0);
        let crc = crate::runtime::bytes::crc32(&forged);
        forged.extend_from_slice(&crc.to_le_bytes());
        let mut fresh = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        assert!(restore_session(&mut fresh, &forged).unwrap_err().contains("trailing"));
    }

    #[test]
    fn legacy_v1_snapshots_still_restore() {
        let cfg = cfg(Driver::Multi, Algorithm::Soam, 23);
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        session.step(6);
        let a = {
            let mut s = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            restore_session(&mut s, &snapshot_session(&session)).unwrap();
            s.run_to_end()
        };
        // Re-create the PR 5 on-disk format from the v2 bytes: strip the
        // 4-byte trailer, patch the version field back to 1 (the body
        // layout is unchanged between the versions).
        let v2 = snapshot_session(&session);
        let mut v1 = v2[..v2.len() - 4].to_vec();
        v1[8..12].copy_from_slice(&LEGACY_VERSION.to_le_bytes());
        let mut resumed = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        restore_session(&mut resumed, &v1).unwrap();
        let b = resumed.run_to_end();
        assert_eq!(a.units, b.units);
        assert_eq!(a.qe.to_bits(), b.qe.to_bits());
    }

    #[test]
    fn any_single_bit_flip_is_a_checksum_error() {
        let cfg = cfg(Driver::Multi, Algorithm::Gng, 7);
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        session.step(4);
        let bytes = snapshot_session(&session);
        // Sampled offsets here (every offset × a session rebuild would be
        // slow); the exhaustive sweep lives in rust/tests/properties.rs.
        let mut fresh = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        for byte in [9, 13, bytes.len() / 3, bytes.len() / 2, bytes.len() - 2] {
            let mut flipped = bytes.clone();
            flipped[byte] ^= 0x10;
            let err = restore_session(&mut fresh, &flipped)
                .expect_err(&format!("flip at byte {byte} must fail"));
            // Flips inside the magic fail on the magic itself; everything
            // after it is caught by the checksum before decoding.
            assert!(
                err.contains("checksum") || err.contains("magic") || err.contains("version"),
                "flip at byte {byte}: unexpected error {err:?}"
            );
            fresh = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        }
    }

    #[test]
    fn durable_write_retains_the_previous_generation() {
        let cfg = cfg(Driver::Multi, Algorithm::Soam, 31);
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        let path = scratch_path("snapshot_rotation.msgsnap");
        let prev = prev_path(&path);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&prev).ok();

        session.step(3);
        let gen1 = snapshot_session(&session);
        write_durable(&path, &gen1).unwrap();
        assert!(path.exists());
        assert!(!prev.exists(), "first generation has no predecessor");

        session.step(3);
        let gen2 = snapshot_session(&session);
        write_durable(&path, &gen2).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), gen2, "latest is the new generation");
        assert_eq!(std::fs::read(&prev).unwrap(), gen1, "previous generation retained");
        // No temp file left behind.
        assert!(!path.with_extension("tmp").exists());

        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&prev).ok();
    }

    #[test]
    fn injected_torn_write_clobbers_latest_but_not_prev() {
        let _guard = fault::test_lock();
        let cfg = cfg(Driver::Multi, Algorithm::Soam, 37);
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        let path = scratch_path("snapshot_torn.msgsnap");
        let prev = prev_path(&path);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&prev).ok();

        session.step(3);
        let gen1 = snapshot_session(&session);
        write_durable(&path, &gen1).unwrap();

        // Second write is torn at 10 bytes, written non-atomically. The
        // scope is the file stem (pid-unique here), so a concurrent test's
        // checkpoint writes can never consume this spec.
        let stem = path.file_stem().unwrap().to_str().unwrap();
        fault::install(
            fault::parse_faults(&format!("checkpoint_write/{stem}:truncate=10@1")).unwrap(),
        );
        session.step(3);
        let gen2 = snapshot_session(&session);
        write_durable(&path, &gen2).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), &gen2[..10], "latest is torn");
        assert_eq!(std::fs::read(&prev).unwrap(), gen1, "prev holds the last good bytes");

        // The torn latest is rejected, the retained generation restores.
        let mut fresh = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        assert!(load_from(&path, &mut fresh).is_err());
        let mut fresh = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        load_from(&prev, &mut fresh).unwrap();

        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&prev).ok();
    }

    #[test]
    fn file_roundtrip() {
        let cfg = cfg(Driver::Multi, Algorithm::Soam, 5);
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        session.step(5);
        let path = scratch_path("snapshot_roundtrip.msgsnap");
        save_to(&path, &session).unwrap();
        let a = session.run_to_end();
        let mut resumed = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        load_from(&path, &mut resumed).unwrap();
        let b = resumed.run_to_end();
        assert_eq!(a.units, b.units);
        assert_eq!(a.qe.to_bits(), b.qe.to_bits());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(prev_path(&path)).ok();
    }
}
