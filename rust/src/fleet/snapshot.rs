//! Versioned, bit-exact checkpoint format for [`ConvergenceSession`]s.
//!
//! A snapshot captures everything a later process needs to continue a
//! half-converged run **bit-identically to never having stopped**:
//!
//! - the network slab — unit scalars, adjacency **in list order** (it
//!   drives the f32 operation order of later updates), the sharded free
//!   lists with their global-LIFO stamps (allocation order of future unit
//!   ids), via [`crate::som::Network::write_state`];
//! - the algorithm's scalars: the QE EMA, GNG's `signals_seen`,
//!   `decay_epoch` and per-slot `error_epoch` stamps (stored errors are
//!   only meaningful together with their stamps — materializing before
//!   saving would change *when* each decay ladder runs), SOAM's strike
//!   tables;
//! - the driver RNG state (and, for pipelined sessions, the forked
//!   sampler stream) via [`crate::rng::Rng::state`];
//! - the session counters (iterations, signals, discards, the pipelined
//!   m-schedule lag, termination flags).
//!
//! What is deliberately **not** stored: the mesh/sampler (rebuilt
//! deterministically from the [`super::JobSpec`]), the Find-Winners
//! structures (rebuilt from the restored network — they are derived
//! state), the executor (it holds no cross-batch semantic state), phase
//! timings and trace points (reporting only). Restoring therefore
//! requires the *same spec* the snapshot was taken under; the header
//! pins algorithm, driver, seed and a semantic fingerprint of the mesh +
//! every results-affecting parameter, and the restore fails loudly on
//! any mismatch rather than continuing a subtly different run (only
//! `max_signals` — the raise-the-budget knob — and the bit-invisible
//! performance knobs may change across a resume).
//!
//! Snapshots are only taken at iteration boundaries (between two
//! `step` calls), where every transient buffer is empty — the property
//! that makes the captured state complete.

use std::path::Path;

use crate::engine::ConvergenceSession;
use crate::runtime::bytes::{ByteReader, ByteWriter};

/// File magic ("MSGSN" + "FLT" for fleet).
pub const MAGIC: &[u8; 8] = b"MSGSNFLT";

/// Current snapshot format version. Bump on any layout change; readers
/// reject other versions instead of mis-parsing.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Serialize a session checkpoint. The header pins algorithm, driver,
/// seed AND the session's semantic fingerprint (mesh identity + every
/// results-affecting parameter — see
/// [`ConvergenceSession::fingerprint`]), so a restore under an edited
/// spec fails instead of continuing a subtly different run. `max_signals`
/// and the performance knobs are deliberately outside the fingerprint.
pub fn snapshot_session(session: &ConvergenceSession) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.raw(MAGIC);
    w.u32(SNAPSHOT_VERSION);
    w.str(session.algo().name());
    w.str(session.driver().name());
    w.u64(session.seed());
    w.u64(session.fingerprint());
    session.write_state(&mut w);
    w.into_inner()
}

/// Restore a checkpoint into a freshly built session (same spec: same
/// mesh, same `RunConfig`). Validates the header against the session
/// before touching any state.
pub fn restore_session(session: &mut ConvergenceSession, bytes: &[u8]) -> Result<(), String> {
    let mut r = ByteReader::new(bytes);
    r.expect_raw(MAGIC).map_err(|e| e.to_string())?;
    let version = r.u32().map_err(|e| e.to_string())?;
    if version != SNAPSHOT_VERSION {
        return Err(format!(
            "snapshot version {version} (this build reads version {SNAPSHOT_VERSION})"
        ));
    }
    let algo = r.str().map_err(|e| e.to_string())?;
    if algo != session.algo().name() {
        return Err(format!(
            "snapshot is a {algo:?} run, the job spec builds {:?}",
            session.algo().name()
        ));
    }
    let driver = r.str().map_err(|e| e.to_string())?;
    if driver != session.driver().name() {
        return Err(format!(
            "snapshot driver {driver:?} != spec driver {:?}",
            session.driver().name()
        ));
    }
    let seed = r.u64().map_err(|e| e.to_string())?;
    if seed != session.seed() {
        return Err(format!("snapshot seed {seed} != spec seed {}", session.seed()));
    }
    let fingerprint = r.u64().map_err(|e| e.to_string())?;
    if fingerprint != session.fingerprint() {
        return Err(format!(
            "snapshot config fingerprint {fingerprint:#x} != the spec's {:#x} — the mesh \
             or a results-affecting parameter changed since the checkpoint (only \
             max_signals and the performance knobs may differ across a resume)",
            session.fingerprint()
        ));
    }
    session.read_state(&mut r)?;
    r.expect_end().map_err(|e| e.to_string())?;
    Ok(())
}

/// Write a checkpoint file (atomic-ish: temp file + rename, so a crash
/// mid-write never leaves a truncated checkpoint under the final name).
pub fn save_to(path: &Path, session: &ConvergenceSession) -> std::io::Result<()> {
    let bytes = snapshot_session(session);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)
}

/// Read a checkpoint file into a freshly built session.
pub fn load_from(path: &Path, session: &mut ConvergenceSession) -> Result<(), String> {
    let bytes = std::fs::read(path)
        .map_err(|e| format!("reading checkpoint {}: {e}", path.display()))?;
    restore_session(session, &bytes)
        .map_err(|e| format!("checkpoint {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Driver, RunConfig};
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    fn cfg(driver: Driver, algorithm: Algorithm, seed: u64) -> RunConfig {
        let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
        cfg.driver = driver;
        cfg.algorithm = algorithm;
        cfg.seed = seed;
        cfg.soam.insertion_threshold = 0.15;
        cfg.gwr.insertion_threshold = 0.15;
        cfg.limits.max_signals = 15_000;
        cfg
    }

    /// Kill-and-resume must be bit-identical to an uninterrupted session
    /// (the full matrix against the Multi reference lives in
    /// `rust/tests/executor_parity.rs`; this is the format's own test).
    #[test]
    fn roundtrip_resume_matches_uninterrupted() {
        for (driver, algorithm) in [
            (Driver::Multi, Algorithm::Soam),
            (Driver::Multi, Algorithm::Gng),
            (Driver::Pipelined, Algorithm::Soam),
            (Driver::Single, Algorithm::Gwr),
        ] {
            let cfg = cfg(driver, algorithm, 19);
            let mesh = benchmark_mesh(cfg.shape, 20);

            let mut uninterrupted = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            let a = uninterrupted.run_to_end();

            let mut first = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            // Step a prefix (batches for batched modes, signals for single).
            let prefix = if driver == Driver::Single { 4_000 } else { 12 };
            first.step(prefix);
            let bytes = snapshot_session(&first);
            drop(first);

            let mut resumed = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            restore_session(&mut resumed, &bytes).unwrap();
            let b = resumed.run_to_end();

            let label = format!("{}/{}", driver.name(), a.algorithm);
            assert_eq!(a.iterations, b.iterations, "{label}");
            assert_eq!(a.signals, b.signals, "{label}");
            assert_eq!(a.discarded, b.discarded, "{label}");
            assert_eq!(a.units, b.units, "{label}");
            assert_eq!(a.connections, b.connections, "{label}");
            assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
            let (na, nb) = (uninterrupted.algo().net(), resumed.algo().net());
            assert_eq!(na.capacity(), nb.capacity(), "{label}: slab");
            for id in 0..na.capacity() as u32 {
                assert_eq!(na.is_alive(id), nb.is_alive(id), "{label}: unit {id}");
                if !na.is_alive(id) {
                    continue;
                }
                let (ua, ub) = (na.unit(id), nb.unit(id));
                assert_eq!(ua.pos.x.to_bits(), ub.pos.x.to_bits(), "{label}: unit {id}");
                assert_eq!(ua.firing.to_bits(), ub.firing.to_bits(), "{label}: unit {id}");
                assert_eq!(ua.error.to_bits(), ub.error.to_bits(), "{label}: unit {id}");
                let ea: Vec<(u32, u32)> =
                    na.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
                let eb: Vec<(u32, u32)> =
                    nb.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
                assert_eq!(ea, eb, "{label}: edges of {id}");
            }
        }
    }

    #[test]
    fn header_mismatches_are_rejected() {
        let cfg_a = cfg(Driver::Multi, Algorithm::Soam, 1);
        let mesh = benchmark_mesh(cfg_a.shape, 20);
        let mut session = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        session.step(3);
        let bytes = snapshot_session(&session);

        // Wrong algorithm.
        let mut other = ConvergenceSession::new(
            &cfg(Driver::Multi, Algorithm::Gng, 1),
            &mesh,
            None,
        )
        .unwrap();
        assert!(restore_session(&mut other, &bytes).unwrap_err().contains("gng"));

        // Wrong driver.
        let mut other =
            ConvergenceSession::new(&cfg(Driver::Parallel, Algorithm::Soam, 1), &mesh, None)
                .unwrap();
        assert!(restore_session(&mut other, &bytes).unwrap_err().contains("driver"));

        // Wrong seed.
        let mut other =
            ConvergenceSession::new(&cfg(Driver::Multi, Algorithm::Soam, 2), &mesh, None)
                .unwrap();
        assert!(restore_session(&mut other, &bytes).unwrap_err().contains("seed"));

        // Same algorithm/driver/seed but an edited results-affecting
        // parameter: the fingerprint must reject it.
        let mut edited_cfg = cfg(Driver::Multi, Algorithm::Soam, 1);
        edited_cfg.soam.insertion_threshold = 0.11;
        let mut other = ConvergenceSession::new(&edited_cfg, &mesh, None).unwrap();
        assert!(
            restore_session(&mut other, &bytes).unwrap_err().contains("fingerprint"),
            "edited insertion_threshold must be rejected"
        );

        // …while raising only max_signals (the resume-budget knob) passes
        // the header and restores cleanly.
        let mut raised_cfg = cfg(Driver::Multi, Algorithm::Soam, 1);
        raised_cfg.limits.max_signals *= 2;
        let mut other = ConvergenceSession::new(&raised_cfg, &mesh, None).unwrap();
        restore_session(&mut other, &bytes).unwrap();

        // Truncation anywhere errors, never panics.
        let mut fresh =
            ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        for cut in [0, 4, MAGIC.len(), bytes.len() / 2, bytes.len() - 1] {
            assert!(
                restore_session(&mut fresh, &bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
            fresh = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        }

        // Bad version.
        let mut bad = bytes.clone();
        bad[8] = 0xFF;
        assert!(restore_session(&mut fresh, &bad).unwrap_err().contains("version"));

        // Trailing garbage.
        let mut bad = bytes.clone();
        bad.push(0);
        let mut fresh = ConvergenceSession::new(&cfg_a, &mesh, None).unwrap();
        assert!(restore_session(&mut fresh, &bad).unwrap_err().contains("trailing"));
    }

    #[test]
    fn file_roundtrip() {
        let cfg = cfg(Driver::Multi, Algorithm::Soam, 5);
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        session.step(5);
        let path = std::env::temp_dir().join("msgsn_test_snapshot.msgsnap");
        save_to(&path, &session).unwrap();
        let a = session.run_to_end();
        let mut resumed = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        load_from(&path, &mut resumed).unwrap();
        let b = resumed.run_to_end();
        assert_eq!(a.units, b.units);
        assert_eq!(a.qe.to_bits(), b.qe.to_bits());
        std::fs::remove_file(path).ok();
    }
}
