//! Fleet job specifications and the JSON jobs-manifest parser.
//!
//! A manifest describes N independent reconstruction jobs — each with its
//! own point-cloud source (a benchmark shape or an OBJ/OFF file), its own
//! algorithm/driver/seed and any [`RunConfig`] knob — that the
//! [`super::Fleet`] scheduler multiplexes over one worker pool:
//!
//! ```json
//! {
//!   "version": 1,
//!   "jobs": [
//!     {
//!       "name": "blob-soam",
//!       "mesh": "blob",
//!       "algorithm": "soam",
//!       "driver": "parallel",
//!       "seed": 7,
//!       "config": { "regions": 64, "max_signals": 400000 }
//!     },
//!     { "name": "scan", "mesh": "clouds/scan.obj", "driver": "multi" }
//!   ]
//! }
//! ```
//!
//! `mesh` accepts a benchmark-shape name (`blob|eight|hand|heptoroid`) or a
//! path to an OBJ/OFF file; `config` keys go through the same
//! [`RunConfig::apply`] the CLI's `--set` and config files use, so every
//! knob (thresholds, thread counts, regions, limits, …) is available per
//! job. Parsing reuses the in-repo JSON parser (`runtime::json`, via
//! [`crate::runtime::parse_json`]); unknown keys are errors — a typo must
//! not silently run a default job.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{Algorithm, ConfigValue, Driver, RunConfig};
use crate::mesh::{benchmark_mesh, read_obj, read_off, BenchmarkShape, Mesh};
use crate::runtime::{parse_json, render_json, Json};

/// Supported manifest schema version.
pub const MANIFEST_VERSION: u64 = 1;

/// Scheduling priority class (the serve-tier QoS knob, manifest key
/// `"qos"`). The scheduler multiplies the fleet-wide per-round stride by
/// the class weight, so an `interactive` job advances
/// [`QosClass::INTERACTIVE_WEIGHT`]× the batches of a `batch` job per
/// round-robin turn. Because chunked stepping is stride-invariant (a
/// session stepped in any chunking is bit-identical to a blocking run —
/// `rust/tests/fleet.rs` proves it), QoS weighting changes *when* a job
/// finishes relative to its neighbors, never *what* it converges to.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QosClass {
    /// Latency-sensitive: [`QosClass::INTERACTIVE_WEIGHT`]× the stride.
    Interactive,
    /// Throughput work: the baseline stride (the default).
    #[default]
    Batch,
}

impl QosClass {
    /// Stride multiplier of the `interactive` class.
    pub const INTERACTIVE_WEIGHT: u64 = 4;

    pub fn weight(self) -> u64 {
        match self {
            QosClass::Interactive => Self::INTERACTIVE_WEIGHT,
            QosClass::Batch => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "interactive" => Some(QosClass::Interactive),
            "batch" => Some(QosClass::Batch),
            _ => None,
        }
    }
}

/// One fleet job: a point-cloud source plus a full run configuration.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Unique job name (report rows, checkpoint file names).
    pub name: String,
    /// Mesh file to load instead of the benchmark shape in `cfg.shape`.
    pub mesh_path: Option<PathBuf>,
    /// Full run configuration (driver, algorithm, seed, every knob).
    pub cfg: RunConfig,
    /// Per-job override of the fleet's retry budget (crash-isolation
    /// restore attempts before the job is quarantined —
    /// [`super::FleetOptions::max_retries`]). `Some(0)` quarantines on the
    /// first failure.
    pub retries: Option<u32>,
    /// Scheduling priority class ([`QosClass`], manifest key `"qos"`).
    pub qos: QosClass,
}

impl JobSpec {
    /// A spec over a benchmark shape, named after shape + algorithm.
    pub fn from_config(name: impl Into<String>, cfg: RunConfig) -> Self {
        Self { name: name.into(), mesh_path: None, cfg, retries: None, qos: QosClass::default() }
    }

    /// Materialize the job's point-cloud source.
    pub fn build_mesh(&self) -> Result<Mesh> {
        match &self.mesh_path {
            None => Ok(benchmark_mesh(self.cfg.shape, self.cfg.mesh_resolution)),
            Some(path) => {
                let mesh = match path.extension().and_then(|e| e.to_str()) {
                    Some("off") => read_off(path)?,
                    _ => read_obj(path)?,
                };
                if mesh.is_empty() {
                    bail!("mesh {} has no faces", path.display());
                }
                Ok(mesh)
            }
        }
    }

    /// Checkpoint-safe file stem: the job name with every non
    /// `[A-Za-z0-9._-]` byte replaced by `_` (names come from user
    /// manifests and become file names).
    pub fn file_stem(&self) -> String {
        self.name
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                    c
                } else {
                    '_'
                }
            })
            .collect()
    }
}

/// Parse a jobs manifest (see module docs). Job names must be unique;
/// missing names default to `job<N>`.
pub fn parse_manifest(text: &str) -> Result<Vec<JobSpec>> {
    let doc = parse_json(text).context("jobs manifest is not valid JSON")?;
    let version = doc
        .get("version")
        .and_then(Json::as_u64)
        .context("manifest needs a numeric \"version\"")?;
    if version != MANIFEST_VERSION {
        bail!("manifest version {version} (this build reads version {MANIFEST_VERSION})");
    }
    let jobs = doc
        .get("jobs")
        .and_then(Json::as_arr)
        .context("manifest needs a \"jobs\" array")?;
    if jobs.is_empty() {
        bail!("manifest has an empty \"jobs\" array");
    }
    let mut specs = Vec::with_capacity(jobs.len());
    for (k, job) in jobs.iter().enumerate() {
        let spec = parse_job(job, k).with_context(|| format!("jobs[{k}]"))?;
        specs.push(spec);
    }
    for i in 0..specs.len() {
        for j in i + 1..specs.len() {
            if specs[i].name == specs[j].name {
                bail!("duplicate job name {:?} (jobs[{i}] and jobs[{j}])", specs[i].name);
            }
        }
    }
    Ok(specs)
}

/// Split a manifest into per-job **payloads**: `(resolved name, single-job
/// manifest text)` pairs, one per job, in manifest order. This is the
/// dist-layer routing format — the coordinator validates the whole
/// manifest once (this call parses it fully first), then ships each job
/// to its worker as a self-contained manifest the worker re-parses with
/// [`parse_manifest`]. Defaulted names (`job<N>`) are pinned into the
/// payload so both sides agree on the job's identity regardless of its
/// position in the original manifest.
pub fn manifest_job_payloads(text: &str) -> Result<Vec<(String, String)>> {
    let specs = parse_manifest(text)?;
    let doc = parse_json(text).expect("parse_manifest validated the JSON");
    let jobs = doc
        .get("jobs")
        .and_then(Json::as_arr)
        .expect("parse_manifest validated the jobs array");
    let mut payloads = Vec::with_capacity(specs.len());
    for (spec, job) in specs.iter().zip(jobs) {
        let Json::Obj(map) = job else { unreachable!("parse_job requires objects") };
        let mut map = map.clone();
        map.insert("name".to_string(), Json::Str(spec.name.clone()));
        let payload = format!(
            "{{\"version\": {MANIFEST_VERSION}, \"jobs\": [{}]}}",
            render_json(&Json::Obj(map))
        );
        payloads.push((spec.name.clone(), payload));
    }
    Ok(payloads)
}

/// Parse a single-job payload produced by [`manifest_job_payloads`].
pub fn parse_job_payload(text: &str) -> Result<JobSpec> {
    let mut specs = parse_manifest(text)?;
    if specs.len() != 1 {
        bail!("job payload must contain exactly one job, found {}", specs.len());
    }
    Ok(specs.pop().expect("checked len"))
}

fn parse_job(job: &Json, index: usize) -> Result<JobSpec> {
    let Json::Obj(map) = job else { bail!("job entry must be an object") };
    for key in map.keys() {
        if !matches!(
            key.as_str(),
            "name" | "mesh" | "algorithm" | "driver" | "seed" | "config" | "retries" | "qos"
        ) {
            bail!(
                "unknown job key {key:?} \
                 (expected name|mesh|algorithm|driver|seed|config|retries|qos)"
            );
        }
    }

    let name = match job.get("name") {
        None => format!("job{index}"),
        Some(v) => v.as_str().context("\"name\" must be a string")?.to_string(),
    };
    if name.is_empty() {
        bail!("job name must not be empty");
    }

    // Mesh source first: a shape name selects the preset the remaining
    // knobs override (the CLI's behavior); a path keeps the default preset.
    let (shape, mesh_path) = match job.get("mesh") {
        None => (BenchmarkShape::Blob, None),
        Some(v) => {
            let s = v.as_str().context("\"mesh\" must be a string")?;
            match BenchmarkShape::from_name(s) {
                Some(shape) => (shape, None),
                None => {
                    let path = Path::new(s);
                    match path.extension().and_then(|e| e.to_str()) {
                        Some("obj" | "off") => (BenchmarkShape::Blob, Some(path.to_path_buf())),
                        _ => bail!(
                            "\"mesh\" {s:?} is neither a benchmark shape \
                             (blob|eight|hand|heptoroid) nor an .obj/.off path"
                        ),
                    }
                }
            }
        }
    };
    let mut cfg = RunConfig::preset(shape);

    if let Some(v) = job.get("algorithm") {
        let s = v.as_str().context("\"algorithm\" must be a string")?;
        cfg.algorithm =
            Algorithm::from_name(s).with_context(|| format!("unknown algorithm {s:?}"))?;
    }
    if let Some(v) = job.get("driver") {
        let s = v.as_str().context("\"driver\" must be a string")?;
        cfg.driver = Driver::from_config_name(s)
            .map_err(|why| anyhow::anyhow!(why))?
            .with_context(|| format!("unknown driver {s:?} (expected {})", Driver::NAMES))?;
    }
    if let Some(v) = job.get("seed") {
        cfg.seed = v.as_u64().context("\"seed\" must be a non-negative integer")?;
    }
    if let Some(config) = job.get("config") {
        let Json::Obj(map) = config else { bail!("\"config\" must be an object") };
        for (key, value) in map {
            let value = json_to_config_value(value)
                .with_context(|| format!("config key {key:?} has a non-scalar value"))?;
            cfg.apply(key, &value).with_context(|| format!("config key {key:?}"))?;
        }
    }
    let retries = match job.get("retries") {
        None => None,
        Some(v) => {
            let n = v.as_u64().context("\"retries\" must be a non-negative integer")?;
            Some(u32::try_from(n).context("\"retries\" out of range")?)
        }
    };
    let qos = match job.get("qos") {
        None => QosClass::default(),
        Some(v) => {
            let s = v.as_str().context("\"qos\" must be a string")?;
            QosClass::from_name(s)
                .with_context(|| format!("unknown qos class {s:?} (expected interactive|batch)"))?
        }
    };
    Ok(JobSpec { name, mesh_path, cfg, retries, qos })
}

/// Manifest values reuse the config-file scalar domain.
fn json_to_config_value(v: &Json) -> Option<ConfigValue> {
    match v {
        Json::Num(x) => Some(ConfigValue::Num(*x)),
        Json::Str(s) => Some(ConfigValue::Str(s.clone())),
        Json::Bool(b) => Some(ConfigValue::Bool(*b)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "version": 1,
      "jobs": [
        {
          "name": "blob-soam",
          "mesh": "blob",
          "algorithm": "soam",
          "driver": "parallel",
          "seed": 7,
          "config": { "regions": 64, "max_signals": 150000, "update_threads": 3 }
        },
        { "mesh": "eight", "algorithm": "gng", "driver": "multi", "seed": 9 }
      ]
    }"#;

    #[test]
    fn parses_jobs_with_overrides_and_default_names() {
        let specs = parse_manifest(MANIFEST).unwrap();
        assert_eq!(specs.len(), 2);
        let a = &specs[0];
        assert_eq!(a.name, "blob-soam");
        assert_eq!(a.cfg.shape, BenchmarkShape::Blob);
        assert_eq!(a.cfg.driver, Driver::Parallel);
        assert_eq!(a.cfg.algorithm, Algorithm::Soam);
        assert_eq!(a.cfg.seed, 7);
        assert_eq!(a.cfg.regions, 64);
        assert_eq!(a.cfg.update_threads, 3);
        assert_eq!(a.cfg.limits.max_signals, 150_000);
        let b = &specs[1];
        assert_eq!(b.name, "job1", "missing names default to the index");
        assert_eq!(b.cfg.shape, BenchmarkShape::Eight);
        assert_eq!(b.cfg.algorithm, Algorithm::Gng);
        assert_eq!(b.cfg.driver, Driver::Multi);
        assert_eq!(a.retries, None, "retry budget defaults to the fleet-wide option");
    }

    #[test]
    fn per_job_retry_budget_parses() {
        let text = r#"{"version": 1, "jobs": [
          {"name": "fragile", "retries": 0},
          {"name": "tough", "retries": 5},
          {"name": "default"}
        ]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs[0].retries, Some(0));
        assert_eq!(specs[1].retries, Some(5));
        assert_eq!(specs[2].retries, None);
        let bad = r#"{"version": 1, "jobs": [{"name": "x", "retries": "lots"}]}"#;
        assert!(parse_manifest(bad).is_err(), "non-integer retries rejected");
    }

    #[test]
    fn qos_class_parses_and_defaults_to_batch() {
        let text = r#"{"version": 1, "jobs": [
          {"name": "fg", "qos": "interactive"},
          {"name": "bg", "qos": "batch"},
          {"name": "default"}
        ]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs[0].qos, QosClass::Interactive);
        assert_eq!(specs[0].qos.weight(), QosClass::INTERACTIVE_WEIGHT);
        assert_eq!(specs[1].qos, QosClass::Batch);
        assert_eq!(specs[2].qos, QosClass::Batch, "qos defaults to batch");
        let bad = r#"{"version": 1, "jobs": [{"name": "x", "qos": "vip"}]}"#;
        assert!(parse_manifest(bad).is_err(), "unknown qos class rejected");
        // The dist/serve payload path pins qos through the round-trip.
        let payloads = manifest_job_payloads(text).unwrap();
        assert_eq!(parse_job_payload(&payloads[0].1).unwrap().qos, QosClass::Interactive);
    }

    #[test]
    fn mesh_paths_are_detected_by_extension() {
        let text = r#"{"version": 1, "jobs": [{"name": "scan", "mesh": "clouds/a.off"}]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs[0].mesh_path.as_deref(), Some(Path::new("clouds/a.off")));
        let text = r#"{"version": 1, "jobs": [{"name": "scan", "mesh": "clouds/a.xyz"}]}"#;
        assert!(parse_manifest(text).is_err(), "unknown extension rejected");
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(parse_manifest("not json").is_err());
        assert!(parse_manifest(r#"{"jobs": []}"#).is_err(), "missing version");
        assert!(parse_manifest(r#"{"version": 2, "jobs": [{}]}"#).is_err(), "future version");
        assert!(parse_manifest(r#"{"version": 1, "jobs": []}"#).is_err(), "no jobs");
        assert!(
            parse_manifest(r#"{"version": 1, "jobs": [{"driver": "warp9"}]}"#).is_err(),
            "unknown driver"
        );
        assert!(
            parse_manifest(r#"{"version": 1, "jobs": [{"frobnicate": 1}]}"#).is_err(),
            "unknown job key"
        );
        assert!(
            parse_manifest(
                r#"{"version": 1, "jobs": [{"config": {"nonesuch": 1}}]}"#
            )
            .is_err(),
            "unknown config key"
        );
        assert!(
            parse_manifest(
                r#"{"version": 1, "jobs": [{"name": "a"}, {"name": "a"}]}"#
            )
            .is_err(),
            "duplicate names"
        );
    }

    #[test]
    fn file_stem_sanitizes() {
        let spec = JobSpec::from_config("job/../weird name", RunConfig::default());
        assert_eq!(spec.file_stem(), "job_.._weird_name");
    }

    #[test]
    fn job_payloads_round_trip_and_pin_defaulted_names() {
        let payloads = manifest_job_payloads(MANIFEST).unwrap();
        assert_eq!(payloads.len(), 2);
        assert_eq!(payloads[0].0, "blob-soam");
        assert_eq!(payloads[1].0, "job1", "defaulted name pinned into the payload");
        let originals = parse_manifest(MANIFEST).unwrap();
        for ((name, payload), original) in payloads.iter().zip(&originals) {
            let spec = parse_job_payload(payload)
                .unwrap_or_else(|e| panic!("payload for {name} must re-parse: {e}\n{payload}"));
            assert_eq!(&spec.name, name);
            assert_eq!(spec.cfg.shape, original.cfg.shape);
            assert_eq!(spec.cfg.driver, original.cfg.driver);
            assert_eq!(spec.cfg.algorithm, original.cfg.algorithm);
            assert_eq!(spec.cfg.seed, original.cfg.seed);
            assert_eq!(spec.cfg.regions, original.cfg.regions);
            assert_eq!(spec.cfg.update_threads, original.cfg.update_threads);
            assert_eq!(spec.cfg.limits.max_signals, original.cfg.limits.max_signals);
            assert_eq!(spec.retries, original.retries);
        }
        // A multi-job text is not a valid single-job payload.
        assert!(parse_job_payload(MANIFEST).is_err());
    }
}
