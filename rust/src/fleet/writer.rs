//! Asynchronous checkpoint write-out: one dedicated writer thread takes
//! encoded snapshot bytes off the scheduler's hands so durable I/O
//! (fsync + rotation + rename — see [`super::snapshot::write_durable`])
//! never stalls convergence stepping.
//!
//! The split of labor is deliberate: *encoding* stays on the scheduler
//! thread (it borrows the live session; the bytes are the intergeneration
//! boundary), *writing* moves here. The channel carries owned byte
//! buffers, so the scheduler is free to mutate the session the moment
//! `enqueue` returns — the snapshot is already immutable.
//!
//! Failure model: a write that errors (or panics, e.g. under an injected
//! `checkpoint_write:panic` fault) is reported as a [`WriteOutcome`] on
//! the result channel and the writer thread *keeps running* — a failed
//! checkpoint must cost at most one recovery generation, never the
//! write-out path for every other job. The scheduler polls outcomes each
//! round and surfaces failures as progress lines *and* per-job report
//! notes; [`CheckpointWriter::drain`] blocks until every queued write has
//! landed (called before restore fallbacks and at end of run, so "last
//! good generation" is on disk, not in a queue).
//!
//! The queue is **bounded** ([`CheckpointWriter::with_capacity`]): a
//! writer falling behind (slow disk, fsync storms) makes [`enqueue`]
//! return `false` — the scheduler drops that generation and records a
//! per-job note instead of growing an unbounded backlog of snapshot
//! buffers. Dropping a *periodic* checkpoint is safe by construction: it
//! only widens the resume window back to the previous generation.
//!
//! [`enqueue`]: CheckpointWriter::enqueue

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use super::snapshot::write_durable;

struct WriteRequest {
    job: String,
    path: PathBuf,
    bytes: Vec<u8>,
}

/// Result of one queued checkpoint write, reported back to the scheduler.
#[derive(Debug)]
pub struct WriteOutcome {
    /// Fleet job name the checkpoint belongs to.
    pub job: String,
    /// Final checkpoint path.
    pub path: PathBuf,
    /// `Err` carries the I/O error (or caught panic) message.
    pub result: Result<(), String>,
}

/// Background durable-checkpoint writer (see module docs). Dropping it
/// finishes every queued write, then joins the thread.
pub struct CheckpointWriter {
    tx: Option<Sender<WriteRequest>>,
    outcomes: Receiver<WriteOutcome>,
    handle: Option<JoinHandle<()>>,
    in_flight: usize,
    /// Most writes allowed in flight before [`Self::enqueue`] refuses.
    capacity: usize,
}

/// Default bound on queued-but-unwritten checkpoints. Deep enough that a
/// healthy writer never hits it (a fleet checkpoints one generation per
/// job per cadence), shallow enough that a wedged disk cannot buffer
/// gigabytes of snapshot bytes.
pub const DEFAULT_QUEUE_CAPACITY: usize = 64;

impl CheckpointWriter {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_QUEUE_CAPACITY)
    }

    /// A writer whose queue holds at most `capacity` in-flight writes
    /// (minimum 1).
    pub fn with_capacity(capacity: usize) -> Self {
        let (tx, rx) = channel::<WriteRequest>();
        let (out_tx, out_rx) = channel::<WriteOutcome>();
        let handle = std::thread::Builder::new()
            .name("msgsn-ckpt-writer".to_string())
            .spawn(move || {
                for req in rx {
                    // An injected panic in write_durable must not kill the
                    // writer: convert it to an Err outcome and keep serving
                    // the other jobs' checkpoints.
                    let t0 = std::time::Instant::now();
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        write_durable(&req.path, &req.bytes)
                    }));
                    let result = match result {
                        Ok(Ok(())) => Ok(()),
                        Ok(Err(e)) => Err(e.to_string()),
                        Err(payload) => Err(format!(
                            "checkpoint write panicked: {}",
                            panic_message(&payload)
                        )),
                    };
                    if result.is_ok() {
                        crate::telemetry::observe(
                            crate::telemetry::Histogram::CheckpointWriteNanos,
                            t0.elapsed().as_nanos() as u64,
                        );
                        crate::telemetry::add(
                            crate::telemetry::Counter::CheckpointsWritten,
                            1,
                        );
                    }
                    // The scheduler may already be gone (drop order at end
                    // of run); losing the outcome then is fine.
                    let _ = out_tx.send(WriteOutcome { job: req.job, path: req.path, result });
                }
            })
            .expect("spawn checkpoint writer");
        Self {
            tx: Some(tx),
            outcomes: out_rx,
            handle: Some(handle),
            in_flight: 0,
            capacity: capacity.max(1),
        }
    }

    /// Queue one encoded snapshot for durable write-out. Returns `true`
    /// immediately on acceptance (the outcome arrives via [`Self::poll`] /
    /// [`Self::drain`]); `false` when the bounded queue is full — the
    /// caller drops this generation and should record why.
    #[must_use = "a false return means the checkpoint was dropped"]
    pub fn enqueue(&mut self, job: &str, path: PathBuf, bytes: Vec<u8>) -> bool {
        // `in_flight` counts writes whose outcome has not been collected
        // yet; the scheduler polls every round, so a full queue means the
        // writer genuinely is not keeping up.
        if self.in_flight >= self.capacity {
            return false;
        }
        let req = WriteRequest { job: job.to_string(), path, bytes };
        self.tx
            .as_ref()
            .expect("writer channel open while not dropping")
            .send(req)
            .expect("checkpoint writer thread alive");
        self.in_flight += 1;
        crate::telemetry::set_gauge(
            crate::telemetry::Gauge::WriterQueueDepth,
            self.in_flight as u64,
        );
        true
    }

    /// Collect every outcome that has landed so far, without blocking.
    pub fn poll(&mut self) -> Vec<WriteOutcome> {
        let mut out = Vec::new();
        loop {
            match self.outcomes.try_recv() {
                Ok(o) => {
                    self.in_flight -= 1;
                    out.push(o);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        crate::telemetry::set_gauge(
            crate::telemetry::Gauge::WriterQueueDepth,
            self.in_flight as u64,
        );
        out
    }

    /// Block until every queued write has landed, returning the outcomes.
    /// Called before a restore-from-last-good fallback (the "last good"
    /// generation must be on disk, not in the queue) and at end of run.
    pub fn drain(&mut self) -> Vec<WriteOutcome> {
        let mut out = Vec::new();
        while self.in_flight > 0 {
            match self.outcomes.recv() {
                Ok(o) => {
                    self.in_flight -= 1;
                    out.push(o);
                }
                // Writer gone with requests unanswered: nothing more will
                // arrive (only reachable if the writer thread was killed
                // externally — the catch_unwind keeps panics from doing it).
                Err(_) => break,
            }
        }
        out
    }
}

impl Default for CheckpointWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        // Closing the request channel ends the writer's loop *after* it
        // has served everything already queued — pending checkpoints
        // complete even when the fleet is dropped mid-run.
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Best-effort human-readable panic payload (panics carry `&str` or
/// `String` in practice).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::fault;

    fn scratch(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("msgsn_{}_{}", std::process::id(), name))
    }

    #[test]
    fn writes_land_and_outcomes_report() {
        let mut w = CheckpointWriter::new();
        let p1 = scratch("writer_a.msgsnap");
        let p2 = scratch("writer_b.msgsnap");
        assert!(w.enqueue("a", p1.clone(), vec![1, 2, 3]));
        assert!(w.enqueue("b", p2.clone(), vec![4, 5]));
        let outcomes = w.drain();
        assert_eq!(outcomes.len(), 2);
        assert!(outcomes.iter().all(|o| o.result.is_ok()), "{outcomes:?}");
        assert_eq!(std::fs::read(&p1).unwrap(), vec![1, 2, 3]);
        assert_eq!(std::fs::read(&p2).unwrap(), vec![4, 5]);
        assert!(w.poll().is_empty(), "drain consumed everything");
        for p in [p1, p2] {
            std::fs::remove_file(&p).ok();
            std::fs::remove_file(crate::fleet::snapshot::prev_path(&p)).ok();
        }
    }

    #[test]
    fn drop_completes_queued_writes() {
        let p = scratch("writer_drop.msgsnap");
        std::fs::remove_file(&p).ok();
        let mut w = CheckpointWriter::new();
        assert!(w.enqueue("d", p.clone(), vec![9; 64]));
        drop(w);
        assert_eq!(std::fs::read(&p).unwrap(), vec![9; 64]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_survives_injected_panic_and_reports_it() {
        let _guard = fault::test_lock();
        let p_bad = scratch("writer_panic.msgsnap");
        let p_good = scratch("writer_after.msgsnap");
        let stem = p_bad.file_stem().unwrap().to_str().unwrap();
        fault::install(fault::parse_faults(&format!("checkpoint_write/{stem}:panic")).unwrap());

        let mut w = CheckpointWriter::new();
        assert!(w.enqueue("bad", p_bad.clone(), vec![1]));
        assert!(w.enqueue("good", p_good.clone(), vec![2]));
        let outcomes = w.drain();
        assert_eq!(outcomes.len(), 2, "writer must survive the panic");
        let bad = outcomes.iter().find(|o| o.job == "bad").unwrap();
        let err = bad.result.as_ref().unwrap_err();
        assert!(err.contains("panicked"), "panic surfaced as Err: {err}");
        assert!(outcomes.iter().find(|o| o.job == "good").unwrap().result.is_ok());
        assert_eq!(std::fs::read(&p_good).unwrap(), vec![2]);

        std::fs::remove_file(&p_bad).ok();
        std::fs::remove_file(&p_good).ok();
    }

    #[test]
    fn injected_write_error_is_an_outcome_not_a_crash() {
        let _guard = fault::test_lock();
        let p = scratch("writer_err.msgsnap");
        let stem = p.file_stem().unwrap().to_str().unwrap();
        fault::install(fault::parse_faults(&format!("checkpoint_write/{stem}:err")).unwrap());
        let mut w = CheckpointWriter::new();
        assert!(w.enqueue("e", p.clone(), vec![7]));
        let outcomes = w.drain();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].result.as_ref().unwrap_err().contains("injected"));
        assert!(!p.exists(), "err action writes nothing");
    }

    #[test]
    fn full_queue_refuses_instead_of_buffering() {
        // Capacity 1, and the one slot is stuck: a `delay`-free way to
        // wedge the writer is a panic fault that still takes the slot
        // until drained. Simpler: enqueue 1 with capacity 1, don't poll,
        // and observe the second enqueue refused regardless of whether
        // the first already landed.
        let p1 = scratch("writer_cap_a.msgsnap");
        let p2 = scratch("writer_cap_b.msgsnap");
        let mut w = CheckpointWriter::with_capacity(1);
        assert!(w.enqueue("a", p1.clone(), vec![1]));
        assert!(!w.enqueue("b", p2.clone(), vec![2]), "queue bounded at 1");
        let outcomes = w.drain();
        assert_eq!(outcomes.len(), 1, "the refused write never entered the queue");
        // With the outcome collected, capacity frees up again.
        assert!(w.enqueue("b", p2.clone(), vec![2]));
        assert_eq!(w.drain().len(), 1);
        for p in [p1, p2] {
            std::fs::remove_file(&p).ok();
            std::fs::remove_file(crate::fleet::snapshot::prev_path(&p)).ok();
        }
    }
}
