//! Command-line interface for the `msgsn` binary (hand-rolled — the
//! vendored crate set has no `clap`).
//!
//! ```text
//! msgsn run        --mesh eight --driver multi [--seed N] [--set k=v]…
//! msgsn fleet      --jobs jobs.json [--checkpoint-every N] [--resume]
//! msgsn serve      --listen 127.0.0.1:7081 [--jobs jobs.json] [--checkpoint-secs S]
//! msgsn coordinator --jobs jobs.json --listen 127.0.0.1:7070 --workers 2
//! msgsn worker     --connect 127.0.0.1:7070 --name w1
//! msgsn reproduce  [--table N]… [--figure N]… [--all] [--scale quick|paper]
//! msgsn mesh       --shape hand [--resolution N] [--out hand.obj]
//! msgsn artifacts  [--dir artifacts] [--warmup-n 4096]
//! msgsn help
//! ```

mod parser;

pub use parser::{ArgError, Parsed};

use std::fmt;

/// A parsed `msgsn` invocation.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// One reconstruction run, printing the paper-style report table.
    Run(Parsed),
    /// N concurrent reconstructions from a jobs manifest, with resumable
    /// checkpointing (the fleet subsystem).
    Fleet(Parsed),
    /// The fleet as a long-running daemon: line-JSON protocol over TCP
    /// (submit/status/watch/query/cancel/shutdown).
    Serve(Parsed),
    /// Regenerate paper tables/figures.
    Reproduce(Parsed),
    /// Generate / inspect benchmark meshes.
    Mesh(Parsed),
    /// Inspect / warm the AOT artifact registry.
    Artifacts(Parsed),
    /// Ablation studies of the multi-signal design choices.
    Ablate(Parsed),
    /// Distributed fleet: the coordinator process (owns the manifest,
    /// routes jobs to workers, migrates them on worker death).
    Coordinator(Parsed),
    /// Distributed fleet: one worker process (runs a fleet driven by the
    /// coordinator's assignments).
    Worker(Parsed),
    Help,
}

/// Usage text (also the `help` command output).
pub const USAGE: &str = "\
msgsn — multi-signal growing self-organizing networks (paper reproduction)

USAGE:
  msgsn run [OPTIONS]            one reconstruction run, report to stdout
      --mesh <blob|eight|hand|heptoroid>   benchmark cloud     [blob]
      --driver <single|indexed|multi|pipelined|parallel>       [single]
                                 (pjrt is quarantined: not wired to the
                                 unified executor — programmatic use only)
      --algorithm <soam|gwr|gng>                               [soam]
      --seed <N>                                               [42]
      --config <file.toml>       load config file
      --set <key=value>          override any config key (repeatable;
                                 e.g. queue_depth=4, update_threads=8,
                                 find_threads=8 — 0 = auto-detect;
                                 update_threads drives the pooled Update
                                 split of parallel AND pipelined;
                                 regions=R partitions the volume into R
                                 spatial regions for the region-sharded
                                 Find Winners + Update schedule of the
                                 multi/pipelined/parallel drivers — 1
                                 disables; results are bit-identical for
                                 any R;
                                 fw_isa=auto|fallback|avx2|avx512|neon
                                 forces the SIMD Find-Winners tier —
                                 bit-identical on every tier, env
                                 MSGSN_FW_ISA is the auto-mode hint)
      --max-signals <N>          safety cap
      --trace                    record trace points
      --save-mesh <out.obj>      write the reconstructed network mesh
      --quiet                    suppress the report table

  msgsn fleet [OPTIONS]          N concurrent reconstructions, one process
      --jobs <jobs.json>         jobs manifest (required; see README for
                                 the schema: per-job mesh/algorithm/driver/
                                 seed/retries plus any config key)
      --checkpoint-every <N>     snapshot each job every N scheduler turns
                                 (bit-exact resume; 0 = off)    [0]
      --checkpoint-secs <S>      also snapshot a job when S wall-clock
                                 seconds passed since its last checkpoint
                                 (fractional ok; composes with the turn
                                 cadence)
      --checkpoint-dir <dir>     where *.msgsnap checkpoints (and their
                                 retained *.msgsnap.prev generations) live
                                                               [checkpoints]
      --resume                   resume jobs from their checkpoints; a torn
                                 or corrupt latest falls back per job to
                                 the previous generation
      --stride <N>               batches per job per round-robin turn  [1]
      --max-retries <N>          restore-from-last-good retries before a
                                 crashed job is quarantined (per-job
                                 \"retries\" manifest key overrides)  [2]
      --faults <spec,...>        arm deterministic fault injection (testing;
                                 same grammar as env MSGSN_FAULTS, e.g.
                                 checkpoint_write:truncate@2,job:panic@turn=7)
      --report-json <path>       also write the final report as JSON
                                 (rows + outcome + exit_code; embeds a
                                 \"telemetry\" object when telemetry is on)
      --metrics-json <path>      write the telemetry registry (counters,
                                 gauges, histograms + trace tail) as JSON
                                 at exit; implies telemetry on
      --trace-file <path>        write the structured event trace as JSONL
                                 at exit; implies telemetry on
      --quiet                    suppress progress lines
      env MSGSN_TELEMETRY=1 enables the instrument registry without
      writing files (bit-identical results either way)
      exit code: 0 all jobs succeeded, 2 some quarantined, 3 all
      quarantined (1 = usage/config errors)

  msgsn serve [OPTIONS]          the fleet as a long-running TCP daemon
      --listen <host:port>       accept client connections here
                                                               [127.0.0.1:7081]
      --jobs <jobs.json>         preload a jobs manifest (optional — an
                                 empty daemon waits for submits)
      --checkpoint-every <N>     as in msgsn fleet              [0]
      --checkpoint-secs <S>      as in msgsn fleet
      --checkpoint-dir <dir>     as in msgsn fleet              [checkpoints]
      --resume                   restore preloaded jobs from checkpoints
      --stride <N>               batches per job per round      [1]
      --max-retries <N>          as in msgsn fleet              [2]
      --watch-every <N>          progress event cadence (rounds) [8]
      --report-json <path>       write the final report as JSON on drain
      --metrics-json <path>      write the telemetry registry as JSON on
                                 drain; implies telemetry on
      --trace-file <path>        write the event trace as JSONL on drain;
                                 implies telemetry on
      --faults <spec,...>        arm fault injection (adds serve_conn:
                                 drop|err|delay=N|dup on client
                                 connections, scope c<id>)
      --quiet                    suppress progress lines
      protocol: line-delimited JSON — {\"cmd\": \"submit\", \"job\": {…}} |
      status | watch | query (units|mesh|snapshot) | cancel | metrics |
      shutdown; the metrics verb answers from the telemetry registry
      only (never touches a session — polls cannot perturb convergence);
      runs until a shutdown request drains the fleet, then exits with
      the fleet exit code (0/2/3; 1 = usage/config errors)

  msgsn coordinator [OPTIONS]    distributed fleet: the coordinator process
      --jobs <jobs.json>         jobs manifest (required; same schema as
                                 msgsn fleet)
      --listen <host:port>       accept worker TCP connections here
                                                               [127.0.0.1:7070]
      --workers <N>              wait for N workers before scheduling  [1]
      --heartbeat-timeout <S>    evict a worker silent for S seconds
                                 (fractional ok)               [5]
      --max-retries <N>          cross-worker crash retries before a job
                                 is quarantined                [2]
      --trace-file <path>        write the event trace (admits, failures,
                                 migrations, evictions, checkpoint
                                 promotions) as JSONL at exit; implies
                                 telemetry on
      --quiet                    suppress progress lines
      exit code: 0 all jobs done, 2 some quarantined, 3 all quarantined,
      4 every worker died/hung with jobs outstanding (1 = usage/config)

  msgsn worker [OPTIONS]         distributed fleet: one worker process
      --connect <host:port>      coordinator address            [127.0.0.1:7070]
      --name <id>                worker identity (heartbeats + fault
                                 scope worker/<id>:...)         [w<pid>]
      --stride <N>               batches per job per round      [1]
      --checkpoint-rounds <N>    ship a migration snapshot of every
                                 running job each N rounds (0 = finals
                                 only)                          [8]
      --trace-file <path>        write the event trace as JSONL at exit;
                                 implies telemetry on
      --quiet                    suppress progress lines
      exits when the coordinator sends shutdown (0) or the link dies (1)

  msgsn reproduce [OPTIONS]      regenerate the paper's evaluation
      --table <1|2|3|4>          one table (repeatable)
      --figure <2|7|8|9|10>      one figure (repeatable)
      --all                      every table and figure
      --paper-only               only the paper's four driver columns
      --scale <smoke|quick|paper>  workload scale              [quick]
      --out <dir>                results directory             [results]
      --seed <N>                                               [42]
      --set <key=value>          override config keys (repeatable)

  msgsn mesh [OPTIONS]           benchmark-mesh utilities
      --shape <name>             which shape                   [blob]
      --resolution <N>           marching grid (0 = default)   [0]
      --out <file.obj|.off>      write the mesh
      (always prints V/E/F, Euler characteristic, genus, area)

  msgsn artifacts [OPTIONS]      AOT artifact registry
      --dir <path>               artifact directory            [artifacts]
      --flavor <pallas|scan>     flavor to inspect/warm
      --warmup-n <N>             pre-compile buckets up to n=N

  msgsn ablate [OPTIONS]         ablation studies (DESIGN.md section 6)
      --which <locks|schedule|cell|executor|all>               [all]
      --max-signals <N>          per-run cap                   [400000]
      --seed <N>                                               [42]

  msgsn help                     this text
";

/// Top-level parse of `argv[1..]`.
pub fn parse(args: &[String]) -> Result<Command, ArgError> {
    let Some((cmd, rest)) = args.split_first() else {
        return Ok(Command::Help);
    };
    match cmd.as_str() {
        "run" => Ok(Command::Run(parser::parse_flags(
            rest,
            &[
                "mesh", "driver", "algorithm", "seed", "config", "set",
                "max-signals", "save-mesh",
            ],
            &["trace", "quiet"],
        )?)),
        "fleet" => Ok(Command::Fleet(parser::parse_flags(
            rest,
            &[
                "jobs",
                "checkpoint-every",
                "checkpoint-secs",
                "checkpoint-dir",
                "stride",
                "max-retries",
                "faults",
                "report-json",
                "metrics-json",
                "trace-file",
            ],
            &["resume", "quiet"],
        )?)),
        "serve" => Ok(Command::Serve(parser::parse_flags(
            rest,
            &[
                "listen",
                "jobs",
                "checkpoint-every",
                "checkpoint-secs",
                "checkpoint-dir",
                "stride",
                "max-retries",
                "watch-every",
                "faults",
                "report-json",
                "metrics-json",
                "trace-file",
            ],
            &["resume", "quiet"],
        )?)),
        "reproduce" => Ok(Command::Reproduce(parser::parse_flags(
            rest,
            &["table", "figure", "scale", "out", "seed", "set"],
            &["all", "paper-only"],
        )?)),
        "mesh" => Ok(Command::Mesh(parser::parse_flags(
            rest,
            &["shape", "resolution", "out"],
            &[],
        )?)),
        "artifacts" => Ok(Command::Artifacts(parser::parse_flags(
            rest,
            &["dir", "flavor", "warmup-n"],
            &[],
        )?)),
        "ablate" => Ok(Command::Ablate(parser::parse_flags(
            rest,
            &["which", "max-signals", "seed"],
            &[],
        )?)),
        "coordinator" => Ok(Command::Coordinator(parser::parse_flags(
            rest,
            &[
                "jobs",
                "listen",
                "workers",
                "heartbeat-timeout",
                "max-retries",
                "trace-file",
            ],
            &["quiet"],
        )?)),
        "worker" => Ok(Command::Worker(parser::parse_flags(
            rest,
            &["connect", "name", "stride", "checkpoint-rounds", "trace-file"],
            &["quiet"],
        )?)),
        "help" | "--help" | "-h" => Ok(Command::Help),
        other => Err(ArgError::UnknownCommand(other.to_string())),
    }
}

impl fmt::Display for Command {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Command::Run(_) => write!(f, "run"),
            Command::Fleet(_) => write!(f, "fleet"),
            Command::Serve(_) => write!(f, "serve"),
            Command::Reproduce(_) => write!(f, "reproduce"),
            Command::Mesh(_) => write!(f, "mesh"),
            Command::Artifacts(_) => write!(f, "artifacts"),
            Command::Ablate(_) => write!(f, "ablate"),
            Command::Coordinator(_) => write!(f, "coordinator"),
            Command::Worker(_) => write!(f, "worker"),
            Command::Help => write!(f, "help"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_run_command() {
        let cmd = parse(&argv("run --mesh eight --driver multi --seed 7")).unwrap();
        let Command::Run(p) = cmd else { panic!("not run") };
        assert_eq!(p.get("mesh"), Some("eight"));
        assert_eq!(p.get("driver"), Some("multi"));
        assert_eq!(p.get("seed"), Some("7"));
    }

    #[test]
    fn repeatable_set_flags() {
        let Command::Run(p) = parse(&argv("run --set a=1 --set b=2")).unwrap() else {
            panic!()
        };
        assert_eq!(p.get_all("set"), vec!["a=1", "b=2"]);
    }

    #[test]
    fn parses_fleet_command() {
        let cmd = parse(&argv(
            "fleet --jobs jobs.json --checkpoint-every 64 --checkpoint-dir ck --resume",
        ))
        .unwrap();
        let Command::Fleet(p) = cmd else { panic!("not fleet") };
        assert_eq!(p.get("jobs"), Some("jobs.json"));
        assert_eq!(p.get("checkpoint-every"), Some("64"));
        assert_eq!(p.get("checkpoint-dir"), Some("ck"));
        assert!(p.flag("resume"));
        assert!(!p.flag("quiet"));
    }

    #[test]
    fn parses_fleet_durability_flags() {
        let cmd = parse(&argv(
            "fleet --jobs j.json --checkpoint-secs 2.5 --max-retries 4 \
             --faults checkpoint_write:truncate@2,job:panic@turn=7",
        ))
        .unwrap();
        let Command::Fleet(p) = cmd else { panic!("not fleet") };
        assert_eq!(p.get("checkpoint-secs"), Some("2.5"));
        assert_eq!(p.get("max-retries"), Some("4"));
        assert_eq!(
            p.get("faults"),
            Some("checkpoint_write:truncate@2,job:panic@turn=7")
        );
    }

    #[test]
    fn parses_serve_command() {
        let cmd = parse(&argv(
            "serve --listen 127.0.0.1:7081 --jobs jobs.json --checkpoint-secs 1 \
             --watch-every 4 --report-json report.json --quiet",
        ))
        .unwrap();
        let Command::Serve(p) = cmd else { panic!("not serve") };
        assert_eq!(p.get("listen"), Some("127.0.0.1:7081"));
        assert_eq!(p.get("jobs"), Some("jobs.json"));
        assert_eq!(p.get("checkpoint-secs"), Some("1"));
        assert_eq!(p.get("watch-every"), Some("4"));
        assert_eq!(p.get("report-json"), Some("report.json"));
        assert!(p.flag("quiet"));
    }

    #[test]
    fn parses_fleet_report_json_flag() {
        let Command::Fleet(p) =
            parse(&argv("fleet --jobs j.json --report-json out.json")).unwrap()
        else {
            panic!("not fleet")
        };
        assert_eq!(p.get("report-json"), Some("out.json"));
    }

    #[test]
    fn parses_telemetry_flags_on_every_verb_that_has_them() {
        let Command::Fleet(p) = parse(&argv(
            "fleet --jobs j.json --metrics-json m.json --trace-file t.jsonl",
        ))
        .unwrap() else {
            panic!("not fleet")
        };
        assert_eq!(p.get("metrics-json"), Some("m.json"));
        assert_eq!(p.get("trace-file"), Some("t.jsonl"));

        let Command::Serve(p) = parse(&argv(
            "serve --metrics-json m.json --trace-file t.jsonl",
        ))
        .unwrap() else {
            panic!("not serve")
        };
        assert_eq!(p.get("metrics-json"), Some("m.json"));
        assert_eq!(p.get("trace-file"), Some("t.jsonl"));

        let Command::Coordinator(p) =
            parse(&argv("coordinator --jobs j.json --trace-file t.jsonl")).unwrap()
        else {
            panic!("not coordinator")
        };
        assert_eq!(p.get("trace-file"), Some("t.jsonl"));

        let Command::Worker(p) = parse(&argv("worker --trace-file t.jsonl")).unwrap() else {
            panic!("not worker")
        };
        assert_eq!(p.get("trace-file"), Some("t.jsonl"));

        // run/mesh/etc. deliberately do not take them.
        assert!(matches!(
            parse(&argv("run --metrics-json m.json")),
            Err(ArgError::UnknownFlag(_))
        ));
    }

    #[test]
    fn parses_coordinator_command() {
        let cmd = parse(&argv(
            "coordinator --jobs jobs.json --listen 127.0.0.1:7171 --workers 2 \
             --heartbeat-timeout 0.5 --max-retries 1",
        ))
        .unwrap();
        let Command::Coordinator(p) = cmd else { panic!("not coordinator") };
        assert_eq!(p.get("jobs"), Some("jobs.json"));
        assert_eq!(p.get("listen"), Some("127.0.0.1:7171"));
        assert_eq!(p.get("workers"), Some("2"));
        assert_eq!(p.get("heartbeat-timeout"), Some("0.5"));
        assert_eq!(p.get("max-retries"), Some("1"));
    }

    #[test]
    fn parses_worker_command() {
        let cmd = parse(&argv(
            "worker --connect 127.0.0.1:7171 --name w1 --stride 2 --checkpoint-rounds 4 --quiet",
        ))
        .unwrap();
        let Command::Worker(p) = cmd else { panic!("not worker") };
        assert_eq!(p.get("connect"), Some("127.0.0.1:7171"));
        assert_eq!(p.get("name"), Some("w1"));
        assert_eq!(p.get("stride"), Some("2"));
        assert_eq!(p.get("checkpoint-rounds"), Some("4"));
        assert!(p.flag("quiet"));
    }

    #[test]
    fn boolean_flags() {
        let Command::Run(p) = parse(&argv("run --trace")).unwrap() else { panic!() };
        assert!(p.flag("trace"));
        assert!(!p.flag("quiet"));
    }

    #[test]
    fn reproduce_tables_and_figures() {
        let Command::Reproduce(p) =
            parse(&argv("reproduce --table 1 --table 4 --figure 9")).unwrap()
        else {
            panic!()
        };
        assert_eq!(p.get_all("table"), vec!["1", "4"]);
        assert_eq!(p.get_all("figure"), vec!["9"]);
    }

    #[test]
    fn rejects_unknown() {
        assert!(matches!(
            parse(&argv("frobnicate")),
            Err(ArgError::UnknownCommand(_))
        ));
        assert!(matches!(
            parse(&argv("run --bogus 1")),
            Err(ArgError::UnknownFlag(_))
        ));
        assert!(matches!(
            parse(&argv("run --mesh")),
            Err(ArgError::MissingValue(_))
        ));
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(parse(&[]).unwrap(), Command::Help);
        assert_eq!(parse(&argv("--help")).unwrap(), Command::Help);
    }
}
