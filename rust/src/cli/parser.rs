//! Flag parser: `--name value` / `--name` (boolean) / repeatable flags.

use std::fmt;

/// Argument errors.
#[derive(Clone, Debug, PartialEq)]
pub enum ArgError {
    UnknownCommand(String),
    UnknownFlag(String),
    MissingValue(String),
    /// `(flag, value, expected)`
    BadValue(String, String, &'static str),
}

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArgError::UnknownCommand(c) => {
                write!(f, "unknown command {c:?} (try `msgsn help`)")
            }
            ArgError::UnknownFlag(x) => write!(f, "unknown flag --{x}"),
            ArgError::MissingValue(x) => write!(f, "flag --{x} needs a value"),
            ArgError::BadValue(flag, v, want) => {
                write!(f, "--{flag} {v:?}: expected {want}")
            }
        }
    }
}

impl std::error::Error for ArgError {}

/// Parsed flags of one subcommand.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Parsed {
    /// `(flag, value)` in argv order; repeatable flags appear repeatedly.
    values: Vec<(String, String)>,
    flags: Vec<String>,
}

impl Parsed {
    /// Last value of a flag (CLI convention: later overrides earlier).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// All values of a repeatable flag, in order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    /// Boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Typed accessor with a default.
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
        expected: &'static str,
    ) -> Result<T, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::BadValue(name.into(), v.into(), expected)),
        }
    }
}

/// Parse `args` given the allowed value-flags and boolean-flags.
pub fn parse_flags(
    args: &[String],
    value_flags: &[&str],
    bool_flags: &[&str],
) -> Result<Parsed, ArgError> {
    let mut out = Parsed::default();
    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        let name = arg
            .strip_prefix("--")
            .ok_or_else(|| ArgError::UnknownFlag(arg.clone()))?;
        // `--name=value` form.
        if let Some((n, v)) = name.split_once('=') {
            if value_flags.contains(&n) {
                out.values.push((n.to_string(), v.to_string()));
                continue;
            }
            return Err(ArgError::UnknownFlag(n.to_string()));
        }
        if bool_flags.contains(&name) {
            out.flags.push(name.to_string());
        } else if value_flags.contains(&name) {
            let v = it
                .next()
                .ok_or_else(|| ArgError::MissingValue(name.to_string()))?;
            out.values.push((name.to_string(), v.clone()));
        } else {
            return Err(ArgError::UnknownFlag(name.to_string()));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn equals_form() {
        let p = parse_flags(&argv("--seed=9 --mesh=hand"), &["seed", "mesh"], &[]).unwrap();
        assert_eq!(p.get("seed"), Some("9"));
        assert_eq!(p.get("mesh"), Some("hand"));
    }

    #[test]
    fn later_value_wins() {
        let p = parse_flags(&argv("--seed 1 --seed 2"), &["seed"], &[]).unwrap();
        assert_eq!(p.get("seed"), Some("2"));
        assert_eq!(p.get_all("seed"), vec!["1", "2"]);
    }

    #[test]
    fn typed_accessor() {
        let p = parse_flags(&argv("--seed 11"), &["seed"], &[]).unwrap();
        assert_eq!(p.get_parsed("seed", 0u64, "integer").unwrap(), 11);
        assert_eq!(p.get_parsed("missing", 5u32, "integer").unwrap(), 5);
        let bad = parse_flags(&argv("--seed x"), &["seed"], &[]).unwrap();
        assert!(bad.get_parsed("seed", 0u64, "integer").is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(parse_flags(&argv("oops"), &[], &[]).is_err());
    }

    #[test]
    fn display_messages() {
        assert_eq!(
            ArgError::MissingValue("x".into()).to_string(),
            "flag --x needs a value"
        );
    }
}
