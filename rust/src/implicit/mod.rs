//! Implicit scalar fields and CSG — the source geometry substrate.
//!
//! The paper evaluates on four benchmark meshes (Stanford Bunny, Eight,
//! Skeleton Hand, Heptoroid) that are not redistributable in this offline
//! image. Per the substitution rule (DESIGN.md §3) we rebuild the *relevant
//! properties* — genus and local-feature-size profile — as procedural
//! implicit surfaces, polygonized by [`crate::marching`]:
//!
//! | paper mesh | proxy ([`shapes`]) | genus | LFS profile |
//! |---|---|---|---|
//! | Stanford Bunny | `blob` (union of 4 spheres) | 0 | moderate variation |
//! | Eight | `eight` (two merged tori) | 2 | nearly constant |
//! | Skeleton Hand | `hand` (palm + 5 finger loops) | 5 | wide variation, thin features |
//! | Heptoroid | `heptoroid` (plate with 22 holes) | 22 | low & variable |
//!
//! Convention: field value `< 0` inside, `> 0` outside; the surface is the
//! zero level set. Values need not be exact distances — only the sign and
//! continuity matter to the polygonizer.

mod field;
pub mod shapes;

pub use field::{
    Cylinder, Difference, Field, Intersection, RoundedBox, Sphere, Torus, Union,
};
