//! Field trait, signed-distance primitives and hard CSG operators.
//!
//! Hard (`min`/`max`) CSG is used instead of smooth blending on purpose:
//! blending radii can silently change topology (fill a hole, fuse two
//! handles), and the benchmark shapes pin their genus with tests.

use crate::geometry::Vec3;

/// A scalar field over R³; negative inside, positive outside.
pub trait Field: Send + Sync {
    /// Field value at `p`.
    fn eval(&self, p: Vec3) -> f32;

    /// Central-difference gradient (used by the polygonizer to orient
    /// output triangles outward).
    fn gradient(&self, p: Vec3, h: f32) -> Vec3 {
        let dx = self.eval(p + Vec3::new(h, 0.0, 0.0)) - self.eval(p - Vec3::new(h, 0.0, 0.0));
        let dy = self.eval(p + Vec3::new(0.0, h, 0.0)) - self.eval(p - Vec3::new(0.0, h, 0.0));
        let dz = self.eval(p + Vec3::new(0.0, 0.0, h)) - self.eval(p - Vec3::new(0.0, 0.0, h));
        Vec3::new(dx, dy, dz)
    }
}

impl<F: Fn(Vec3) -> f32 + Send + Sync> Field for F {
    fn eval(&self, p: Vec3) -> f32 {
        self(p)
    }
}

/// Sphere of radius `r` centered at `c` (exact SDF).
#[derive(Clone, Copy, Debug)]
pub struct Sphere {
    pub center: Vec3,
    pub radius: f32,
}

impl Sphere {
    pub fn new(center: Vec3, radius: f32) -> Self {
        Self { center, radius }
    }
}

impl Field for Sphere {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        (p - self.center).norm() - self.radius
    }
}

/// Torus with arbitrary center and (unit) axis; major radius `major`,
/// tube radius `minor` (exact SDF).
#[derive(Clone, Copy, Debug)]
pub struct Torus {
    pub center: Vec3,
    pub axis: Vec3,
    pub major: f32,
    pub minor: f32,
}

impl Torus {
    pub fn new(center: Vec3, axis: Vec3, major: f32, minor: f32) -> Self {
        let axis = axis.normalized().expect("torus axis must be nonzero");
        Self { center, axis, major, minor }
    }
}

impl Field for Torus {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        let q = p - self.center;
        let z = q.dot(self.axis);
        let radial = (q - self.axis * z).norm();
        let dr = radial - self.major;
        (dr * dr + z * z).sqrt() - self.minor
    }
}

/// Infinite cylinder of radius `radius` around the line `center + t·axis`.
/// Used subtractively to punch through-holes (heptoroid plate).
#[derive(Clone, Copy, Debug)]
pub struct Cylinder {
    pub center: Vec3,
    pub axis: Vec3,
    pub radius: f32,
}

impl Cylinder {
    pub fn new(center: Vec3, axis: Vec3, radius: f32) -> Self {
        let axis = axis.normalized().expect("cylinder axis must be nonzero");
        Self { center, axis, radius }
    }
}

impl Field for Cylinder {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        let q = p - self.center;
        let z = q.dot(self.axis);
        (q - self.axis * z).norm() - self.radius
    }
}

/// Axis-aligned box with rounded edges: half-extents `half`, corner radius
/// `round` (exact SDF).
#[derive(Clone, Copy, Debug)]
pub struct RoundedBox {
    pub center: Vec3,
    pub half: Vec3,
    pub round: f32,
}

impl RoundedBox {
    pub fn new(center: Vec3, half: Vec3, round: f32) -> Self {
        Self { center, half, round }
    }
}

impl Field for RoundedBox {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        let q = p - self.center;
        let d = Vec3::new(q.x.abs(), q.y.abs(), q.z.abs()) - self.half
            + Vec3::splat(self.round);
        let outside = Vec3::new(d.x.max(0.0), d.y.max(0.0), d.z.max(0.0)).norm();
        let inside = d.x.max(d.y).max(d.z).min(0.0);
        outside + inside - self.round
    }
}

/// CSG union: `min` of the children.
pub struct Union {
    pub children: Vec<Box<dyn Field>>,
}

impl Union {
    pub fn new(children: Vec<Box<dyn Field>>) -> Self {
        assert!(!children.is_empty(), "empty union");
        Self { children }
    }
}

impl Field for Union {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        self.children
            .iter()
            .map(|c| c.eval(p))
            .fold(f32::INFINITY, f32::min)
    }
}

/// CSG intersection: `max` of the children.
pub struct Intersection {
    pub children: Vec<Box<dyn Field>>,
}

impl Intersection {
    pub fn new(children: Vec<Box<dyn Field>>) -> Self {
        assert!(!children.is_empty(), "empty intersection");
        Self { children }
    }
}

impl Field for Intersection {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        self.children
            .iter()
            .map(|c| c.eval(p))
            .fold(f32::NEG_INFINITY, f32::max)
    }
}

/// CSG difference `base \ cut₁ \ cut₂ …` : `max(base, -cutᵢ)`.
pub struct Difference {
    pub base: Box<dyn Field>,
    pub cuts: Vec<Box<dyn Field>>,
}

impl Difference {
    pub fn new(base: Box<dyn Field>, cuts: Vec<Box<dyn Field>>) -> Self {
        Self { base, cuts }
    }
}

impl Field for Difference {
    #[inline]
    fn eval(&self, p: Vec3) -> f32 {
        let mut v = self.base.eval(p);
        for c in &self.cuts {
            v = v.max(-c.eval(p));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sphere_sign_convention() {
        let s = Sphere::new(Vec3::ZERO, 1.0);
        assert!(s.eval(Vec3::ZERO) < 0.0);
        assert!(s.eval(Vec3::new(2.0, 0.0, 0.0)) > 0.0);
        assert!(s.eval(Vec3::new(1.0, 0.0, 0.0)).abs() < 1e-6);
    }

    #[test]
    fn torus_ring_points() {
        let t = Torus::new(Vec3::ZERO, Vec3::new(0.0, 0.0, 1.0), 1.0, 0.25);
        // On the ring circle: deepest inside.
        assert!((t.eval(Vec3::new(1.0, 0.0, 0.0)) + 0.25).abs() < 1e-6);
        // Center of the hole: outside.
        assert!(t.eval(Vec3::ZERO) > 0.0);
        // On the tube surface.
        assert!(t.eval(Vec3::new(1.25, 0.0, 0.0)).abs() < 1e-6);
    }

    #[test]
    fn torus_arbitrary_axis_is_rotation_invariant() {
        let a = Torus::new(Vec3::ZERO, Vec3::new(0.0, 0.0, 1.0), 1.0, 0.2);
        let b = Torus::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0), 1.0, 0.2);
        // Swap x/z between the two evaluations.
        let p = Vec3::new(0.3, 0.8, 0.1);
        let q = Vec3::new(0.1, 0.8, 0.3);
        assert!((a.eval(p) - b.eval(q)).abs() < 1e-6);
    }

    #[test]
    fn cylinder_axis_independence() {
        let c = Cylinder::new(Vec3::ZERO, Vec3::new(0.0, 0.0, 1.0), 0.5);
        assert_eq!(c.eval(Vec3::new(0.0, 0.0, -37.0)), c.eval(Vec3::ZERO));
        assert!(c.eval(Vec3::new(1.0, 0.0, 5.0)) > 0.0);
    }

    #[test]
    fn rounded_box_inside_outside() {
        let b = RoundedBox::new(Vec3::ZERO, Vec3::new(1.0, 0.5, 0.25), 0.05);
        assert!(b.eval(Vec3::ZERO) < 0.0);
        assert!(b.eval(Vec3::new(1.2, 0.0, 0.0)) > 0.0);
        assert!(b.eval(Vec3::new(0.0, 0.0, 0.26)) > 0.0);
    }

    #[test]
    fn csg_difference_punches_hole() {
        let plate = RoundedBox::new(Vec3::ZERO, Vec3::new(1.0, 1.0, 0.2), 0.02);
        let hole = Cylinder::new(Vec3::ZERO, Vec3::new(0.0, 0.0, 1.0), 0.3);
        let d = Difference::new(Box::new(plate), vec![Box::new(hole)]);
        assert!(d.eval(Vec3::ZERO) > 0.0, "inside the hole is outside the solid");
        assert!(d.eval(Vec3::new(0.6, 0.0, 0.0)) < 0.0, "plate material remains");
    }

    #[test]
    fn gradient_points_outward() {
        let s = Sphere::new(Vec3::ZERO, 1.0);
        let g = s.gradient(Vec3::new(0.9, 0.0, 0.0), 1e-3);
        assert!(g.x > 0.0);
        assert!(g.normalized().unwrap().x > 0.99);
    }

    #[test]
    fn closure_as_field() {
        let f = |p: Vec3| p.norm() - 2.0;
        assert!(Field::eval(&f, Vec3::ZERO) < 0.0);
    }
}
