//! The four benchmark shapes (proxies for the paper's test meshes).
//!
//! Each builder returns the implicit field, the polygonization bounds and
//! the genus the reconstruction must reproduce (pinned by tests through the
//! Euler characteristic of the marched mesh — `V − E + F = 2 − 2g`).

use crate::geometry::{Aabb, Vec3};

use super::{Cylinder, Difference, Field, RoundedBox, Sphere, Torus, Union};

/// A benchmark shape: field + meshing bounds + expected topology.
pub struct Shape {
    pub field: Box<dyn Field>,
    pub bounds: Aabb,
    pub genus: u32,
    pub name: &'static str,
    /// Default marching-grid resolution that resolves the thinnest feature.
    pub default_resolution: u32,
}

/// Bunny proxy: a blobby union of four spheres — genus 0 with non-trivial
/// curvature (and hence LFS) variation, like the original's ears/body ratio.
pub fn blob() -> Shape {
    let field = Union::new(vec![
        Box::new(Sphere::new(Vec3::new(0.0, 0.0, 0.0), 0.42)),
        Box::new(Sphere::new(Vec3::new(0.34, 0.22, 0.05), 0.26)),
        Box::new(Sphere::new(Vec3::new(-0.28, 0.26, 0.12), 0.17)),
        Box::new(Sphere::new(Vec3::new(0.02, -0.38, 0.18), 0.13)),
    ]);
    Shape {
        field: Box::new(field),
        bounds: Aabb::new(Vec3::splat(-0.8), Vec3::splat(0.8)),
        genus: 0,
        name: "blob",
        default_resolution: 64,
    }
}

/// Eight / double-torus proxy: two tori merged side-by-side — genus 2 with
/// nearly constant LFS (tube radius everywhere).
pub fn eight() -> Shape {
    let field = Union::new(vec![
        Box::new(Torus::new(
            Vec3::new(-0.27, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            0.22,
            0.09,
        )),
        Box::new(Torus::new(
            Vec3::new(0.27, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            0.22,
            0.09,
        )),
    ]);
    Shape {
        field: Box::new(field),
        bounds: Aabb::new(Vec3::new(-0.7, -0.45, -0.25), Vec3::new(0.7, 0.45, 0.25)),
        genus: 2,
        name: "eight",
        default_resolution: 72,
    }
}

/// Skeleton-hand proxy: a palm sphere with five thin finger *loops* — genus
/// 5 with widely varying LFS (the thin loops mimic the wrist/finger regions
/// the paper calls out as "considerably low" LFS).
pub fn hand() -> Shape {
    let palm = Sphere::new(Vec3::ZERO, 0.42);
    let mut children: Vec<Box<dyn Field>> = vec![Box::new(palm)];
    // Five loops fanned over the upper hemisphere. Each torus sits with its
    // center on the palm surface and its ring plane containing the radial
    // direction, so part of the ring is inside the palm and the rest arcs
    // outside: union ⇒ one handle each.
    let fingers = 5;
    for i in 0..fingers {
        let phi = (i as f32 / (fingers - 1) as f32 - 0.5) * 1.9; // fan angle
        let radial = Vec3::new(phi.sin(), phi.cos(), 0.15 * (i as f32 - 2.0))
            .normalized()
            .unwrap();
        let center = radial * 0.42;
        // Ring plane must contain `radial` ⇒ torus axis ⊥ radial.
        let axis = radial.cross(Vec3::new(0.0, 0.0, 1.0)).normalized().unwrap();
        let major = 0.16 + 0.02 * (i as f32 - 2.0).abs(); // vary loop size
        children.push(Box::new(Torus::new(center, axis, major, 0.045)));
    }
    Shape {
        field: Box::new(Union::new(children)),
        bounds: Aabb::new(Vec3::splat(-0.85), Vec3::splat(0.85)),
        genus: 5,
        name: "hand",
        default_resolution: 96,
    }
}

/// Heptoroid proxy: a rounded plate punched by 22 through-holes (11 × 2
/// grid) — genus 22 with low, variable LFS in the thin walls between holes.
pub fn heptoroid() -> Shape {
    let plate = RoundedBox::new(Vec3::ZERO, Vec3::new(1.32, 0.36, 0.1), 0.04);
    let mut cuts: Vec<Box<dyn Field>> = Vec::new();
    let (cols, rows) = (11, 2);
    for i in 0..cols {
        for j in 0..rows {
            let x = (i as f32 - (cols - 1) as f32 / 2.0) * 0.23;
            let y = (j as f32 - (rows - 1) as f32 / 2.0) * 0.34;
            cuts.push(Box::new(Cylinder::new(
                Vec3::new(x, y, 0.0),
                Vec3::new(0.0, 0.0, 1.0),
                0.075,
            )));
        }
    }
    debug_assert_eq!(cols * rows, 22);
    Shape {
        field: Box::new(Difference::new(Box::new(plate), cuts)),
        bounds: Aabb::new(Vec3::new(-1.6, -0.65, -0.3), Vec3::new(1.6, 0.65, 0.3)),
        genus: 22,
        name: "heptoroid",
        default_resolution: 160,
    }
}

/// All four benchmark shapes in paper order (Bunny, Eight, Hand, Heptoroid).
pub fn all() -> Vec<Shape> {
    vec![blob(), eight(), hand(), heptoroid()]
}

/// Look a shape up by name.
pub fn by_name(name: &str) -> Option<Shape> {
    match name {
        "blob" | "bunny" => Some(blob()),
        "eight" => Some(eight()),
        "hand" => Some(hand()),
        "heptoroid" => Some(heptoroid()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_shapes_have_interior_and_exterior() {
        for s in all() {
            // bounds corner must be outside…
            assert!(s.field.eval(s.bounds.min) > 0.0, "{}", s.name);
            // …and the field must go negative somewhere on a coarse probe.
            let mut found_inside = false;
            let steps = 24;
            'outer: for i in 0..steps {
                for j in 0..steps {
                    for k in 0..steps {
                        let t = Vec3::new(
                            (i as f32 + 0.5) / steps as f32,
                            (j as f32 + 0.5) / steps as f32,
                            (k as f32 + 0.5) / steps as f32,
                        );
                        let p = Vec3::new(
                            s.bounds.min.x + t.x * s.bounds.extent().x,
                            s.bounds.min.y + t.y * s.bounds.extent().y,
                            s.bounds.min.z + t.z * s.bounds.extent().z,
                        );
                        if s.field.eval(p) < 0.0 {
                            found_inside = true;
                            break 'outer;
                        }
                    }
                }
            }
            assert!(found_inside, "{} has no interior on probe grid", s.name);
        }
    }

    #[test]
    fn heptoroid_has_22_holes() {
        let s = heptoroid();
        // The center of each hole is outside the solid.
        let (cols, rows) = (11, 2);
        for i in 0..cols {
            for j in 0..rows {
                let x = (i as f32 - (cols - 1) as f32 / 2.0) * 0.23;
                let y = (j as f32 - (rows - 1) as f32 / 2.0) * 0.34;
                assert!(s.field.eval(Vec3::new(x, y, 0.0)) > 0.0, "hole {i},{j}");
            }
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for s in all() {
            assert!(by_name(s.name).is_some());
        }
        assert!(by_name("bunny").is_some(), "paper alias");
        assert!(by_name("nonesuch").is_none());
    }

    #[test]
    fn paper_order_and_genus() {
        let shapes = all();
        let genus: Vec<u32> = shapes.iter().map(|s| s.genus).collect();
        assert_eq!(genus, vec![0, 2, 5, 22]);
    }
}
