//! `msgsn` — the Layer-3 coordinator binary.
//!
//! Self-contained after `make artifacts`: loads AOT-compiled Find-Winners
//! buckets from `artifacts/` and never touches Python.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use msgsn::bench::{self, Scale};
use msgsn::cli::{parse, Command, Parsed, USAGE};
use msgsn::config::{parse_config_text, Algorithm, ConfigValue, Driver, RunConfig};
use msgsn::engine::{make_algorithm, make_findwinners, run, run_convergence};
use msgsn::fleet::{parse_manifest, Fleet, FleetOptions, FleetOutcome};
use msgsn::mesh::{benchmark_mesh, write_obj, write_off, BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::runtime::Registry;

fn main() -> ExitCode {
    // Arm-time validation of the env fault profile: a malformed
    // MSGSN_FAULTS is a startup usage error, not a panic at whatever
    // fault point happens to fire first, hours into a run.
    if let Err(e) = msgsn::runtime::fault::validate_env() {
        eprintln!("error: MSGSN_FAULTS: {e}");
        return ExitCode::FAILURE;
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        Command::Help => {
            print!("{USAGE}");
            Ok(())
        }
        Command::Run(p) => cmd_run(&p),
        // The fleet maps job statuses to its own exit codes (0 success,
        // 2 partial failure, 3 total failure) — handled apart from the
        // generic Ok/Err → 0/1 fold below.
        Command::Fleet(p) => {
            return match cmd_fleet(&p) {
                Ok(code) => code,
                Err(e) => {
                    eprintln!("error: {e:#}");
                    ExitCode::FAILURE
                }
            }
        }
        // Same: the daemon exits with the drained fleet's exit code.
        Command::Serve(p) => {
            return match cmd_serve(&p) {
                Ok(code) => code,
                Err(e) => {
                    eprintln!("error: {e:#}");
                    ExitCode::FAILURE
                }
            }
        }
        // Same: the coordinator folds job outcomes into exit codes
        // 0/2/3 plus 4 for "every worker lost".
        Command::Coordinator(p) => {
            return match cmd_coordinator(&p) {
                Ok(code) => code,
                Err(e) => {
                    eprintln!("error: {e:#}");
                    ExitCode::FAILURE
                }
            }
        }
        Command::Worker(p) => cmd_worker(&p),
        Command::Reproduce(p) => cmd_reproduce(&p),
        Command::Mesh(p) => cmd_mesh(&p),
        Command::Artifacts(p) => cmd_artifacts(&p),
        Command::Ablate(p) => cmd_ablate(&p),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Build a RunConfig from preset + config file + --set overrides.
fn build_config(p: &Parsed) -> Result<RunConfig> {
    let shape = match p.get("mesh") {
        None => BenchmarkShape::Blob,
        Some(name) => BenchmarkShape::from_name(name)
            .with_context(|| format!("unknown mesh {name:?}"))?,
    };
    let mut cfg = RunConfig::preset(shape);
    if let Some(path) = p.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let map = parse_config_text(&text)?;
        cfg.apply_all(&map)?;
    }
    if let Some(d) = p.get("driver") {
        cfg.driver = Driver::from_config_name(d)
            .map_err(|why| anyhow::anyhow!(why))?
            .with_context(|| format!("unknown driver {d:?} (expected {})", Driver::NAMES))?;
    }
    if let Some(a) = p.get("algorithm") {
        cfg.algorithm =
            Algorithm::from_name(a).with_context(|| format!("unknown algorithm {a:?}"))?;
    }
    cfg.seed = p.get_parsed("seed", cfg.seed, "integer")?;
    if let Some(n) = p.get("max-signals") {
        cfg.limits.max_signals = n.parse().context("--max-signals expects an integer")?;
    }
    if p.flag("trace") {
        cfg.limits.trace = true;
    }
    for kv in p.get_all("set") {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("--set expects key=value, got {kv:?}"))?;
        // Values go through the config-file value parser (numbers, bools,
        // bare strings).
        let value = match v {
            "true" => ConfigValue::Bool(true),
            "false" => ConfigValue::Bool(false),
            _ => v
                .parse::<f64>()
                .map(ConfigValue::Num)
                .unwrap_or_else(|_| ConfigValue::Str(v.to_string())),
        };
        cfg.apply(k, &value)?;
    }
    Ok(cfg)
}

fn cmd_run(p: &Parsed) -> Result<()> {
    let cfg = build_config(p)?;
    let mesh = benchmark_mesh(cfg.shape, cfg.mesh_resolution);
    let stats = mesh.stats();
    if !p.flag("quiet") {
        println!(
            "mesh {} ({}): {} vertices, {} faces, genus {:?}",
            cfg.shape.name(),
            cfg.shape.paper_name(),
            stats.vertices,
            stats.faces,
            stats.genus
        );
    }
    let mut rng = Rng::seed_from(cfg.seed);
    let report = run(&mesh, cfg.driver, &cfg, &mut rng)?;
    if !p.flag("quiet") {
        print!("{}", report.to_table().render());
    }
    if let Some(path) = p.get("save-mesh") {
        // Export the reconstructed network triangulation.
        let algo_mesh = reconstruct_for_export(&mesh, &cfg)?;
        let path = Path::new(path);
        match path.extension().and_then(|e| e.to_str()) {
            Some("off") => write_off(&algo_mesh, path)?,
            _ => write_obj(&algo_mesh, path)?,
        }
        println!("wrote reconstruction to {}", path.display());
    }
    Ok(())
}

/// Run a jobs manifest: N concurrent reconstructions round-robin over one
/// worker pool, with durable bit-exact checkpointing, per-job crash
/// isolation with retry/quarantine, and status-bearing exit codes
/// (`fleet` subsystem).
fn cmd_fleet(p: &Parsed) -> Result<ExitCode> {
    let manifest_path = p
        .get("jobs")
        .context("--jobs <jobs.json> is required (see `msgsn help` for the schema)")?;
    let text = std::fs::read_to_string(manifest_path)
        .with_context(|| format!("reading jobs manifest {manifest_path}"))?;
    let specs = parse_manifest(&text)?;
    let quiet = p.flag("quiet");
    telemetry_arm(p);

    if let Some(profile) = p.get("faults") {
        let specs = msgsn::runtime::fault::parse_faults(profile)
            .map_err(anyhow::Error::msg)
            .context("--faults")?;
        msgsn::runtime::fault::install(specs);
    }

    let opts = FleetOptions {
        stride: p.get_parsed("stride", 1u64, "integer")?.max(1),
        checkpoint_every: p.get_parsed("checkpoint-every", 0u64, "integer")?,
        checkpoint_secs: p
            .get("checkpoint-secs")
            .map(|s| {
                s.parse::<f64>().context("--checkpoint-secs expects seconds (fractional ok)")
            })
            .transpose()?,
        checkpoint_dir: Some(PathBuf::from(p.get("checkpoint-dir").unwrap_or("checkpoints"))),
        max_retries: p.get_parsed("max-retries", 2u32, "integer")?,
        ..FleetOptions::default()
    };

    let mut fleet = Fleet::new(specs)?;
    if !quiet {
        println!(
            "fleet: {} jobs, shared worker pool width {}",
            fleet.jobs().len(),
            fleet.pool_width()
        );
    }
    if p.flag("resume") {
        let dir = opts.checkpoint_dir.as_deref().expect("checkpoint dir defaulted");
        let resumed = fleet.resume_from(dir)?;
        if !quiet {
            if resumed.is_empty() {
                println!("resume: no checkpoints under {} — starting fresh", dir.display());
            } else {
                for o in &resumed {
                    println!("resume: {} from {}", o.name, o.source.describe());
                }
            }
        }
    }
    let report = fleet.run(&opts, |line| {
        if !quiet {
            println!("{line}");
        }
    })?;
    print!("{}", report.to_table().render());
    if let Some(path) = p.get("report-json") {
        write_report_json(&report, path)?;
    }
    telemetry_flush(p)?;
    let outcome = report.outcome();
    match outcome {
        FleetOutcome::AllSucceeded => {}
        FleetOutcome::PartialFailure => {
            eprintln!("fleet: partial failure — some jobs quarantined (exit 2)")
        }
        FleetOutcome::AllFailed => eprintln!("fleet: all jobs quarantined (exit 3)"),
    }
    Ok(ExitCode::from(outcome.exit_code()))
}

/// `--report-json`: the FleetReport as machine-readable JSON (rows +
/// outcome + exit_code) — what CI asserts on instead of scraping stdout.
/// When telemetry is on the registry snapshot + trace tail ride along
/// under a `"telemetry"` key.
fn write_report_json(report: &msgsn::fleet::FleetReport, path: &str) -> Result<()> {
    let mut doc = report.to_json();
    if msgsn::telemetry::enabled() {
        if let msgsn::runtime::Json::Obj(m) = &mut doc {
            m.insert("telemetry".to_string(), msgsn::telemetry::metrics_json(64));
        }
    }
    let mut text = msgsn::runtime::render_json(&doc);
    text.push('\n');
    std::fs::write(path, text).with_context(|| format!("writing report JSON {path}"))
}

/// Arm the telemetry registry when an exposition flag asks for it —
/// called before the fleet/server/worker is built, so job admissions
/// land in the trace.
fn telemetry_arm(p: &Parsed) {
    if p.get("metrics-json").is_some() || p.get("trace-file").is_some() {
        msgsn::telemetry::set_enabled(true);
    }
}

/// Flush `--metrics-json` / `--trace-file` at the end of a run. Metrics
/// first: its trace tail is a copy, while `--trace-file` drains the ring.
fn telemetry_flush(p: &Parsed) -> Result<()> {
    if let Some(path) = p.get("metrics-json") {
        let mut text = msgsn::runtime::render_json(&msgsn::telemetry::metrics_json(64));
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing metrics JSON {path}"))?;
    }
    if let Some(path) = p.get("trace-file") {
        let events = msgsn::telemetry::trace::drain_all();
        std::fs::write(path, msgsn::telemetry::trace::to_jsonl(&events))
            .with_context(|| format!("writing trace JSONL {path}"))?;
    }
    Ok(())
}

/// The fleet as a long-running TCP daemon (`serve` subsystem): admits
/// jobs over line-JSON, streams progress, answers batch-boundary
/// queries, drains on `shutdown`, exits with the fleet exit code.
fn cmd_serve(p: &Parsed) -> Result<ExitCode> {
    use msgsn::serve::{ServeOptions, Server};

    let quiet = p.flag("quiet");
    telemetry_arm(p);
    if let Some(profile) = p.get("faults") {
        let specs = msgsn::runtime::fault::parse_faults(profile)
            .map_err(anyhow::Error::msg)
            .context("--faults")?;
        msgsn::runtime::fault::install(specs);
    }

    let specs = match p.get("jobs") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading jobs manifest {path}"))?;
            parse_manifest(&text)?
        }
        None => Vec::new(),
    };

    let opts = ServeOptions {
        fleet: FleetOptions {
            stride: p.get_parsed("stride", 1u64, "integer")?.max(1),
            checkpoint_every: p.get_parsed("checkpoint-every", 0u64, "integer")?,
            checkpoint_secs: p
                .get("checkpoint-secs")
                .map(|s| {
                    s.parse::<f64>().context("--checkpoint-secs expects seconds (fractional ok)")
                })
                .transpose()?,
            checkpoint_dir: Some(PathBuf::from(p.get("checkpoint-dir").unwrap_or("checkpoints"))),
            max_retries: p.get_parsed("max-retries", 2u32, "integer")?,
            ..FleetOptions::default()
        },
        watch_every: p.get_parsed("watch-every", 8u64, "integer")?.max(1),
        ..ServeOptions::default()
    };

    let listen = p.get("listen").unwrap_or("127.0.0.1:7081");
    let mut server = Server::bind(listen, specs)?;
    if p.flag("resume") {
        let dir = opts.fleet.checkpoint_dir.clone().expect("checkpoint dir defaulted");
        let resumed = server.resume_from(&dir)?;
        if !quiet {
            for o in &resumed {
                println!("resume: {} from {}", o.name, o.source.describe());
            }
        }
    }
    // Announced unconditionally (and flushed by the newline): the e2e
    // harness waits for this line before connecting.
    println!("serve: listening on {}", server.local_addr()?);
    let report = server.run(&opts, |line| {
        if !quiet {
            println!("{line}");
        }
    })?;
    print!("{}", report.to_table().render());
    if let Some(path) = p.get("report-json") {
        write_report_json(&report, path)?;
    }
    telemetry_flush(p)?;
    let outcome = report.outcome();
    match outcome {
        FleetOutcome::AllSucceeded => {}
        FleetOutcome::PartialFailure => {
            eprintln!("serve: partial failure — some jobs quarantined (exit 2)")
        }
        FleetOutcome::AllFailed => eprintln!("serve: all jobs quarantined (exit 3)"),
    }
    Ok(ExitCode::from(outcome.exit_code()))
}

/// Distributed fleet, coordinator side: own the manifest, accept worker
/// TCP connections, route jobs, migrate on worker death (`dist`
/// subsystem). Exit codes 0/2/3 mirror `msgsn fleet`; 4 = every worker
/// died or hung with jobs outstanding.
fn cmd_coordinator(p: &Parsed) -> Result<ExitCode> {
    use msgsn::dist::{Coordinator, DistOptions, DistOutcome, Link, TcpPipe};

    let manifest_path = p
        .get("jobs")
        .context("--jobs <jobs.json> is required (see `msgsn help` for the schema)")?;
    let text = std::fs::read_to_string(manifest_path)
        .with_context(|| format!("reading jobs manifest {manifest_path}"))?;
    let payloads = msgsn::fleet::manifest_job_payloads(&text)?;
    let quiet = p.flag("quiet");
    telemetry_arm(p);

    let listen = p.get("listen").unwrap_or("127.0.0.1:7070");
    let expected: usize = p.get_parsed("workers", 1usize, "integer")?.max(1);
    let heartbeat_secs: f64 = p
        .get("heartbeat-timeout")
        .map(|s| s.parse::<f64>().context("--heartbeat-timeout expects seconds"))
        .transpose()?
        .unwrap_or(5.0);
    let opts = DistOptions {
        heartbeat_timeout: std::time::Duration::from_secs_f64(heartbeat_secs.max(0.001)),
        max_retries: p.get_parsed("max-retries", 2u32, "integer")?,
        ..DistOptions::default()
    };

    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding coordinator listener on {listen}"))?;
    if !quiet {
        println!(
            "coordinator: {} jobs, waiting for {expected} worker(s) on {listen}",
            payloads.len()
        );
    }
    let mut coordinator = Coordinator::new(payloads, opts);
    for _ in 0..expected {
        let (stream, peer) = listener.accept().context("accepting a worker connection")?;
        let label = peer.to_string();
        let pipe = TcpPipe::new(stream).context("configuring the worker socket")?;
        if !quiet {
            println!("coordinator: worker link from {label}");
        }
        coordinator.add_worker(&label, Box::new(Link::new(pipe, label.clone())));
    }

    let report = coordinator.run(|line| {
        if !quiet {
            println!("{line}");
        }
    });
    print!("{}", report.to_table().render());
    telemetry_flush(p)?;
    let outcome = report.outcome();
    match outcome {
        DistOutcome::AllDone => {}
        DistOutcome::PartialFailure => {
            eprintln!("coordinator: partial failure — some jobs quarantined (exit 2)")
        }
        DistOutcome::AllFailed => eprintln!("coordinator: all jobs quarantined (exit 3)"),
        DistOutcome::WorkersLost => {
            eprintln!("coordinator: every worker died/hung with jobs outstanding (exit 4)")
        }
    }
    Ok(ExitCode::from(outcome.exit_code()))
}

/// Distributed fleet, worker side: connect to the coordinator and run a
/// protocol-driven fleet until it sends shutdown.
fn cmd_worker(p: &Parsed) -> Result<()> {
    use msgsn::dist::{run_worker, Link, TcpPipe, WorkerOptions};

    let addr = p.get("connect").unwrap_or("127.0.0.1:7070");
    let opts = WorkerOptions {
        name: p
            .get("name")
            .map(str::to_string)
            .unwrap_or_else(|| format!("w{}", std::process::id())),
        stride: p.get_parsed("stride", 1u64, "integer")?.max(1),
        checkpoint_rounds: p.get_parsed("checkpoint-rounds", 8u64, "integer")?,
        ..WorkerOptions::default()
    };
    let quiet = p.flag("quiet");
    telemetry_arm(p);

    let pipe = TcpPipe::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let mut link = Link::new(pipe, opts.name.clone());
    if !quiet {
        println!("worker {}: connected to {addr}", opts.name);
    }
    run_worker(&mut link, &opts, |line| {
        if !quiet {
            println!("{line}");
        }
    })
    .map_err(anyhow::Error::msg)?;
    telemetry_flush(p)?;
    if !quiet {
        println!("worker {}: shutdown received, exiting", opts.name);
    }
    Ok(())
}

/// Re-run (same seed) keeping the network, then export its triangulation.
fn reconstruct_for_export(
    mesh: &msgsn::mesh::Mesh,
    cfg: &RunConfig,
) -> Result<msgsn::mesh::Mesh> {
    let sampler = SurfaceSampler::new(mesh);
    let mut algo = make_algorithm(cfg);
    let mut fw = make_findwinners(cfg)?;
    let mut rng = Rng::seed_from(cfg.seed);
    run_convergence(algo.as_mut(), &sampler, fw.as_mut(), cfg, &mut rng);
    Ok(algo.net().to_mesh())
}

fn cmd_reproduce(p: &Parsed) -> Result<()> {
    let scale_name = p.get("scale").unwrap_or("quick");
    let scale = Scale::from_name(scale_name)
        .with_context(|| format!("unknown scale {scale_name:?} (smoke|quick|paper)"))?;
    let out_dir = PathBuf::from(p.get("out").unwrap_or("results"));
    let seed: u64 = p.get_parsed("seed", 42, "integer")?;

    let mut tables: Vec<u32> = p
        .get_all("table")
        .iter()
        .map(|s| s.parse().with_context(|| format!("bad table {s:?}")))
        .collect::<Result<_>>()?;
    let mut figures: Vec<u32> = p
        .get_all("figure")
        .iter()
        .map(|s| s.parse().with_context(|| format!("bad figure {s:?}")))
        .collect::<Result<_>>()?;
    if p.flag("all") || (tables.is_empty() && figures.is_empty()) {
        tables = vec![1, 2, 3, 4];
        figures = vec![2, 7, 8, 9, 10];
    }
    for &t in &tables {
        if bench::render::table_shape(t).is_none() {
            bail!("no paper table {t}");
        }
    }

    // Which meshes are needed: tables name them directly; figures need all.
    let shapes: Vec<BenchmarkShape> = if figures.is_empty() {
        tables
            .iter()
            .map(|&t| bench::render::table_shape(t).unwrap())
            .collect()
    } else {
        BenchmarkShape::ALL.to_vec()
    };

    println!(
        "reproduce: scale={} seed={seed} meshes={:?} tables={tables:?} figures={figures:?}",
        scale.name,
        shapes.iter().map(|s| s.name()).collect::<Vec<_>>(),
    );
    // Default: the full six-driver comparison (the paper's four columns
    // plus pipelined/parallel); `--paper-only` restricts to the paper's
    // grid — worthwhile at `--scale paper`, where every extra driver is
    // another hours-long run.
    let drivers: &[Driver] = if p.flag("paper-only") {
        &Driver::PAPER_COLUMNS
    } else {
        &Driver::ALL
    };
    let artifacts = PathBuf::from("artifacts");
    let grid = bench::grid::run_grid(
        &shapes,
        drivers,
        &scale,
        seed,
        Some(artifacts),
        |line| println!("{line}"),
    )?;

    for &n in &tables {
        let (text, _) = bench::render_table(&grid, n)?;
        println!("\n{text}");
    }
    for &n in &figures {
        let (text, _) = bench::render_figure(&grid, n)?;
        println!("\n{text}");
    }
    let written = bench::write_all(&grid, &out_dir, &tables, &figures)?;
    println!("\nwrote {} files under {}", written.len(), out_dir.display());
    Ok(())
}

fn cmd_mesh(p: &Parsed) -> Result<()> {
    let shape = match p.get("shape") {
        None => BenchmarkShape::Blob,
        Some(name) => BenchmarkShape::from_name(name)
            .with_context(|| format!("unknown shape {name:?}"))?,
    };
    let resolution: u32 = p.get_parsed("resolution", 0, "integer")?;
    let mesh = benchmark_mesh(shape, resolution);
    let s = mesh.stats();
    println!(
        "{} (proxy for {}; marching resolution {})",
        shape.name(),
        shape.paper_name(),
        if resolution == 0 { shape.default_resolution() } else { resolution },
    );
    println!(
        "  V={} E={} F={} chi={} genus={:?} components={} watertight={} area={:.4}",
        s.vertices,
        s.edges,
        s.faces,
        s.euler_characteristic,
        s.genus,
        s.components,
        s.watertight,
        s.total_area,
    );
    let expected = shape.expected_genus();
    match s.genus {
        Some(g) if g == expected => println!("  genus matches the paper mesh ({expected})"),
        got => bail!("genus {got:?} != expected {expected} — raise --resolution"),
    }
    // The paper's second complexity axis: the LFS distribution (§3.1).
    let mut rng = Rng::seed_from(0xFEA7);
    let lfs = msgsn::mesh::estimate_lfs(&mesh, 1500, &mut rng);
    println!(
        "  LFS (unit-cube scale): min={:.4} p05={:.4} median={:.4} max={:.4} cv={:.2}",
        lfs.min, lfs.p05, lfs.median, lfs.max, lfs.cv
    );
    if let Some(path) = p.get("out") {
        let path = Path::new(path);
        match path.extension().and_then(|e| e.to_str()) {
            Some("off") => write_off(&mesh, path)?,
            _ => write_obj(&mesh, path)?,
        }
        println!("  wrote {}", path.display());
    }
    Ok(())
}

fn cmd_ablate(p: &Parsed) -> Result<()> {
    let which = p.get("which").unwrap_or("all");
    let max_signals: u64 = p.get_parsed("max-signals", 400_000, "integer")?;
    let seed: u64 = p.get_parsed("seed", 42, "integer")?;
    if matches!(which, "locks" | "all") {
        println!("Ablation: collision policy (winner lock / staleness guard)\n");
        println!("{}", bench::ablate_collision_policy(max_signals, seed).render());
    }
    if matches!(which, "schedule" | "all") {
        println!("Ablation: parallelism schedule (paper's pow2 vs fixed m)\n");
        println!("{}", bench::ablate_m_schedule(max_signals, seed).render());
    }
    if matches!(which, "cell" | "all") {
        println!("Ablation: hash-index cube size (Indexed variant)\n");
        println!("{}", bench::ablate_index_cell(seed)?.render());
    }
    if matches!(which, "executor" | "all") {
        println!("Ablation: Update-phase execution (multi / pipelined / parallel)\n");
        println!("{}", bench::ablate_update_executor(max_signals, seed)?.render());
    }
    if !matches!(which, "locks" | "schedule" | "cell" | "executor" | "all") {
        bail!("--which expects locks|schedule|cell|executor|all");
    }
    Ok(())
}

fn cmd_artifacts(p: &Parsed) -> Result<()> {
    let dir = PathBuf::from(p.get("dir").unwrap_or("artifacts"));
    let mut reg = Registry::open(&dir, p.get("flavor"))?;
    println!(
        "artifacts at {}: flavor={} pad={} buckets:",
        dir.display(),
        reg.flavor(),
        msgsn::runtime::PAD_VALUE
    );
    let entries: Vec<_> = reg.manifest().artifacts.clone();
    for e in &entries {
        println!("  {:6} m={:5} n={:5} {}", e.flavor, e.m, e.n, e.file);
    }
    if let Some(n) = p.get("warmup-n") {
        let max_n: usize = n.parse().context("--warmup-n expects an integer")?;
        let t0 = std::time::Instant::now();
        let count = reg.warmup(max_n)?;
        println!(
            "warmed {count} buckets (n <= {max_n}) in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}
