//! Local-topology classification of network neighborhoods.
//!
//! The SOAM termination criterion (paper §2.1) is *topological*: "the
//! learning process terminates when all units have reached a local topology
//! consistent with that of a surface". Concretely, a unit's neighborhood is
//! surface-consistent when the subgraph *induced by its neighbors* (the
//! link of the vertex) is a single closed cycle — then the unit's star is a
//! triangulated disk. A single open chain is a half-disk (surface boundary);
//! anything else is non-manifold or under-connected.
//!
//! This module is pure graph logic, independent of the network store, so it
//! is reusable (and property-testable) in isolation.

use std::collections::HashMap;

/// Classification of the link (induced neighbor subgraph) of a unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// No neighbors at all.
    Isolated,
    /// Neighbors exist but none are connected to each other.
    Dust,
    /// Single open chain through all neighbors — boundary of a surface
    /// patch (half-disk).
    HalfDisk,
    /// Single closed cycle through all neighbors — interior point of a
    /// 2-manifold (disk). The SOAM stability target.
    Disk,
    /// Anything else: branching (degree > 2), multiple components, or a
    /// cycle plus extra chords — locally non-manifold.
    NonManifold,
}

impl LinkClass {
    /// Surface-consistent for a *closed* surface (the benchmark meshes are
    /// all closed, so SOAM requires `Disk` everywhere).
    pub fn is_disk(self) -> bool {
        matches!(self, LinkClass::Disk)
    }
}

/// Classify the link of a unit.
///
/// * `neighbors` — the unit's neighbor ids (any id type order).
/// * `connected` — edge oracle over *neighbor pairs* (the global adjacency
///   restricted to the link).
pub fn classify_link(
    neighbors: &[u32],
    mut connected: impl FnMut(u32, u32) -> bool,
) -> LinkClass {
    let k = neighbors.len();
    if k == 0 {
        return LinkClass::Isolated;
    }
    if k == 1 {
        // A single neighbor can form neither a chain of length ≥1 nor a
        // cycle; treat as dust (under-connected).
        return LinkClass::Dust;
    }

    // Induced adjacency (k is small — typically ≤ 10 — so O(k²) is right).
    let mut degree = vec![0u32; k];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); k];
    let mut edges = 0usize;
    for i in 0..k {
        for j in i + 1..k {
            if connected(neighbors[i], neighbors[j]) {
                degree[i] += 1;
                degree[j] += 1;
                adj[i].push(j);
                adj[j].push(i);
                edges += 1;
            }
        }
    }
    if edges == 0 {
        return LinkClass::Dust;
    }
    if degree.iter().any(|&d| d > 2) {
        return LinkClass::NonManifold;
    }

    // All degrees ≤ 2: the graph is a disjoint union of chains and cycles.
    // Connectivity check over vertices with degree ≥ 1.
    let mut seen = vec![false; k];
    let start = (0..k).find(|&i| degree[i] > 0).unwrap();
    let mut stack = vec![start];
    seen[start] = true;
    let mut reached = 1usize;
    while let Some(v) = stack.pop() {
        for &w in &adj[v] {
            if !seen[w] {
                seen[w] = true;
                reached += 1;
                stack.push(w);
            }
        }
    }
    let active = (0..k).filter(|&i| degree[i] > 0).count();
    if reached < active || active < k {
        // Multiple components, or isolated neighbors alongside a chain/cycle.
        return LinkClass::NonManifold;
    }

    let endpoints = degree.iter().filter(|&&d| d == 1).count();
    match endpoints {
        0 => {
            // Single component, all degree 2 ⇒ one cycle through all k.
            // A cycle needs at least 3 vertices.
            if k >= 3 {
                LinkClass::Disk
            } else {
                // k == 2 with "all degree 2" would need a doubled edge —
                // impossible in a simple graph; defensive fallback.
                LinkClass::NonManifold
            }
        }
        2 => LinkClass::HalfDisk,
        _ => LinkClass::NonManifold,
    }
}

/// Extract the triangle faces of a network graph: 3-cliques `(a, b, c)`
/// with `a < b < c`. Used to compute the Euler characteristic of a SOAM
/// reconstruction and verify its genus against the target mesh.
pub fn triangles(adjacency: &HashMap<u32, Vec<u32>>) -> Vec<[u32; 3]> {
    let mut tris = Vec::new();
    for (&a, na) in adjacency {
        for &b in na {
            if b <= a {
                continue;
            }
            let nb = match adjacency.get(&b) {
                Some(n) => n,
                None => continue,
            };
            for &c in na {
                if c <= b {
                    continue;
                }
                if nb.contains(&c) {
                    tris.push([a, b, c]);
                }
            }
        }
    }
    tris.sort_unstable();
    tris.dedup();
    tris
}

/// Euler characteristic `V − E + F` of a graph whose faces are its
/// 3-cliques (valid when every face of the complex is a triangle, as in a
/// SOAM reconstruction at convergence).
pub fn euler_characteristic(adjacency: &HashMap<u32, Vec<u32>>) -> i64 {
    let v = adjacency.len() as i64;
    let e: i64 = adjacency.values().map(|n| n.len() as i64).sum::<i64>() / 2;
    let f = triangles(adjacency).len() as i64;
    v - e + f
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge_set(edges: &[(u32, u32)]) -> impl FnMut(u32, u32) -> bool + '_ {
        move |a, b| {
            edges
                .iter()
                .any(|&(x, y)| (x == a && y == b) || (x == b && y == a))
        }
    }

    #[test]
    fn isolated_and_dust() {
        assert_eq!(classify_link(&[], edge_set(&[])), LinkClass::Isolated);
        assert_eq!(classify_link(&[1], edge_set(&[])), LinkClass::Dust);
        assert_eq!(classify_link(&[1, 2, 3], edge_set(&[])), LinkClass::Dust);
    }

    #[test]
    fn triangle_link_is_disk() {
        // Neighbors 1,2,3 forming a cycle 1-2-3-1.
        let edges = [(1, 2), (2, 3), (3, 1)];
        assert_eq!(classify_link(&[1, 2, 3], edge_set(&edges)), LinkClass::Disk);
    }

    #[test]
    fn square_link_is_disk() {
        let edges = [(1, 2), (2, 3), (3, 4), (4, 1)];
        assert_eq!(
            classify_link(&[1, 2, 3, 4], edge_set(&edges)),
            LinkClass::Disk
        );
    }

    #[test]
    fn chain_is_half_disk() {
        let edges = [(1, 2), (2, 3), (3, 4)];
        assert_eq!(
            classify_link(&[1, 2, 3, 4], edge_set(&edges)),
            LinkClass::HalfDisk
        );
        // Two neighbors joined by one edge: chain of length 1.
        assert_eq!(classify_link(&[7, 9], edge_set(&[(7, 9)])), LinkClass::HalfDisk);
    }

    #[test]
    fn branching_is_non_manifold() {
        // Star: neighbor 1 connected to 2, 3, 4 (degree 3 in the link).
        let edges = [(1, 2), (1, 3), (1, 4)];
        assert_eq!(
            classify_link(&[1, 2, 3, 4], edge_set(&edges)),
            LinkClass::NonManifold
        );
    }

    #[test]
    fn two_components_non_manifold() {
        let edges = [(1, 2), (3, 4)];
        assert_eq!(
            classify_link(&[1, 2, 3, 4], edge_set(&edges)),
            LinkClass::NonManifold
        );
    }

    #[test]
    fn cycle_plus_isolated_neighbor_non_manifold() {
        let edges = [(1, 2), (2, 3), (3, 1)];
        assert_eq!(
            classify_link(&[1, 2, 3, 4], edge_set(&edges)),
            LinkClass::NonManifold
        );
    }

    #[test]
    fn cycle_with_chord_non_manifold() {
        let edges = [(1, 2), (2, 3), (3, 4), (4, 1), (1, 3)];
        assert_eq!(
            classify_link(&[1, 2, 3, 4], edge_set(&edges)),
            LinkClass::NonManifold
        );
    }

    fn octahedron_adj() -> HashMap<u32, Vec<u32>> {
        // Octahedron: 0/1 poles on x, 2/3 on y, 4/5 on z; every pair of
        // non-opposite vertices is adjacent.
        let mut adj: HashMap<u32, Vec<u32>> = HashMap::new();
        let opposite = |v: u32| v ^ 1;
        for a in 0..6u32 {
            for b in 0..6u32 {
                if a != b && b != opposite(a) {
                    adj.entry(a).or_default().push(b);
                }
            }
        }
        adj
    }

    #[test]
    fn octahedron_triangles_and_euler() {
        let adj = octahedron_adj();
        let tris = triangles(&adj);
        assert_eq!(tris.len(), 8);
        assert_eq!(euler_characteristic(&adj), 2); // sphere
    }

    #[test]
    fn octahedron_links_are_disks() {
        let adj = octahedron_adj();
        for v in 0..6u32 {
            let nbrs = adj[&v].clone();
            let class = classify_link(&nbrs, |a, b| adj[&a].contains(&b));
            assert_eq!(class, LinkClass::Disk, "vertex {v}");
        }
    }
}
