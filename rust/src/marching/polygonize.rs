//! The marching-tetrahedra polygonizer.
//!
//! Pipeline: evaluate the field on a vertex grid → per cell, per Kuhn tet,
//! classify the 4 corners by sign → emit 0/1/2 triangles whose vertices are
//! interpolated zero crossings on tet edges → weld vertices by grid-edge key
//! (exact, no epsilon matching) → orient every triangle outward along the
//! field gradient.
//!
//! Welding by *grid-edge identity* rather than by position is what makes the
//! output watertight: two triangles from different tets/cells that cross the
//! same grid edge share the same output vertex index by construction.

use std::collections::HashMap;

use crate::geometry::{Aabb, Vec3};
use crate::implicit::Field;
use crate::mesh::Mesh;

use super::kuhn::{cube_corner_offset, KUHN_TETS};

/// Discretization of the polygonization volume.
#[derive(Clone, Copy, Debug)]
pub struct GridSpec {
    pub bounds: Aabb,
    /// Cells along x/y/z.
    pub nx: u32,
    pub ny: u32,
    pub nz: u32,
}

impl GridSpec {
    /// Cubic cells: `resolution` cells along the longest axis, proportional
    /// counts (≥ 2) on the others.
    pub fn cubic(bounds: Aabb, resolution: u32) -> Self {
        assert!(resolution >= 2, "resolution must be >= 2");
        let e = bounds.extent();
        let cell = bounds.max_extent() / resolution as f32;
        let n = |len: f32| ((len / cell).round() as u32).max(2);
        Self { bounds, nx: n(e.x), ny: n(e.y), nz: n(e.z) }
    }

    #[inline]
    fn cell_size(&self) -> Vec3 {
        let e = self.bounds.extent();
        Vec3::new(e.x / self.nx as f32, e.y / self.ny as f32, e.z / self.nz as f32)
    }

    #[inline]
    fn point(&self, ix: u32, iy: u32, iz: u32) -> Vec3 {
        let c = self.cell_size();
        self.bounds.min + Vec3::new(ix as f32 * c.x, iy as f32 * c.y, iz as f32 * c.z)
    }

    /// Grid-vertex id (vertex grid is (nx+1)×(ny+1)×(nz+1)).
    #[inline]
    fn vid(&self, ix: u32, iy: u32, iz: u32) -> u64 {
        let sx = self.nx as u64 + 1;
        let sy = self.ny as u64 + 1;
        ix as u64 + iy as u64 * sx + iz as u64 * sx * sy
    }
}

/// Polygonize `field` over `bounds` at `resolution` cells along the longest
/// axis. Returns a welded, outward-oriented triangle mesh.
pub fn polygonize(field: &dyn Field, bounds: Aabb, resolution: u32) -> Mesh {
    let spec = GridSpec::cubic(bounds, resolution);
    polygonize_grid(field, &spec)
}

/// Polygonize with an explicit grid.
pub fn polygonize_grid(field: &dyn Field, spec: &GridSpec) -> Mesh {
    let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
    let (sx, sy) = (nx as usize + 1, ny as usize + 1);
    let sz = nz as usize + 1;

    // 1. Field values on the vertex grid (single pass, cached).
    let mut values = vec![0.0f32; sx * sy * sz];
    for iz in 0..=nz {
        for iy in 0..=ny {
            for ix in 0..=nx {
                let mut v = field.eval(spec.point(ix, iy, iz));
                // Push exact zeros off the surface so sign classification is
                // total and no degenerate (zero-length) edges appear.
                if v == 0.0 {
                    v = f32::MIN_POSITIVE;
                }
                values[spec.vid(ix, iy, iz) as usize] = v;
            }
        }
    }

    // 2. March all tets, welding crossing vertices by grid-edge key.
    let mut weld: HashMap<(u64, u64), u32> = HashMap::new();
    let mut vertices: Vec<Vec3> = Vec::new();
    let mut faces: Vec<[u32; 3]> = Vec::new();

    let mut edge_vertex = |ga: u64, pa: Vec3, va: f32, gb: u64, pb: Vec3, vb: f32,
                           vertices: &mut Vec<Vec3>|
     -> u32 {
        let key = if ga < gb { (ga, gb) } else { (gb, ga) };
        *weld.entry(key).or_insert_with(|| {
            // Zero crossing; va, vb have opposite signs. Clamp away from the
            // endpoints so crossings on different edges incident to a grid
            // vertex that lies (numerically) on the surface stay distinct —
            // otherwise they would produce geometrically degenerate faces.
            let t = (va / (va - vb)).clamp(1e-4, 1.0 - 1e-4);
            let idx = vertices.len() as u32;
            vertices.push(pa.lerp(pb, t));
            idx
        })
    };

    for iz in 0..nz {
        for iy in 0..ny {
            for ix in 0..nx {
                // Gather the cube's 8 corners once.
                let mut gid = [0u64; 8];
                let mut pos = [Vec3::ZERO; 8];
                let mut val = [0.0f32; 8];
                for c in 0..8u8 {
                    let (dx, dy, dz) = cube_corner_offset(c);
                    let (jx, jy, jz) = (ix + dx, iy + dy, iz + dz);
                    let id = spec.vid(jx, jy, jz);
                    gid[c as usize] = id;
                    pos[c as usize] = spec.point(jx, jy, jz);
                    val[c as usize] = values[id as usize];
                }
                // Cheap reject: cube entirely on one side.
                let any_in = val.iter().any(|&v| v < 0.0);
                let any_out = val.iter().any(|&v| v >= 0.0);
                if !(any_in && any_out) {
                    continue;
                }
                for tet in KUHN_TETS {
                    march_tet(
                        &tet, &gid, &pos, &val, &mut vertices, &mut faces,
                        &mut edge_vertex,
                    );
                }
            }
        }
    }

    // 3. Outward orientation along the field gradient.
    let h = spec.cell_size().x.min(spec.cell_size().y).min(spec.cell_size().z) * 0.5;
    for f in &mut faces {
        let (a, b, c) = (
            vertices[f[0] as usize],
            vertices[f[1] as usize],
            vertices[f[2] as usize],
        );
        let n = (b - a).cross(c - a);
        if n.norm2() == 0.0 {
            continue; // degenerate sliver; orientation is meaningless
        }
        let centroid = (a + b + c) / 3.0;
        let g = field.gradient(centroid, h);
        if n.dot(g) < 0.0 {
            f.swap(1, 2);
        }
    }

    let mut mesh = Mesh::new(vertices, faces);
    mesh.compact();
    mesh
}

/// Emit triangles for one tetrahedron.
#[allow(clippy::too_many_arguments)]
fn march_tet(
    tet: &[u8; 4],
    gid: &[u64; 8],
    pos: &[Vec3; 8],
    val: &[f32; 8],
    vertices: &mut Vec<Vec3>,
    faces: &mut Vec<[u32; 3]>,
    edge_vertex: &mut impl FnMut(u64, Vec3, f32, u64, Vec3, f32, &mut Vec<Vec3>) -> u32,
) {
    let corners: Vec<usize> = tet.iter().map(|&c| c as usize).collect();
    let inside: Vec<usize> = corners.iter().copied().filter(|&c| val[c] < 0.0).collect();
    let outside: Vec<usize> = corners.iter().copied().filter(|&c| val[c] >= 0.0).collect();

    let mut ev = |i: usize, o: usize, vertices: &mut Vec<Vec3>| {
        edge_vertex(gid[i], pos[i], val[i], gid[o], pos[o], val[o], vertices)
    };

    match inside.len() {
        0 | 4 => {}
        1 => {
            let i = inside[0];
            let t = [
                ev(i, outside[0], vertices),
                ev(i, outside[1], vertices),
                ev(i, outside[2], vertices),
            ];
            push_face(faces, t);
        }
        3 => {
            let o = outside[0];
            let t = [
                ev(inside[0], o, vertices),
                ev(inside[1], o, vertices),
                ev(inside[2], o, vertices),
            ];
            push_face(faces, t);
        }
        2 => {
            // Quad spanned by the 4 crossing edges, split into 2 triangles.
            // Corner order walks around the quad: (i0,o0) (i0,o1) (i1,o1)
            // (i1,o0) — adjacent corners share a tet corner, so the quad is
            // planar-convex in parameter space and the split never crosses.
            let q = [
                ev(inside[0], outside[0], vertices),
                ev(inside[0], outside[1], vertices),
                ev(inside[1], outside[1], vertices),
                ev(inside[1], outside[0], vertices),
            ];
            push_face(faces, [q[0], q[1], q[2]]);
            push_face(faces, [q[0], q[2], q[3]]);
        }
        _ => unreachable!(),
    }
}

#[inline]
fn push_face(faces: &mut Vec<[u32; 3]>, f: [u32; 3]) {
    // Drop degenerate triangles (can only appear if two crossing points weld
    // to the same grid edge — impossible by construction, but cheap to guard).
    if f[0] != f[1] && f[1] != f[2] && f[0] != f[2] {
        faces.push(f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::implicit::{Sphere, Torus};

    fn sphere_mesh(res: u32) -> Mesh {
        let s = Sphere::new(Vec3::ZERO, 0.75);
        polygonize(&s, Aabb::new(Vec3::splat(-1.0), Vec3::splat(1.0)), res)
    }

    #[test]
    fn sphere_is_watertight_genus_zero() {
        let m = sphere_mesh(24);
        let st = m.stats();
        assert!(st.watertight, "{st:?}");
        assert_eq!(st.components, 1);
        assert_eq!(st.euler_characteristic, 2);
        assert_eq!(st.genus, Some(0));
    }

    #[test]
    fn sphere_vertices_near_surface() {
        let m = sphere_mesh(32);
        for v in &m.vertices {
            let r = v.norm();
            assert!((r - 0.75).abs() < 0.08, "vertex at radius {r}");
        }
    }

    #[test]
    fn sphere_area_converges() {
        let exact = 4.0 * std::f64::consts::PI * 0.75f64 * 0.75;
        let a = sphere_mesh(48).total_area();
        assert!((a - exact).abs() / exact < 0.03, "area {a} vs {exact}");
    }

    #[test]
    fn torus_genus_one() {
        let t = Torus::new(Vec3::ZERO, Vec3::new(0.0, 0.0, 1.0), 0.5, 0.2);
        let m = polygonize(
            &t,
            Aabb::new(Vec3::new(-0.9, -0.9, -0.35), Vec3::new(0.9, 0.9, 0.35)),
            48,
        );
        let st = m.stats();
        assert!(st.watertight);
        assert_eq!(st.components, 1);
        assert_eq!(st.genus, Some(1), "{st:?}");
    }

    #[test]
    fn orientation_points_outward() {
        let m = sphere_mesh(24);
        for (i, _) in m.faces.iter().enumerate() {
            let t = m.triangle(i);
            let n = match t.normal() {
                Some(n) => n,
                None => continue, // degenerate sliver, no orientation
            };
            let out = t.centroid().normalized().unwrap();
            assert!(n.dot(out) > 0.0, "face {i} inward");
        }
    }

    #[test]
    fn resolution_scales_triangle_count() {
        let lo = sphere_mesh(12).faces.len();
        let hi = sphere_mesh(24).faces.len();
        assert!(hi > 3 * lo, "lo={lo} hi={hi}");
    }

    #[test]
    fn empty_field_gives_empty_mesh() {
        let s = Sphere::new(Vec3::splat(100.0), 0.1); // far outside bounds
        let m = polygonize(&s, Aabb::new(Vec3::splat(-1.0), Vec3::splat(1.0)), 8);
        assert!(m.is_empty());
    }

    #[test]
    fn anisotropic_bounds_respected() {
        let spec = GridSpec::cubic(
            Aabb::new(Vec3::new(-2.0, -1.0, -0.5), Vec3::new(2.0, 1.0, 0.5)),
            32,
        );
        assert_eq!(spec.nx, 32);
        assert_eq!(spec.ny, 16);
        assert_eq!(spec.nz, 8);
    }
}
