//! Kuhn decomposition of the unit cube into 6 tetrahedra.
//!
//! Cube corners are numbered by bits: bit 0 → x, bit 1 → y, bit 2 → z, so
//! corner 0 = (0,0,0) and corner 7 = (1,1,1). Each tetrahedron is a monotone
//! path 0 → a → b → 7 along cube edges (one per permutation of the three
//! axis steps). All six share the main diagonal 0–7.
//!
//! Why this decomposition: the diagonal it induces on each cube *face* is
//! determined by the face alone (e.g. face x=1 always gets 1–7, face x=0
//! always 0–6, which coincide between x-neighbors). Using the same
//! decomposition in every cell therefore makes triangulations of adjacent
//! cells agree on the shared face — the property that guarantees watertight
//! output (verified by `mesh::stats` in the polygonizer tests).

/// The 6 Kuhn tetrahedra, as cube-corner indices. Order within each tet is
/// the monotone path (0, first step, second step, 7).
pub const KUHN_TETS: [[u8; 4]; 6] = [
    [0, 1, 3, 7], // x, y, z
    [0, 1, 5, 7], // x, z, y
    [0, 2, 3, 7], // y, x, z
    [0, 2, 6, 7], // y, z, x
    [0, 4, 5, 7], // z, x, y
    [0, 4, 6, 7], // z, y, x
];

/// Offset of cube corner `c` (bit 0 → x, bit 1 → y, bit 2 → z).
#[inline]
pub fn cube_corner_offset(c: u8) -> (u32, u32, u32) {
    ((c & 1) as u32, ((c >> 1) & 1) as u32, ((c >> 2) & 1) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn six_distinct_tets_cover_all_corners() {
        let mut seen: HashSet<[u8; 4]> = HashSet::new();
        let mut corners: HashSet<u8> = HashSet::new();
        for t in KUHN_TETS {
            assert!(seen.insert(t), "duplicate tet {t:?}");
            corners.extend(t);
            // Every tet contains the main diagonal.
            assert_eq!(t[0], 0);
            assert_eq!(t[3], 7);
        }
        assert_eq!(corners, (0..8).collect());
    }

    #[test]
    fn tets_are_monotone_paths() {
        for t in KUHN_TETS {
            // Each step sets exactly one additional bit.
            for w in t.windows(2) {
                let diff = w[0] ^ w[1];
                assert_eq!(diff.count_ones(), 1, "non-edge step in {t:?}");
                assert_eq!(w[0] & diff, 0, "bit cleared along path {t:?}");
            }
        }
    }

    #[test]
    fn tets_tile_the_cube_by_volume() {
        // Volume of a tet with corners a,b,c,d = |det(b-a, c-a, d-a)| / 6.
        let corner = |c: u8| {
            let (x, y, z) = cube_corner_offset(c);
            [x as f64, y as f64, z as f64]
        };
        let sub = |a: [f64; 3], b: [f64; 3]| [a[0] - b[0], a[1] - b[1], a[2] - b[2]];
        let det = |u: [f64; 3], v: [f64; 3], w: [f64; 3]| {
            u[0] * (v[1] * w[2] - v[2] * w[1]) - u[1] * (v[0] * w[2] - v[2] * w[0])
                + u[2] * (v[0] * w[1] - v[1] * w[0])
        };
        let mut total = 0.0;
        for t in KUHN_TETS {
            let (a, b, c, d) = (corner(t[0]), corner(t[1]), corner(t[2]), corner(t[3]));
            let v = det(sub(b, a), sub(c, a), sub(d, a)).abs() / 6.0;
            assert!((v - 1.0 / 6.0).abs() < 1e-12, "tet {t:?} volume {v}");
            total += v;
        }
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shared_face_diagonals_match_between_neighbors() {
        // Face x=1 of a cell must use diagonal {1,7}; face x=0 must use
        // {0,6}; these are the same world edge for x-neighbors. Likewise
        // y: {2,7}/{0,5}, z: {4,7}/{0,3}.
        let face_diag = |corners: [u8; 4]| {
            // Collect tets with 3 corners on the face; the repeated pair of
            // corner sets share the diagonal.
            let inface: Vec<Vec<u8>> = KUHN_TETS
                .iter()
                .map(|t| t.iter().copied().filter(|c| corners.contains(c)).collect())
                .filter(|v: &Vec<u8>| v.len() == 3)
                .collect();
            assert_eq!(inface.len(), 2, "face {corners:?}");
            let a: HashSet<u8> = inface[0].iter().copied().collect();
            let b: HashSet<u8> = inface[1].iter().copied().collect();
            let mut shared: Vec<u8> = a.intersection(&b).copied().collect();
            shared.sort_unstable();
            shared
        };
        assert_eq!(face_diag([1, 3, 5, 7]), vec![1, 7]); // x = 1
        assert_eq!(face_diag([0, 2, 4, 6]), vec![0, 6]); // x = 0
        assert_eq!(face_diag([2, 3, 6, 7]), vec![2, 7]); // y = 1
        assert_eq!(face_diag([0, 1, 4, 5]), vec![0, 5]); // y = 0
        assert_eq!(face_diag([4, 5, 6, 7]), vec![4, 7]); // z = 1
        assert_eq!(face_diag([0, 1, 2, 3]), vec![0, 3]); // z = 0
        // Correspondence across the shared face: +x neighbor's {0,6} is this
        // cell's {1,7} (add bit 0), +y neighbor's {0,5} is {2,7} (add bit 1),
        // +z neighbor's {0,3} is {4,7} (add bit 2).
        assert_eq!([0 | 1, 6 | 1], [1, 7]);
        assert_eq!([0 | 2, 5 | 2], [2, 7]);
        assert_eq!([0 | 4, 3 | 4], [4, 7]);
    }
}
