//! Polygonization of implicit surfaces by **marching tetrahedra**.
//!
//! Marching tetrahedra is chosen over classic marching cubes deliberately:
//! the Kuhn 6-tetrahedra decomposition shares face diagonals between
//! neighboring cells, so the extracted surface is watertight and 2-manifold
//! *by construction* — no 256-entry case table whose transcription errors
//! would silently corrupt the genus tests that pin our benchmark shapes
//! (DESIGN.md §3). The cost is ~2× more triangles, which is irrelevant here:
//! meshes are generated once per run and only ever *sampled*.

mod kuhn;
mod polygonize;

pub use kuhn::{cube_corner_offset, KUHN_TETS};
pub use polygonize::{polygonize, GridSpec};

use crate::geometry::Aabb;
use crate::implicit::Field;
use crate::mesh::Mesh;

/// Convenience wrapper: polygonize `field` over `bounds` with a cubic grid
/// of `resolution` cells along the longest axis.
pub fn polygonize_simple(field: &dyn Field, bounds: Aabb, resolution: u32) -> Mesh {
    polygonize(field, bounds, resolution)
}
