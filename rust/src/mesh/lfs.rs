//! Local feature size (LFS) estimation.
//!
//! The paper characterizes its benchmark meshes by genus and by the LFS
//! distribution — "the minimal distance to the medial axis" (§3.1, citing
//! Amenta & Bern): the Bunny has "non-negligible variations", Eight
//! "relatively constant LFS almost everywhere", the Hand "widely variable …
//! in many areas considerably low", the Heptoroid "low and variable". Our
//! proxy meshes must reproduce these *profiles*, not just the genus — this
//! module measures them (and `rust/tests/integration.rs` pins them).
//!
//! Estimator: the classic *shrinking-ball / maximal-ball* bound. For a
//! vertex `v` with outward normal `n`, any other surface point `w` bounds
//! the radius of the medial ball tangent at `v`:
//!
//! `r(v, w) = ‖w − v‖² / (2 · |n · (w − v)|)`
//!
//! (the radius of the sphere through `w` tangent to the surface at `v`).
//! `LFS(v) ≈ min over w of r(v, w)`, taking both sides of the surface into
//! account via the absolute value. Exact for dense samples; we evaluate on
//! a vertex subsample for speed.

use crate::geometry::Vec3;
use crate::rng::Rng;

use super::Mesh;

/// Summary of an LFS distribution (mesh-scale units).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LfsStats {
    pub min: f32,
    pub p05: f32,
    pub median: f32,
    pub mean: f32,
    pub max: f32,
    /// Coefficient of variation (stddev / mean) — the paper's
    /// "constant vs widely variable" axis.
    pub cv: f32,
    pub samples: usize,
}

/// Area-weighted pseudo-normals per vertex (right-hand face orientation).
pub fn vertex_normals(mesh: &Mesh) -> Vec<Vec3> {
    let mut normals = vec![Vec3::ZERO; mesh.vertices.len()];
    for f in 0..mesh.faces.len() {
        let [a, b, c] = mesh.faces[f];
        let t = mesh.triangle(f);
        // Cross product length = 2·area: weighting falls out naturally.
        let n = (t.b - t.a).cross(t.c - t.a);
        normals[a as usize] += n;
        normals[b as usize] += n;
        normals[c as usize] += n;
    }
    for n in &mut normals {
        *n = n.normalized().unwrap_or(Vec3::ZERO);
    }
    // Two rounds of one-ring averaging: marching-tetrahedra triangles are
    // irregular and raw area-weighted normals carry ~5-10° of noise, which
    // biases the shrinking-ball minimum low (r = R/(1 + 2Rδθ/‖d‖)).
    let mut ring: Vec<Vec<u32>> = vec![Vec::new(); mesh.vertices.len()];
    for &[a, b, c] in &mesh.faces {
        for (u, v) in [(a, b), (b, c), (c, a)] {
            if !ring[u as usize].contains(&v) {
                ring[u as usize].push(v);
            }
            if !ring[v as usize].contains(&u) {
                ring[v as usize].push(u);
            }
        }
    }
    for _ in 0..2 {
        let prev = normals.clone();
        for (i, nbrs) in ring.iter().enumerate() {
            let mut acc = prev[i] * 2.0; // keep some of the own normal
            for &j in nbrs {
                acc += prev[j as usize];
            }
            normals[i] = acc.normalized().unwrap_or(prev[i]);
        }
    }
    normals
}

/// Mean edge length (over a face sample) — the discretization scale.
pub fn mean_edge_length(mesh: &Mesh, rng: &mut Rng) -> f32 {
    let faces = mesh.faces.len();
    assert!(faces > 0);
    let picks = faces.min(512);
    let mut acc = 0.0f64;
    for _ in 0..picks {
        let t = mesh.triangle(rng.index(faces));
        acc += (t.a.dist(t.b) + t.b.dist(t.c) + t.c.dist(t.a)) as f64 / 3.0;
    }
    (acc / picks as f64) as f32
}

/// Estimate the LFS at `sample_count` random vertices against all vertices.
///
/// Pairs closer than `2.5 × mean edge length` are excluded: at that range
/// the marching-grid position noise `δ` dominates the normal offset and the
/// bound degenerates to `ε²/2δ ≈ O(cell)` regardless of the true LFS, so
/// thin features below the discretization scale are clipped rather than
/// spuriously reported. `O(sample_count · V)`.
pub fn estimate_lfs(mesh: &Mesh, sample_count: usize, rng: &mut Rng) -> LfsStats {
    assert!(!mesh.vertices.is_empty(), "empty mesh");
    let normals = vertex_normals(mesh);
    let v_count = mesh.vertices.len();
    let picks = sample_count.min(v_count);
    let cutoff = 2.5 * mean_edge_length(mesh, rng);
    let cutoff_sq = cutoff * cutoff;

    let mut values = Vec::with_capacity(picks);
    for _ in 0..picks {
        let i = rng.index(v_count);
        let v = mesh.vertices[i];
        let n = normals[i];
        if n == Vec3::ZERO {
            continue;
        }
        let mut best = f32::INFINITY;
        for (j, &w) in mesh.vertices.iter().enumerate() {
            if j == i {
                continue;
            }
            let d = w - v;
            let d2 = d.norm2();
            if d2 < cutoff_sq {
                continue; // below the discretization scale (see above)
            }
            let h = n.dot(d).abs();
            // Guard near-tangent pairs: they bound r by (near) infinity.
            if h > 1e-9 {
                let r = d2 / (2.0 * h);
                if r < best {
                    best = r;
                }
            }
        }
        if best.is_finite() {
            values.push(best);
        }
    }
    assert!(!values.is_empty(), "no valid LFS samples");
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let q = |p: f64| values[((values.len() - 1) as f64 * p) as usize];
    let mean = values.iter().sum::<f32>() / values.len() as f32;
    let var = values
        .iter()
        .map(|x| (x - mean) * (x - mean))
        .sum::<f32>()
        / values.len() as f32;
    LfsStats {
        min: values[0],
        p05: q(0.05),
        median: q(0.5),
        mean,
        max: *values.last().unwrap(),
        cv: var.sqrt() / mean,
        samples: values.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Aabb;
    use crate::implicit::{Sphere, Torus};
    use crate::marching::polygonize;

    #[test]
    fn sphere_lfs_is_the_radius() {
        // The medial axis of a sphere is its center: LFS == radius
        // everywhere, with near-zero variation.
        let s = Sphere::new(Vec3::ZERO, 0.7);
        let mesh = polygonize(&s, Aabb::new(Vec3::splat(-1.0), Vec3::splat(1.0)), 40);
        let mut rng = Rng::seed_from(1);
        let stats = estimate_lfs(&mesh, 300, &mut rng);
        // The estimator is a lower bound with discretization noise: accept
        // a 25% low bias; what matters for the benchmark characterization
        // is the *profile* (near-constant here).
        assert!(
            stats.median > 0.5 && stats.median < 0.8,
            "sphere LFS should be ≈0.7: {stats:?}"
        );
        assert!(stats.cv < 0.25, "sphere LFS must be ~constant: {stats:?}");
    }

    #[test]
    fn torus_lfs_is_the_tube_radius() {
        // For a torus with minor radius r << R the medial tube dominates:
        // LFS ≈ r on most of the surface.
        let t = Torus::new(Vec3::ZERO, Vec3::new(0.0, 0.0, 1.0), 0.6, 0.15);
        let mesh = polygonize(
            &t,
            Aabb::new(Vec3::new(-0.9, -0.9, -0.3), Vec3::new(0.9, 0.9, 0.3)),
            56,
        );
        let mut rng = Rng::seed_from(2);
        let stats = estimate_lfs(&mesh, 300, &mut rng);
        assert!(
            stats.median > 0.09 && stats.median < 0.2,
            "torus LFS should be ≈0.15: {stats:?}"
        );
    }

    #[test]
    fn normals_point_outward_on_sphere() {
        let s = Sphere::new(Vec3::ZERO, 0.5);
        let mesh = polygonize(&s, Aabb::new(Vec3::splat(-1.0), Vec3::splat(1.0)), 24);
        let normals = vertex_normals(&mesh);
        for (v, n) in mesh.vertices.iter().zip(&normals) {
            assert!(v.normalized().unwrap().dot(*n) > 0.7);
        }
    }

    #[test]
    fn stats_are_ordered() {
        let s = Sphere::new(Vec3::ZERO, 0.5);
        let mesh = polygonize(&s, Aabb::new(Vec3::splat(-1.0), Vec3::splat(1.0)), 24);
        let mut rng = Rng::seed_from(3);
        let st = estimate_lfs(&mesh, 200, &mut rng);
        assert!(st.min <= st.p05 && st.p05 <= st.median && st.median <= st.max);
        assert!(st.samples > 100);
    }
}
