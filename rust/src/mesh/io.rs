//! Wavefront OBJ and OFF mesh IO (the two formats the original benchmark
//! meshes circulate in). Reader accepts the common minimal subsets; writer
//! emits canonical files that round-trip through the reader.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::geometry::Vec3;

use super::Mesh;

/// Cap on up-front `Vec` reservations from parsed counts: a corrupt or
/// hostile counts line must not drive a huge allocation before any actual
/// data is validated (the vectors still grow to whatever the file really
/// contains).
const MAX_RESERVE: usize = 1 << 20;

/// Read a Wavefront OBJ (v/f lines; polygons are fan-triangulated;
/// `v/vt/vn` face syntax accepted, negative indices resolved).
pub fn read_obj(path: &Path) -> Result<Mesh> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading OBJ {}", path.display()))?;
    parse_obj(&text)
}

/// Parse OBJ text. Total on arbitrary input: malformed, truncated or
/// non-finite (NaN/inf coordinate) documents return `Err`, never panic —
/// property-tested over a mutation corpus in `rust/tests/properties.rs`.
pub fn parse_obj(text: &str) -> Result<Mesh> {
    let mut vertices = Vec::new();
    let mut faces = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        let mut it = line.split_whitespace();
        match it.next() {
            Some("v") => {
                let mut coord = |what| -> Result<f32> {
                    let v: f32 = it
                        .next()
                        .with_context(|| format!("line {}: missing {what}", lineno + 1))?
                        .parse()
                        .with_context(|| format!("line {}: bad {what}", lineno + 1))?;
                    if !v.is_finite() {
                        bail!("line {}: non-finite {what} ({v})", lineno + 1);
                    }
                    Ok(v)
                };
                let (x, y, z) = (coord("x")?, coord("y")?, coord("z")?);
                vertices.push(Vec3::new(x, y, z));
            }
            Some("f") => {
                let idx: Vec<u32> = it
                    .map(|tok| parse_obj_index(tok, vertices.len(), lineno))
                    .collect::<Result<_>>()?;
                if idx.len() < 3 {
                    bail!("line {}: face with {} vertices", lineno + 1, idx.len());
                }
                for k in 1..idx.len() - 1 {
                    faces.push([idx[0], idx[k], idx[k + 1]]);
                }
            }
            _ => {} // comments, normals, groups… ignored
        }
    }
    Ok(Mesh::new(vertices, faces))
}

fn parse_obj_index(tok: &str, nverts: usize, lineno: usize) -> Result<u32> {
    let first = tok.split('/').next().unwrap_or("");
    let i: i64 = first
        .parse()
        .with_context(|| format!("line {}: bad face index {tok:?}", lineno + 1))?;
    let resolved = if i < 0 { nverts as i64 + i } else { i - 1 };
    if resolved < 0 || resolved >= nverts as i64 {
        bail!("line {}: face index {i} out of range", lineno + 1);
    }
    Ok(resolved as u32)
}

/// Write a Wavefront OBJ.
pub fn write_obj(mesh: &Mesh, path: &Path) -> Result<()> {
    let mut out = String::with_capacity(mesh.vertices.len() * 32);
    out.push_str("# msgsn mesh\n");
    for v in &mesh.vertices {
        out.push_str(&format!("v {} {} {}\n", v.x, v.y, v.z));
    }
    for f in &mesh.faces {
        out.push_str(&format!("f {} {} {}\n", f[0] + 1, f[1] + 1, f[2] + 1));
    }
    fs::File::create(path)
        .and_then(|mut f| f.write_all(out.as_bytes()))
        .with_context(|| format!("writing OBJ {}", path.display()))
}

/// Read an OFF file (header `OFF`, counts line, vertices, faces).
pub fn read_off(path: &Path) -> Result<Mesh> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading OFF {}", path.display()))?;
    parse_off(&text)
}

/// Parse OFF text. Total on arbitrary input: malformed, truncated or
/// non-finite (NaN/inf coordinate) documents return `Err`, never panic —
/// counts from the header are bounded before any reservation, so a corrupt
/// counts line cannot drive a huge allocation either. Property-tested over
/// a mutation corpus in `rust/tests/properties.rs`.
pub fn parse_off(text: &str) -> Result<Mesh> {
    let mut tokens = text
        .lines()
        .filter(|l| !l.trim_start().starts_with('#'))
        .flat_map(|l| l.split_whitespace());
    match tokens.next() {
        Some("OFF") => {}
        other => bail!("not an OFF file (header {:?})", other),
    }
    let mut next_usize = |what: &str| -> Result<usize> {
        tokens
            .next()
            .with_context(|| format!("OFF: missing {what}"))?
            .parse()
            .with_context(|| format!("OFF: bad {what}"))
    };
    let nv = next_usize("vertex count")?;
    let nf = next_usize("face count")?;
    let _ne = next_usize("edge count")?;
    // Re-create the iterator state by collecting remaining tokens.
    let rest: Vec<&str> = tokens.collect();
    let mut pos = 0;
    let mut take = |what: &str| -> Result<&str> {
        let t = rest.get(pos).copied().with_context(|| format!("OFF: missing {what}"))?;
        pos += 1;
        Ok(t)
    };
    // Counts are only trusted up to the token budget actually present: a
    // header claiming 10^18 vertices fails on the first missing token, so
    // reservations are clamped (the vectors still grow as far as real
    // tokens carry them).
    if nv.saturating_mul(3) > rest.len() {
        bail!("OFF: header claims {nv} vertices but only {} tokens follow", rest.len());
    }
    let mut vertices = Vec::with_capacity(nv.min(MAX_RESERVE));
    for _ in 0..nv {
        let parse_coord = |tok: &str, what: &str| -> Result<f32> {
            let v: f32 = tok.parse().with_context(|| format!("OFF: bad {what}"))?;
            if !v.is_finite() {
                bail!("OFF: non-finite {what} ({v})");
            }
            Ok(v)
        };
        let x = parse_coord(take("x")?, "x")?;
        let y = parse_coord(take("y")?, "y")?;
        let z = parse_coord(take("z")?, "z")?;
        vertices.push(Vec3::new(x, y, z));
    }
    let mut faces = Vec::with_capacity(nf.min(MAX_RESERVE));
    for _ in 0..nf {
        let k: usize = take("face arity")?.parse().context("OFF: bad arity")?;
        if k < 3 {
            bail!("OFF: face with {k} vertices");
        }
        if k > rest.len() {
            bail!("OFF: face arity {k} exceeds the file's token count");
        }
        let mut idx = Vec::with_capacity(k);
        for _ in 0..k {
            let i: u32 = take("face index")?.parse().context("OFF: bad index")?;
            if i as usize >= nv {
                bail!("OFF: index {i} out of range");
            }
            idx.push(i);
        }
        for j in 1..k - 1 {
            faces.push([idx[0], idx[j], idx[j + 1]]);
        }
    }
    Ok(Mesh::new(vertices, faces))
}

/// Write an OFF file.
pub fn write_off(mesh: &Mesh, path: &Path) -> Result<()> {
    let mut out = String::with_capacity(mesh.vertices.len() * 32);
    out.push_str("OFF\n");
    out.push_str(&format!(
        "{} {} 0\n",
        mesh.vertices.len(),
        mesh.faces.len()
    ));
    for v in &mesh.vertices {
        out.push_str(&format!("{} {} {}\n", v.x, v.y, v.z));
    }
    for f in &mesh.faces {
        out.push_str(&format!("3 {} {} {}\n", f[0], f[1], f[2]));
    }
    fs::File::create(path)
        .and_then(|mut f| f.write_all(out.as_bytes()))
        .with_context(|| format!("writing OFF {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::super::core::octahedron;
    use super::*;

    #[test]
    fn obj_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("msgsn_test_roundtrip.obj");
        let m = octahedron();
        write_obj(&m, &path).unwrap();
        let back = read_obj(&path).unwrap();
        assert_eq!(back.vertices.len(), m.vertices.len());
        assert_eq!(back.faces, m.faces);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn off_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("msgsn_test_roundtrip.off");
        let m = octahedron();
        write_off(&m, &path).unwrap();
        let back = read_off(&path).unwrap();
        assert_eq!(back.vertices.len(), m.vertices.len());
        assert_eq!(back.faces, m.faces);
        assert_eq!(back.stats().genus, Some(0));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn obj_quad_triangulated() {
        let m = parse_obj("v 0 0 0\nv 1 0 0\nv 1 1 0\nv 0 1 0\nf 1 2 3 4\n").unwrap();
        assert_eq!(m.faces, vec![[0, 1, 2], [0, 2, 3]]);
    }

    #[test]
    fn obj_slash_and_negative_indices() {
        let m = parse_obj("v 0 0 0\nv 1 0 0\nv 0 1 0\nf 1/1/1 2/2/2 -1/3\n").unwrap();
        assert_eq!(m.faces, vec![[0, 1, 2]]);
    }

    #[test]
    fn obj_bad_index_errors() {
        assert!(parse_obj("v 0 0 0\nf 1 2 3\n").is_err());
    }

    #[test]
    fn off_header_required() {
        assert!(parse_off("NOFF\n0 0 0\n").is_err());
    }

    #[test]
    fn off_with_comments() {
        let text = "OFF\n# a comment\n3 1 0\n0 0 0\n1 0 0\n0 1 0\n3 0 1 2\n";
        let m = parse_off(text).unwrap();
        assert_eq!(m.faces, vec![[0, 1, 2]]);
    }

    #[test]
    fn non_finite_coordinates_rejected() {
        for bad in ["nan", "NaN", "inf", "-inf", "1e999"] {
            let obj = format!("v {bad} 0 0\nv 1 0 0\nv 0 1 0\nf 1 2 3\n");
            assert!(parse_obj(&obj).is_err(), "OBJ accepted {bad}");
            let off = format!("OFF\n3 1 0\n{bad} 0 0\n1 0 0\n0 1 0\n3 0 1 2\n");
            assert!(parse_off(&off).is_err(), "OFF accepted {bad}");
        }
    }

    #[test]
    fn absurd_counts_error_without_allocating() {
        // A counts line claiming ~10^18 elements must fail fast (token
        // budget check), not reserve terabytes.
        assert!(parse_off("OFF\n999999999999999999 1 0\n0 0 0\n").is_err());
        assert!(parse_off("OFF\n3 999999999999999999 0\n0 0 0\n1 0 0\n0 1 0\n").is_err());
        // Huge face arity likewise.
        assert!(parse_off("OFF\n3 1 0\n0 0 0\n1 0 0\n0 1 0\n999999999 0 1 2\n").is_err());
    }
}
