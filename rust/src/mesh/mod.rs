//! Indexed triangle meshes: storage, IO, topology statistics and the
//! uniform surface sampler that produces the paper's input signals.

mod core;
mod io;
pub mod lfs;
mod sampler;

pub use core::{Mesh, MeshStats};
pub use io::{parse_obj, parse_off, read_obj, read_off, write_obj, write_off};
pub use lfs::{estimate_lfs, LfsStats};
pub use sampler::SurfaceSampler;

use crate::implicit::shapes;
use crate::marching;

/// The four benchmark point-cloud sources, in the paper's order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BenchmarkShape {
    /// Stanford-Bunny proxy (genus 0).
    Blob,
    /// Double torus (genus 2).
    Eight,
    /// Skeleton-hand proxy (genus 5).
    Hand,
    /// Heptoroid proxy (genus 22).
    Heptoroid,
}

impl BenchmarkShape {
    pub const ALL: [BenchmarkShape; 4] = [
        BenchmarkShape::Blob,
        BenchmarkShape::Eight,
        BenchmarkShape::Hand,
        BenchmarkShape::Heptoroid,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BenchmarkShape::Blob => "blob",
            BenchmarkShape::Eight => "eight",
            BenchmarkShape::Hand => "hand",
            BenchmarkShape::Heptoroid => "heptoroid",
        }
    }

    /// The paper mesh this shape stands in for.
    pub fn paper_name(self) -> &'static str {
        match self {
            BenchmarkShape::Blob => "Stanford Bunny",
            BenchmarkShape::Eight => "Eight",
            BenchmarkShape::Hand => "Skeleton Hand",
            BenchmarkShape::Heptoroid => "Heptoroid",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "blob" | "bunny" => Some(BenchmarkShape::Blob),
            "eight" => Some(BenchmarkShape::Eight),
            "hand" => Some(BenchmarkShape::Hand),
            "heptoroid" => Some(BenchmarkShape::Heptoroid),
            _ => None,
        }
    }

    pub fn expected_genus(self) -> u32 {
        self.shape().genus
    }

    pub fn shape(self) -> shapes::Shape {
        match self {
            BenchmarkShape::Blob => shapes::blob(),
            BenchmarkShape::Eight => shapes::eight(),
            BenchmarkShape::Hand => shapes::hand(),
            BenchmarkShape::Heptoroid => shapes::heptoroid(),
        }
    }

    pub fn default_resolution(self) -> u32 {
        self.shape().default_resolution
    }
}

/// Polygonize one benchmark shape at the given grid resolution
/// (`resolution == 0` selects the shape's default) and normalize it into the
/// unit cube, matching the paper's setup where per-mesh parameters are
/// comparable across shapes.
pub fn benchmark_mesh(shape: BenchmarkShape, resolution: u32) -> Mesh {
    let s = shape.shape();
    let res = if resolution == 0 { s.default_resolution } else { resolution };
    let mut mesh = marching::polygonize(s.field.as_ref(), s.bounds, res);
    mesh.normalize_to_unit_cube();
    mesh
}
