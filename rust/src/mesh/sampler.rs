//! Uniform surface sampling — the paper's Sample phase.
//!
//! "In each experiment, the point cloud was taken from a triangular mesh and
//! sampled with uniform probability distribution P(ξ)" (§3.1). Uniform over
//! *area* means: choose a face with probability ∝ its area (binary search on
//! the cumulative area table), then a uniform point inside it (square-root
//! barycentric trick in `geometry::Triangle`).

use crate::geometry::{Aabb, Vec3};
use crate::rng::Rng;

use super::Mesh;

/// Pre-built area-weighted sampler over a mesh surface.
pub struct SurfaceSampler {
    triangles: Vec<crate::geometry::Triangle>,
    /// Cumulative areas; `cdf[i]` = total area of faces `0..=i`.
    cdf: Vec<f64>,
    total_area: f64,
    /// Bounding box of the sampled surface — every sample (and every unit
    /// position derived from samples by convex combination) lies inside.
    /// This is the bounding volume the `regions` partition cuts up.
    bounds: Aabb,
}

impl SurfaceSampler {
    /// Build the cumulative table. Degenerate (zero-area) faces are kept in
    /// the table with zero mass — they can never be selected.
    pub fn new(mesh: &Mesh) -> Self {
        let triangles: Vec<_> = (0..mesh.faces.len()).map(|f| mesh.triangle(f)).collect();
        let mut cdf = Vec::with_capacity(triangles.len());
        let mut acc = 0.0f64;
        for t in &triangles {
            acc += t.area() as f64;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "cannot sample a zero-area mesh");
        Self { triangles, cdf, total_area: acc, bounds: mesh.bounds() }
    }

    pub fn total_area(&self) -> f64 {
        self.total_area
    }

    /// Bounding box of the surface being sampled.
    pub fn bounds(&self) -> Aabb {
        self.bounds
    }

    /// One uniform sample from the surface.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> Vec3 {
        let target = rng.f64() * self.total_area;
        // First face whose cumulative area exceeds the target.
        let mut lo = 0usize;
        let mut hi = self.cdf.len() - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.cdf[mid] <= target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        self.triangles[lo].sample_uniform(rng)
    }

    /// Fill `out` with `count` samples (hot-path variant reusing the output
    /// buffer — the multi-signal driver calls this every iteration).
    pub fn sample_batch(&self, rng: &mut Rng, count: usize, out: &mut Vec<Vec3>) {
        out.clear();
        out.reserve(count);
        for _ in 0..count {
            out.push(self.sample(rng));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::core::octahedron;
    use super::*;
    use crate::geometry::Triangle;
    use crate::mesh::Mesh;

    #[test]
    fn samples_lie_on_surface() {
        let m = octahedron();
        let s = SurfaceSampler::new(&m);
        let mut rng = Rng::seed_from(1);
        for _ in 0..2000 {
            let p = s.sample(&mut rng);
            // Octahedron surface: |x|+|y|+|z| = 1.
            let l1 = p.x.abs() + p.y.abs() + p.z.abs();
            assert!((l1 - 1.0).abs() < 1e-5, "{l1}");
        }
    }

    #[test]
    fn area_weighting_respected() {
        // Two triangles: one with 4x the area of the other.
        let m = Mesh::new(
            vec![
                Vec3::ZERO,
                Vec3::new(1.0, 0.0, 0.0),
                Vec3::new(0.0, 1.0, 0.0),
                Vec3::new(5.0, 0.0, 0.0),
                Vec3::new(3.0, 2.0, 0.0),
                Vec3::new(5.0, 2.0, 0.0),
            ],
            vec![[0, 1, 2], [3, 4, 5]],
        );
        let big_area = m.triangle(1).area();
        let small_area = m.triangle(0).area();
        let ratio = (big_area / small_area) as f64;
        let s = SurfaceSampler::new(&m);
        let mut rng = Rng::seed_from(3);
        let n = 40_000;
        let mut big = 0usize;
        for _ in 0..n {
            if s.sample(&mut rng).x > 2.0 {
                big += 1;
            }
        }
        let got = big as f64 / (n - big) as f64;
        assert!((got - ratio).abs() / ratio < 0.1, "got {got}, want {ratio}");
    }

    #[test]
    fn degenerate_faces_never_selected() {
        let m = Mesh::new(
            vec![
                Vec3::ZERO,
                Vec3::new(1.0, 0.0, 0.0),
                Vec3::new(0.0, 1.0, 0.0),
                Vec3::new(9.0, 9.0, 9.0),
            ],
            vec![[3, 3, 3], [0, 1, 2]],
        );
        let s = SurfaceSampler::new(&m);
        let mut rng = Rng::seed_from(5);
        for _ in 0..1000 {
            let p = s.sample(&mut rng);
            assert!(p.x < 2.0, "sampled the degenerate face at (9,9,9)");
        }
    }

    #[test]
    fn batch_reuses_buffer() {
        let m = octahedron();
        let s = SurfaceSampler::new(&m);
        let mut rng = Rng::seed_from(7);
        let mut buf = Vec::new();
        s.sample_batch(&mut rng, 128, &mut buf);
        assert_eq!(buf.len(), 128);
        s.sample_batch(&mut rng, 16, &mut buf);
        assert_eq!(buf.len(), 16);
    }

    #[test]
    fn sampler_total_area_matches_mesh() {
        let m = octahedron();
        let s = SurfaceSampler::new(&m);
        assert!((s.total_area() - m.total_area()).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "zero-area")]
    fn zero_area_mesh_panics() {
        let m = Mesh::new(vec![Vec3::ZERO; 3], vec![[0, 1, 2]]);
        let _ = SurfaceSampler::new(&m);
    }

    #[test]
    fn deterministic_given_seed() {
        let t = Triangle::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0), Vec3::new(0.0, 1.0, 0.0));
        let m = Mesh::new(vec![t.a, t.b, t.c], vec![[0, 1, 2]]);
        let s = SurfaceSampler::new(&m);
        let mut a = Rng::seed_from(99);
        let mut b = Rng::seed_from(99);
        for _ in 0..100 {
            assert_eq!(s.sample(&mut a), s.sample(&mut b));
        }
    }
}
