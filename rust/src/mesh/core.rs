//! Mesh storage and topology statistics.
//!
//! The statistics matter for the reproduction: the benchmark meshes are
//! *defined* by their genus (topological complexity) and LFS profile
//! (geometric complexity), and the SOAM termination check verifies the
//! reconstructed network is a closed 2-manifold of the right genus via the
//! same Euler-characteristic arithmetic implemented here.

use std::collections::HashMap;

use crate::geometry::{Aabb, Triangle, Vec3};

/// Indexed triangle mesh.
#[derive(Clone, Debug, Default)]
pub struct Mesh {
    pub vertices: Vec<Vec3>,
    pub faces: Vec<[u32; 3]>,
}

/// Topology / geometry summary of a mesh (see [`Mesh::stats`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeshStats {
    pub vertices: usize,
    pub edges: usize,
    pub faces: usize,
    /// `V − E + F`.
    pub euler_characteristic: i64,
    /// `(2·C − χ) / 2` summed over components — valid for closed orientable
    /// surfaces; `None` when the mesh is not watertight.
    pub genus: Option<u32>,
    pub components: usize,
    pub watertight: bool,
    pub total_area: f64,
}

impl Mesh {
    pub fn new(vertices: Vec<Vec3>, faces: Vec<[u32; 3]>) -> Self {
        Self { vertices, faces }
    }

    pub fn is_empty(&self) -> bool {
        self.faces.is_empty()
    }

    pub fn triangle(&self, f: usize) -> Triangle {
        let [a, b, c] = self.faces[f];
        Triangle::new(
            self.vertices[a as usize],
            self.vertices[b as usize],
            self.vertices[c as usize],
        )
    }

    pub fn bounds(&self) -> Aabb {
        Aabb::from_points(self.vertices.iter())
    }

    pub fn total_area(&self) -> f64 {
        (0..self.faces.len())
            .map(|f| self.triangle(f).area() as f64)
            .sum()
    }

    /// Unique undirected edges with their face-incidence counts.
    fn edge_counts(&self) -> HashMap<(u32, u32), u32> {
        let mut edges: HashMap<(u32, u32), u32> = HashMap::new();
        for &[a, b, c] in &self.faces {
            for (u, v) in [(a, b), (b, c), (c, a)] {
                let key = (u.min(v), u.max(v));
                *edges.entry(key).or_insert(0) += 1;
            }
        }
        edges
    }

    /// Number of connected components (over the face-edge graph restricted
    /// to referenced vertices).
    fn component_count(&self) -> usize {
        if self.vertices.is_empty() {
            return 0;
        }
        let n = self.vertices.len();
        let mut parent: Vec<u32> = (0..n as u32).collect();
        fn find(parent: &mut [u32], mut x: u32) -> u32 {
            while parent[x as usize] != x {
                parent[x as usize] = parent[parent[x as usize] as usize];
                x = parent[x as usize];
            }
            x
        }
        let mut referenced = vec![false; n];
        for &[a, b, c] in &self.faces {
            for v in [a, b, c] {
                referenced[v as usize] = true;
            }
            for (u, v) in [(a, b), (b, c)] {
                let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
                if ru != rv {
                    parent[ru as usize] = rv;
                }
            }
        }
        let mut roots = std::collections::HashSet::new();
        for v in 0..n as u32 {
            if referenced[v as usize] {
                roots.insert(find(&mut parent, v));
            }
        }
        roots.len()
    }

    /// Full statistics pass.
    pub fn stats(&self) -> MeshStats {
        let edges = self.edge_counts();
        let watertight = !self.faces.is_empty() && edges.values().all(|&c| c == 2);
        let v = self
            .faces
            .iter()
            .flat_map(|f| f.iter().copied())
            .collect::<std::collections::HashSet<_>>()
            .len();
        let e = edges.len();
        let f = self.faces.len();
        let chi = v as i64 - e as i64 + f as i64;
        let components = self.component_count();
        let genus = if watertight {
            let g2 = 2 * components as i64 - chi;
            if g2 >= 0 && g2 % 2 == 0 {
                Some((g2 / 2) as u32)
            } else {
                None
            }
        } else {
            None
        };
        MeshStats {
            vertices: v,
            edges: e,
            faces: f,
            euler_characteristic: chi,
            genus,
            components,
            watertight,
            total_area: self.total_area(),
        }
    }

    /// Translate + uniformly scale so the bounding box fits `[0,1]³`
    /// (centered on the longest axis). Keeps aspect ratio.
    pub fn normalize_to_unit_cube(&mut self) {
        if self.vertices.is_empty() {
            return;
        }
        let b = self.bounds();
        let scale = 1.0 / b.max_extent().max(1e-20);
        let center = b.center();
        for v in &mut self.vertices {
            *v = (*v - center) * scale + Vec3::splat(0.5);
        }
    }

    /// Drop vertices not referenced by any face, remapping indices.
    pub fn compact(&mut self) {
        let mut remap = vec![u32::MAX; self.vertices.len()];
        let mut kept = Vec::new();
        for f in &mut self.faces {
            for v in f.iter_mut() {
                let old = *v as usize;
                if remap[old] == u32::MAX {
                    remap[old] = kept.len() as u32;
                    kept.push(self.vertices[old]);
                }
                *v = remap[old];
            }
        }
        self.vertices = kept;
    }
}

/// A canonical closed test mesh: the regular octahedron (V=6, E=12, F=8,
/// genus 0). Used across the test suite.
#[cfg(test)]
pub fn octahedron() -> Mesh {
    let vertices = vec![
        Vec3::new(1.0, 0.0, 0.0),
        Vec3::new(-1.0, 0.0, 0.0),
        Vec3::new(0.0, 1.0, 0.0),
        Vec3::new(0.0, -1.0, 0.0),
        Vec3::new(0.0, 0.0, 1.0),
        Vec3::new(0.0, 0.0, -1.0),
    ];
    let faces = vec![
        [0, 2, 4],
        [2, 1, 4],
        [1, 3, 4],
        [3, 0, 4],
        [2, 0, 5],
        [1, 2, 5],
        [3, 1, 5],
        [0, 3, 5],
    ];
    Mesh::new(vertices, faces)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn octahedron_stats() {
        let s = octahedron().stats();
        assert_eq!(s.vertices, 6);
        assert_eq!(s.edges, 12);
        assert_eq!(s.faces, 8);
        assert_eq!(s.euler_characteristic, 2);
        assert_eq!(s.genus, Some(0));
        assert_eq!(s.components, 1);
        assert!(s.watertight);
    }

    #[test]
    fn open_mesh_is_not_watertight() {
        let mut m = octahedron();
        m.faces.pop();
        let s = m.stats();
        assert!(!s.watertight);
        assert_eq!(s.genus, None);
    }

    #[test]
    fn two_components_counted() {
        let mut m = octahedron();
        let other = octahedron();
        let off = m.vertices.len() as u32;
        m.vertices
            .extend(other.vertices.iter().map(|v| *v + Vec3::splat(10.0)));
        m.faces
            .extend(other.faces.iter().map(|f| [f[0] + off, f[1] + off, f[2] + off]));
        let s = m.stats();
        assert_eq!(s.components, 2);
        assert_eq!(s.euler_characteristic, 4);
        assert_eq!(s.genus, Some(0));
    }

    #[test]
    fn normalize_fits_unit_cube() {
        let mut m = octahedron();
        for v in &mut m.vertices {
            *v = *v * 37.0 + Vec3::new(5.0, -3.0, 100.0);
        }
        m.normalize_to_unit_cube();
        let b = m.bounds();
        assert!(b.min.x >= -1e-5 && b.max.x <= 1.0 + 1e-5);
        assert!((b.max_extent() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn compact_removes_orphans() {
        let mut m = octahedron();
        m.vertices.push(Vec3::splat(99.0)); // orphan
        m.compact();
        assert_eq!(m.vertices.len(), 6);
        assert_eq!(m.stats().genus, Some(0));
    }

    #[test]
    fn area_of_octahedron() {
        // 8 equilateral-right triangles with legs √2: area = 8·(√3/4·2) = 4√3.
        let a = octahedron().total_area();
        assert!((a - 4.0 * 3.0f64.sqrt()).abs() < 1e-5, "{a}");
    }
}
