//! Uniform spatial hash grid — the substrate of the paper's **Indexed**
//! variant (§3.1).
//!
//! "The hash index is constructed by defining a grid of cubes of fixed size
//! inside an axis-parallel bounding box that contains all the input
//! signals." Units are bucketed by cell; a query scans the signal's cell
//! plus its 26 neighbors and falls back to the exhaustive search when that
//! neighborhood holds fewer than two units. Maintenance (insert / move /
//! remove) happens during the Update phase and is O(1) per change.

use crate::geometry::{Aabb, Vec3};
use crate::som::{Network, UnitId};

/// Uniform grid over a fixed bounding box.
pub struct HashGrid {
    bounds: Aabb,
    cell: f32,
    dims: [u32; 3],
    buckets: Vec<Vec<UnitId>>,
    /// Where each unit currently lives (`u32::MAX` = not indexed).
    slot_of: Vec<u32>,
}

impl HashGrid {
    /// `cell` is the cube edge length ("index cube size" — the paper tunes
    /// it per run; `config` exposes it).
    pub fn new(bounds: Aabb, cell: f32) -> Self {
        assert!(cell > 0.0, "cell size must be positive");
        let e = bounds.extent();
        let dim = |len: f32| ((len / cell).ceil() as u32).max(1);
        let dims = [dim(e.x), dim(e.y), dim(e.z)];
        let total = dims[0] as usize * dims[1] as usize * dims[2] as usize;
        Self {
            bounds,
            cell,
            dims,
            buckets: vec![Vec::new(); total],
            slot_of: Vec::new(),
        }
    }

    pub fn cell_size(&self) -> f32 {
        self.cell
    }

    #[inline]
    fn coords(&self, p: Vec3) -> [u32; 3] {
        let rel = p - self.bounds.min;
        let clamp = |v: f32, d: u32| (v / self.cell).floor().clamp(0.0, (d - 1) as f32) as u32;
        [
            clamp(rel.x, self.dims[0]),
            clamp(rel.y, self.dims[1]),
            clamp(rel.z, self.dims[2]),
        ]
    }

    #[inline]
    fn flat(&self, c: [u32; 3]) -> usize {
        (c[0] as usize)
            + (c[1] as usize) * self.dims[0] as usize
            + (c[2] as usize) * self.dims[0] as usize * self.dims[1] as usize
    }

    fn ensure_slot_capacity(&mut self, id: UnitId) {
        if self.slot_of.len() <= id as usize {
            self.slot_of.resize(id as usize + 1, u32::MAX);
        }
    }

    /// Index a unit at `p`.
    pub fn insert(&mut self, id: UnitId, p: Vec3) {
        self.ensure_slot_capacity(id);
        debug_assert_eq!(self.slot_of[id as usize], u32::MAX, "unit {id} already indexed");
        let flat = self.flat(self.coords(p));
        self.buckets[flat].push(id);
        self.slot_of[id as usize] = flat as u32;
    }

    /// Remove a unit (position no longer needed — we remember its bucket).
    pub fn remove(&mut self, id: UnitId) {
        let slot = self.slot_of[id as usize];
        debug_assert_ne!(slot, u32::MAX, "unit {id} not indexed");
        let bucket = &mut self.buckets[slot as usize];
        let k = bucket.iter().position(|&u| u == id).expect("unit in recorded bucket");
        bucket.swap_remove(k);
        self.slot_of[id as usize] = u32::MAX;
    }

    /// Update a unit's cell after it moved to `p` (no-op when it stays in
    /// the same cell — the common case for small adaptation steps).
    pub fn update(&mut self, id: UnitId, p: Vec3) {
        let new_flat = self.flat(self.coords(p)) as u32;
        let old = self.slot_of[id as usize];
        if old == new_flat {
            return;
        }
        self.remove(id);
        self.buckets[new_flat as usize].push(id);
        self.slot_of[id as usize] = new_flat as u32;
    }

    /// Rebuild from a network (initialization / recovery).
    pub fn rebuild(&mut self, net: &Network) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.slot_of.clear();
        for id in net.ids() {
            self.insert(id, net.pos(id));
        }
    }

    /// Visit all units in the 3×3×3 cell neighborhood of `p`.
    #[inline]
    pub fn for_neighborhood(&self, p: Vec3, mut visit: impl FnMut(UnitId)) {
        let c = self.coords(p);
        let lo = |v: u32| v.saturating_sub(1);
        let hi = |v: u32, d: u32| (v + 1).min(d - 1);
        for z in lo(c[2])..=hi(c[2], self.dims[2]) {
            for y in lo(c[1])..=hi(c[1], self.dims[1]) {
                for x in lo(c[0])..=hi(c[0], self.dims[0]) {
                    for &id in &self.buckets[self.flat([x, y, z])] {
                        visit(id);
                    }
                }
            }
        }
    }

    /// Is `id` currently indexed? (Cheap: one `slot_of` probe.)
    #[inline]
    pub fn contains(&self, id: UnitId) -> bool {
        self.slot_of
            .get(id as usize)
            .is_some_and(|&s| s != u32::MAX)
    }

    /// Number of indexed units (for invariants/tests).
    pub fn len(&self) -> usize {
        self.slot_of.iter().filter(|&&s| s != u32::MAX).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Structural invariant: every recorded slot contains the unit.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (id, &slot) in self.slot_of.iter().enumerate() {
            if slot != u32::MAX {
                let b = &self.buckets[slot as usize];
                if !b.contains(&(id as UnitId)) {
                    return Err(format!("unit {id} missing from bucket {slot}"));
                }
            }
        }
        let total: usize = self.buckets.iter().map(|b| b.len()).sum();
        if total != self.len() {
            return Err(format!("bucket total {total} != indexed {}", self.len()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> HashGrid {
        HashGrid::new(Aabb::new(Vec3::ZERO, Vec3::ONE), 0.1)
    }

    #[test]
    fn insert_query_roundtrip() {
        let mut g = grid();
        g.insert(7, Vec3::new(0.55, 0.55, 0.55));
        let mut seen = Vec::new();
        g.for_neighborhood(Vec3::new(0.5, 0.5, 0.5), |id| seen.push(id));
        assert_eq!(seen, vec![7]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn far_unit_not_in_neighborhood() {
        let mut g = grid();
        g.insert(1, Vec3::new(0.05, 0.05, 0.05));
        g.insert(2, Vec3::new(0.95, 0.95, 0.95));
        let mut seen = Vec::new();
        g.for_neighborhood(Vec3::new(0.05, 0.05, 0.05), |id| seen.push(id));
        assert_eq!(seen, vec![1]);
    }

    #[test]
    fn update_moves_between_cells() {
        let mut g = grid();
        g.insert(3, Vec3::new(0.05, 0.05, 0.05));
        g.update(3, Vec3::new(0.95, 0.95, 0.95));
        let mut seen = Vec::new();
        g.for_neighborhood(Vec3::new(0.95, 0.95, 0.95), |id| seen.push(id));
        assert_eq!(seen, vec![3]);
        let mut old = Vec::new();
        g.for_neighborhood(Vec3::new(0.05, 0.05, 0.05), |id| old.push(id));
        assert!(old.is_empty());
        g.check_invariants().unwrap();
    }

    #[test]
    fn update_same_cell_is_noop() {
        let mut g = grid();
        g.insert(4, Vec3::new(0.51, 0.51, 0.51));
        g.update(4, Vec3::new(0.52, 0.52, 0.52));
        assert_eq!(g.len(), 1);
        g.check_invariants().unwrap();
    }

    #[test]
    fn remove_clears_unit() {
        let mut g = grid();
        g.insert(5, Vec3::new(0.5, 0.5, 0.5));
        g.remove(5);
        assert!(g.is_empty());
        g.check_invariants().unwrap();
    }

    #[test]
    fn out_of_bounds_points_clamp() {
        let mut g = grid();
        g.insert(6, Vec3::new(-5.0, 5.0, 0.5));
        let mut seen = Vec::new();
        g.for_neighborhood(Vec3::new(0.0, 1.0, 0.5), |id| seen.push(id));
        assert_eq!(seen, vec![6]);
    }

    #[test]
    fn rebuild_matches_network() {
        let mut net = Network::new();
        let a = net.insert(Vec3::new(0.1, 0.1, 0.1), 0.0);
        let b = net.insert(Vec3::new(0.9, 0.9, 0.9), 0.0);
        let c = net.insert(Vec3::new(0.5, 0.5, 0.5), 0.0);
        net.connect(a, b);
        net.remove(b);
        let _ = c;
        let mut g = grid();
        g.rebuild(&net);
        assert_eq!(g.len(), 2);
        g.check_invariants().unwrap();
    }

    #[test]
    fn neighborhood_covers_27_cells() {
        let mut g = grid();
        // Corner-adjacent cell: distance one cell diagonally.
        g.insert(8, Vec3::new(0.61, 0.61, 0.61));
        let mut seen = Vec::new();
        g.for_neighborhood(Vec3::new(0.59, 0.59, 0.59), |id| seen.push(id));
        assert_eq!(seen, vec![8]);
        // Two cells away: not visited.
        let mut far = Vec::new();
        g.for_neighborhood(Vec3::new(0.35, 0.61, 0.61), |id| far.push(id));
        assert!(far.is_empty());
    }
}
