//! Artifact registry: PJRT client + per-bucket compiled-executable cache.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::manifest::{ArtifactEntry, Manifest};
use super::PAD_VALUE;

/// Execution statistics (exposed to the perf harness).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub exec_time: Duration,
    pub compilations: u64,
    pub compile_time: Duration,
}

/// Loads HLO-text buckets lazily and keeps compiled executables cached.
pub struct Registry {
    client: xla::PjRtClient,
    manifest: Manifest,
    flavor: String,
    cache: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    pub stats: ExecStats,
}

impl Registry {
    /// Open the artifact directory. `flavor` overrides the manifest default
    /// (`pallas` or `scan` — both have identical semantics; see
    /// `python/tests/test_model.py::TestFlavorParity`).
    pub fn open(dir: &Path, flavor: Option<&str>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        if (manifest.pad_value - PAD_VALUE).abs() > PAD_VALUE * 1e-6 {
            bail!(
                "manifest pad_value {} != runtime PAD_VALUE {PAD_VALUE}",
                manifest.pad_value
            );
        }
        let flavor = flavor.unwrap_or(&manifest.default_flavor).to_string();
        if !manifest.flavors().contains(&flavor.as_str()) {
            bail!(
                "flavor {flavor:?} not in manifest (have {:?})",
                manifest.flavors()
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            manifest,
            flavor,
            cache: HashMap::new(),
            stats: ExecStats::default(),
        })
    }

    pub fn flavor(&self) -> &str {
        &self.flavor
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Bucket entry for a batch of `m` signals over `n` unit slots.
    pub fn bucket_for(&self, m: usize, n: usize) -> Result<ArtifactEntry> {
        self.manifest
            .bucket_for(&self.flavor, m, n)
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "no {} artifact bucket for m={m}, n={n} — re-run `make \
                     artifacts` with a larger --max-n",
                    self.flavor
                )
            })
    }

    /// Compile (or fetch from cache) the executable of a bucket.
    pub fn executable(&mut self, entry: &ArtifactEntry) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (entry.m, entry.n);
        if !self.cache.contains_key(&key) {
            let path = self.manifest.path_of(entry);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading HLO text {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling bucket m={} n={}: {e}", entry.m, entry.n))?;
            self.stats.compilations += 1;
            self.stats.compile_time += t0.elapsed();
            self.cache.insert(key, exe);
        }
        Ok(&self.cache[&key])
    }

    /// Execute a bucket on raw row-major buffers.
    ///
    /// `signals`: `m·dim` floats (padded by the caller to the bucket's m);
    /// `units`: `n·dim` floats (padded with [`PAD_VALUE`]). Returns
    /// `(i1, i2, d1, d2)` of length `m`.
    pub fn execute(
        &mut self,
        entry: &ArtifactEntry,
        signals: &[f32],
        units: &[f32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>)> {
        let dim = entry.dim;
        if signals.len() != entry.m * dim {
            bail!("signals buffer {} != m*dim {}", signals.len(), entry.m * dim);
        }
        if units.len() != entry.n * dim {
            bail!("units buffer {} != n*dim {}", units.len(), entry.n * dim);
        }
        // Borrow-split: compile first (mutable), then run readonly.
        self.executable(entry)?;
        let exe = &self.cache[&(entry.m, entry.n)];

        let as_bytes = |x: &[f32]| -> &[u8] {
            // Safety: f32 slice reinterpreted as bytes; alignment of u8 is 1.
            unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
        };
        let sig_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[entry.m, dim],
            as_bytes(signals),
        )
        .map_err(|e| anyhow!("signal literal: {e}"))?;
        let unit_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[entry.n, dim],
            as_bytes(units),
        )
        .map_err(|e| anyhow!("unit literal: {e}"))?;

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&[sig_lit, unit_lit])
            .map_err(|e| anyhow!("PJRT execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("PJRT result sync: {e}"))?;
        self.stats.executions += 1;
        self.stats.exec_time += t0.elapsed();

        // aot.py lowers with return_tuple=True: a 4-tuple (i1, i2, d1, d2).
        let (i1, i2, d1, d2) = result
            .to_tuple4()
            .map_err(|e| anyhow!("result tuple: {e}"))?;
        Ok((
            i1.to_vec::<i32>().map_err(|e| anyhow!("i1: {e}"))?,
            i2.to_vec::<i32>().map_err(|e| anyhow!("i2: {e}"))?,
            d1.to_vec::<f32>().map_err(|e| anyhow!("d1: {e}"))?,
            d2.to_vec::<f32>().map_err(|e| anyhow!("d2: {e}"))?,
        ))
    }

    /// Pre-compile every bucket up to `max_n` (warm start for benches, so
    /// compile time never pollutes phase timings).
    pub fn warmup(&mut self, max_n: usize) -> Result<usize> {
        let entries: Vec<ArtifactEntry> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.flavor == self.flavor && a.n <= max_n)
            .cloned()
            .collect();
        let count = entries.len();
        for e in &entries {
            self.executable(e)?;
        }
        Ok(count)
    }
}

// Tests that need real artifacts live in rust/tests/pjrt_roundtrip.rs (they
// require `make artifacts` to have run).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_is_actionable() {
        let err = match Registry::open(Path::new("/nonexistent/artifacts"), None) {
            Ok(_) => panic!("open must fail on a missing directory"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
