//! A persistent worker pool: OS threads created once per engine run,
//! parked between jobs, with generation-stamped job handoff.
//!
//! PR-1 parallelized the Update plan pass with `std::thread::scope`, which
//! spawns (and joins) fresh OS threads on **every flush** — tens of µs per
//! flush that push the parallel break-even up to batches of ~512. This pool
//! replaces that: workers live for the whole run and a job handoff is one
//! mutex/condvar round-trip. Both users — the `Parallel` driver's plan pass
//! (`coordinator::executor`) and the `find_threads` sharding of
//! `BatchRust::find2_batch` — share one pool per engine run.
//!
//! ## Protocol
//!
//! A job is a lifetime-erased `&dyn Fn(usize)` plus a monotonically
//! increasing generation stamp. [`WorkerPool::run`] publishes the job under
//! the mutex, wakes the workers, then blocks until every **active** worker
//! (index `< active`) has acknowledged that generation; inactive workers
//! neither run nor ack, so a handoff costs O(active). Only active workers
//! can touch the closure, and all of them ack before `run` returns — that
//! barrier is what makes the lifetime erasure sound: no worker can still
//! be touching the closure (or anything it borrows) once `run` returns, so
//! borrowing stack data of the caller is safe exactly as with scoped
//! threads. Worker panics are caught, survive the barrier, and are
//! re-raised by `run` — a bug in a job crashes the caller (as
//! `thread::scope` would), never a silent deadlock.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Resolve a thread-count knob: `0` = auto-detect the machine's available
/// parallelism, anything else is taken literally (minimum 1).
pub fn resolve_threads(n: usize) -> usize {
    if n == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        n
    }
}

/// One published job (see module docs).
struct Job {
    /// Generation stamp; workers execute a job exactly once per bump.
    generation: u64,
    /// Lifetime-erased task. Only valid between `run` publishing it and the
    /// matching all-ack barrier; `run` clears it before returning.
    task: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Workers with index `< active` call the task this generation.
    active: usize,
    shutdown: bool,
}

struct Shared {
    size: usize,
    job: Mutex<Job>,
    wake: Condvar,
    /// `(generation, acks)` — reset by the first acker of each generation.
    /// Only workers with index `< active` ack, so a job handoff costs
    /// O(active), not O(pool size).
    done: Mutex<(u64, usize)>,
    all_done: Condvar,
    /// First panic payload caught on a worker this job; re-raised by `run`
    /// after the barrier (scoped-thread semantics — a worker panic must
    /// crash the caller, not deadlock it).
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Diagnostic identity: names this pool's workers at the `pool_job`
    /// fault point (see [`crate::runtime::fault`]), so a fault spec can
    /// target one pool instead of every pool in the process. Never read on
    /// the job hot path beyond the fault-point evaluation.
    label: Option<String>,
}

/// Persistent worker pool (see module docs). Dropping joins the workers.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes `run` callers: the pool is shared between the Update plan
    /// pass and Find-Winners sharding (never concurrent today, but the gate
    /// makes that a property of the pool rather than of its callers).
    gate: Mutex<()>,
}

impl WorkerPool {
    /// Spawn `workers` parked threads (≥ 1).
    pub fn new(workers: usize) -> Self {
        Self::build(workers, None)
    }

    /// Like [`Self::new`], with a diagnostic label that scopes this pool's
    /// workers at the `pool_job` fault point ([`crate::runtime::fault`]) —
    /// a `pool_job/<label>:…` spec then fires only on this pool's jobs.
    pub fn with_label(workers: usize, label: impl Into<String>) -> Self {
        Self::build(workers, Some(label.into()))
    }

    fn build(workers: usize, label: Option<String>) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            size: workers,
            job: Mutex::new(Job { generation: 0, task: None, active: 0, shutdown: false }),
            wake: Condvar::new(),
            done: Mutex::new((0, 0)),
            all_done: Condvar::new(),
            panic: Mutex::new(None),
            label,
        });
        let handles = (0..workers)
            .map(|index| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("msgsn-worker-{index}"))
                    .spawn(move || worker_loop(&shared, index))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, handles, gate: Mutex::new(()) }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.shared.size
    }

    /// Execute `f(w)` on workers `w ∈ 0..min(active, size)` and block until
    /// every *active* worker has finished with this job. `f` may freely
    /// borrow the caller's stack (scoped-thread semantics — see module
    /// docs). A panic on a worker is caught, the barrier still completes,
    /// and the payload is re-raised here — exactly as `thread::scope`
    /// would on join.
    pub fn run(&self, active: usize, f: &(dyn Fn(usize) + Sync)) {
        let active = active.min(self.shared.size);
        if active == 0 {
            return;
        }
        let _gate = self.gate.lock().unwrap();
        crate::telemetry::set_gauge(crate::telemetry::Gauge::PoolWorkersActive, active as u64);
        let generation = {
            let mut job = self.shared.job.lock().unwrap();
            job.generation += 1;
            // SAFETY: pure lifetime erasure. The all-ack wait below does
            // not return until every active worker is done with this
            // generation, and `task` is cleared before `run` returns, so
            // the borrow never escapes this call.
            job.task = Some(unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                    f,
                )
            });
            job.active = active;
            self.shared.wake.notify_all();
            job.generation
        };
        let mut done = self.shared.done.lock().unwrap();
        while done.0 != generation || done.1 != active {
            done = self.shared.all_done.wait(done).unwrap();
        }
        drop(done);
        self.shared.job.lock().unwrap().task = None;
        // Poison-tolerant: the payload slot is plain data (a caught panic
        // payload), so a thread that panicked while holding this lock —
        // however it managed to — must not escalate one caught job panic
        // into a pool-wide abort on every later `run`.
        let payload =
            self.shared.panic.lock().unwrap_or_else(std::sync::PoisonError::into_inner).take();
        // Release every lock (including the caller gate) before re-raising,
        // so a propagated job panic cannot poison the pool's mutexes.
        drop(_gate);
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }

    /// Work-stealing variant of [`Self::run`]: execute `f(j)` exactly once
    /// for every job index `j ∈ 0..jobs`, with up to `workers` active
    /// workers *claiming* indices through a shared atomic counter instead of
    /// being handed a fixed slice each. A worker that finishes a cheap job
    /// immediately claims the next unclaimed one, so one skewed job no
    /// longer idles the rest of the pool — the caller just has to cut the
    /// work into more jobs than workers (a 2–4× factor is plenty).
    ///
    /// Job indices are only an *assignment* mechanism: which worker runs
    /// which job is racy, but as long as `f`'s output locations are a pure
    /// function of the index (the chunk-pair pattern used by the plan pass
    /// and `find2_batch`), results are identical for any schedule.
    pub fn run_indexed(&self, workers: usize, jobs: usize, f: &(dyn Fn(usize) + Sync)) {
        if jobs == 0 {
            return;
        }
        let next = AtomicUsize::new(0);
        self.run(workers.max(1).min(jobs), &|_| {
            // Telemetry is accumulated locally and flushed once per worker
            // per generation — zero per-claim overhead. A worker's claims
            // beyond its first are the work-stealing traffic.
            let mut claimed = 0u64;
            loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                if j >= jobs {
                    break;
                }
                claimed += 1;
                f(j);
            }
            if claimed > 0 {
                crate::telemetry::add(crate::telemetry::Counter::PoolJobs, claimed);
                crate::telemetry::add(crate::telemetry::Counter::PoolSteals, claimed - 1);
            }
        });
    }
}

/// Split `n` work items into chunk jobs for [`WorkerPool::run_indexed`]:
/// small enough that claiming balances skew (≈ 4 jobs per worker), never
/// below `min_chunk` items (the per-handoff overhead floor). Returns the
/// chunk length; `n.div_ceil(chunk)` is the job count.
pub fn steal_chunk(n: usize, workers: usize, min_chunk: usize) -> usize {
    debug_assert!(n > 0 && workers > 0 && min_chunk > 0);
    n.div_ceil(workers.max(1) * 4).max(min_chunk)
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut job = self.shared.job.lock().unwrap();
            job.shutdown = true;
            self.shared.wake.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, index: usize) {
    let mut seen = 0u64;
    loop {
        let (task, generation, active) = {
            let mut job = shared.job.lock().unwrap();
            loop {
                if job.shutdown {
                    return;
                }
                if job.generation != seen {
                    break;
                }
                job = shared.wake.wait(job).unwrap();
            }
            seen = job.generation;
            (job.task, job.generation, job.active)
        };
        // Inactive workers neither run the task nor ack — the handoff
        // barrier costs O(active), and they simply pick up the next
        // generation whenever they wake.
        if index >= active {
            continue;
        }
        if let Some(f) = task {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // Inside the catch so an injected worker panic takes the
                // exact path a real job panic would: caught here, stashed,
                // re-raised in the caller after the barrier.
                crate::runtime::fault::maybe_panic(
                    crate::runtime::fault::FaultPoint::PoolJob,
                    shared.label.as_deref(),
                    None,
                );
                f(index)
            }));
            if let Err(payload) = result {
                // Poison-tolerant for the same reason as in `run`: stashing
                // a payload into plain data must never abort the pool.
                shared
                    .panic
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .get_or_insert(payload);
            }
        }
        let mut done = shared.done.lock().unwrap();
        if done.0 != generation {
            *done = (generation, 0);
        }
        done.1 += 1;
        if done.1 == active {
            shared.all_done.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_active_worker_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: [AtomicUsize; 4] = std::array::from_fn(|_| AtomicUsize::new(0));
        pool.run(4, &|w| {
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.map(|h| h.into_inner()), [1, 1, 1, 1]);
    }

    #[test]
    fn inactive_workers_do_not_run() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        let max_index = AtomicUsize::new(0);
        pool.run(2, &|w| {
            hits.fetch_add(1, Ordering::SeqCst);
            max_index.fetch_max(w, Ordering::SeqCst);
        });
        assert_eq!(hits.into_inner(), 2);
        assert!(max_index.into_inner() < 2);
    }

    #[test]
    fn reusable_across_many_generations_with_borrowed_state() {
        let pool = WorkerPool::new(3);
        let mut total = 0usize;
        for round in 0..200 {
            // Borrow a fresh stack buffer each round (the scoped-thread
            // property the lifetime erasure must preserve).
            let out: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
            pool.run(3, &|w| {
                out[w].store(round + w, Ordering::SeqCst);
            });
            total += out.iter().map(|v| v.load(Ordering::SeqCst)).sum::<usize>();
        }
        assert_eq!(total, (0..200).map(|r| 3 * r + 3).sum::<usize>());
    }

    #[test]
    fn active_count_clamps_to_size_and_zero_is_noop() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.run(100, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        pool.run(0, &|_| {
            hits.fetch_add(100, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.run(2, &|_| {});
        drop(pool); // must not hang or panic
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates_to_caller() {
        // A panicking job must crash the caller (like thread::scope's
        // join), never deadlock the barrier.
        let pool = WorkerPool::new(2);
        pool.run(2, &|w| {
            if w == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(1, &|_| panic!("transient"));
        }));
        assert!(caught.is_err());
        // Workers caught the panic themselves, so the pool still works.
        let hits = AtomicUsize::new(0);
        pool.run(2, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.into_inner(), 2);
    }

    #[test]
    fn injected_pool_job_fault_behaves_like_a_real_job_panic() {
        use crate::runtime::fault;
        let _guard = fault::test_lock();
        // Scoped to THIS pool's label: other tests run unlabeled pools
        // concurrently, and an unscoped spec would fire on (or be eaten
        // by) their workers. Fires on the 2nd matching evaluation: exactly
        // one worker of the first generation panics, the barrier still
        // completes, the caller sees the payload, and the pool keeps
        // working afterwards.
        fault::install(fault::parse_faults("pool_job/zz-ut-pool:panic@2").unwrap());
        let pool = WorkerPool::with_label(2, "zz-ut-pool");
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|_| {});
        }));
        assert!(caught.is_err(), "injected fault must re-raise in the caller");
        let hits = AtomicUsize::new(0);
        pool.run(2, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.into_inner(), 2, "pool must survive the injected panic");
    }

    #[test]
    fn run_indexed_claims_every_job_exactly_once() {
        let pool = WorkerPool::new(3);
        for jobs in [0usize, 1, 2, 3, 17, 64] {
            let hits: Vec<AtomicUsize> = (0..jobs).map(|_| AtomicUsize::new(0)).collect();
            pool.run_indexed(3, jobs, &|j| {
                hits[j].fetch_add(1, Ordering::SeqCst);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "jobs={jobs}: some job not run exactly once"
            );
        }
    }

    #[test]
    fn run_indexed_balances_a_skewed_job() {
        // One job sleeps; the other workers must drain the remaining jobs
        // meanwhile (with static slicing the skewed worker's whole slice
        // would wait behind the sleep).
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        pool.run_indexed(2, 8, &|j| {
            if j == 0 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.into_inner(), 8);
    }

    #[test]
    fn steal_chunk_respects_floor_and_splits() {
        assert_eq!(steal_chunk(100, 4, 16), 16, "floor wins on small n");
        assert_eq!(steal_chunk(8192, 4, 16), 512, "≈4 jobs per worker");
        assert_eq!(steal_chunk(7, 4, 16), 16, "chunk may exceed n (1 job)");
        let n = 10_000;
        let chunk = steal_chunk(n, 8, 32);
        assert!(n.div_ceil(chunk) >= 8, "at least one job per worker");
    }

    #[test]
    fn resolve_threads_auto_detects() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
    }
}
