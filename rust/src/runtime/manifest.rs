//! Typed view of `artifacts/manifest.json` — the contract between the AOT
//! compile path (`python/compile/aot.py`) and this runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::json::{parse_json, Json};

/// One AOT-compiled Find-Winners bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub flavor: String,
    /// Signal-batch capacity.
    pub m: usize,
    /// Unit capacity (padded slots hold `pad_value`).
    pub n: usize,
    pub dim: usize,
    pub file: String,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub pad_value: f32,
    pub m_cap: usize,
    pub dim: usize,
    pub default_flavor: String,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` to build the AOT buckets",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub(crate) fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = parse_json(text).map_err(|e| anyhow!("{e}"))?;
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("manifest: missing numeric {key:?}"))
        };
        let pad_value = num("pad_value")? as f32;
        let m_cap = num("m_cap")? as usize;
        let dim = num("dim")? as usize;
        let default_flavor = v
            .get("default_flavor")
            .and_then(Json::as_str)
            .unwrap_or("pallas")
            .to_string();
        let mut artifacts = Vec::new();
        for (i, e) in v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing artifacts[]"))?
            .iter()
            .enumerate()
        {
            let field_num = |key: &str| -> Result<usize> {
                e.get(key)
                    .and_then(Json::as_u64)
                    .map(|x| x as usize)
                    .ok_or_else(|| anyhow!("manifest artifact {i}: missing {key:?}"))
            };
            artifacts.push(ArtifactEntry {
                flavor: e
                    .get("flavor")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest artifact {i}: missing flavor"))?
                    .to_string(),
                m: field_num("m")?,
                n: field_num("n")?,
                dim: field_num("dim")?,
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest artifact {i}: missing file"))?
                    .to_string(),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts — re-run `make artifacts`");
        }
        // Buckets must be sorted by capacity per flavor for bucket_for().
        artifacts.sort_by_key(|a| (a.flavor.clone(), a.n, a.m));
        Ok(Manifest { dir: dir.to_path_buf(), pad_value, m_cap, dim, default_flavor, artifacts })
    }

    /// Flavors present in the manifest.
    pub fn flavors(&self) -> Vec<&str> {
        let mut f: Vec<&str> = self.artifacts.iter().map(|a| a.flavor.as_str()).collect();
        f.sort_unstable();
        f.dedup();
        f
    }

    /// Smallest bucket of `flavor` holding `m` signals and `n` unit slots.
    pub fn bucket_for(&self, flavor: &str, m: usize, n: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.flavor == flavor && a.m >= m.min(self.m_cap) && a.n >= n)
            .min_by_key(|a| (a.n, a.m))
    }

    /// Absolute path of an entry's HLO text.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax": "0.8.2", "pad_value": 1e30, "m_cap": 8192,
      "min_n": 128, "dim": 3, "block_m": 128, "block_n": 128,
      "default_flavor": "pallas",
      "artifacts": [
        {"flavor": "pallas", "m": 128, "n": 128, "dim": 3, "file": "p128.hlo.txt"},
        {"flavor": "pallas", "m": 8192, "n": 16384, "dim": 3, "file": "p16384.hlo.txt"},
        {"flavor": "scan", "m": 128, "n": 128, "dim": 3, "file": "s128.hlo.txt"},
        {"flavor": "scan", "m": 256, "n": 256, "dim": 3, "file": "s256.hlo.txt"}
      ]
    }"#;

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = sample();
        assert_eq!(m.pad_value, 1e30);
        assert_eq!(m.m_cap, 8192);
        assert_eq!(m.default_flavor, "pallas");
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.flavors(), vec!["pallas", "scan"]);
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let m = sample();
        let b = m.bucket_for("scan", 100, 200).unwrap();
        assert_eq!((b.m, b.n), (256, 256));
        let b = m.bucket_for("pallas", 8192, 9000).unwrap();
        assert_eq!((b.m, b.n), (8192, 16384));
        assert!(m.bucket_for("scan", 100, 100_000).is_none());
        assert!(m.bucket_for("mxu", 1, 1).is_none());
    }

    #[test]
    fn m_above_cap_still_resolves() {
        // The engine never requests m > m_cap, but a request at the cap must
        // resolve to the capped artifacts.
        let m = sample();
        let b = m.bucket_for("pallas", 8192, 16384).unwrap();
        assert_eq!(b.m, 8192);
    }

    #[test]
    fn rejects_empty_and_malformed() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::parse(
            r#"{"pad_value":1,"m_cap":1,"dim":3,"artifacts":[]}"#,
            Path::new("/tmp")
        )
        .is_err());
    }

    #[test]
    fn path_joins_dir() {
        let m = sample();
        let p = m.path_of(&m.artifacts[0]);
        assert!(p.to_string_lossy().starts_with("/tmp/a/"));
    }
}
