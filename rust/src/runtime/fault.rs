//! Deterministic fault injection — the harness every durability claim in
//! the fleet layer is tested with.
//!
//! A *fault point* is a named hook compiled into a real code path (the
//! checkpoint write, the snapshot decode, the session step, the pool
//! job). A *fault spec* arms one point with an action (torn write, panic,
//! injected error) and a deterministic trigger (the n-th evaluation, or
//! the first evaluation at/after a turn counter). Specs come from the
//! `MSGSN_FAULTS` environment variable (the CI fault profile), the
//! `msgsn fleet --faults` flag, or [`install`] in tests — all three share
//! one grammar:
//!
//! ```text
//! MSGSN_FAULTS = spec ("," spec)*
//! spec         = point ["/" scope] ":" action ["@" trigger]
//! point        = "checkpoint_write" | "snapshot_decode"
//!              | "session_step" | "job"            (alias) | "pool_job"
//!              | "transport_send" | "transport_recv" | "worker"
//!              | "serve_conn"
//! action       = "truncate" "@" BYTES              (torn write, 1st hit)
//!              | "truncate" "=" BYTES ["@" trigger]
//!              | "panic"    ["@" trigger]
//!              | "err"      ["@" trigger]
//!              | "drop"     ["@" trigger]          (transport: lose a frame)
//!              | "delay" "=" N ["@" trigger]       (transport: hold a frame
//!                                                   N operations; worker:
//!                                                   stall N milliseconds)
//!              | "dup"      ["@" trigger]          (transport: frame twice)
//! trigger      = "turn=" N      (first evaluation whose turn ≥ N)
//!              | N              (the N-th evaluation; default 1)
//! ```
//!
//! Examples: `checkpoint_write:truncate@2` (first checkpoint write is cut
//! to 2 bytes, written *non-atomically* over the final path — the torn
//! write the two-generation layout defends against),
//! `job:panic@turn=7` (the first session step at iteration ≥ 7 panics),
//! `checkpoint_write/scan-a:truncate=100@2` (job `scan-a`'s second
//! checkpoint write is cut at 100 bytes).
//!
//! **Scopes**: `session_step` matches the fleet job *name* (solo sessions
//! have none); `checkpoint_write`/`snapshot_decode` match the checkpoint
//! *file stem* (`a.msgsnap` → `a`; the retained generation `a.msgsnap.prev`
//! decodes under scope `a.msgsnap`, so latest and previous can be targeted
//! separately); `pool_job` matches the pool's diagnostic label
//! ([`crate::runtime::WorkerPool::with_label`] — engine pools are
//! unlabeled); `transport_send`/`transport_recv` match the link's peer
//! label and `worker` matches the worker process name (the dist layer,
//! `rust/src/dist/`); `serve_conn` matches the serve daemon's connection
//! label (`c<id>`, in accept order — the serve layer, `rust/src/serve/`).
//! A spec without a scope matches every evaluation of its point.
//!
//! **Determinism + one-shot**: every spec fires at most once and is then
//! retired; every live spec matching a point observes each evaluation (its
//! hit counter advances), and the first spec whose trigger is satisfied
//! fires. Repeating a spec N times makes it fire on N successive
//! qualifying evaluations — e.g. three copies of `session_step/x:panic@turn=3`
//! crash job `x` on its first run *and* both retries, driving it to
//! quarantine.
//!
//! **Zero-cost when empty**: [`fire`] is two relaxed atomic loads (the
//! one-time env install check and the armed flag) when no spec is
//! installed — the registry never takes a lock on the hot path.
//!
//! A malformed `MSGSN_FAULTS` value fails **at arm time**: `main()` calls
//! [`validate_env`] before dispatching any command, so a typo'd chaos
//! profile exits immediately with the parse diagnostic instead of only
//! failing when (or if) the first fault point fires. Library users that
//! never reach `main` keep the lazy backstop: the first [`fire`] panics on
//! a malformed profile rather than silently testing nothing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, Once, OnceLock, PoisonError};

/// Environment variable holding the process-wide fault profile.
pub const ENV_VAR: &str = "MSGSN_FAULTS";

/// Named fault points compiled into real code paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// A durable checkpoint write ([`crate::fleet::snapshot::write_durable`]).
    /// `truncate` simulates a torn write (bytes cut and written
    /// non-atomically over the final path), `err` an I/O failure.
    CheckpointWrite,
    /// Decoding a checkpoint file during restore
    /// ([`crate::fleet::snapshot::load_from`]). Any action injects a decode
    /// error (`panic` panics).
    SnapshotDecode,
    /// A session advancing ([`crate::engine::ConvergenceSession::step`]).
    /// Any action panics — the poison-input simulation the fleet's
    /// `catch_unwind` isolation is tested with.
    SessionStep,
    /// A task executing on a [`crate::runtime::WorkerPool`] worker. Any
    /// action panics on the worker (caught there, re-raised in the caller —
    /// the scoped-thread semantics the pool guarantees). Scope = the pool's
    /// diagnostic label ([`crate::runtime::WorkerPool::with_label`]).
    PoolJob,
    /// A dist transport link sending one frame. `drop` loses the frame,
    /// `delay=N` holds it back for N subsequent sends, `dup` transmits it
    /// twice, `truncate=N` cuts the frame (the receiver must reject it),
    /// `err` fails the send, `panic` panics. Scope = the link's peer label.
    TransportSend,
    /// A dist transport link receiving one frame (same action menu as
    /// [`FaultPoint::TransportSend`], applied on the receive side).
    TransportRecv,
    /// A dist worker process at the top of its scheduler round. `panic`
    /// kills the worker (the worker-death simulation), `delay=N` stalls it
    /// N milliseconds without dying (the hung-worker simulation that only
    /// a heartbeat timeout can detect). Scope = the worker name.
    WorkerStep,
    /// The serve daemon handling one complete request line from a client
    /// connection (`rust/src/serve/`). `drop` discards the request and
    /// closes the connection (the vanished client the daemon must
    /// survive), `err` closes it after an error response, `delay=N`
    /// stalls the daemon N milliseconds, `dup` handles the request twice
    /// (the duplicate an idempotent protocol must absorb), `truncate=N`
    /// cuts the request line (a parse-error response), `panic` panics.
    /// Scope = the connection label (`c<id>`, in accept order).
    ServeConn,
}

impl FaultPoint {
    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::CheckpointWrite => "checkpoint_write",
            FaultPoint::SnapshotDecode => "snapshot_decode",
            FaultPoint::SessionStep => "session_step",
            FaultPoint::PoolJob => "pool_job",
            FaultPoint::TransportSend => "transport_send",
            FaultPoint::TransportRecv => "transport_recv",
            FaultPoint::WorkerStep => "worker",
            FaultPoint::ServeConn => "serve_conn",
        }
    }

    fn from_name(s: &str) -> Option<FaultPoint> {
        match s {
            "checkpoint_write" => Some(FaultPoint::CheckpointWrite),
            "snapshot_decode" => Some(FaultPoint::SnapshotDecode),
            // `job` reads better in profiles targeting fleet jobs.
            "session_step" | "job" => Some(FaultPoint::SessionStep),
            "pool_job" => Some(FaultPoint::PoolJob),
            "transport_send" => Some(FaultPoint::TransportSend),
            "transport_recv" => Some(FaultPoint::TransportRecv),
            "worker" => Some(FaultPoint::WorkerStep),
            "serve_conn" => Some(FaultPoint::ServeConn),
            _ => None,
        }
    }
}

/// What an armed spec does when it fires.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Cut the write to this many bytes — and write them *without* the
    /// tmp+rename dance, simulating the torn file a crash mid-write of a
    /// non-atomic writer would leave.
    Truncate(u64),
    /// Panic at the fault point.
    Panic,
    /// Return an injected error from the fault point.
    Error,
    /// Transport points: lose the frame — sent into the void / received
    /// and discarded. The partition simulation.
    Drop,
    /// Transport points: hold the frame back for N subsequent operations
    /// on the same link (reordering/stall simulation). Worker point: stall
    /// the worker N milliseconds without killing it (the hung worker only
    /// a heartbeat timeout catches).
    Delay(u64),
    /// Transport points: transmit/deliver the frame twice — the duplicate
    /// the protocol's idempotent acks must absorb.
    Dup,
}

/// When a spec fires (deterministic; see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTrigger {
    /// On the n-th matching evaluation (1-based; the default is 1).
    Hit(u64),
    /// On the first matching evaluation whose turn counter is ≥ n. `≥`
    /// rather than `=` because schedulers step in strides — an exact turn
    /// can be skipped over.
    Turn(u64),
}

/// One armed fault: point + optional scope + action + trigger.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub point: FaultPoint,
    /// `None` matches every evaluation of the point; `Some` must equal the
    /// evaluation's scope exactly (job name / checkpoint file stem).
    pub scope: Option<String>,
    pub action: FaultAction,
    pub trigger: FaultTrigger,
}

impl FaultSpec {
    /// Does this spec observe an evaluation of `point` under `scope`?
    /// An unscoped spec matches every scope (including `None`); a scoped
    /// spec requires an exact match.
    fn matches(&self, point: FaultPoint, scope: Option<&str>) -> bool {
        self.point == point
            && match &self.scope {
                None => true,
                Some(want) => scope == Some(want.as_str()),
            }
    }
}

struct Armed {
    spec: FaultSpec,
    /// Evaluations this spec has observed (drives [`FaultTrigger::Hit`]).
    hits: u64,
    /// One-shot: set when fired, never fires again.
    spent: bool,
}

/// Fast-path flag: true iff any unspent spec is installed.
static ARMED_ANY: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn state() -> &'static Mutex<Vec<Armed>> {
    static STATE: OnceLock<Mutex<Vec<Armed>>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_state() -> MutexGuard<'static, Vec<Armed>> {
    // A panic while holding the registry lock (e.g. an injected panic
    // unwinding through a test) must not disarm fault handling for the
    // rest of the process.
    state().lock().unwrap_or_else(PoisonError::into_inner)
}

fn install_inner(specs: Vec<FaultSpec>) {
    let mut st = lock_state();
    *st = specs.into_iter().map(|spec| Armed { spec, hits: 0, spent: false }).collect();
    ARMED_ANY.store(!st.is_empty(), Ordering::Relaxed);
}

fn ensure_env_installed() {
    ENV_INIT.call_once(|| {
        let Ok(text) = std::env::var(ENV_VAR) else { return };
        if text.trim().is_empty() {
            return;
        }
        match parse_faults(&text) {
            Ok(specs) => install_inner(specs),
            // Loud by design: a typo'd profile must not silently test
            // nothing (see module docs).
            Err(e) => panic!("{ENV_VAR}: {e}"),
        }
    });
}

/// Validate (and arm) the `MSGSN_FAULTS` profile **now**, instead of at
/// the first fault-point evaluation. `main()` calls this before
/// dispatching any command so a typo'd chaos profile fails the run
/// immediately with the parse diagnostic — today the lazy install would
/// only panic when (or if) a fault point fires. Returns the number of
/// specs armed from the environment (0 when unset/empty); `Err` carries
/// the parse diagnostic and leaves nothing armed.
pub fn validate_env() -> Result<usize, String> {
    let text = match std::env::var(ENV_VAR) {
        Ok(text) if !text.trim().is_empty() => text,
        _ => return Ok(0),
    };
    let specs = parse_faults(&text)?;
    let count = specs.len();
    // Consume the lazy one-shot first so it cannot clobber this install,
    // then arm the validated profile (idempotent if the lazy path already
    // installed the same env profile).
    ensure_env_installed();
    install_inner(specs);
    Ok(count)
}

/// Install a fault profile programmatically, replacing whatever is armed
/// (including the `MSGSN_FAULTS` profile). Tests must hold [`test_lock`]
/// around install/fire sequences — the registry is process-global.
pub fn install(specs: Vec<FaultSpec>) {
    // Consume the one-time env install first so it cannot later clobber
    // this explicit profile.
    ensure_env_installed();
    install_inner(specs);
}

/// Disarm every spec (the `MSGSN_FAULTS` profile included).
pub fn clear() {
    install(Vec::new());
}

/// Number of unspent specs currently armed.
pub fn armed_specs() -> usize {
    ensure_env_installed();
    lock_state().iter().filter(|a| !a.spent).count()
}

/// Evaluate a fault point. `scope` is the evaluation's identity (job name
/// / file stem; see module docs), `turn` feeds `@turn=` triggers (pass the
/// caller's monotone counter, `None` where no counter exists). Returns the
/// action to simulate, or `None` — the overwhelmingly common case, costing
/// two relaxed atomic loads.
#[inline]
pub fn fire(point: FaultPoint, scope: Option<&str>, turn: Option<u64>) -> Option<FaultAction> {
    ensure_env_installed();
    if !ARMED_ANY.load(Ordering::Relaxed) {
        return None;
    }
    fire_slow(point, scope, turn)
}

#[cold]
fn fire_slow(point: FaultPoint, scope: Option<&str>, turn: Option<u64>) -> Option<FaultAction> {
    let mut st = lock_state();
    let mut fired = None;
    for a in st.iter_mut() {
        if a.spent || !a.spec.matches(point, scope) {
            continue;
        }
        a.hits += 1;
        let fires = match a.spec.trigger {
            FaultTrigger::Hit(n) => a.hits >= n,
            FaultTrigger::Turn(n) => turn.is_some_and(|t| t >= n),
        };
        if fires {
            a.spent = true;
            fired = Some(a.spec.action.clone());
            break;
        }
    }
    if st.iter().all(|a| a.spent) {
        ARMED_ANY.store(false, Ordering::Relaxed);
    }
    fired
}

/// Evaluate a panic-only fault point ([`FaultPoint::SessionStep`],
/// [`FaultPoint::PoolJob`]): any armed action panics with an identifiable
/// payload.
#[inline]
pub fn maybe_panic(point: FaultPoint, scope: Option<&str>, turn: Option<u64>) {
    if let Some(action) = fire(point, scope, turn) {
        panic!(
            "injected fault: {} {:?} (scope {:?}, turn {:?})",
            point.name(),
            action,
            scope,
            turn
        );
    }
}

/// Serializes tests that install fault profiles (the registry is
/// process-global and `cargo test` runs threads in parallel). Dropping the
/// guard clears programmatic specs and re-installs the `MSGSN_FAULTS`
/// profile — fresh, with zeroed hit counters — so env-profile runs keep
/// exercising the recovery paths after a guarded test ran.
pub struct TestGuard {
    _inner: MutexGuard<'static, ()>,
}

pub fn test_lock() -> TestGuard {
    static GATE: Mutex<()> = Mutex::new(());
    // A previous test panicking under the guard is normal (#[should_panic],
    // injected panics) — poison is not an error here.
    let inner = GATE.lock().unwrap_or_else(PoisonError::into_inner);
    TestGuard { _inner: inner }
}

impl Drop for TestGuard {
    fn drop(&mut self) {
        let specs = std::env::var(ENV_VAR)
            .ok()
            .and_then(|s| parse_faults(&s).ok())
            .unwrap_or_default();
        install(specs);
    }
}

/// Parse a comma-separated fault profile (see the module-level grammar).
pub fn parse_faults(text: &str) -> Result<Vec<FaultSpec>, String> {
    let mut specs = Vec::new();
    for raw in text.split(',') {
        let raw = raw.trim();
        if raw.is_empty() {
            continue;
        }
        specs.push(parse_spec(raw).map_err(|e| format!("fault spec {raw:?}: {e}"))?);
    }
    Ok(specs)
}

fn parse_spec(raw: &str) -> Result<FaultSpec, String> {
    let (target, rest) =
        raw.split_once(':').ok_or("expected point[/scope]:action[@trigger]")?;
    let (point_name, scope) = match target.split_once('/') {
        Some((p, s)) if !s.is_empty() => (p, Some(s.to_string())),
        Some(_) => return Err("empty scope after '/'".to_string()),
        None => (target, None),
    };
    let point = FaultPoint::from_name(point_name).ok_or_else(|| {
        format!(
            "unknown fault point {point_name:?} \
             (expected checkpoint_write|snapshot_decode|session_step|job|pool_job\
             |transport_send|transport_recv|worker|serve_conn)"
        )
    })?;
    let (head, at_suffix) = match rest.split_once('@') {
        Some((h, t)) => (h, Some(t)),
        None => (rest, None),
    };
    let (action_name, eq_arg) = match head.split_once('=') {
        Some((a, v)) => (a, Some(v)),
        None => (head, None),
    };
    let parse_n = |what: &str, s: &str| -> Result<u64, String> {
        s.parse::<u64>().map_err(|_| format!("{what} expects an integer, got {s:?}"))
    };
    let parse_trigger = |t: Option<&str>| -> Result<FaultTrigger, String> {
        match t {
            None => Ok(FaultTrigger::Hit(1)),
            Some(t) => match t.split_once('=') {
                Some(("turn", n)) => Ok(FaultTrigger::Turn(parse_n("@turn=", n)?)),
                Some((k, _)) => Err(format!("unknown trigger kind {k:?} (expected turn=N or N)")),
                None => Ok(FaultTrigger::Hit(parse_n("@hit", t)?)),
            },
        }
    };
    let (action, trigger) = match action_name {
        "truncate" => match eq_arg {
            // `truncate=BYTES[@trigger]` — the unambiguous form.
            Some(v) => (FaultAction::Truncate(parse_n("truncate=", v)?), parse_trigger(at_suffix)?),
            // `truncate@BYTES` — shorthand: the `@` number is the byte
            // count, the trigger defaults to the first hit.
            None => {
                let bytes = at_suffix.ok_or("truncate needs a byte count: truncate@N")?;
                (FaultAction::Truncate(parse_n("truncate@", bytes)?), FaultTrigger::Hit(1))
            }
        },
        "panic" | "err" | "drop" | "dup" => {
            if eq_arg.is_some() {
                return Err(format!("{action_name} takes no '=' argument"));
            }
            let action = match action_name {
                "panic" => FaultAction::Panic,
                "err" => FaultAction::Error,
                "drop" => FaultAction::Drop,
                _ => FaultAction::Dup,
            };
            (action, parse_trigger(at_suffix)?)
        }
        "delay" => {
            let n = eq_arg.ok_or("delay needs a count: delay=N")?;
            (FaultAction::Delay(parse_n("delay=", n)?), parse_trigger(at_suffix)?)
        }
        other => {
            return Err(format!(
                "unknown action {other:?} (expected truncate|panic|err|drop|delay|dup)"
            ))
        }
    };
    Ok(FaultSpec { point, scope, action, trigger })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips() {
        let specs = parse_faults(
            "checkpoint_write:truncate@2, job:panic@turn=7,\
             snapshot_decode/a:err,pool_job:panic@3,\
             checkpoint_write/scan-a:truncate=100@2",
        )
        .unwrap();
        assert_eq!(specs.len(), 5);
        assert_eq!(
            specs[0],
            FaultSpec {
                point: FaultPoint::CheckpointWrite,
                scope: None,
                action: FaultAction::Truncate(2),
                trigger: FaultTrigger::Hit(1),
            }
        );
        assert_eq!(
            specs[1],
            FaultSpec {
                point: FaultPoint::SessionStep,
                scope: None,
                action: FaultAction::Panic,
                trigger: FaultTrigger::Turn(7),
            }
        );
        assert_eq!(
            specs[2],
            FaultSpec {
                point: FaultPoint::SnapshotDecode,
                scope: Some("a".to_string()),
                action: FaultAction::Error,
                trigger: FaultTrigger::Hit(1),
            }
        );
        assert_eq!(specs[3].trigger, FaultTrigger::Hit(3));
        assert_eq!(
            specs[4],
            FaultSpec {
                point: FaultPoint::CheckpointWrite,
                scope: Some("scan-a".to_string()),
                action: FaultAction::Truncate(100),
                trigger: FaultTrigger::Hit(2),
            }
        );
        // Empty input / stray commas are fine.
        assert!(parse_faults("").unwrap().is_empty());
        assert!(parse_faults(" , ,").unwrap().is_empty());
    }

    #[test]
    fn grammar_parses_transport_points_and_actions() {
        let specs = parse_faults(
            "transport_recv:drop@turn=32,transport_send/w1:delay=3@2,\
             transport_recv/w2:dup,worker:panic@2,worker/w-slow:delay=500@turn=4",
        )
        .unwrap();
        assert_eq!(specs.len(), 5);
        assert_eq!(
            specs[0],
            FaultSpec {
                point: FaultPoint::TransportRecv,
                scope: None,
                action: FaultAction::Drop,
                trigger: FaultTrigger::Turn(32),
            }
        );
        assert_eq!(
            specs[1],
            FaultSpec {
                point: FaultPoint::TransportSend,
                scope: Some("w1".to_string()),
                action: FaultAction::Delay(3),
                trigger: FaultTrigger::Hit(2),
            }
        );
        assert_eq!(specs[2].action, FaultAction::Dup);
        assert_eq!(specs[3].point, FaultPoint::WorkerStep);
        let serve = parse_faults("serve_conn:drop@2,serve_conn/c1:delay=5").unwrap();
        assert_eq!(
            serve[0],
            FaultSpec {
                point: FaultPoint::ServeConn,
                scope: None,
                action: FaultAction::Drop,
                trigger: FaultTrigger::Hit(2),
            }
        );
        assert_eq!(serve[1].scope.as_deref(), Some("c1"));
        assert_eq!(
            specs[4],
            FaultSpec {
                point: FaultPoint::WorkerStep,
                scope: Some("w-slow".to_string()),
                action: FaultAction::Delay(500),
                trigger: FaultTrigger::Turn(4),
            }
        );
    }

    #[test]
    fn grammar_rejects_malformed_specs() {
        for bad in [
            "nonsense",
            "warp:panic",
            "job:frobnicate",
            "job:panic@turn=x",
            "job:panic@zap=3",
            "checkpoint_write:truncate",
            "checkpoint_write:truncate@x",
            "job:panic=3",
            "job/:panic",
            "transport_send:delay",
            "transport_send:delay=x",
            "transport_recv:drop=2",
            "worker:dup=1",
        ] {
            assert!(parse_faults(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn validate_env_is_clean_on_the_current_environment() {
        // `MSGSN_FAULTS` is either unset (normal runs, → Ok(0)) or holds
        // the CI chaos profile (→ Ok(n), armed). Either way a well-formed
        // environment must validate; re-arming under the guard is safe
        // because the guard's drop reinstalls the env profile fresh.
        let _guard = test_lock();
        assert!(validate_env().is_ok());
    }

    // Every spec these tests install into the PROCESS-GLOBAL registry is
    // scoped to a `zz-ut-*` name no real code path ever uses: `test_lock`
    // serializes the fault tests against each other, but NOT against the
    // rest of the suite, and innocent pool/session/snapshot activity in
    // concurrently-running tests evaluates these same points (scope `None`
    // or pid-unique file stems). An armed UNSCOPED spec would match them —
    // eating the spec out from under the assertions here, or panicking an
    // innocent test. Unscoped matching is covered by the pure predicate
    // test below, off the registry.

    #[test]
    fn specs_fire_once_with_scope_and_trigger_matching() {
        let _guard = test_lock();
        install(
            parse_faults(
                "snapshot_decode/zz-ut-a:err,job/zz-ut-j:panic@turn=5,\
                 pool_job/zz-ut-p:panic@2",
            )
            .unwrap(),
        );
        assert_eq!(armed_specs(), 3);

        // Scope mismatch never fires; match fires exactly once.
        assert_eq!(fire(FaultPoint::SnapshotDecode, Some("zz-ut-b"), None), None);
        assert_eq!(
            fire(FaultPoint::SnapshotDecode, Some("zz-ut-a"), None),
            Some(FaultAction::Error)
        );
        assert_eq!(fire(FaultPoint::SnapshotDecode, Some("zz-ut-a"), None), None, "one-shot");

        // Turn trigger: ≥, so a strided scheduler that skips the exact
        // turn still fires.
        assert_eq!(fire(FaultPoint::SessionStep, Some("zz-ut-j"), Some(4)), None);
        assert_eq!(
            fire(FaultPoint::SessionStep, Some("zz-ut-j"), Some(6)),
            Some(FaultAction::Panic)
        );
        assert_eq!(fire(FaultPoint::SessionStep, Some("zz-ut-j"), Some(9)), None, "one-shot");

        // Hit trigger: fires on the 2nd evaluation.
        assert_eq!(fire(FaultPoint::PoolJob, Some("zz-ut-p"), None), None);
        assert_eq!(fire(FaultPoint::PoolJob, Some("zz-ut-p"), None), Some(FaultAction::Panic));
        assert_eq!(armed_specs(), 0, "every spec retired");
        // With everything spent, the fast path is re-disarmed.
        assert_eq!(fire(FaultPoint::PoolJob, Some("zz-ut-p"), None), None);
    }

    #[test]
    fn unscoped_specs_match_every_scope() {
        // Pure predicate test, deliberately NOT installed: see the module
        // comment above — an armed unscoped spec would leak into other
        // tests' pool/session/snapshot activity.
        let unscoped = FaultSpec {
            point: FaultPoint::SessionStep,
            scope: None,
            action: FaultAction::Panic,
            trigger: FaultTrigger::Hit(1),
        };
        assert!(unscoped.matches(FaultPoint::SessionStep, None));
        assert!(unscoped.matches(FaultPoint::SessionStep, Some("any-job")));
        assert!(!unscoped.matches(FaultPoint::PoolJob, None), "wrong point never matches");
        let scoped = FaultSpec { scope: Some("a".to_string()), ..unscoped };
        assert!(scoped.matches(FaultPoint::SessionStep, Some("a")));
        assert!(!scoped.matches(FaultPoint::SessionStep, Some("b")));
        assert!(!scoped.matches(FaultPoint::SessionStep, None), "scoped needs a scope");
    }

    #[test]
    fn repeated_specs_fire_on_successive_evaluations() {
        let _guard = test_lock();
        install(parse_faults("job/zz-ut-r:panic@turn=3,job/zz-ut-r:panic@turn=3").unwrap());
        assert_eq!(
            fire(FaultPoint::SessionStep, Some("zz-ut-r"), Some(3)),
            Some(FaultAction::Panic)
        );
        assert_eq!(
            fire(FaultPoint::SessionStep, Some("zz-ut-r"), Some(3)),
            Some(FaultAction::Panic)
        );
        assert_eq!(fire(FaultPoint::SessionStep, Some("zz-ut-r"), Some(3)), None);
    }

    #[test]
    #[should_panic(expected = "injected fault: session_step")]
    fn maybe_panic_panics_with_identifiable_payload() {
        let _guard = test_lock();
        install(parse_faults("session_step/zz-ut-mp:panic").unwrap());
        maybe_panic(FaultPoint::SessionStep, Some("zz-ut-mp"), Some(0));
    }

    #[test]
    fn clear_disarms_everything() {
        let _guard = test_lock();
        install(parse_faults("job/zz-ut-c:panic").unwrap());
        clear();
        assert_eq!(armed_specs(), 0);
        assert_eq!(fire(FaultPoint::SessionStep, Some("zz-ut-c"), Some(0)), None);
    }
}
