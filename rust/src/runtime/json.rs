//! Minimal JSON parser (the vendored crate set has no `serde_json`).
//!
//! Full JSON value grammar minus the exotica the AOT manifest never uses:
//! no `\uXXXX` surrogate pairs (plain `\uXXXX` BMP escapes are supported),
//! numbers parse through `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Render a value back to compact JSON text. The round-trip contract is
/// `parse_json(render_json(v)) == v`: numbers print through Rust's
/// shortest-round-trip `f64` formatting (integral values print without a
/// fraction — `7`, not `7.0`), strings re-escape quotes, backslashes and
/// control characters, object keys keep `BTreeMap` order. Non-finite
/// numbers (unreachable from `parse_json`) render as `null`, the only
/// valid-JSON option.
pub fn render_json(v: &Json) -> String {
    let mut out = String::new();
    render_into(v, &mut out);
    out
}

fn render_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.is_finite() {
                out.push_str(&format!("{x}"));
            } else {
                out.push_str("null");
            }
        }
        Json::Str(s) => render_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render_string(k, out);
                out.push(':');
                render_into(val, out);
            }
            out.push('}');
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse_json(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: format!("bad number {s:?}") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1, "pad_value": 1e30, "dim": 3,
          "artifacts": [
            {"flavor": "pallas", "m": 128, "n": 128, "file": "a.hlo.txt"},
            {"flavor": "scan", "m": 8192, "n": 16384, "file": "b.hlo.txt"}
          ],
          "ok": true, "missing": null
        }"#;
        let v = parse_json(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("pad_value").unwrap().as_f64(), Some(1e30));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[1].get("n").unwrap().as_u64(), Some(16384));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("missing"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse_json(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\"b\"A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("1 2").is_err());
        assert!(parse_json("{\"a\" 1}").is_err());
        assert!(parse_json("nul").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse_json("-1.5e-3").unwrap().as_f64(), Some(-1.5e-3));
        assert_eq!(parse_json("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse_json("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn nested_arrays() {
        let v = parse_json("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_u64(), Some(3));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_json("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse_json("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn render_round_trips() {
        for doc in [
            r#"{"version": 1, "jobs": [{"name": "a b", "seed": 7, "retries": 0,
                "config": {"max_signals": 4000, "insertion_threshold": 0.2}}]}"#,
            r#"{"neg": -1.5e-3, "big": 1e30, "zero": 0, "text": "q\"\\\n\tend"}"#,
            "[[1,2],[3],[],{},null,true,false]",
        ] {
            let v = parse_json(doc).unwrap();
            let rendered = render_json(&v);
            assert_eq!(parse_json(&rendered).unwrap(), v, "{rendered}");
        }
        // Integral floats print as integers (manifest schema expects them).
        assert_eq!(render_json(&Json::Num(7.0)), "7");
        assert_eq!(render_json(&Json::Num(f64::NAN)), "null");
        // Control characters escape to \uXXXX.
        assert_eq!(render_json(&Json::Str("\u{1}".into())), "\"\\u0001\"");
    }
}
