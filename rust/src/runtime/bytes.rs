//! Little-endian byte codec for the fleet snapshot format (the vendored
//! crate set has no `serde`/`bincode`).
//!
//! The contract that matters is **bit-exactness**: every `f32` travels as
//! its raw bit pattern (`to_bits`/`from_bits`), so a snapshot/restore
//! round-trip reproduces the exact float the network held — including
//! negative zeros, subnormals from GNG's decay ladder, and any NaN payload
//! a corrupted file might carry (the reader never interprets the value,
//! only the caller's invariant checks do).
//!
//! The reader is total: every accessor returns `Err` on truncation instead
//! of panicking, and length-prefixed reads validate the prefix against the
//! remaining buffer *before* allocating, so a corrupt length cannot drive
//! a huge `Vec::with_capacity`.

use std::fmt;

/// CRC-32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) lookup table,
/// built at compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE) of `bytes` — the checksum in the snapshot v2 trailer.
/// Detects every single-bit flip and every burst error up to 32 bits, the
/// corruption classes a torn or bit-rotted checkpoint file produces.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Append-only snapshot writer.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bit pattern — the bit-exactness contract (see module docs).
    #[inline]
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Raw bytes, no prefix (magic headers).
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }
}

/// Snapshot read error: byte offset + message.
#[derive(Clone, Debug, PartialEq)]
pub struct ByteError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ByteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "snapshot byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ByteError {}

/// Cursor over a snapshot buffer. Every accessor is total (`Err` on
/// truncation, never a panic).
#[derive(Clone, Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn err(&self, msg: impl Into<String>) -> ByteError {
        ByteError { offset: self.pos, message: msg.into() }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        if self.remaining() < n {
            return Err(self.err(format!("truncated: need {n} bytes, have {}", self.remaining())));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, ByteError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, ByteError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(self.err(format!("bad bool byte {other}"))),
        }
    }

    pub fn u32(&mut self) -> Result<u32, ByteError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, ByteError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32(&mut self) -> Result<f32, ByteError> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Length-prefixed UTF-8 string; the prefix is validated against the
    /// remaining bytes before anything is copied.
    pub fn str(&mut self) -> Result<String, ByteError> {
        let len = self.u32()? as usize;
        if len > self.remaining() {
            return Err(self.err(format!("string length {len} exceeds remaining bytes")));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.err("string is not UTF-8"))
    }

    /// Read a `u32` element count, rejecting any count that could not
    /// possibly fit in the remaining bytes at `min_elem_bytes` each — the
    /// guard that keeps a corrupt prefix from driving a huge allocation.
    pub fn len_prefix(&mut self, min_elem_bytes: usize) -> Result<usize, ByteError> {
        let n = self.u32()? as usize;
        let need = n.saturating_mul(min_elem_bytes.max(1));
        if need > self.remaining() {
            return Err(self.err(format!(
                "length prefix {n} needs {need} bytes, only {} remain",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// Borrow the next `n` raw bytes (validated against the remaining
    /// buffer first — truncation is an `Err`, never a panic). The wire
    /// codec uses this for checkpoint blobs after a `len_prefix` check.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], ByteError> {
        self.take(n)
    }

    /// Expect an exact magic byte sequence.
    pub fn expect_raw(&mut self, magic: &[u8]) -> Result<(), ByteError> {
        let got = self.take(magic.len())?;
        if got != magic {
            return Err(ByteError {
                offset: self.pos - magic.len(),
                message: format!("bad magic {got:?} (expected {magic:?})"),
            });
        }
        Ok(())
    }

    /// Fail if unread bytes remain (trailing garbage in a snapshot file).
    pub fn expect_end(&self) -> Result<(), ByteError> {
        if self.remaining() != 0 {
            return Err(self.err(format!("{} trailing bytes", self.remaining())));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_strings() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.bool(true);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(-0.0);
        w.f32(f32::from_bits(1)); // smallest subnormal
        w.str("fleet/job-1");
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f32().unwrap().to_bits(), 1);
        assert_eq!(r.str().unwrap(), "fleet/job-1");
        r.expect_end().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.u64(42);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf[..5]);
        assert!(r.u64().is_err());
        let mut r = ByteReader::new(&[]);
        assert!(r.u8().is_err());
        assert!(r.str().is_err());
    }

    #[test]
    fn corrupt_length_prefix_rejected_before_allocation() {
        let mut w = ByteWriter::new();
        w.u32(u32::MAX); // absurd element count
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert!(r.len_prefix(4).is_err());
        // A string prefix beyond the buffer is equally rejected.
        let mut w = ByteWriter::new();
        w.u32(1_000_000);
        w.raw(b"abc");
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert!(r.str().is_err());
    }

    #[test]
    fn magic_and_trailing_garbage() {
        let mut w = ByteWriter::new();
        w.raw(b"MSGSNAP1");
        w.u8(9);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        r.expect_raw(b"MSGSNAP1").unwrap();
        assert!(r.expect_end().is_err(), "unread byte must be flagged");
        assert_eq!(r.u8().unwrap(), 9);
        r.expect_end().unwrap();
        let mut r = ByteReader::new(&buf);
        assert!(r.expect_raw(b"MSGSNAPX").is_err());
    }

    #[test]
    fn bad_bool_rejected() {
        let mut r = ByteReader::new(&[2]);
        assert!(r.bool().is_err());
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"msgsn"), crc32(b"msgsn"));
        assert_ne!(crc32(b"msgsn"), crc32(b"msgsm"));
    }

    #[test]
    fn crc32_detects_every_single_bit_flip() {
        let mut w = ByteWriter::new();
        w.raw(b"MSGSNFLT");
        w.u64(0x0123_4567_89AB_CDEF);
        w.f32(-0.0);
        let buf = w.into_inner();
        let good = crc32(&buf);
        let mut flipped = buf.clone();
        for byte in 0..flipped.len() {
            for bit in 0..8 {
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), good, "flip at byte {byte} bit {bit} undetected");
                flipped[byte] ^= 1 << bit;
            }
        }
        assert_eq!(crc32(&flipped), good, "flips must have been undone");
    }
}
