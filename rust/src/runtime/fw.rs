//! `PjrtFindWinners`: the paper's **GPU-based** Find Winners — the batched
//! top-2 search executed from the AOT Pallas/XLA artifact via PJRT.
//!
//! Marshalling contract (DESIGN.md §8): signals are zero-padded up to the
//! bucket's `m` (extra rows are computed and discarded — semantics equal to
//! the unbucketed schedule because output rows are independent, pinned by
//! `python/tests/test_model.py::test_signal_rows_independent`); unit slots
//! are the network slab in id order, dead slots pre-filled with `PAD_VALUE`
//! so the kernel's winner index IS the `UnitId`.

use anyhow::Result;

use crate::config::RunConfig;
use crate::findwinners::{exhaustive_top2, FindWinners};
use crate::geometry::Vec3;
use crate::som::{Network, Winners};

use super::registry::Registry;
use super::PAD_VALUE;

/// Batched Find Winners over the PJRT runtime.
pub struct PjrtFindWinners {
    registry: Registry,
    sig_buf: Vec<f32>,
    unit_buf: Vec<f32>,
}

impl PjrtFindWinners {
    pub fn new(registry: Registry) -> Self {
        Self { registry, sig_buf: Vec::new(), unit_buf: Vec::new() }
    }

    /// Build from a run configuration (artifact dir + flavor override).
    pub fn from_config(cfg: &RunConfig) -> Result<Self> {
        let registry = Registry::open(&cfg.artifacts_dir, cfg.flavor.as_deref())?;
        Ok(Self::new(registry))
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }
}

impl FindWinners for PjrtFindWinners {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Single-signal queries don't amortize a PJRT dispatch; the multi
    /// driver never calls this, but keep it correct for completeness.
    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners> {
        exhaustive_top2(net, signal)
    }

    fn find2_batch(
        &mut self,
        net: &Network,
        signals: &[Vec3],
        out: &mut Vec<Option<Winners>>,
    ) {
        out.clear();
        if signals.is_empty() {
            return;
        }
        let m_live = signals.len();
        let n_needed = net.capacity().max(2);
        let entry = self
            .registry
            .bucket_for(m_live, n_needed.max(m_live))
            .expect("artifact bucket (run `make artifacts`)");

        // Signals: live rows then zero padding.
        self.sig_buf.clear();
        self.sig_buf.reserve(entry.m * entry.dim);
        for s in signals {
            self.sig_buf.extend_from_slice(&[s.x, s.y, s.z]);
        }
        self.sig_buf.resize(entry.m * entry.dim, 0.0);

        // Units: slab order (dead slots already PAD), pad rows to bucket n.
        net.fill_positions(&mut self.unit_buf, PAD_VALUE);
        self.unit_buf.resize(entry.n * entry.dim, PAD_VALUE);

        let (i1, i2, d1, d2) = self
            .registry
            .execute(&entry, &self.sig_buf, &self.unit_buf)
            .expect("PJRT find-winners execution");

        out.reserve(m_live);
        for j in 0..m_live {
            // Fewer than two live units ⇒ a padded slot "won" with +inf.
            if !d2[j].is_finite() || i1[j] == i2[j] {
                out.push(None);
            } else {
                out.push(Some(Winners {
                    w1: i1[j] as u32,
                    w2: i2[j] as u32,
                    d1_sq: d1[j],
                    d2_sq: d2[j],
                }));
            }
        }
    }
}
