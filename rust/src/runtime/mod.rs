//! Execution runtimes: the PJRT client for the AOT Find-Winners artifacts
//! (the paper's **GPU-based** column) and the persistent CPU worker pool
//! shared by the Update plan pass and `find_threads` sharding.
//!
//! `python/compile/aot.py` lowers the Layer-1/2 JAX+Pallas computation to
//! HLO **text** per size bucket; this module loads the text
//! (`HloModuleProto::from_text_file`), compiles it once per bucket on the
//! PJRT CPU client, caches the executable, and marshals network state in
//! and winners out. Python never runs here.

pub mod bytes;
pub mod fault;
mod fw;
mod json;
mod manifest;
pub mod pool;
mod registry;

pub use bytes::{ByteError, ByteReader, ByteWriter};
pub use fw::PjrtFindWinners;
pub use json::{parse_json, render_json, Json, JsonError};
pub use manifest::{ArtifactEntry, Manifest};
pub use pool::{resolve_threads, steal_chunk, WorkerPool};
pub use registry::{ExecStats, Registry};

/// Padding sentinel for unit slots; `PAD_VALUE²` overflows f32 to `+inf`,
/// so padded slots can never win. MUST match `kernels/ref.py::PAD_VALUE`
/// (checked against the manifest at load).
pub const PAD_VALUE: f32 = 1e30;
