//! Bounded structured event ring: the narrative half of the telemetry
//! spine.
//!
//! Counters say *how much*; the trace says *what happened, in order*.
//! Lifecycle transitions — job admitted/retried/quarantined/migrated,
//! worker evicted, checkpoint promoted, connection severed — emit a
//! [`TraceEvent`] carrying a process-monotonic sequence number, the
//! event kind, an optional job label, and free-form fields. Events land
//! in a fixed-capacity ring guarded by one mutex: transitions are rare
//! (per job-lifecycle, not per signal), so a short critical section off
//! the hot path is the right trade. On overflow the ring **drops the
//! oldest event and increments a drop counter — it never blocks** and
//! never grows without bound.
//!
//! Rendering is line-delimited JSON (`runtime::json`), one event per
//! line, so `--trace-file` output replays a run (e.g. a dist
//! kill-and-migrate) as an ordered, parseable narrative.
//!
//! Emission is gated on [`super::registry::enabled`] — telemetry off
//! means one relaxed load and no lock touch, preserving the
//! non-perturbation contract.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock, PoisonError};

use crate::runtime::Json;

use super::registry::{self, Counter};

/// Default ring capacity; tune with [`set_capacity`].
pub const DEFAULT_CAPACITY: usize = 1024;

/// One structured lifecycle event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Process-monotonic sequence number (counts every emit, including
    /// events later evicted by overflow — gaps in a tail reveal drops).
    pub seq: u64,
    /// Event kind: `job_admitted`, `job_retried`, `job_quarantined`,
    /// `job_migrated`, `job_done`, `worker_evicted`,
    /// `checkpoint_promoted`, `conn_severed`.
    pub kind: &'static str,
    /// Job label, when the event concerns one.
    pub job: Option<String>,
    /// Kind-specific fields, in emission order.
    pub fields: Vec<(&'static str, Json)>,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("seq".to_string(), Json::Num(self.seq as f64));
        obj.insert("kind".to_string(), Json::Str(self.kind.to_string()));
        if let Some(job) = &self.job {
            obj.insert("job".to_string(), Json::Str(job.clone()));
        }
        for (k, v) in &self.fields {
            obj.insert((*k).to_string(), v.clone());
        }
        Json::Obj(obj)
    }
}

struct Ring {
    events: VecDeque<TraceEvent>,
    capacity: usize,
    next_seq: u64,
    dropped: u64,
}

fn ring() -> &'static Mutex<Ring> {
    static RING: OnceLock<Mutex<Ring>> = OnceLock::new();
    RING.get_or_init(|| {
        Mutex::new(Ring {
            events: VecDeque::new(),
            capacity: DEFAULT_CAPACITY,
            next_seq: 0,
            dropped: 0,
        })
    })
}

fn lock_ring() -> std::sync::MutexGuard<'static, Ring> {
    ring().lock().unwrap_or_else(PoisonError::into_inner)
}

/// Emit an event (no-op when telemetry is disabled). On a full ring the
/// oldest event is evicted and [`Counter::TraceEventsDropped`] bumped;
/// emission itself never blocks beyond the short ring lock.
pub fn emit(kind: &'static str, job: Option<&str>, fields: Vec<(&'static str, Json)>) {
    if !registry::enabled() {
        return;
    }
    let mut r = lock_ring();
    let seq = r.next_seq;
    r.next_seq += 1;
    if r.events.len() >= r.capacity {
        r.events.pop_front();
        r.dropped += 1;
        registry::add(Counter::TraceEventsDropped, 1);
    }
    r.events.push_back(TraceEvent { seq, kind, job: job.map(str::to_string), fields });
}

/// Resize the ring (tests, long-lived daemons). Shrinking evicts oldest
/// events without counting them as overflow drops.
pub fn set_capacity(capacity: usize) {
    let mut r = lock_ring();
    r.capacity = capacity.max(1);
    while r.events.len() > r.capacity {
        r.events.pop_front();
    }
}

/// Copy the newest `n` events, oldest-first.
pub fn tail(n: usize) -> Vec<TraceEvent> {
    let r = lock_ring();
    let skip = r.events.len().saturating_sub(n);
    r.events.iter().skip(skip).cloned().collect()
}

/// Drain every buffered event, oldest-first (used by `--trace-file`
/// flushes at end of run).
pub fn drain_all() -> Vec<TraceEvent> {
    let mut r = lock_ring();
    r.events.drain(..).collect()
}

/// Events evicted by overflow since the last [`reset`].
pub fn dropped() -> u64 {
    lock_ring().dropped
}

/// Clear the ring and restore the default capacity (tests; called by
/// [`super::registry::reset`]).
pub fn reset() {
    let mut r = lock_ring();
    r.events.clear();
    r.capacity = DEFAULT_CAPACITY;
    r.next_seq = 0;
    r.dropped = 0;
}

/// Render events as JSONL: one `render_json` object per line.
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&crate::runtime::render_json(&e.to_json()));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{set_enabled, test_lock};

    #[test]
    fn disabled_emit_is_a_no_op() {
        let _guard = test_lock();
        set_enabled(false);
        emit("job_admitted", Some("j0"), vec![]);
        set_enabled(true);
        assert!(tail(10).is_empty());
    }

    #[test]
    fn events_carry_monotone_seq_and_fields() {
        let _guard = test_lock();
        set_enabled(true);
        emit("job_admitted", Some("j0"), vec![("attempt", Json::Num(1.0))]);
        emit("job_done", Some("j0"), vec![]);
        let events = tail(10);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, "job_admitted");
        assert_eq!(events[1].kind, "job_done");
        assert!(events[0].seq < events[1].seq);
        let line = to_jsonl(&events[..1]);
        let doc = crate::runtime::parse_json(line.trim()).expect("valid jsonl line");
        assert_eq!(doc.get("kind").and_then(|v| v.as_str()), Some("job_admitted"));
        assert_eq!(doc.get("job").and_then(|v| v.as_str()), Some("j0"));
        assert_eq!(doc.get("attempt").and_then(|v| v.as_u64()), Some(1));
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let _guard = test_lock();
        set_enabled(true);
        set_capacity(4);
        for k in 0..10u64 {
            emit("job_admitted", Some(&format!("j{k}")), vec![]);
        }
        let events = tail(100);
        assert_eq!(events.len(), 4);
        // Oldest were evicted: the survivors are the last four emits.
        assert_eq!(events[0].job.as_deref(), Some("j6"));
        assert_eq!(events[3].job.as_deref(), Some("j9"));
        assert_eq!(dropped(), 6);
        assert_eq!(
            crate::telemetry::registry::counter(Counter::TraceEventsDropped),
            6
        );
        // seq keeps counting across drops, exposing the gap.
        assert_eq!(events[3].seq, 9);
    }
}
