//! Lock-free instrument registry: named counters, gauges and log-2
//! histograms behind one process-wide enable flag.
//!
//! Every instrument is **preregistered** as an enum variant, so a hot
//! path never hashes a name or takes a lock — [`add`] is an index into a
//! static `AtomicU64` array and one relaxed `fetch_add`. The enable gate
//! mirrors the [`crate::runtime::fault`] fast path exactly: a `Once` for
//! the one-time `MSGSN_TELEMETRY` env read plus one relaxed `AtomicBool`
//! load, so a *disabled* registry costs a single relaxed load per
//! instrument site and touches nothing else.
//!
//! **Non-perturbation is the contract.** Instruments are pure observers:
//! they never branch the computation, never touch an RNG, and never
//! reorder admissions or commits. `rust/tests/telemetry.rs` proves a run
//! with every instrument armed is *bit-identical* to one with the
//! registry disabled — the same bar every optimization in this repo
//! clears.
//!
//! Orderings are `Relaxed` throughout: counters are monotone statistics
//! read at batch boundaries, not synchronization edges. A snapshot may
//! therefore be internally skewed by in-flight increments (counter A
//! read before a worker's paired bump of counter B lands) — fine for
//! observability, and why nothing here may ever gate logic.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, Once, PoisonError};

use crate::runtime::Json;

/// Environment variable enabling telemetry process-wide (`1`/`true`/`on`).
/// CLI flags that need the registry (`--metrics-json`, `--trace-file`)
/// enable it programmatically via [`set_enabled`] as well.
pub const ENV_VAR: &str = "MSGSN_TELEMETRY";

/// Preregistered monotone counters. The variant order IS the storage
/// index — append new instruments to the end of [`Counter::ALL`] too.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Nanoseconds spent in the Sample phase (paper Tables 1–4 axis).
    PhaseSampleNanos,
    /// Nanoseconds spent in Find Winners.
    PhaseFindNanos,
    /// Nanoseconds spent in Update.
    PhaseUpdateNanos,
    /// Signals drawn through any session (single + batched paths).
    SignalsProcessed,
    /// Multi-signal batches executed.
    Batches,
    /// Indexed jobs executed on a [`crate::runtime::WorkerPool`].
    PoolJobs,
    /// Pool claims beyond a worker's first in one `run_indexed` call —
    /// the work-stealing traffic.
    PoolSteals,
    /// Live units whose roster assignment crossed a region boundary.
    RegionCrossings,
    /// Batched signals resolved entirely inside their 3×3×3 region
    /// neighborhood (no global scan).
    RegionLocalResolves,
    /// Batched signals that fell back to the global tile scan.
    RegionFallbackScans,
    /// Durable checkpoint write-outs that completed successfully.
    CheckpointsWritten,
    /// Checkpoint write-outs dropped because the writer queue was full.
    CheckpointsDropped,
    /// Checkpoint write-outs that failed (I/O error or writer panic).
    CheckpointsFailed,
    /// Jobs admitted into a fleet (static manifest, serve submit, dist
    /// assign — all funnel through `Fleet::add_job`).
    JobsAdmitted,
    /// Job crash-retries granted (fleet + dist coordinator).
    JobsRetried,
    /// Jobs quarantined after exhausting their retry budget.
    JobsQuarantined,
    /// Jobs migrated off an evicted dist worker.
    JobsMigrated,
    /// Dist workers evicted (death, hang, or corrupt link).
    WorkersEvicted,
    /// Transport frames sent.
    FramesSent,
    /// Transport frames received and decoded.
    FramesReceived,
    /// Transport frames dropped by fault injection (either side).
    FramesDropped,
    /// Serve connections accepted.
    ServeConnsOpened,
    /// Serve connections closed by the daemon (hangup, error, protocol
    /// violation, injected sever).
    ServeConnsSevered,
    /// Complete request lines handled by the serve daemon.
    ServeRequests,
    /// Trace events evicted from the ring by overflow
    /// ([`crate::telemetry::trace`]).
    TraceEventsDropped,
}

impl Counter {
    /// Every counter, in storage order.
    pub const ALL: [Counter; 25] = [
        Counter::PhaseSampleNanos,
        Counter::PhaseFindNanos,
        Counter::PhaseUpdateNanos,
        Counter::SignalsProcessed,
        Counter::Batches,
        Counter::PoolJobs,
        Counter::PoolSteals,
        Counter::RegionCrossings,
        Counter::RegionLocalResolves,
        Counter::RegionFallbackScans,
        Counter::CheckpointsWritten,
        Counter::CheckpointsDropped,
        Counter::CheckpointsFailed,
        Counter::JobsAdmitted,
        Counter::JobsRetried,
        Counter::JobsQuarantined,
        Counter::JobsMigrated,
        Counter::WorkersEvicted,
        Counter::FramesSent,
        Counter::FramesReceived,
        Counter::FramesDropped,
        Counter::ServeConnsOpened,
        Counter::ServeConnsSevered,
        Counter::ServeRequests,
        Counter::TraceEventsDropped,
    ];

    /// Prometheus-style metric name (`_total` suffix by convention).
    pub fn name(self) -> &'static str {
        match self {
            Counter::PhaseSampleNanos => "msgsn_phase_sample_nanos_total",
            Counter::PhaseFindNanos => "msgsn_phase_find_nanos_total",
            Counter::PhaseUpdateNanos => "msgsn_phase_update_nanos_total",
            Counter::SignalsProcessed => "msgsn_signals_processed_total",
            Counter::Batches => "msgsn_batches_total",
            Counter::PoolJobs => "msgsn_pool_jobs_total",
            Counter::PoolSteals => "msgsn_pool_steals_total",
            Counter::RegionCrossings => "msgsn_region_crossings_total",
            Counter::RegionLocalResolves => "msgsn_region_local_resolves_total",
            Counter::RegionFallbackScans => "msgsn_region_fallback_scans_total",
            Counter::CheckpointsWritten => "msgsn_checkpoints_written_total",
            Counter::CheckpointsDropped => "msgsn_checkpoints_dropped_total",
            Counter::CheckpointsFailed => "msgsn_checkpoints_failed_total",
            Counter::JobsAdmitted => "msgsn_jobs_admitted_total",
            Counter::JobsRetried => "msgsn_jobs_retried_total",
            Counter::JobsQuarantined => "msgsn_jobs_quarantined_total",
            Counter::JobsMigrated => "msgsn_jobs_migrated_total",
            Counter::WorkersEvicted => "msgsn_workers_evicted_total",
            Counter::FramesSent => "msgsn_frames_sent_total",
            Counter::FramesReceived => "msgsn_frames_received_total",
            Counter::FramesDropped => "msgsn_frames_dropped_total",
            Counter::ServeConnsOpened => "msgsn_serve_conns_opened_total",
            Counter::ServeConnsSevered => "msgsn_serve_conns_severed_total",
            Counter::ServeRequests => "msgsn_serve_requests_total",
            Counter::TraceEventsDropped => "msgsn_trace_events_dropped_total",
        }
    }
}

/// Preregistered gauges (last-write-wins instantaneous values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// Workers activated by the pool's most recent parallel section.
    PoolWorkersActive,
    /// Checkpoint writer queue depth after the most recent enqueue/poll.
    WriterQueueDepth,
    /// Serve connections currently registered.
    ServeConnsOpen,
}

impl Gauge {
    pub const ALL: [Gauge; 3] =
        [Gauge::PoolWorkersActive, Gauge::WriterQueueDepth, Gauge::ServeConnsOpen];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::PoolWorkersActive => "msgsn_pool_workers_active",
            Gauge::WriterQueueDepth => "msgsn_writer_queue_depth",
            Gauge::ServeConnsOpen => "msgsn_serve_conns_open",
        }
    }
}

/// Preregistered fixed-bucket log-2 histograms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Histogram {
    /// Durable checkpoint write latency (tmp+fsync+rename), nanoseconds.
    CheckpointWriteNanos,
}

impl Histogram {
    pub const ALL: [Histogram; 1] = [Histogram::CheckpointWriteNanos];

    pub fn name(self) -> &'static str {
        match self {
            Histogram::CheckpointWriteNanos => "msgsn_checkpoint_write_nanos",
        }
    }
}

/// Buckets per histogram: bucket `b` counts values in `[2^(b-1), 2^b)`
/// (bucket 0 holds 0 and 1); the last bucket absorbs everything larger.
pub const HIST_BUCKETS: usize = 40;

// The const-item repeat trick: a `const` with interior mutability is the
// sanctioned way to initialize a static atomic array.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

static COUNTERS: [AtomicU64; Counter::ALL.len()] = [ZERO; Counter::ALL.len()];
static GAUGES: [AtomicU64; Gauge::ALL.len()] = [ZERO; Gauge::ALL.len()];
static HIST_COUNTS: [[AtomicU64; HIST_BUCKETS]; Histogram::ALL.len()] =
    [[ZERO; HIST_BUCKETS]; Histogram::ALL.len()];
static HIST_TOTALS: [AtomicU64; Histogram::ALL.len()] = [ZERO; Histogram::ALL.len()];
static HIST_SUMS: [AtomicU64; Histogram::ALL.len()] = [ZERO; Histogram::ALL.len()];

/// Fast-path flag, mirroring `runtime::fault::ARMED_ANY`.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn ensure_env_installed() {
    ENV_INIT.call_once(|| {
        if env_requests_enable() {
            ENABLED.store(true, Ordering::Relaxed);
        }
    });
}

fn env_requests_enable() -> bool {
    match std::env::var(ENV_VAR) {
        Ok(v) => matches!(v.trim(), "1" | "true" | "on" | "yes"),
        Err(_) => false,
    }
}

/// Is the registry recording? One `Once` fast path + one relaxed load —
/// the entire cost of a disabled instrument site.
#[inline]
pub fn enabled() -> bool {
    ensure_env_installed();
    ENABLED.load(Ordering::Relaxed)
}

/// Enable/disable the registry programmatically (CLI flags, tests). Takes
/// precedence over the `MSGSN_TELEMETRY` env install.
pub fn set_enabled(on: bool) {
    ensure_env_installed();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Bump a counter by `n`. Disabled: a single relaxed load.
#[inline]
pub fn add(c: Counter, n: u64) {
    if !enabled() {
        return;
    }
    COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
}

/// Set a gauge to `v` (last write wins).
#[inline]
pub fn set_gauge(g: Gauge, v: u64) {
    if !enabled() {
        return;
    }
    GAUGES[g as usize].store(v, Ordering::Relaxed);
}

/// Record one observation into a log-2 histogram.
#[inline]
pub fn observe(h: Histogram, v: u64) {
    if !enabled() {
        return;
    }
    let b = bucket_of(v);
    let i = h as usize;
    HIST_COUNTS[i][b].fetch_add(1, Ordering::Relaxed);
    HIST_TOTALS[i].fetch_add(1, Ordering::Relaxed);
    HIST_SUMS[i].fetch_add(v, Ordering::Relaxed);
}

/// Log-2 bucket index of `v` (see [`HIST_BUCKETS`]).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros() as usize).saturating_sub(1)).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `b` (`u64::MAX` for the overflow
/// bucket).
pub fn bucket_le(b: usize) -> u64 {
    if b + 1 >= HIST_BUCKETS {
        u64::MAX
    } else {
        (1u64 << (b + 1)) - 1
    }
}

/// Read a single counter's current value (test + exposition helper).
pub fn counter(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// One histogram's snapshot: cumulative log-2 buckets + count + sum.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub name: &'static str,
    pub count: u64,
    pub sum: u64,
    /// `(inclusive upper bound, cumulative count ≤ bound)`, ascending.
    /// Empty trailing buckets are elided; the last entry always carries
    /// the full count (Prometheus `+Inf` semantics).
    pub buckets: Vec<(u64, u64)>,
}

/// A point-in-time copy of every instrument.
#[derive(Clone, Debug)]
pub struct RegistrySnapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub histograms: Vec<HistogramSnapshot>,
}

/// Copy the registry. Relaxed reads: values are monotone statistics, not
/// a consistent cut (see module docs).
pub fn snapshot() -> RegistrySnapshot {
    let counters = Counter::ALL
        .iter()
        .map(|c| (c.name(), COUNTERS[*c as usize].load(Ordering::Relaxed)))
        .collect();
    let gauges = Gauge::ALL
        .iter()
        .map(|g| (g.name(), GAUGES[*g as usize].load(Ordering::Relaxed)))
        .collect();
    let histograms = Histogram::ALL
        .iter()
        .map(|h| {
            let i = *h as usize;
            let count = HIST_TOTALS[i].load(Ordering::Relaxed);
            let sum = HIST_SUMS[i].load(Ordering::Relaxed);
            let mut cum = 0u64;
            let mut buckets = Vec::new();
            let mut last_nonempty = 0usize;
            let raw: Vec<u64> =
                (0..HIST_BUCKETS).map(|b| HIST_COUNTS[i][b].load(Ordering::Relaxed)).collect();
            for (b, n) in raw.iter().enumerate() {
                if *n > 0 {
                    last_nonempty = b;
                }
            }
            for (b, n) in raw.iter().enumerate().take(last_nonempty + 1) {
                cum += n;
                buckets.push((bucket_le(b), cum));
            }
            HistogramSnapshot { name: h.name(), count, sum, buckets }
        })
        .collect();
    RegistrySnapshot { counters, gauges, histograms }
}

impl RegistrySnapshot {
    /// JSON form (`runtime::json`): counters/gauges as name → value maps,
    /// histograms as `{count, sum, buckets: [[le, cumulative], …]}`.
    pub fn to_json(&self) -> Json {
        let mut counters = std::collections::BTreeMap::new();
        for (name, v) in &self.counters {
            counters.insert((*name).to_string(), Json::Num(*v as f64));
        }
        let mut gauges = std::collections::BTreeMap::new();
        for (name, v) in &self.gauges {
            gauges.insert((*name).to_string(), Json::Num(*v as f64));
        }
        let mut hists = std::collections::BTreeMap::new();
        for h in &self.histograms {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("count".to_string(), Json::Num(h.count as f64));
            obj.insert("sum".to_string(), Json::Num(h.sum as f64));
            obj.insert(
                "buckets".to_string(),
                Json::Arr(
                    h.buckets
                        .iter()
                        .map(|(le, n)| {
                            Json::Arr(vec![
                                // The +Inf bucket has no finite bound.
                                if *le == u64::MAX {
                                    Json::Null
                                } else {
                                    Json::Num(*le as f64)
                                },
                                Json::Num(*n as f64),
                            ])
                        })
                        .collect(),
                ),
            );
            hists.insert(h.name.to_string(), Json::Obj(obj));
        }
        let mut root = std::collections::BTreeMap::new();
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("gauges".to_string(), Json::Obj(gauges));
        root.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(root)
    }

    /// Prometheus text exposition (`# TYPE` lines + samples; histograms
    /// as cumulative `_bucket{le=…}` series with `_sum`/`_count`).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for h in &self.histograms {
            let name = h.name;
            out.push_str(&format!("# TYPE {name} histogram\n"));
            for (le, n) in &h.buckets {
                if *le == u64::MAX {
                    continue;
                }
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {n}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

/// Zero every instrument (tests; a long-lived process that wants
/// per-interval numbers should diff snapshots instead).
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    for g in &GAUGES {
        g.store(0, Ordering::Relaxed);
    }
    for i in 0..Histogram::ALL.len() {
        for b in 0..HIST_BUCKETS {
            HIST_COUNTS[i][b].store(0, Ordering::Relaxed);
        }
        HIST_TOTALS[i].store(0, Ordering::Relaxed);
        HIST_SUMS[i].store(0, Ordering::Relaxed);
    }
    super::trace::reset();
}

/// Serializes tests that enable/reset the process-global registry, the
/// same discipline as [`crate::runtime::fault::test_lock`]. Dropping the
/// guard resets every instrument and restores the `MSGSN_TELEMETRY`
/// enable state, so an unguarded suite never sees a guarded test's
/// numbers.
pub struct TestGuard {
    _inner: MutexGuard<'static, ()>,
}

pub fn test_lock() -> TestGuard {
    static GATE: Mutex<()> = Mutex::new(());
    let inner = GATE.lock().unwrap_or_else(PoisonError::into_inner);
    TestGuard { _inner: inner }
}

impl Drop for TestGuard {
    fn drop(&mut self) {
        reset();
        set_enabled(env_requests_enable());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let _guard = test_lock();
        set_enabled(false);
        reset();
        add(Counter::SignalsProcessed, 10);
        observe(Histogram::CheckpointWriteNanos, 100);
        set_gauge(Gauge::ServeConnsOpen, 3);
        let snap = snapshot();
        assert!(snap.counters.iter().all(|(_, v)| *v == 0));
        assert!(snap.gauges.iter().all(|(_, v)| *v == 0));
        assert!(snap.histograms.iter().all(|h| h.count == 0 && h.sum == 0));
    }

    #[test]
    fn enabled_counters_accumulate_and_snapshot() {
        let _guard = test_lock();
        set_enabled(true);
        reset();
        add(Counter::SignalsProcessed, 7);
        add(Counter::SignalsProcessed, 5);
        set_gauge(Gauge::PoolWorkersActive, 4);
        assert_eq!(counter(Counter::SignalsProcessed), 12);
        let snap = snapshot();
        let sig = snap
            .counters
            .iter()
            .find(|(n, _)| *n == "msgsn_signals_processed_total")
            .unwrap();
        assert_eq!(sig.1, 12);
        let g =
            snap.gauges.iter().find(|(n, _)| *n == "msgsn_pool_workers_active").unwrap();
        assert_eq!(g.1, 4);
    }

    #[test]
    fn log2_buckets_are_cumulative_and_bounded() {
        let _guard = test_lock();
        set_enabled(true);
        reset();
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        for v in [1u64, 2, 3, 1024, u64::MAX] {
            observe(Histogram::CheckpointWriteNanos, v);
        }
        let snap = snapshot();
        let h = &snap.histograms[0];
        assert_eq!(h.count, 5);
        assert_eq!(h.sum, 1u64.wrapping_add(2).wrapping_add(3).wrapping_add(1024).wrapping_add(u64::MAX));
        // Cumulative: each bucket count is ≥ the previous one, and the
        // last bucket carries the full count.
        let mut prev = 0;
        for (_, n) in &h.buckets {
            assert!(*n >= prev);
            prev = *n;
        }
        assert_eq!(h.buckets.last().unwrap().1, 5);
    }

    #[test]
    fn prometheus_text_renders_every_instrument_kind() {
        let _guard = test_lock();
        set_enabled(true);
        reset();
        add(Counter::Batches, 3);
        set_gauge(Gauge::WriterQueueDepth, 2);
        observe(Histogram::CheckpointWriteNanos, 4096);
        let text = snapshot().render_prometheus();
        assert!(text.contains("# TYPE msgsn_batches_total counter"));
        assert!(text.contains("msgsn_batches_total 3"));
        assert!(text.contains("msgsn_writer_queue_depth 2"));
        assert!(text.contains("msgsn_checkpoint_write_nanos_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("msgsn_checkpoint_write_nanos_count 1"));
    }

    #[test]
    fn snapshot_json_round_trips_through_the_parser() {
        let _guard = test_lock();
        set_enabled(true);
        reset();
        add(Counter::FramesSent, 9);
        observe(Histogram::CheckpointWriteNanos, 77);
        let text = crate::runtime::render_json(&snapshot().to_json());
        let doc = crate::runtime::parse_json(&text).expect("valid json");
        let frames = doc
            .get("counters")
            .and_then(|c| c.get("msgsn_frames_sent_total"))
            .and_then(|v| v.as_u64());
        assert_eq!(frames, Some(9));
        let count = doc
            .get("histograms")
            .and_then(|h| h.get("msgsn_checkpoint_write_nanos"))
            .and_then(|h| h.get("count"))
            .and_then(|v| v.as_u64());
        assert_eq!(count, Some(1));
    }

    #[test]
    fn reset_zeroes_everything() {
        let _guard = test_lock();
        set_enabled(true);
        add(Counter::PoolSteals, 5);
        observe(Histogram::CheckpointWriteNanos, 10);
        reset();
        assert_eq!(counter(Counter::PoolSteals), 0);
        assert_eq!(snapshot().histograms[0].count, 0);
    }
}
