//! Process-wide observability spine: lock-free instruments + a bounded
//! structured event trace, with JSON / Prometheus-text exposition.
//!
//! The paper's whole argument is a measurement story — per-phase times
//! (Sample / Find Winners / Update, Tables 1–4) and time-per-signal —
//! so this crate measures itself continuously, from live processes,
//! without bending the bit-parity contract:
//!
//! - [`registry`] — preregistered counters, gauges and log-2 histograms
//!   on relaxed atomics, zero-cost-when-disabled (one relaxed load per
//!   instrument site; gate pattern mirrors [`crate::runtime::fault`]).
//!   Instrumented paths: engine phase timings and signal/batch counts,
//!   pool job/steal traffic, region crossings and fallback scans,
//!   checkpoint write-out latency and drops, fleet/dist job lifecycle
//!   (retry/quarantine/migration, worker eviction), transport frames,
//!   serve connections and requests.
//! - [`trace`] — a bounded drop-oldest ring of structured lifecycle
//!   events rendered as JSONL; flushed by `--trace-file` and embedded
//!   in `--report-json`.
//! - Exposition — the serve protocol's `metrics` verb and
//!   `msgsn fleet --metrics-json PATH` both emit [`metrics_json`];
//!   [`RegistrySnapshot::render_prometheus`] produces scrape-able text.
//!
//! Enable with `MSGSN_TELEMETRY=1` (or programmatically via
//! [`set_enabled`]; the CLI does so for `--metrics-json`/`--trace-file`).
//! The invariant `rust/tests/telemetry.rs` proves: telemetry-on runs are
//! **bit-identical** to telemetry-off runs — instruments observe, they
//! never steer.

pub mod registry;
pub mod trace;

pub use registry::{
    add, counter, enabled, observe, set_enabled, set_gauge, snapshot, test_lock, Counter,
    Gauge, Histogram, HistogramSnapshot, RegistrySnapshot, TestGuard, ENV_VAR,
};
pub use trace::{emit, TraceEvent};

use crate::runtime::Json;

/// Combined exposition document: the registry snapshot plus the newest
/// trace events — the payload of the serve `metrics` verb and of
/// `--metrics-json`.
pub fn metrics_json(trace_tail: usize) -> Json {
    let snap = registry::snapshot();
    let events = trace::tail(trace_tail);
    let mut obj = match snap.to_json() {
        Json::Obj(m) => m,
        _ => unreachable!("snapshot json is an object"),
    };
    obj.insert(
        "trace".to_string(),
        Json::Arr(events.iter().map(TraceEvent::to_json).collect()),
    );
    obj.insert("trace_dropped".to_string(), Json::Num(trace::dropped() as f64));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_json_combines_registry_and_trace() {
        let _guard = test_lock();
        set_enabled(true);
        add(Counter::ServeRequests, 2);
        emit("job_admitted", Some("j0"), vec![]);
        let doc = metrics_json(16);
        assert_eq!(
            doc.get("counters")
                .and_then(|c| c.get("msgsn_serve_requests_total"))
                .and_then(|v| v.as_u64()),
            Some(2)
        );
        let trace = doc.get("trace").and_then(|t| t.as_arr()).expect("trace array");
        assert_eq!(trace.len(), 1);
        assert_eq!(doc.get("trace_dropped").and_then(|v| v.as_u64()), Some(0));
    }
}
