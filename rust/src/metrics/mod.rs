//! Phase timing and report rendering.
//!
//! The paper reports, per run: iterations, signals, discarded signals,
//! units, connections, total time, per-phase times (Sample / Find Winners /
//! Update) and times per signal (Tables 1–4). [`PhaseTimes`] accumulates
//! the per-phase clocks; [`table`] renders aligned text tables for the
//! reproduction harness.

use std::time::{Duration, Instant};

/// The three phases of the basic iteration (paper §2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Sample,
    FindWinners,
    Update,
}

/// Accumulated wall-clock per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    pub sample: Duration,
    pub find: Duration,
    pub update: Duration,
}

impl PhaseTimes {
    #[inline]
    pub fn add(&mut self, phase: Phase, d: Duration) {
        match phase {
            Phase::Sample => self.sample += d,
            Phase::FindWinners => self.find += d,
            Phase::Update => self.update += d,
        }
    }

    /// Fold another accumulation into this one — aggregating per-job
    /// phase times into fleet totals (`FleetReport::phase_totals`).
    pub fn merge(&mut self, other: &PhaseTimes) {
        self.sample += other.sample;
        self.find += other.find;
        self.update += other.update;
    }

    pub fn total(&self) -> Duration {
        self.sample + self.find + self.update
    }

    /// Fraction of total time spent in Find Winners (Fig. 2's y-axis).
    pub fn find_fraction(&self) -> f64 {
        let t = self.total().as_secs_f64();
        if t == 0.0 {
            0.0
        } else {
            self.find.as_secs_f64() / t
        }
    }
}

/// Scope timer: measures into a `PhaseTimes` slot on drop-free explicit
/// stop (explicit to keep the hot loop free of drop glue).
pub struct PhaseClock {
    start: Instant,
}

impl PhaseClock {
    #[inline]
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Stop and record, returning the measured duration so callers can
    /// feed telemetry off the same single `Instant::elapsed` read.
    #[inline]
    pub fn stop(self, times: &mut PhaseTimes, phase: Phase) -> Duration {
        let d = self.start.elapsed();
        times.add(phase, d);
        d
    }
}

/// Minimal aligned-text table builder (the vendored set has no prettytable;
/// the reproduction harness prints the paper's tables through this).
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Render with per-column alignment (first column left, rest right).
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for c in 0..cols {
            width[c] = self.header[c].chars().count();
            for r in &self.rows {
                width[c] = width[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                let pad = width[c] - cell.chars().count();
                if c == 0 {
                    line.push_str(&format!(" {}{} |", cell, " ".repeat(pad)));
                } else {
                    line.push_str(&format!(" {}{} |", " ".repeat(pad), cell));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }

    /// CSV dump (results/ files consumed by plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Human duration (s with ms precision).
pub fn fmt_secs(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

/// Scientific notation matching the paper's "time per signal" rows.
pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    format!("{x:.4e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_accumulate() {
        let mut t = PhaseTimes::default();
        t.add(Phase::Sample, Duration::from_millis(10));
        t.add(Phase::FindWinners, Duration::from_millis(60));
        t.add(Phase::Update, Duration::from_millis(30));
        assert_eq!(t.total(), Duration::from_millis(100));
        assert!((t.find_fraction() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn clock_measures_something() {
        let mut times = PhaseTimes::default();
        let c = PhaseClock::start();
        std::thread::sleep(Duration::from_millis(2));
        let d = c.stop(&mut times, Phase::Update);
        assert!(times.update >= Duration::from_millis(1));
        assert_eq!(d, times.update);
    }

    #[test]
    fn phase_times_merge_adds_slotwise() {
        let mut a = PhaseTimes {
            sample: Duration::from_millis(1),
            find: Duration::from_millis(2),
            update: Duration::from_millis(3),
        };
        let b = PhaseTimes {
            sample: Duration::from_millis(10),
            find: Duration::from_millis(20),
            update: Duration::from_millis(30),
        };
        a.merge(&b);
        assert_eq!(a.sample, Duration::from_millis(11));
        assert_eq!(a.find, Duration::from_millis(22));
        assert_eq!(a.update, Duration::from_millis(33));
        assert_eq!(a.total(), Duration::from_millis(66));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{s}");
    }

    #[test]
    fn csv_escapes_nothing_but_works() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn sci_format_matches_paper_style() {
        assert_eq!(fmt_sci(5.4692e-6), "5.4692e-6");
        assert_eq!(fmt_sci(0.0), "0");
    }
}
