//! The dist coordinator: owns the jobs manifest, routes each job to one
//! of N workers, and keeps the manifest converging through worker death,
//! hangs, and lossy links.
//!
//! ## Failure model
//!
//! | failure                  | detector                          | recovery |
//! |--------------------------|-----------------------------------|----------|
//! | worker process dies      | transport `Closed` on next poll   | evict; migrate its jobs from the last good checkpoint |
//! | worker hangs             | missed heartbeats (`heartbeat_timeout`) | evict; migrate (partition-safe: the evicted link is never polled again) |
//! | link corrupts a frame    | per-frame CRC → `Frame` error     | evict (the link is untrustworthy) |
//! | message loss / dup       | seq + ack + retransmission        | Assigns resent until acked; finals resent by the worker; dups re-acked |
//! | job crashes on a worker  | `Failed` message                  | retry budget + exponential backoff, placed on a worker it has not failed on |
//! | job fails everywhere     | retry budget exhausted            | quarantined — reported, never silently dropped |
//! | every worker lost        | alive count hits 0 with jobs open | [`DistOutcome::WorkersLost`], exit code 4 |
//!
//! **Partition safety.** Eviction is one-way: once a worker misses its
//! heartbeat window (or its link errors), the coordinator stops polling
//! that link forever. A hung-but-alive worker on the far side of a
//! partition can keep computing and sending — nothing it says is read, so
//! its stale results can never race the migrated job's. The only thing
//! ever sent on an evicted link is the final best-effort `Shutdown`.
//!
//! **Migration is bit-exact.** The unit of migration is the
//! `fleet::snapshot` v2 blob — the same CRC-trailed format the fleet
//! proves restores bit-identically. The coordinator CRC-checks every
//! received generation ([`crate::fleet::snapshot::verify_bytes`]) before
//! accepting it as "last good", and a monotone `(owner, turn)` watermark
//! keeps a duplicated *older* snapshot from regressing a newer one.
//! A job migrated at an arbitrary round therefore finishes bit-identical
//! to one that never moved (`rust/tests/dist.rs`).

use std::time::{Duration, Instant};

use crate::fleet::snapshot;
use crate::metrics::Table;
use crate::runtime::Json;

use super::transport::{Transport, TransportError};
use super::wire::{Message, PROTOCOL_VERSION};

/// Coordinator knobs.
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Evict a worker that has not been heard from for this long. Must
    /// exceed the worker's worst-case round time (it heartbeats once per
    /// round, *between* job steps).
    pub heartbeat_timeout: Duration,
    /// How long each scheduler round waits on each worker's link for the
    /// first message (subsequent drains never block).
    pub poll: Duration,
    /// Crash-retries a job gets (across workers) before quarantine —
    /// same budget discipline as [`crate::fleet::FleetOptions::max_retries`].
    pub max_retries: u32,
    /// Base of the turn-based exponential backoff after a `Failed`
    /// report: the k-th failure delays reassignment by
    /// `backoff_rounds · 2^(k−1)` coordinator rounds.
    pub backoff_rounds: u64,
    /// Resend an unacked Assign (same seq) every this many rounds.
    pub assign_resend_rounds: u64,
}

impl Default for DistOptions {
    fn default() -> Self {
        Self {
            heartbeat_timeout: Duration::from_secs(5),
            poll: Duration::from_millis(1),
            max_retries: 2,
            backoff_rounds: 2,
            assign_resend_rounds: 50,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobPhase {
    /// Waiting for (re)assignment — fresh, migrated, or backing off.
    Pending,
    /// Routed to a worker (acked or in flight).
    Assigned,
    /// Final snapshot received and verified.
    Done,
    /// Retry budget exhausted.
    Quarantined,
}

struct JobState {
    name: String,
    /// Single-job manifest text ([`crate::fleet::manifest_job_payloads`]).
    payload: String,
    phase: JobPhase,
    owner: Option<usize>,
    owner_name: Option<String>,
    assign_seq: u64,
    acked: bool,
    assigned_round: u64,
    /// Crash reports charged against the retry budget (migrations are free).
    attempts: u32,
    retry_at_round: u64,
    /// Workers this job crashed on — avoided on reassignment while any
    /// other candidate is alive.
    failed_on: Vec<String>,
    last_error: Option<String>,
    /// Last good checkpoint generation + its `(owner, turn)` watermark.
    ckpt: Option<Vec<u8>>,
    ckpt_from: Option<String>,
    ckpt_turn: u64,
    /// The verified final snapshot — the job's result.
    final_bytes: Option<Vec<u8>>,
    signals: u64,
    units: u64,
    /// Times the job changed workers because its owner was evicted.
    migrations: u32,
}

struct WorkerSlot {
    name: String,
    link: Box<dyn Transport>,
    alive: bool,
    /// Hello received (jobs are only routed to introduced workers).
    hello: bool,
    last_heard: Instant,
}

/// Final state of one job in the [`DistReport`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistJobStatus {
    Done,
    Quarantined,
    /// Still open when the coordinator ran out of workers.
    Unfinished,
}

impl DistJobStatus {
    pub fn name(self) -> &'static str {
        match self {
            DistJobStatus::Done => "done",
            DistJobStatus::Quarantined => "quarantined",
            DistJobStatus::Unfinished => "unfinished",
        }
    }
}

/// One job's outcome row.
#[derive(Clone, Debug)]
pub struct DistRow {
    pub name: String,
    pub status: DistJobStatus,
    /// Worker that produced the final result (or held the job last).
    pub worker: Option<String>,
    pub attempts: u32,
    pub migrations: u32,
    pub signals: u64,
    pub units: u64,
    pub error: Option<String>,
}

/// Process-level outcome, for the `msgsn coordinator` exit code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistOutcome {
    AllDone,
    /// Some — not all — jobs quarantined; the rest are done.
    PartialFailure,
    AllFailed,
    /// Every worker died/hung with jobs still open.
    WorkersLost,
}

impl DistOutcome {
    /// Exit code: 0 success, 2 partial, 3 all failed — matching
    /// [`crate::fleet::FleetOutcome::exit_code`] — plus 4 for the
    /// coordinator-specific "no workers left" state.
    pub fn exit_code(self) -> u8 {
        match self {
            DistOutcome::AllDone => 0,
            DistOutcome::PartialFailure => 2,
            DistOutcome::AllFailed => 3,
            DistOutcome::WorkersLost => 4,
        }
    }
}

/// Aggregated result of a coordinator run, one row per manifest job.
#[derive(Clone, Debug)]
pub struct DistReport {
    pub rows: Vec<DistRow>,
}

impl DistReport {
    pub fn outcome(&self) -> DistOutcome {
        if self.rows.iter().any(|r| r.status == DistJobStatus::Unfinished) {
            return DistOutcome::WorkersLost;
        }
        let quarantined =
            self.rows.iter().filter(|r| r.status == DistJobStatus::Quarantined).count();
        if quarantined == 0 {
            DistOutcome::AllDone
        } else if quarantined == self.rows.len() {
            DistOutcome::AllFailed
        } else {
            DistOutcome::PartialFailure
        }
    }

    /// One summary row per job.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "job",
            "status",
            "worker",
            "attempts",
            "migrations",
            "signals",
            "units",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.status.name().to_string(),
                r.worker.clone().unwrap_or_else(|| "-".to_string()),
                r.attempts.to_string(),
                r.migrations.to_string(),
                r.signals.to_string(),
                r.units.to_string(),
            ]);
        }
        t
    }
}

/// The coordinator (see module docs).
pub struct Coordinator {
    opts: DistOptions,
    workers: Vec<WorkerSlot>,
    jobs: Vec<JobState>,
    next_seq: u64,
}

impl Coordinator {
    /// `payloads` is `(job name, single-job manifest text)` per job —
    /// exactly what [`crate::fleet::manifest_job_payloads`] produces.
    pub fn new(payloads: Vec<(String, String)>, opts: DistOptions) -> Self {
        let jobs = payloads
            .into_iter()
            .map(|(name, payload)| JobState {
                name,
                payload,
                phase: JobPhase::Pending,
                owner: None,
                owner_name: None,
                assign_seq: 0,
                acked: false,
                assigned_round: 0,
                attempts: 0,
                retry_at_round: 0,
                failed_on: Vec::new(),
                last_error: None,
                ckpt: None,
                ckpt_from: None,
                ckpt_turn: 0,
                final_bytes: None,
                signals: 0,
                units: 0,
                migrations: 0,
            })
            .collect();
        Self { opts, workers: Vec::new(), jobs, next_seq: 1 }
    }

    /// Register a connected worker link. `name` is diagnostic (the wire
    /// identity arrives in the worker's own Hello); the *link*'s peer
    /// label is what fault scopes match.
    pub fn add_worker(&mut self, name: &str, link: Box<dyn Transport>) {
        self.workers.push(WorkerSlot {
            name: name.to_string(),
            link,
            alive: true,
            hello: false,
            last_heard: Instant::now(),
        });
    }

    /// The verified final snapshot for a finished job — restore it into a
    /// fresh session to get the network (`rust/tests/dist.rs` does this
    /// to prove migration bit-exactness).
    pub fn final_snapshot(&self, name: &str) -> Option<&[u8]> {
        self.jobs
            .iter()
            .find(|j| j.name == name)
            .and_then(|j| j.final_bytes.as_deref())
    }

    /// Drive the manifest to completion (or to [`DistOutcome::WorkersLost`]).
    pub fn run(&mut self, mut progress: impl FnMut(&str)) -> DistReport {
        let mut round: u64 = 0;
        loop {
            // 1. Pump every *alive* worker's link (evicted links are
            // never polled again — see "Partition safety").
            for w in 0..self.workers.len() {
                if !self.workers[w].alive {
                    continue;
                }
                self.workers[w].link.set_turn(round);
                let mut first = true;
                for _ in 0..256 {
                    let timeout = if first { self.opts.poll } else { Duration::ZERO };
                    first = false;
                    match self.workers[w].link.recv(timeout) {
                        Ok(Some(msg)) => {
                            self.workers[w].last_heard = Instant::now();
                            self.handle(w, msg, round, &mut progress);
                            if !self.workers[w].alive {
                                break;
                            }
                        }
                        Ok(None) => break,
                        // Injected err: treat as a lost message.
                        Err(TransportError::Injected) => continue,
                        Err(e) => {
                            self.evict(w, &e.to_string(), round, &mut progress);
                            break;
                        }
                    }
                }
            }

            // 2. Heartbeat timeouts: the only detector for a worker that
            // is hung rather than dead.
            for w in 0..self.workers.len() {
                if self.workers[w].alive
                    && self.workers[w].last_heard.elapsed() > self.opts.heartbeat_timeout
                {
                    self.evict(w, "heartbeat timeout", round, &mut progress);
                }
            }

            // 3. Termination.
            let outstanding = self
                .jobs
                .iter()
                .any(|j| matches!(j.phase, JobPhase::Pending | JobPhase::Assigned));
            if !outstanding {
                self.broadcast_shutdown();
                return self.report();
            }
            if self.workers.iter().all(|w| !w.alive) {
                progress("all workers lost with jobs outstanding");
                self.broadcast_shutdown();
                return self.report();
            }

            // 4. (Re)assign pending jobs whose backoff has elapsed.
            for j in 0..self.jobs.len() {
                if self.jobs[j].phase == JobPhase::Pending && round >= self.jobs[j].retry_at_round {
                    self.assign(j, round, &mut progress);
                }
            }

            // 5. Retransmit unacked Assigns (same seq — the worker
            // re-acks duplicates).
            for j in 0..self.jobs.len() {
                let job = &self.jobs[j];
                if job.phase == JobPhase::Assigned
                    && !job.acked
                    && round.saturating_sub(job.assigned_round) >= self.opts.assign_resend_rounds
                {
                    self.resend_assign(j, round, &mut progress);
                }
            }

            round += 1;
        }
    }

    fn handle(&mut self, w: usize, msg: Message, round: u64, progress: &mut impl FnMut(&str)) {
        match msg {
            Message::Hello { worker, protocol } => {
                if protocol != PROTOCOL_VERSION {
                    self.evict(
                        w,
                        &format!("protocol {protocol} != {PROTOCOL_VERSION}"),
                        round,
                        progress,
                    );
                    return;
                }
                if !self.workers[w].hello {
                    self.workers[w].hello = true;
                    progress(&format!("worker {worker} connected (protocol {protocol})"));
                }
            }
            Message::Heartbeat { .. } => {} // receipt already reset the clock
            Message::Ack { seq } => {
                if let Some(job) = self
                    .jobs
                    .iter_mut()
                    .find(|j| j.owner == Some(w) && j.assign_seq == seq)
                {
                    job.acked = true;
                }
            }
            Message::Progress { job, signals, units, .. } => {
                if let Some(j) = self.jobs.iter_mut().find(|j| j.name == job && j.owner == Some(w))
                {
                    j.signals = signals;
                    j.units = units;
                }
            }
            Message::CheckpointBytes { seq, job, turn, is_final, bytes } => {
                self.accept_checkpoint(w, seq, &job, turn, is_final, bytes, progress);
            }
            Message::Failed { job, error } => self.job_failed(w, &job, error, round, progress),
            // Assign/Shutdown never legitimately flow worker → coordinator.
            Message::Assign { .. } | Message::Shutdown => {}
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn accept_checkpoint(
        &mut self,
        w: usize,
        seq: u64,
        job: &str,
        turn: u64,
        is_final: bool,
        bytes: Vec<u8>,
        progress: &mut impl FnMut(&str),
    ) {
        let worker_name = self.workers[w].name.clone();
        let Some(state) = self.jobs.iter_mut().find(|j| j.name == job) else {
            return;
        };
        if is_final && state.phase == JobPhase::Done {
            // Duplicate final (our Ack was lost): re-ack so the worker
            // stops retransmitting. The stored result is untouched.
            self.ack_to(w, seq);
            return;
        }
        if state.owner != Some(w) {
            // Stale sender: the job moved on. Only reachable via message
            // reordering — an evicted ex-owner is never polled.
            return;
        }
        // A snapshot that would fail restore must never become "last
        // good": CRC-verify on receipt, at the coordinator, not at the
        // eventual migration target.
        if let Err(e) = snapshot::verify_bytes(&bytes) {
            progress(&format!(
                "job {job}: discarding corrupt checkpoint from {worker_name}: {e}"
            ));
            return;
        }
        if is_final {
            state.final_bytes = Some(bytes);
            state.phase = JobPhase::Done;
            state.owner_name = Some(worker_name.clone());
            progress(&format!("job {job} done on worker {worker_name}"));
            crate::telemetry::emit(
                "checkpoint_promoted",
                Some(job),
                vec![
                    ("worker", Json::Str(worker_name)),
                    ("final", Json::Bool(true)),
                    ("turn", Json::Num(turn as f64)),
                ],
            );
            self.ack_to(w, seq);
        } else {
            // Monotone watermark per owner: a duplicated older frame
            // must not regress a newer generation. A fresh owner (after
            // reassignment) always starts a new watermark.
            let fresh_owner = state.ckpt_from.as_deref() != Some(worker_name.as_str());
            if fresh_owner || turn >= state.ckpt_turn {
                state.ckpt = Some(bytes);
                state.ckpt_from = Some(worker_name.clone());
                state.ckpt_turn = turn;
                crate::telemetry::emit(
                    "checkpoint_promoted",
                    Some(job),
                    vec![
                        ("worker", Json::Str(worker_name)),
                        ("final", Json::Bool(false)),
                        ("turn", Json::Num(turn as f64)),
                    ],
                );
            }
        }
    }

    fn job_failed(
        &mut self,
        w: usize,
        job: &str,
        error: String,
        round: u64,
        progress: &mut impl FnMut(&str),
    ) {
        let worker_name = self.workers[w].name.clone();
        let budget = self.opts.max_retries;
        let backoff_base = self.opts.backoff_rounds.max(1);
        let Some(state) = self.jobs.iter_mut().find(|j| j.name == job && j.owner == Some(w))
        else {
            return;
        };
        state.attempts += 1;
        state.last_error = Some(error.clone());
        state.owner = None;
        state.owner_name = Some(worker_name.clone());
        if !state.failed_on.contains(&worker_name) {
            state.failed_on.push(worker_name.clone());
        }
        crate::telemetry::emit(
            "job_failed",
            Some(job),
            vec![
                ("worker", Json::Str(worker_name.clone())),
                ("attempt", Json::Num(f64::from(state.attempts))),
                ("error", Json::Str(error.clone())),
            ],
        );
        if state.attempts > budget {
            state.phase = JobPhase::Quarantined;
            progress(&format!(
                "job {job} QUARANTINED after {} attempts (last on {worker_name}): {error}",
                state.attempts
            ));
            crate::telemetry::add(crate::telemetry::Counter::JobsQuarantined, 1);
            crate::telemetry::emit(
                "job_quarantined",
                Some(job),
                vec![("attempts", Json::Num(f64::from(state.attempts)))],
            );
        } else {
            state.phase = JobPhase::Pending;
            let backoff =
                backoff_base.saturating_mul(1u64 << u64::from((state.attempts - 1).min(16)));
            state.retry_at_round = round.saturating_add(backoff);
            progress(&format!(
                "job {job} failed on {worker_name} (attempt {}/{}): {error} — retry in {backoff} rounds",
                state.attempts,
                budget + 1
            ));
            crate::telemetry::add(crate::telemetry::Counter::JobsRetried, 1);
            crate::telemetry::emit(
                "job_retried",
                Some(job),
                vec![("attempt", Json::Num(f64::from(state.attempts)))],
            );
        }
    }

    /// Evict a worker and put its open jobs back in the pending pool for
    /// immediate migration. Eviction consumes no retry attempts — worker
    /// death is not the job's fault.
    fn evict(&mut self, w: usize, why: &str, round: u64, progress: &mut impl FnMut(&str)) {
        self.workers[w].alive = false;
        progress(&format!("worker {} evicted: {why}", self.workers[w].name));
        crate::telemetry::add(crate::telemetry::Counter::WorkersEvicted, 1);
        crate::telemetry::emit(
            "worker_evicted",
            None,
            vec![
                ("worker", Json::Str(self.workers[w].name.clone())),
                ("why", Json::Str(why.to_string())),
                ("round", Json::Num(round as f64)),
            ],
        );
        for job in &mut self.jobs {
            if job.owner == Some(w) && job.phase == JobPhase::Assigned {
                job.owner = None;
                job.phase = JobPhase::Pending;
                job.retry_at_round = round;
                job.migrations += 1;
                progress(&format!(
                    "job {} migrating ({})",
                    job.name,
                    match &job.ckpt {
                        Some(_) => format!("from checkpoint @ turn {}", job.ckpt_turn),
                        None => "from scratch".to_string(),
                    }
                ));
                crate::telemetry::add(crate::telemetry::Counter::JobsMigrated, 1);
                crate::telemetry::emit(
                    "job_migrated",
                    Some(&job.name),
                    vec![
                        ("from_checkpoint", Json::Bool(job.ckpt.is_some())),
                        ("ckpt_turn", Json::Num(job.ckpt_turn as f64)),
                        ("migrations", Json::Num(f64::from(job.migrations))),
                    ],
                );
            }
        }
    }

    fn assign(&mut self, j: usize, round: u64, progress: &mut impl FnMut(&str)) {
        let candidates: Vec<usize> = (0..self.workers.len())
            .filter(|&w| self.workers[w].alive && self.workers[w].hello)
            .collect();
        if candidates.is_empty() {
            return; // wait for a Hello (or for WorkersLost to trigger)
        }
        // Placement: pin by manifest index for determinism, avoid workers
        // the job already crashed on while any alternative exists.
        let not_failed: Vec<usize> = candidates
            .iter()
            .copied()
            .filter(|&w| !self.jobs[j].failed_on.contains(&self.workers[w].name))
            .collect();
        let pool = if not_failed.is_empty() { &candidates } else { &not_failed };
        let pinned = j % self.workers.len();
        let pick = if pool.contains(&pinned) { pinned } else { pool[j % pool.len()] };

        let seq = self.next_seq;
        self.next_seq += 1;
        let msg = Message::Assign {
            seq,
            job: self.jobs[j].name.clone(),
            spec_json: self.jobs[j].payload.clone(),
            checkpoint: self.jobs[j].ckpt.clone(),
        };
        match self.workers[pick].link.send(&msg) {
            Ok(()) | Err(TransportError::Injected) => {
                let job = &mut self.jobs[j];
                job.owner = Some(pick);
                job.owner_name = Some(self.workers[pick].name.clone());
                job.phase = JobPhase::Assigned;
                job.assign_seq = seq;
                job.acked = false;
                job.assigned_round = round;
                // New owner, new checkpoint watermark: its first shipped
                // generation is accepted at any turn.
                job.ckpt_from = None;
                job.ckpt_turn = 0;
                progress(&format!(
                    "job {} → worker {} (seq {seq}, {})",
                    job.name,
                    self.workers[pick].name,
                    match &job.ckpt {
                        Some(_) => "resuming from checkpoint",
                        None => "from scratch",
                    }
                ));
            }
            Err(e) => {
                // The job stays Pending; the eviction migrates nothing
                // extra (this job has no owner yet) and the next round
                // picks a surviving worker.
                self.evict(pick, &e.to_string(), round, progress);
            }
        }
    }

    fn resend_assign(&mut self, j: usize, round: u64, progress: &mut impl FnMut(&str)) {
        let Some(w) = self.jobs[j].owner else { return };
        let msg = Message::Assign {
            seq: self.jobs[j].assign_seq,
            job: self.jobs[j].name.clone(),
            spec_json: self.jobs[j].payload.clone(),
            checkpoint: self.jobs[j].ckpt.clone(),
        };
        match self.workers[w].link.send(&msg) {
            Ok(()) | Err(TransportError::Injected) => {
                self.jobs[j].assigned_round = round;
            }
            Err(e) => self.evict(w, &e.to_string(), round, progress),
        }
    }

    fn ack_to(&mut self, w: usize, seq: u64) {
        // Best-effort: a lost Ack just means one more retransmission.
        let _ = self.workers[w].link.send(&Message::Ack { seq });
    }

    /// Best-effort Shutdown to *every* link, evicted ones included — a
    /// hung-but-alive worker that wakes up after eviction should still
    /// drain its mailbox and exit.
    fn broadcast_shutdown(&mut self) {
        for w in &mut self.workers {
            let _ = w.link.send(&Message::Shutdown);
        }
    }

    fn report(&self) -> DistReport {
        DistReport {
            rows: self
                .jobs
                .iter()
                .map(|j| DistRow {
                    name: j.name.clone(),
                    status: match j.phase {
                        JobPhase::Done => DistJobStatus::Done,
                        JobPhase::Quarantined => DistJobStatus::Quarantined,
                        JobPhase::Pending | JobPhase::Assigned => DistJobStatus::Unfinished,
                    },
                    worker: j.owner_name.clone(),
                    attempts: j.attempts,
                    migrations: j.migrations,
                    signals: j.signals,
                    units: j.units,
                    error: j.last_error.clone(),
                })
                .collect(),
        }
    }
}
