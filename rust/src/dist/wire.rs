//! The dist wire protocol: a versioned message set framed with per-frame
//! CRC and size caps.
//!
//! A frame is:
//!
//! ```text
//! magic "MWF1"  (4 bytes)
//! len           (u32 LE — payload length, capped at MAX_FRAME)
//! payload       (len bytes — tagged message body over runtime::bytes)
//! crc32         (u32 LE — CRC-32/IEEE of the payload)
//! ```
//!
//! Decode is **total**: a malformed, truncated, bit-flipped or oversized
//! frame is always an `Err`, never a panic and never a huge allocation —
//! the declared length is validated against both [`MAX_FRAME`] and the
//! actual frame size before anything is copied, and every payload read
//! goes through the total [`crate::runtime::bytes::ByteReader`]
//! (`rust/tests/properties.rs` proves this over an exhaustive truncation
//! sweep and a randomized bit-flip corpus covering every message type).
//!
//! The protocol itself (who sends what when) lives in
//! [`crate::dist::coordinator`] / [`crate::dist::worker`]; this module
//! only defines the vocabulary and its bytes. Checkpoint payloads inside
//! [`Message::Assign`] / [`Message::CheckpointBytes`] are opaque
//! `fleet::snapshot` v2 blobs — they carry their *own* CRC trailer, so a
//! migrated checkpoint is integrity-checked twice: once per hop (frame
//! CRC) and once at restore (snapshot CRC).

use crate::runtime::bytes::{crc32, ByteReader, ByteWriter};

/// Version negotiated in [`Message::Hello`]; a mismatch is a refused
/// worker, not a best-effort parse.
pub const PROTOCOL_VERSION: u32 = 1;

/// Frame magic: **M**sgsn **W**ire **F**rame v**1**.
pub const FRAME_MAGIC: [u8; 4] = *b"MWF1";

/// Hard cap on a frame payload (64 MiB). A declared length beyond this is
/// rejected *before* any allocation — the guard that keeps a corrupt or
/// hostile length field from driving `Vec::with_capacity(4 GiB)`.
pub const MAX_FRAME: usize = 64 << 20;

/// Frame overhead around the payload: magic + len + trailing CRC.
pub const FRAME_OVERHEAD: usize = 12;

/// Everything that travels between coordinator and worker.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Worker → coordinator, once per connection: identity + protocol
    /// version. A version mismatch evicts the worker immediately.
    Hello { worker: String, protocol: u32 },
    /// Coordinator → worker: run this job. `spec_json` is a complete
    /// single-job manifest (see [`crate::fleet::spec::manifest_job_payloads`]);
    /// `checkpoint` is the last good snapshot generation to resume from
    /// (`None` = start fresh). Resent with the *same* `seq` until acked —
    /// the worker re-acks duplicates idempotently.
    Assign { seq: u64, job: String, spec_json: String, checkpoint: Option<Vec<u8>> },
    /// Either direction: acknowledges the `seq` of an [`Message::Assign`]
    /// (worker → coordinator) or of a final [`Message::CheckpointBytes`]
    /// (coordinator → worker). Loss-tolerant: the sender resends until
    /// acked, the receiver re-acks duplicates.
    Ack { seq: u64 },
    /// Worker → coordinator: progress counters for one job.
    Progress { job: String, signals: u64, units: u64, done: bool },
    /// Worker → coordinator: a `fleet::snapshot` v2 blob for one job.
    /// Periodic checkpoints (`is_final: false`) are fire-and-forget — a
    /// lost one only widens the resume window. The final snapshot
    /// (`is_final: true`) *is* the job result and is resent until acked.
    CheckpointBytes { seq: u64, job: String, turn: u64, is_final: bool, bytes: Vec<u8> },
    /// Worker → coordinator: liveness. `seq` is the worker's scheduler
    /// round (monotone), purely diagnostic — receipt is what resets the
    /// coordinator's missed-heartbeat clock.
    Heartbeat { worker: String, seq: u64 },
    /// Worker → coordinator: the job crashed or failed to build/restore.
    /// The coordinator charges the retry budget and reassigns or
    /// quarantines.
    Failed { job: String, error: String },
    /// Coordinator → worker: drain and exit.
    Shutdown,
}

impl Message {
    fn tag(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::Assign { .. } => 2,
            Message::Ack { .. } => 3,
            Message::Progress { .. } => 4,
            Message::CheckpointBytes { .. } => 5,
            Message::Heartbeat { .. } => 6,
            Message::Failed { .. } => 7,
            Message::Shutdown => 8,
        }
    }

    /// Short name for log lines and errors.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "hello",
            Message::Assign { .. } => "assign",
            Message::Ack { .. } => "ack",
            Message::Progress { .. } => "progress",
            Message::CheckpointBytes { .. } => "checkpoint",
            Message::Heartbeat { .. } => "heartbeat",
            Message::Failed { .. } => "failed",
            Message::Shutdown => "shutdown",
        }
    }
}

fn encode_payload(msg: &Message) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(msg.tag());
    match msg {
        Message::Hello { worker, protocol } => {
            w.str(worker);
            w.u32(*protocol);
        }
        Message::Assign { seq, job, spec_json, checkpoint } => {
            w.u64(*seq);
            w.str(job);
            w.str(spec_json);
            match checkpoint {
                None => w.bool(false),
                Some(bytes) => {
                    w.bool(true);
                    w.u32(bytes.len() as u32);
                    w.raw(bytes);
                }
            }
        }
        Message::Ack { seq } => w.u64(*seq),
        Message::Progress { job, signals, units, done } => {
            w.str(job);
            w.u64(*signals);
            w.u64(*units);
            w.bool(*done);
        }
        Message::CheckpointBytes { seq, job, turn, is_final, bytes } => {
            w.u64(*seq);
            w.str(job);
            w.u64(*turn);
            w.bool(*is_final);
            w.u32(bytes.len() as u32);
            w.raw(bytes);
        }
        Message::Heartbeat { worker, seq } => {
            w.str(worker);
            w.u64(*seq);
        }
        Message::Failed { job, error } => {
            w.str(job);
            w.str(error);
        }
        Message::Shutdown => {}
    }
    w.into_inner()
}

/// Length-prefixed byte blob; the prefix is validated against the
/// remaining payload before the copy (same discipline as
/// [`ByteReader::str`]).
fn read_blob(r: &mut ByteReader<'_>) -> Result<Vec<u8>, String> {
    let len = r.len_prefix(1).map_err(|e| e.to_string())?;
    Ok(r.bytes(len).map_err(|e| e.to_string())?.to_vec())
}

fn decode_payload(payload: &[u8]) -> Result<Message, String> {
    let mut r = ByteReader::new(payload);
    let tag = r.u8().map_err(|e| e.to_string())?;
    let s = |r: &mut ByteReader<'_>| r.str().map_err(|e| e.to_string());
    let msg = match tag {
        1 => {
            let worker = s(&mut r)?;
            let protocol = r.u32().map_err(|e| e.to_string())?;
            Message::Hello { worker, protocol }
        }
        2 => {
            let seq = r.u64().map_err(|e| e.to_string())?;
            let job = s(&mut r)?;
            let spec_json = s(&mut r)?;
            let checkpoint = if r.bool().map_err(|e| e.to_string())? {
                Some(read_blob(&mut r)?)
            } else {
                None
            };
            Message::Assign { seq, job, spec_json, checkpoint }
        }
        3 => Message::Ack { seq: r.u64().map_err(|e| e.to_string())? },
        4 => {
            let job = s(&mut r)?;
            let signals = r.u64().map_err(|e| e.to_string())?;
            let units = r.u64().map_err(|e| e.to_string())?;
            let done = r.bool().map_err(|e| e.to_string())?;
            Message::Progress { job, signals, units, done }
        }
        5 => {
            let seq = r.u64().map_err(|e| e.to_string())?;
            let job = s(&mut r)?;
            let turn = r.u64().map_err(|e| e.to_string())?;
            let is_final = r.bool().map_err(|e| e.to_string())?;
            let bytes = read_blob(&mut r)?;
            Message::CheckpointBytes { seq, job, turn, is_final, bytes }
        }
        6 => {
            let worker = s(&mut r)?;
            let seq = r.u64().map_err(|e| e.to_string())?;
            Message::Heartbeat { worker, seq }
        }
        7 => {
            let job = s(&mut r)?;
            let error = s(&mut r)?;
            Message::Failed { job, error }
        }
        8 => Message::Shutdown,
        other => return Err(format!("unknown message tag {other}")),
    };
    r.expect_end().map_err(|e| e.to_string())?;
    Ok(msg)
}

/// Encode a message as one self-delimiting frame (see module docs).
pub fn encode_frame(msg: &Message) -> Vec<u8> {
    let payload = encode_payload(msg);
    assert!(payload.len() <= MAX_FRAME, "frame payload exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(payload.len() + FRAME_OVERHEAD);
    out.extend_from_slice(&FRAME_MAGIC);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let crc = crc32(&payload);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Validate a frame *header* (first 8 bytes: magic + declared length).
/// Streaming receivers call this before allocating the payload buffer, so
/// the size cap holds even when the rest of the frame hasn't arrived yet.
pub fn check_header(header: &[u8; 8]) -> Result<usize, String> {
    if header[..4] != FRAME_MAGIC {
        return Err(format!("bad frame magic {:?} (expected {FRAME_MAGIC:?})", &header[..4]));
    }
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    if len > MAX_FRAME {
        return Err(format!("frame length {len} exceeds cap {MAX_FRAME}"));
    }
    Ok(len)
}

/// Decode one complete frame. Total: any malformed input — wrong magic,
/// inconsistent or oversized length, CRC mismatch, truncated or
/// trailing-garbage payload — is an `Err`.
pub fn decode_frame(frame: &[u8]) -> Result<Message, String> {
    if frame.len() < FRAME_OVERHEAD {
        return Err(format!("frame of {} bytes is shorter than the frame overhead", frame.len()));
    }
    let mut header = [0u8; 8];
    header.copy_from_slice(&frame[..8]);
    let len = check_header(&header)?;
    if len != frame.len() - FRAME_OVERHEAD {
        return Err(format!(
            "frame length field {len} disagrees with frame size {} - {FRAME_OVERHEAD}",
            frame.len()
        ));
    }
    let payload = &frame[8..8 + len];
    let want = u32::from_le_bytes([
        frame[8 + len],
        frame[9 + len],
        frame[10 + len],
        frame[11 + len],
    ]);
    let got = crc32(payload);
    if got != want {
        return Err(format!("frame CRC mismatch (stored {want:#010x}, computed {got:#010x})"));
    }
    decode_payload(payload)
}

/// One sample of every message variant — shared by the codec tests and
/// the corruption property suite so "every message type" stays true by
/// construction when a variant is added.
pub fn sample_messages() -> Vec<Message> {
    vec![
        Message::Hello { worker: "w-1".into(), protocol: PROTOCOL_VERSION },
        Message::Assign {
            seq: 7,
            job: "blob-soam".into(),
            spec_json: "{\"version\": 1, \"jobs\": [{\"name\": \"blob-soam\"}]}".into(),
            checkpoint: Some(vec![0xAB; 40]),
        },
        Message::Ack { seq: 7 },
        Message::Progress { job: "blob-soam".into(), signals: 4096, units: 131, done: false },
        Message::CheckpointBytes {
            seq: 9,
            job: "blob-soam".into(),
            turn: 64,
            is_final: true,
            bytes: (0..=255u8).collect(),
        },
        Message::Heartbeat { worker: "w-1".into(), seq: 12 },
        Message::Failed { job: "blob-soam".into(), error: "injected fault: worker".into() },
        Message::Shutdown,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_message_round_trips() {
        for msg in sample_messages() {
            let frame = encode_frame(&msg);
            assert_eq!(decode_frame(&frame).unwrap(), msg, "{}", msg.kind());
        }
    }

    #[test]
    fn assign_none_checkpoint_round_trips() {
        let msg = Message::Assign {
            seq: 1,
            job: "j".into(),
            spec_json: "{}".into(),
            checkpoint: None,
        };
        let frame = encode_frame(&msg);
        assert_eq!(decode_frame(&frame).unwrap(), msg);
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        // A header that declares MAX_FRAME + 1: rejected at the header
        // check, before any payload buffer exists.
        let mut header = [0u8; 8];
        header[..4].copy_from_slice(&FRAME_MAGIC);
        header[4..].copy_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        assert!(check_header(&header).is_err());
        // The same header embedded in a (tiny) frame is equally rejected.
        let mut frame = header.to_vec();
        frame.extend_from_slice(&[0u8; 8]);
        assert!(decode_frame(&frame).is_err());
    }

    #[test]
    fn wrong_magic_and_crc_are_errors() {
        let mut frame = encode_frame(&Message::Shutdown);
        frame[0] ^= 0x01;
        assert!(decode_frame(&frame).is_err(), "bad magic");
        let mut frame = encode_frame(&Message::Ack { seq: 3 });
        let last = frame.len() - 1;
        frame[last] ^= 0x80;
        assert!(decode_frame(&frame).is_err(), "bad CRC");
    }

    #[test]
    fn length_field_must_agree_with_frame_size() {
        let mut frame = encode_frame(&Message::Ack { seq: 3 });
        frame[4] = frame[4].wrapping_add(1);
        assert!(decode_frame(&frame).is_err());
    }

    #[test]
    fn trailing_garbage_inside_payload_is_an_error() {
        // A payload with extra bytes after the message body: CRC is made
        // valid, so only the `expect_end` discipline catches it.
        let mut payload = vec![8u8]; // Shutdown tag
        payload.push(0xEE);
        let mut frame = Vec::new();
        frame.extend_from_slice(&FRAME_MAGIC);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        let crc = crate::runtime::bytes::crc32(&payload);
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&crc.to_le_bytes());
        assert!(decode_frame(&frame).is_err());
    }
}
