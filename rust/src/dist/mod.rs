//! dist — the fault-tolerant multi-process fleet: a coordinator process
//! that owns the jobs manifest and N worker processes that each run
//! today's [`crate::fleet::Fleet`] unchanged.
//!
//! The ROADMAP's "multi-process backend split: snapshots as the wire
//! format" seam. The design bet is that the fleet already has the two
//! hard pieces — a bit-exact, CRC-trailed checkpoint format
//! ([`crate::fleet::snapshot`]) and per-job failure isolation — so
//! distribution is *routing*, not new state machinery: the coordinator
//! moves single-job manifests and snapshot blobs between workers, and
//! every recovery path (worker death, hang, lossy link) reduces to
//! "restore the last good generation somewhere else", which the fleet
//! proves is indistinguishable from never having crashed.
//!
//! Layering (each module's docs carry its own contract):
//!
//! - [`wire`] — the versioned message vocabulary and its total,
//!   size-capped, CRC-checked frame codec;
//! - [`transport`] — [`transport::Pipe`] byte movers (in-process
//!   channels, length-prefixed TCP) wrapped by [`transport::Link`], the
//!   one place every injectable network pathology
//!   (`transport_send`/`transport_recv` fault points: drop, delay, dup,
//!   truncate, err, panic) is applied;
//! - [`coordinator`] — manifest ownership, heartbeat-timeout eviction,
//!   partition-safe job migration, retry budget + backoff, quarantine;
//! - [`worker`] — a protocol-driven fleet: Assign in, heartbeats +
//!   checkpoints out, the final snapshot as the job result.
//!
//! `rust/tests/dist.rs` proves the headline property end-to-end: kill a
//! worker at an arbitrary scheduler round and every final network is
//! bit-identical to an undisturbed single-process fleet run.

pub mod coordinator;
pub mod transport;
pub mod wire;
pub mod worker;

pub use coordinator::{
    Coordinator, DistJobStatus, DistOptions, DistOutcome, DistReport, DistRow,
};
pub use transport::{
    channel_transport_pair, ChannelPipe, Link, Pipe, TcpPipe, Transport, TransportError,
};
pub use wire::{Message, PROTOCOL_VERSION};
pub use worker::{run_worker, WorkerOptions};
