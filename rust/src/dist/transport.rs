//! Pluggable transports for the dist layer.
//!
//! Two layers, mirroring unbase's `Network`/`Transport` split:
//!
//! - [`Pipe`]: moves opaque byte frames. [`ChannelPipe`] is the
//!   in-process transport (a pair of `mpsc` channels — keeps the whole
//!   coordinator/worker protocol testable and bit-reproducible inside one
//!   `cargo test` process); [`TcpPipe`] is the real multi-process
//!   transport (length-prefixed frames over `std::net::TcpStream`, with a
//!   persistent partial-frame buffer so a peer stalling mid-frame can
//!   never desynchronize the framing).
//! - [`Link`]: wraps a pipe with the wire codec and the
//!   `transport_send`/`transport_recv` fault points, and implements the
//!   object-safe [`Transport`] trait the coordinator and worker program
//!   against. Every injectable network pathology — dropped, delayed,
//!   duplicated, truncated frames, hard errors — happens *here*, in one
//!   place, identically for both pipes.
//!
//! Fault scope is the link's peer label (the worker name), so a chaos
//! profile can partition one worker while the rest of the fleet keeps its
//! connectivity: `transport_recv/w1:drop@turn=32`.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::runtime::fault::{self, FaultAction, FaultPoint};

use super::wire::{self, Message, FRAME_OVERHEAD};

/// A transport failure, as the protocol layers see it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// The peer is gone (channel disconnected / TCP reset / EOF). For the
    /// coordinator this is an immediate worker-death signal — faster than
    /// the heartbeat timeout, which remains the only detector for a peer
    /// that is *hung* rather than dead.
    Closed(String),
    /// A frame arrived but failed validation (bad magic, length, CRC or
    /// payload). The link is no longer trustworthy; callers treat this
    /// like a dead peer.
    Frame(String),
    /// An injected `err` fault fired at this operation.
    Injected,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed(m) => write!(f, "transport closed: {m}"),
            TransportError::Frame(m) => write!(f, "malformed frame: {m}"),
            TransportError::Injected => write!(f, "injected transport error"),
        }
    }
}

/// Moves opaque byte frames. Implementations are dumb on purpose: all
/// protocol and fault logic lives in [`Link`].
pub trait Pipe: Send {
    /// Transmit one frame.
    fn send_frame(&mut self, frame: &[u8]) -> Result<(), TransportError>;
    /// Receive one complete frame, waiting at most `timeout`. `Ok(None)`
    /// is a clean timeout (including a partial frame still in flight);
    /// `Err(Closed)` means the peer is gone, `Err(Frame)` that the byte
    /// stream itself is broken (TCP framing only).
    fn recv_frame(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>, TransportError>;
}

/// In-process pipe: a pair of `mpsc` channels carrying whole frames.
pub struct ChannelPipe {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

/// Two connected [`ChannelPipe`] ends (coordinator end, worker end).
pub fn channel_pipe_pair() -> (ChannelPipe, ChannelPipe) {
    let (a_tx, a_rx) = channel();
    let (b_tx, b_rx) = channel();
    (ChannelPipe { tx: a_tx, rx: b_rx }, ChannelPipe { tx: b_tx, rx: a_rx })
}

impl Pipe for ChannelPipe {
    fn send_frame(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| TransportError::Closed("peer channel disconnected".into()))
    }

    fn recv_frame(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>, TransportError> {
        if timeout.is_zero() {
            return match self.rx.try_recv() {
                Ok(frame) => Ok(Some(frame)),
                Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    Err(TransportError::Closed("peer channel disconnected".into()))
                }
            };
        }
        match self.rx.recv_timeout(timeout) {
            Ok(frame) => Ok(Some(frame)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TransportError::Closed("peer channel disconnected".into()))
            }
        }
    }
}

/// TCP pipe: frames over a `std::net::TcpStream`. The receive side keeps
/// a persistent buffer of the frame in flight, so a read timeout in the
/// middle of a frame resumes exactly where it left off — a stalled peer
/// can delay a frame but never shear one. The declared length is
/// validated ([`wire::check_header`]) *before* the payload buffer is
/// sized, so the oversized-alloc guard holds on the streaming path too.
pub struct TcpPipe {
    stream: TcpStream,
    /// Bytes of the in-flight frame received so far (header included).
    partial: Vec<u8>,
    /// Total size of the in-flight frame once the header is complete.
    need: Option<usize>,
}

impl TcpPipe {
    pub fn new(stream: TcpStream) -> std::io::Result<Self> {
        stream.set_nodelay(true)?;
        Ok(Self { stream, partial: Vec::new(), need: None })
    }

    pub fn connect(addr: &str) -> std::io::Result<Self> {
        Self::new(TcpStream::connect(addr)?)
    }

    /// Read at most `want` more bytes into `partial`. `Ok(true)` if any
    /// arrived, `Ok(false)` on a clean timeout.
    fn fill(&mut self, want: usize) -> Result<bool, TransportError> {
        let mut buf = vec![0u8; want];
        match self.stream.read(&mut buf) {
            Ok(0) => Err(TransportError::Closed("peer closed the connection".into())),
            Ok(n) => {
                self.partial.extend_from_slice(&buf[..n]);
                Ok(true)
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => Ok(false),
            Err(e) => Err(TransportError::Closed(format!("read failed: {e}"))),
        }
    }
}

impl Pipe for TcpPipe {
    fn send_frame(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        self.stream
            .write_all(frame)
            .and_then(|()| self.stream.flush())
            .map_err(|e| TransportError::Closed(format!("write failed: {e}")))
    }

    fn recv_frame(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>, TransportError> {
        // A zero read-timeout means "block forever" to the socket API;
        // clamp to the shortest poll instead.
        let timeout = timeout.max(Duration::from_millis(1));
        self.stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| TransportError::Closed(format!("set_read_timeout: {e}")))?;
        loop {
            // Phase 1: complete the 8-byte header, then validate it
            // before any payload-sized allocation.
            if self.need.is_none() {
                if self.partial.len() < 8 {
                    if !self.fill(8 - self.partial.len())? {
                        return Ok(None);
                    }
                    continue;
                }
                let mut header = [0u8; 8];
                header.copy_from_slice(&self.partial[..8]);
                let len = wire::check_header(&header).map_err(TransportError::Frame)?;
                self.need = Some(len + FRAME_OVERHEAD);
            }
            // Phase 2: complete the frame.
            let need = self.need.expect("header phase sets need");
            if self.partial.len() < need {
                if !self.fill(need - self.partial.len())? {
                    return Ok(None);
                }
                continue;
            }
            let rest = self.partial.split_off(need);
            let frame = std::mem::replace(&mut self.partial, rest);
            self.need = None;
            return Ok(Some(frame));
        }
    }
}

/// The object-safe transport the coordinator and worker program against.
pub trait Transport: Send {
    fn send(&mut self, msg: &Message) -> Result<(), TransportError>;
    /// `Ok(None)` = nothing arrived within `timeout`.
    fn recv(&mut self, timeout: Duration) -> Result<Option<Message>, TransportError>;
    /// Feed the caller's scheduler round into `@turn=` fault triggers.
    fn set_turn(&mut self, turn: u64);
    /// The peer label (fault scope + diagnostics).
    fn peer(&self) -> &str;
}

/// A [`Pipe`] wrapped with the wire codec and fault injection.
pub struct Link<P: Pipe> {
    pipe: P,
    peer: String,
    turn: u64,
    /// Outgoing frames held back by `delay=N`: (sends remaining, frame).
    delayed_out: Vec<(u64, Vec<u8>)>,
    /// Incoming frames held back by `delay=N`: (recvs remaining, frame).
    delayed_in: Vec<(u64, Vec<u8>)>,
    /// Incoming frames ready before the pipe is polled (matured delays,
    /// duplicated deliveries).
    ready_in: VecDeque<Vec<u8>>,
}

impl<P: Pipe> Link<P> {
    pub fn new(pipe: P, peer: impl Into<String>) -> Self {
        Self {
            pipe,
            peer: peer.into(),
            turn: 0,
            delayed_out: Vec::new(),
            delayed_in: Vec::new(),
            ready_in: VecDeque::new(),
        }
    }

    /// Decrement delay counters and flush/queue everything that matured.
    fn mature(&mut self) -> Result<(), TransportError> {
        for (left, _) in self.delayed_out.iter_mut() {
            *left = left.saturating_sub(1);
        }
        for (left, _) in self.delayed_in.iter_mut() {
            *left = left.saturating_sub(1);
        }
        let mut i = 0;
        while i < self.delayed_out.len() {
            if self.delayed_out[i].0 == 0 {
                let (_, frame) = self.delayed_out.remove(i);
                self.pipe.send_frame(&frame)?;
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.delayed_in.len() {
            if self.delayed_in[i].0 == 0 {
                let (_, frame) = self.delayed_in.remove(i);
                self.ready_in.push_back(frame);
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    fn decode(&self, frame: &[u8]) -> Result<Message, TransportError> {
        wire::decode_frame(frame).map_err(TransportError::Frame)
    }
}

impl<P: Pipe> Transport for Link<P> {
    fn send(&mut self, msg: &Message) -> Result<(), TransportError> {
        self.mature()?;
        let frame = wire::encode_frame(msg);
        match fault::fire(FaultPoint::TransportSend, Some(&self.peer), Some(self.turn)) {
            None => {
                crate::telemetry::add(crate::telemetry::Counter::FramesSent, 1);
                self.pipe.send_frame(&frame)
            }
            Some(FaultAction::Drop) => {
                crate::telemetry::add(crate::telemetry::Counter::FramesDropped, 1);
                Ok(())
            }
            Some(FaultAction::Dup) => {
                crate::telemetry::add(crate::telemetry::Counter::FramesSent, 2);
                self.pipe.send_frame(&frame)?;
                self.pipe.send_frame(&frame)
            }
            Some(FaultAction::Delay(n)) => {
                self.delayed_out.push((n.max(1), frame));
                Ok(())
            }
            Some(FaultAction::Truncate(n)) => {
                let cut = (n as usize).min(frame.len());
                self.pipe.send_frame(&frame[..cut])
            }
            Some(FaultAction::Error) => Err(TransportError::Injected),
            Some(FaultAction::Panic) => {
                panic!("injected fault: transport_send panic (peer {:?})", self.peer)
            }
        }
    }

    fn recv(&mut self, timeout: Duration) -> Result<Option<Message>, TransportError> {
        self.mature()?;
        let frame = match self.ready_in.pop_front() {
            Some(frame) => frame,
            None => match self.pipe.recv_frame(timeout)? {
                Some(frame) => frame,
                None => return Ok(None),
            },
        };
        match fault::fire(FaultPoint::TransportRecv, Some(&self.peer), Some(self.turn)) {
            None => {
                crate::telemetry::add(crate::telemetry::Counter::FramesReceived, 1);
                self.decode(&frame).map(Some)
            }
            Some(FaultAction::Drop) => {
                crate::telemetry::add(crate::telemetry::Counter::FramesDropped, 1);
                Ok(None)
            }
            Some(FaultAction::Dup) => {
                self.ready_in.push_back(frame.clone());
                crate::telemetry::add(crate::telemetry::Counter::FramesReceived, 1);
                self.decode(&frame).map(Some)
            }
            Some(FaultAction::Delay(n)) => {
                self.delayed_in.push((n.max(1), frame));
                Ok(None)
            }
            Some(FaultAction::Truncate(n)) => {
                let cut = (n as usize).min(frame.len());
                self.decode(&frame[..cut]).map(Some)
            }
            Some(FaultAction::Error) => Err(TransportError::Injected),
            Some(FaultAction::Panic) => {
                panic!("injected fault: transport_recv panic (peer {:?})", self.peer)
            }
        }
    }

    fn set_turn(&mut self, turn: u64) {
        self.turn = turn;
    }

    fn peer(&self) -> &str {
        &self.peer
    }
}

/// Two connected in-process [`Transport`]s labeled with the worker name:
/// (coordinator end, worker end).
pub fn channel_transport_pair(
    worker: &str,
) -> (Link<ChannelPipe>, Link<ChannelPipe>) {
    let (coord_pipe, worker_pipe) = channel_pipe_pair();
    (Link::new(coord_pipe, worker), Link::new(worker_pipe, worker))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Link<ChannelPipe>, Link<ChannelPipe>) {
        channel_transport_pair("zz-tp-peer")
    }

    #[test]
    fn channel_link_round_trips_messages() {
        let (mut a, mut b) = pair();
        let msg = Message::Heartbeat { worker: "w".into(), seq: 3 };
        a.send(&msg).unwrap();
        assert_eq!(b.recv(Duration::from_millis(200)).unwrap(), Some(msg));
        assert_eq!(b.recv(Duration::ZERO).unwrap(), None, "empty poll");
    }

    #[test]
    fn disconnect_is_closed_not_panic() {
        let (mut a, b) = pair();
        drop(b);
        let msg = Message::Ack { seq: 1 };
        assert!(matches!(a.send(&msg), Err(TransportError::Closed(_))));
    }

    #[test]
    fn drop_dup_and_delay_faults_shape_delivery() {
        let _guard = fault::test_lock();
        fault::install(
            fault::parse_faults(
                "transport_send/zz-tp-peer:drop@1,transport_send/zz-tp-peer:dup@2,\
                 transport_recv/zz-tp-peer:delay=2@3",
            )
            .unwrap(),
        );
        let (mut a, mut b) = pair();
        let m1 = Message::Ack { seq: 1 };
        let m2 = Message::Ack { seq: 2 };
        // Send 1 dropped, send 2 duplicated.
        a.send(&m1).unwrap();
        a.send(&m2).unwrap();
        // Recv evaluations only advance when a frame is present: recv #1
        // and #2 deliver the duplicated m2.
        assert_eq!(b.recv(Duration::from_millis(200)).unwrap(), Some(m2.clone()));
        assert_eq!(b.recv(Duration::from_millis(50)).unwrap(), Some(m2.clone()));
        // Recv #3 (the next actual frame) trips the delay: held 2 recvs.
        let m3 = Message::Ack { seq: 3 };
        a.send(&m3).unwrap();
        assert_eq!(b.recv(Duration::from_millis(200)).unwrap(), None, "delayed");
        assert_eq!(b.recv(Duration::from_millis(50)).unwrap(), None, "still delayed");
        assert_eq!(b.recv(Duration::from_millis(50)).unwrap(), Some(m3), "matured");
        assert_eq!(fault::armed_specs(), 0);
    }

    #[test]
    fn truncate_fault_surfaces_as_frame_error() {
        let _guard = fault::test_lock();
        fault::install(
            fault::parse_faults("transport_recv/zz-tp-peer:truncate=6@1").unwrap(),
        );
        let (mut a, mut b) = pair();
        a.send(&Message::Shutdown).unwrap();
        assert!(matches!(
            b.recv(Duration::from_millis(200)),
            Err(TransportError::Frame(_))
        ));
    }
}
