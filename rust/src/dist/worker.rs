//! The dist worker: a [`Fleet`] driven by protocol traffic instead of a
//! static manifest.
//!
//! One worker process owns one fleet. Jobs arrive as [`Message::Assign`]
//! payloads (a complete single-job manifest plus an optional snapshot to
//! resume from), run through the *unchanged* fleet scheduler one
//! [`Fleet::step_round`] per protocol round, and leave as a final
//! [`Message::CheckpointBytes`] — the `fleet::snapshot` v2 blob **is** the
//! job result, exactly the bytes a single-process run would have written
//! to disk. That identity is what makes worker-kill migration bit-exact:
//! the coordinator restores the same format the fleet already proves
//! round-trips bit-identically.
//!
//! Division of labor with the coordinator:
//!
//! - the **coordinator** owns the retry budget, backoff, and placement —
//!   the worker forces every admitted job to `retries = Some(0)`, so a
//!   crashing job quarantines locally on the first failure and is
//!   reported upstream as one [`Message::Failed`];
//! - the **worker** owns stepping, periodic checkpoint shipping, and
//!   liveness ([`Message::Heartbeat`] every round).
//!
//! Loss tolerance: Assign handling is idempotent (a resent `seq` is
//! re-acked, not re-run), final checkpoints are resent until acked, and
//! periodic checkpoints are fire-and-forget. An injected transport `err`
//! is treated as a lost message — the retransmission discipline absorbs
//! it — while `Closed`/`Frame` mean the coordinator is gone and the
//! worker exits.

use std::collections::HashMap;
use std::time::Duration;

use crate::fleet::{parse_job_payload, snapshot, Fleet, FleetOptions, JobStatus};
use crate::runtime::fault::{self, FaultAction, FaultPoint};

use super::transport::{Transport, TransportError};
use super::wire::{Message, PROTOCOL_VERSION};

/// Worker knobs.
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// Worker identity: the `Hello` name, the heartbeat label, and the
    /// fault-injection scope for `worker/<name>:...` specs.
    pub name: String,
    /// Iterations each live job advances per round ([`FleetOptions::stride`]).
    pub stride: u64,
    /// Ship a periodic (non-final) snapshot of every running job each
    /// this many rounds (0 = finals only). Smaller = less lost work on
    /// migration, more wire traffic.
    pub checkpoint_rounds: u64,
    /// How long to wait for traffic when no job is live (keeps an idle
    /// worker from spinning; a busy worker polls without blocking).
    pub idle_poll: Duration,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        Self {
            name: "worker".to_string(),
            stride: 1,
            checkpoint_rounds: 8,
            idle_poll: Duration::from_millis(10),
        }
    }
}

/// Send, treating an injected transport error as message loss (the
/// protocol's retransmission discipline absorbs it). `Closed`/`Frame`
/// are fatal: the coordinator is unreachable or the link is corrupt.
fn send(t: &mut dyn Transport, msg: &Message) -> Result<(), String> {
    match t.send(msg) {
        Ok(()) | Err(TransportError::Injected) => Ok(()),
        Err(e) => Err(format!("coordinator link lost: {e}")),
    }
}

/// Parse, admit and (optionally) restore one assigned job. On `Err` the
/// caller removes the job and reports [`Message::Failed`].
fn admit(fleet: &mut Fleet, job: &str, spec_json: &str, checkpoint: Option<&[u8]>) -> Result<(), String> {
    let mut spec =
        parse_job_payload(spec_json).map_err(|e| format!("bad job payload: {e:#}"))?;
    if spec.name != job {
        return Err(format!("payload names job {:?}, assignment says {job:?}", spec.name));
    }
    // The coordinator owns the retry budget: a local crash must surface
    // as one Failed message, not burn rounds in a local retry loop.
    spec.retries = Some(0);
    fleet.add_job(spec).map_err(|e| format!("{e:#}"))?;
    if let Some(bytes) = checkpoint {
        fleet.restore_job(job, bytes)?;
    }
    Ok(())
}

/// Run the worker loop until the coordinator sends [`Message::Shutdown`]
/// (`Ok`) or the link dies (`Err`). `progress` receives the fleet's
/// per-job progress lines plus the worker's own protocol events.
pub fn run_worker(
    transport: &mut dyn Transport,
    opts: &WorkerOptions,
    mut progress: impl FnMut(&str),
) -> Result<(), String> {
    let mut fleet = Fleet::new(Vec::new()).map_err(|e| format!("{e:#}"))?;
    let fleet_opts = FleetOptions {
        stride: opts.stride.max(1),
        checkpoint_every: 0,
        checkpoint_secs: None,
        checkpoint_dir: None,
        max_retries: 0,
        backoff_rounds: 1,
    };
    send(transport, &Message::Hello { worker: opts.name.clone(), protocol: PROTOCOL_VERSION })?;

    let mut round: u64 = 0;
    let mut live = 0usize;
    // Set once anything arrives from the coordinator — until then the
    // Hello is retransmitted (it may have been dropped, and an
    // un-introduced worker is never assigned work).
    let mut greeted = false;
    // job → the assign seq it acked (duplicate Assigns re-ack, never re-run).
    let mut assigned: HashMap<String, u64> = HashMap::new();
    // seq → final CheckpointBytes awaiting the coordinator's Ack.
    let mut unacked_finals: HashMap<u64, Message> = HashMap::new();
    let mut next_seq: u64 = 1;

    loop {
        // Injected worker pathologies: `worker/<name>:panic` kills the
        // process mid-run (the crash the migration machinery exists for),
        // `delay=N` hangs it for N ms (the heartbeat-timeout case).
        match fault::fire(FaultPoint::WorkerStep, Some(&opts.name), Some(round)) {
            None => {}
            Some(FaultAction::Panic) => {
                panic!("injected fault: worker {:?} panic at round {round}", opts.name)
            }
            Some(FaultAction::Delay(ms)) => std::thread::sleep(Duration::from_millis(ms)),
            Some(FaultAction::Error) => {
                return Err(format!("injected fault: worker {:?} err", opts.name))
            }
            // drop/dup/truncate describe frames, not worker steps.
            Some(_) => {}
        }
        transport.set_turn(round);

        // Drain protocol traffic (budget-capped so a chatty coordinator
        // cannot starve the scheduler).
        let mut first = true;
        for _ in 0..64 {
            let timeout = if first && live == 0 { opts.idle_poll } else { Duration::ZERO };
            first = false;
            let msg = match transport.recv(timeout) {
                Ok(Some(m)) => m,
                Ok(None) => break,
                Err(TransportError::Injected) => continue,
                Err(e) => return Err(format!("coordinator link lost: {e}")),
            };
            greeted = true;
            match msg {
                Message::Assign { seq, job, spec_json, checkpoint } => {
                    if assigned.get(&job) == Some(&seq) {
                        // A resent Assign (our Ack was lost): re-ack only.
                        send(transport, &Message::Ack { seq })?;
                        continue;
                    }
                    // A *new* assignment supersedes anything we hold for
                    // the name — including an unacked final the
                    // coordinator evidently never received.
                    fleet.remove_job(&job);
                    unacked_finals.retain(
                        |_, m| !matches!(m, Message::CheckpointBytes { job: j, .. } if *j == job),
                    );
                    match admit(&mut fleet, &job, &spec_json, checkpoint.as_deref()) {
                        Ok(()) => {
                            progress(&format!(
                                "worker {}: job {job} admitted ({})",
                                opts.name,
                                if checkpoint.is_some() { "from checkpoint" } else { "from scratch" }
                            ));
                            assigned.insert(job, seq);
                            send(transport, &Message::Ack { seq })?;
                        }
                        Err(e) => {
                            // A torn restore may leave the session
                            // unusable — drop the job before reporting.
                            fleet.remove_job(&job);
                            assigned.insert(job.clone(), seq);
                            send(transport, &Message::Ack { seq })?;
                            send(transport, &Message::Failed { job, error: e })?;
                        }
                    }
                }
                Message::Ack { seq } => {
                    unacked_finals.remove(&seq);
                }
                Message::Shutdown => return Ok(()),
                // Everything else is worker → coordinator vocabulary.
                _ => {}
            }
        }

        // One scheduler round over whatever is admitted.
        live = fleet.step_round(&fleet_opts, round, None, &mut |line| progress(line));

        // Collect results before mutating the fleet: finals for Done jobs
        // (the snapshot *is* the result), Failed for quarantined ones,
        // periodic snapshots for running ones on the cadence.
        let ship_periodic =
            opts.checkpoint_rounds > 0 && round % opts.checkpoint_rounds == opts.checkpoint_rounds - 1;
        let mut finals: Vec<(String, Vec<u8>, u64, u64)> = Vec::new();
        let mut failures: Vec<(String, String)> = Vec::new();
        let mut periodic: Vec<(String, Vec<u8>, u64, u64)> = Vec::new();
        for j in fleet.jobs() {
            let name = j.spec().name.clone();
            match j.status() {
                JobStatus::Done => {
                    let session = j.session().expect("done job keeps its session");
                    let bytes = snapshot::snapshot_session(session);
                    let (signals, units) =
                        j.report().map_or((0, 0), |r| (r.signals, r.units as u64));
                    finals.push((name, bytes, signals, units));
                }
                JobStatus::Quarantined => {
                    failures.push((name, j.last_error().unwrap_or("crashed").to_string()));
                }
                JobStatus::Running if ship_periodic => {
                    if let Some(s) = j.session() {
                        let r = s.report_so_far();
                        periodic.push((
                            name,
                            snapshot::snapshot_session(s),
                            r.signals,
                            r.units as u64,
                        ));
                    }
                }
                _ => {}
            }
        }
        for (job, bytes, signals, units) in finals {
            let seq = next_seq;
            next_seq += 1;
            let msg =
                Message::CheckpointBytes { seq, job: job.clone(), turn: round, is_final: true, bytes };
            send(transport, &msg)?;
            send(transport, &Message::Progress { job: job.clone(), signals, units, done: true })?;
            unacked_finals.insert(seq, msg);
            // `assigned` keeps the name → seq entry: a late duplicate
            // Assign still re-acks instead of re-running a finished job.
            fleet.remove_job(&job);
        }
        for (job, error) in failures {
            send(transport, &Message::Failed { job: job.clone(), error })?;
            fleet.remove_job(&job);
        }
        for (job, bytes, signals, units) in periodic {
            // Fire-and-forget: a lost periodic snapshot only widens the
            // migration resume window.
            send(
                transport,
                &Message::CheckpointBytes { seq: 0, job: job.clone(), turn: round, is_final: false, bytes },
            )?;
            send(transport, &Message::Progress { job, signals, units, done: false })?;
        }

        send(transport, &Message::Heartbeat { worker: opts.name.clone(), seq: round })?;
        if round % 16 == 15 {
            // Retransmit what loss can strand: the Hello (until the
            // coordinator has spoken back) and finals it has not acked.
            if !greeted {
                send(
                    transport,
                    &Message::Hello { worker: opts.name.clone(), protocol: PROTOCOL_VERSION },
                )?;
            }
            let pending: Vec<Message> = unacked_finals.values().cloned().collect();
            for msg in &pending {
                send(transport, msg)?;
            }
        }
        round += 1;
    }
}
