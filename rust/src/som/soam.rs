//! Self-Organizing Adaptive Map (Piastra 2012) — the algorithm of the
//! paper's experiments.
//!
//! SOAM = GWR-style growth **plus**:
//!
//! 1. a *topological termination criterion*: the run ends when every unit's
//!    link (induced neighbor subgraph) is a single closed cycle — the
//!    network is then a triangulated closed 2-manifold ("all units have
//!    reached a local topology consistent with that of a surface", §2.1) —
//!    and every unit is habituated;
//! 2. a *per-unit adaptive insertion threshold* that "may vary during the
//!    learning process, in order to reflect the local feature size (LFS)":
//!    units whose link stays non-manifold after habituation lower their
//!    threshold geometrically (down to a floor), recruiting more units
//!    exactly where the surface needs finer sampling.
//!
//! The crisp termination criterion is what makes the paper's comparisons
//! meaningful, so `housekeeping` (periodic full scan) also caches per-unit
//! stability for reporting.

use crate::geometry::Vec3;
use crate::mesh::SurfaceSampler;
use crate::rng::Rng;
use crate::runtime::bytes::{ByteReader, ByteWriter};
use crate::topology::LinkClass;

use super::gwr::Gwr;
use super::network::{ChangeLog, Network, UnitId};
use super::params::{GwrParams, SoamParams};
use super::{GrowingNetwork, QeTracker, UpdateKind, UpdatePlan, Winners};

/// Aggregate topological state of the network at the last housekeeping scan.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SoamState {
    pub units: usize,
    pub disks: usize,
    pub half_disks: usize,
    pub non_manifold: usize,
    pub dust_or_isolated: usize,
    pub habituated: usize,
    /// All units habituated and `Disk` — the termination criterion.
    pub stable: bool,
}

/// SOAM algorithm state.
pub struct Soam {
    pub params: SoamParams,
    net: Network,
    qe: QeTracker,
    state: SoamState,
    orphan_buf: Vec<UnitId>,
    /// Consecutive housekeeping scans a unit spent under-connected
    /// (`Dust`/`Isolated` link while habituated), indexed by slot. Units
    /// striking out are removed: they are the shadowed "twin" units of the
    /// competitive-Hebbian pathology (two near-coincident units are always
    /// each other's top-2, so neither ever connects outward).
    strikes: Vec<u8>,
    /// Consecutive scans spent non-manifold while habituated. The LFS
    /// threshold decay fires only when a tangle *persists*
    /// (`NM_STRIKES` scans) — transient tangles during growth must not
    /// trigger refinement, or the network over-grows.
    nm_strikes: Vec<u8>,
    /// GWR parameter view used by the shared update core.
    gwr_view: GwrParams,
}

/// Strikes before an under-connected habituated unit is removed.
const MAX_STRIKES: u8 = 3;

/// Consecutive non-manifold scans before one threshold-decay step.
const NM_STRIKES: u8 = 8;

impl Soam {
    pub fn new(params: SoamParams) -> Self {
        let gwr_view = GwrParams {
            adapt: params.adapt,
            hab: params.hab,
            insertion_threshold: params.insertion_threshold,
            max_units: params.max_units,
            target_qe: 0.0, // unused: SOAM terminates topologically
        };
        Self {
            params,
            net: Network::new(),
            qe: QeTracker::new(0.001),
            state: SoamState::default(),
            orphan_buf: Vec::new(),
            strikes: Vec::new(),
            nm_strikes: Vec::new(),
            gwr_view,
        }
    }

    /// Topological state of the last housekeeping scan.
    pub fn state(&self) -> SoamState {
        self.state
    }

    /// Full topological scan: classify every link, adapt thresholds of
    /// habituated non-manifold units (the LFS mechanism), remove units that
    /// stay under-connected (twin collapse), and compute the termination
    /// state. Removals are reported through `log`.
    fn scan(&mut self, log: &mut ChangeLog) -> SoamState {
        let mut s = SoamState { units: self.net.len(), ..SoamState::default() };
        let floor = self.params.insertion_threshold * self.params.threshold_floor_frac;
        if self.strikes.len() < self.net.capacity() {
            self.strikes.resize(self.net.capacity(), 0);
        }
        if self.nm_strikes.len() < self.net.capacity() {
            self.nm_strikes.resize(self.net.capacity(), 0);
        }
        let ids: Vec<UnitId> = self.net.ids().collect();
        let mut doomed: Vec<UnitId> = Vec::new();
        for id in ids {
            let habituated = self.params.hab.is_habituated(self.net.unit(id).firing);
            if habituated {
                s.habituated += 1;
            }
            match self.net.link_class(id) {
                LinkClass::Disk => {
                    s.disks += 1;
                    self.strikes[id as usize] = 0;
                    self.nm_strikes[id as usize] = 0;
                }
                LinkClass::HalfDisk => {
                    s.half_disks += 1;
                    self.strikes[id as usize] = 0;
                    self.nm_strikes[id as usize] = 0;
                }
                LinkClass::NonManifold => {
                    s.non_manifold += 1;
                    self.strikes[id as usize] = 0;
                    // Refine locally — but only for a *stuck* tangle in a
                    // *mature* region: the unit and every neighbor must be
                    // habituated, and the state must persist NM_STRIKES
                    // scans. During growth non-manifold links are ubiquitous
                    // and refinement would shrink thresholds network-wide
                    // (units ∝ 1/threshold² ⇒ runaway growth).
                    let mature = habituated
                        && self.net.edges_of(id).iter().all(|e| {
                            self.params
                                .hab
                                .is_habituated(self.net.unit(e.to).firing)
                        });
                    if mature {
                        let k = self.nm_strikes[id as usize].saturating_add(1);
                        if k >= NM_STRIKES {
                            self.nm_strikes[id as usize] = 0;
                            let u = self.net.unit_mut(id);
                            u.threshold =
                                (u.threshold * self.params.threshold_decay).max(floor);
                        } else {
                            self.nm_strikes[id as usize] = k;
                        }
                    } else {
                        self.nm_strikes[id as usize] = 0;
                    }
                }
                LinkClass::Dust | LinkClass::Isolated => {
                    s.dust_or_isolated += 1;
                    self.nm_strikes[id as usize] = 0;
                    if habituated {
                        let k = self.strikes[id as usize].saturating_add(1);
                        self.strikes[id as usize] = k;
                        if k >= MAX_STRIKES {
                            doomed.push(id);
                        }
                    } else {
                        self.strikes[id as usize] = 0;
                    }
                }
            }
        }
        for id in doomed {
            if self.net.is_alive(id) && self.net.len() > 2 {
                let pos = self.net.pos(id);
                self.net.remove(id);
                log.removed.push((id, pos));
                self.strikes[id as usize] = 0;
                s.units -= 1;
                s.dust_or_isolated -= 1;
                s.habituated -= 1;
            }
        }
        s.stable = s.units >= 4 && s.disks == s.units && s.habituated == s.units;
        s
    }
}

impl GrowingNetwork for Soam {
    fn name(&self) -> &'static str {
        "soam"
    }

    fn net(&self) -> &Network {
        &self.net
    }

    fn net_mut(&mut self) -> &mut Network {
        &mut self.net
    }

    fn init(&mut self, sampler: &SurfaceSampler, rng: &mut Rng) {
        Gwr::seed_two(
            &mut self.net,
            sampler,
            rng,
            self.params.insertion_threshold,
        );
    }

    fn update(&mut self, signal: Vec3, winners: &Winners, log: &mut ChangeLog) {
        if Gwr::gwr_update(
            &mut self.net,
            &self.gwr_view,
            signal,
            winners,
            log,
            &mut self.orphan_buf,
            true, // per-unit thresholds: the SOAM LFS mechanism
        ) {
            self.qe.push(winners.d1_sq);
        }
    }

    fn housekeeping(&mut self, log: &mut ChangeLog) -> bool {
        self.state = self.scan(log);
        self.state.stable
    }

    fn quantization_error(&self) -> f32 {
        self.qe.value()
    }

    fn classify_update(&self, _signal: Vec3, w: &Winners, _pending_commits: usize) -> UpdateKind {
        Gwr::gwr_classify(&self.net, &self.gwr_view, w, true)
    }

    fn plan_update(&self, signal: Vec3, w: &Winners, plan: &mut UpdatePlan) {
        Gwr::gwr_plan(&self.net, &self.gwr_view, signal, w, plan);
    }

    fn begin_insert(&mut self, signal: Vec3, w: &Winners, plan: &mut UpdatePlan) {
        let view = self.gwr_view;
        Gwr::gwr_begin_insert(
            &mut self.net,
            &view,
            signal,
            w,
            plan,
            true, // per-unit thresholds: the SOAM LFS mechanism
        );
    }

    fn commit_scalars(&mut self, plan: &UpdatePlan, _log: &mut ChangeLog) {
        Gwr::debug_check_no_prune(&self.net, &self.gwr_view, plan);
        self.qe.push(plan.d1_sq);
    }

    fn save_state(&self, w: &mut ByteWriter) {
        w.str("soam");
        let (ema, samples) = self.qe.raw();
        w.f32(ema);
        w.u64(samples);
        // The strike tables are *cross-scan* memory: a unit two strikes
        // from removal must stay two strikes from removal after a resume.
        w.u32(self.strikes.len() as u32);
        for &s in &self.strikes {
            w.u8(s);
        }
        w.u32(self.nm_strikes.len() as u32);
        for &s in &self.nm_strikes {
            w.u8(s);
        }
        // The cached topological state of the last housekeeping scan
        // (reporting only, but kept for report fidelity across resumes).
        w.u64(self.state.units as u64);
        w.u64(self.state.disks as u64);
        w.u64(self.state.half_disks as u64);
        w.u64(self.state.non_manifold as u64);
        w.u64(self.state.dust_or_isolated as u64);
        w.u64(self.state.habituated as u64);
        w.bool(self.state.stable);
        self.net.write_state(w);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let tag = r.str().map_err(|e| e.to_string())?;
        if tag != "soam" {
            return Err(format!("snapshot algorithm {tag:?} is not soam"));
        }
        let ema = r.f32().map_err(|e| e.to_string())?;
        let samples = r.u64().map_err(|e| e.to_string())?;
        self.qe.restore(ema, samples);
        let n = r.len_prefix(1).map_err(|e| e.to_string())?;
        self.strikes.clear();
        for _ in 0..n {
            self.strikes.push(r.u8().map_err(|e| e.to_string())?);
        }
        let n = r.len_prefix(1).map_err(|e| e.to_string())?;
        self.nm_strikes.clear();
        for _ in 0..n {
            self.nm_strikes.push(r.u8().map_err(|e| e.to_string())?);
        }
        self.state = SoamState {
            units: r.u64().map_err(|e| e.to_string())? as usize,
            disks: r.u64().map_err(|e| e.to_string())? as usize,
            half_disks: r.u64().map_err(|e| e.to_string())? as usize,
            non_manifold: r.u64().map_err(|e| e.to_string())? as usize,
            dust_or_isolated: r.u64().map_err(|e| e.to_string())? as usize,
            habituated: r.u64().map_err(|e| e.to_string())? as usize,
            stable: r.bool().map_err(|e| e.to_string())?,
        };
        self.net = Network::read_state(r)?;
        // The strike tables may legitimately lag the slab (they resize at
        // the next scan, and missing entries mean zero strikes — exactly
        // the running process's implicit value), but they can never
        // exceed it: that marks a snapshot whose tables and slab are not
        // from the same run.
        let cap = self.net.capacity();
        if self.strikes.len() > cap || self.nm_strikes.len() > cap {
            return Err(format!(
                "strike tables ({}/{}) exceed the slab ({cap})",
                self.strikes.len(),
                self.nm_strikes.len()
            ));
        }
        self.orphan_buf.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findwinners::{FindWinners, Scalar};
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    fn drive(soam: &mut Soam, sampler: &SurfaceSampler, rng: &mut Rng, signals: u64) {
        let mut fw = Scalar::new();
        let mut log = ChangeLog::default();
        for _ in 0..signals {
            let s = sampler.sample(rng);
            let w = fw.find2(soam.net(), s).unwrap();
            log.clear();
            soam.update(s, &w, &mut log);
        }
    }

    #[test]
    fn grows_toward_disks() {
        // Full convergence takes ~400k signals (see the `soam_blob`
        // integration test); this unit test checks the *direction*: a clear
        // majority of links must be disks or half-disks well before that.
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 24);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(7);
        let mut soam = Soam::new(SoamParams {
            insertion_threshold: 0.18,
            ..SoamParams::default()
        });
        soam.init(&sampler, &mut rng);
        let mut log = ChangeLog::default();
        let mut st = soam.state();
        for _ in 0..60 {
            drive(&mut soam, &sampler, &mut rng, 2_000);
            let stable = soam.housekeeping(&mut log);
            st = soam.state();
            if stable {
                break;
            }
        }
        assert!(st.units > 15, "only {} units", st.units);
        assert!(
            (st.disks + st.half_disks) * 3 > st.units * 2,
            "links not converging toward disks: {st:?}"
        );
        soam.net().check_invariants().unwrap();
    }

    #[test]
    fn twin_units_get_removed() {
        // Two near-coincident units that are always each other's top-2 can
        // never connect outward; the strike mechanism must remove one.
        let mut soam = Soam::new(SoamParams::default());
        let net = soam.net_mut();
        // A proper triangle plus a twin pair far away.
        let a = net.insert(Vec3::new(0.0, 0.0, 0.0), 0.1);
        let b = net.insert(Vec3::new(1.0, 0.0, 0.0), 0.1);
        let c = net.insert(Vec3::new(0.0, 1.0, 0.0), 0.1);
        net.connect(a, b);
        net.connect(b, c);
        net.connect(c, a);
        let t1 = net.insert(Vec3::new(5.0, 5.0, 5.0), 0.1);
        let t2 = net.insert(Vec3::new(5.0, 5.0, 5.001), 0.1);
        net.connect(t1, t2);
        for id in [a, b, c, t1, t2] {
            soam.net_mut().unit_mut(id).firing = 0.01; // habituated
        }
        let mut log = ChangeLog::default();
        for _ in 0..MAX_STRIKES {
            soam.housekeeping(&mut log);
        }
        // At least one of the twins is gone and reported in the log.
        let twins_alive =
            soam.net().is_alive(t1) as usize + soam.net().is_alive(t2) as usize;
        assert!(twins_alive < 2, "twin pair survived: {:?}", soam.state());
        assert!(!log.removed.is_empty());
        soam.net().check_invariants().unwrap();
    }

    #[test]
    fn threshold_decay_bounded_by_floor() {
        // The optional LFS mechanism (off by default): enable it and check
        // it decays stuck-tangle thresholds down to the floor, not below.
        let params = SoamParams { threshold_decay: 0.9, ..SoamParams::default() };
        let mut soam = Soam::new(params);
        let a = soam.net_mut().insert(Vec3::ZERO, params.insertion_threshold);
        // Make `a` habituated and its link non-manifold (star of 3 around a
        // neighbor): neighbors b,c,d with edges b-c, b-d only.
        let b = soam.net_mut().insert(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let c = soam.net_mut().insert(Vec3::new(0.0, 1.0, 0.0), 1.0);
        let d = soam.net_mut().insert(Vec3::new(0.0, 0.0, 1.0), 1.0);
        let e = soam.net_mut().insert(Vec3::new(1.0, 1.0, 0.0), 1.0);
        for n in [b, c, d, e] {
            soam.net_mut().connect(a, n);
        }
        soam.net_mut().connect(b, c);
        soam.net_mut().connect(b, d);
        soam.net_mut().connect(b, e);
        // Mature region: the unit AND all its neighbors habituated.
        for id in [a, b, c, d, e] {
            soam.net_mut().unit_mut(id).firing = 0.05;
        }
        assert_eq!(soam.net().link_class(a), LinkClass::NonManifold);
        let floor = params.insertion_threshold * params.threshold_floor_frac;
        let mut log = ChangeLog::default();
        for _ in 0..500 {
            soam.housekeeping(&mut log);
        }
        let th = soam.net().unit(a).threshold;
        assert!((th - floor).abs() < 1e-6, "threshold {th} should hit floor {floor}");
    }

    #[test]
    fn stable_state_requires_all_disks() {
        // Octahedron wired as a network: every link is a 4-cycle ⇒ stable
        // once habituated.
        let mut soam = Soam::new(SoamParams::default());
        let net = soam.net_mut();
        let mut ids = Vec::new();
        let pts = [
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(-1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(0.0, -1.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.0, 0.0, -1.0),
        ];
        for p in pts {
            ids.push(net.insert(p, 0.1));
        }
        for i in 0..6u32 {
            for j in i + 1..6 {
                // Opposite pairs: (0,1), (2,3), (4,5).
                if !(i / 2 == j / 2) {
                    net.connect(ids[i as usize], ids[j as usize]);
                }
            }
        }
        let mut log = ChangeLog::default();
        assert!(!soam.housekeeping(&mut log), "fresh units are not habituated");
        for i in 0..6 {
            soam.net_mut().unit_mut(ids[i]).firing = 0.01;
        }
        assert!(
            soam.housekeeping(&mut log),
            "octahedron must be stable: {:?}",
            soam.state()
        );
        // Its Euler characteristic is that of a sphere.
        let adj = soam.net().adjacency_map();
        assert_eq!(crate::topology::euler_characteristic(&adj), 2);
    }
}
