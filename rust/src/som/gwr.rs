//! Growing When Required (Marsland, Shapiro, Nehmzow 2002).
//!
//! Insertion is driven by *need*: when a habituated winner is still too far
//! from the signal (distance above the insertion threshold), a new unit is
//! created halfway between them. Termination: quantization-error EMA below
//! target (the "threshold on the overall quantization error" criterion the
//! paper attributes to most growing networks, §2.1).

use crate::geometry::Vec3;
use crate::mesh::SurfaceSampler;
use crate::rng::Rng;
use crate::runtime::bytes::{ByteReader, ByteWriter};

use super::network::{ChangeLog, Network, UnitId};
use super::params::GwrParams;
use super::{GrowingNetwork, PlanKind, QeTracker, UpdateKind, UpdatePlan, Winners};

/// GWR algorithm state.
pub struct Gwr {
    pub params: GwrParams,
    net: Network,
    qe: QeTracker,
    orphan_buf: Vec<UnitId>,
}

impl Gwr {
    pub fn new(params: GwrParams) -> Self {
        Self {
            params,
            net: Network::new(),
            qe: QeTracker::new(0.001),
            orphan_buf: Vec::new(),
        }
    }

    /// Shared GWR-style update core, reused by SOAM (which layers its
    /// threshold adaptation and topological termination on top).
    ///
    /// Returns `true` if the signal was applied (false = stale winners).
    pub(super) fn gwr_update(
        net: &mut Network,
        params: &GwrParams,
        signal: Vec3,
        w: &Winners,
        log: &mut ChangeLog,
        orphan_buf: &mut Vec<UnitId>,
        // SOAM: per-unit thresholds; GWR: the global one.
        per_unit_threshold: bool,
    ) -> bool {
        if !net.is_alive(w.w1) || !net.is_alive(w.w2) || w.w1 == w.w2 {
            return false; // stale winners (multi-signal batch)
        }

        // 1. Edge aging on the winner + competitive Hebbian edge w1–w2.
        net.age_edges_of(w.w1, 1.0);
        net.connect(w.w1, w.w2);

        // 2. Insert or adapt.
        let d1 = w.d1_sq.sqrt();
        let threshold = if per_unit_threshold {
            net.unit(w.w1).threshold
        } else {
            params.insertion_threshold
        };
        let habituated = params.hab.is_habituated(net.unit(w.w1).firing);
        if d1 > threshold && habituated && net.len() < params.max_units {
            // New unit halfway between winner and signal.
            let pos = (net.pos(w.w1) + signal) * 0.5;
            let new_threshold = if per_unit_threshold {
                (net.unit(w.w1).threshold + net.unit(w.w2).threshold) * 0.5
            } else {
                params.insertion_threshold
            };
            let r = net.insert(pos, new_threshold);
            net.connect(r, w.w1);
            net.connect(r, w.w2);
            net.disconnect(w.w1, w.w2);
            log.inserted.push(r);
        } else {
            // Adapt winner and its topological neighbors (paper eq. (1)).
            let hw = net.unit(w.w1).firing;
            let mod_b = if params.adapt.firing_modulation { hw } else { 1.0 };
            let old = net.pos(w.w1);
            let new = old + (signal - old) * (params.adapt.eps_b * mod_b);
            net.set_pos(w.w1, new);
            log.moved.push((w.w1, old));

            // Neighbor list is tiny; copy ids to release the borrow.
            let nbrs: Vec<UnitId> = net.edges_of(w.w1).iter().map(|e| e.to).collect();
            for n in nbrs {
                let hn = net.unit(n).firing;
                let mod_n = if params.adapt.firing_modulation { hn } else { 1.0 };
                let old_n = net.pos(n);
                let new_n = old_n + (signal - old_n) * (params.adapt.eps_n * mod_n);
                net.set_pos(n, new_n);
                log.moved.push((n, old_n));
                let f = net.unit(n).firing;
                net.unit_mut(n).firing = params.hab.fire_neighbor(f);
            }
            let f = net.unit(w.w1).firing;
            net.unit_mut(w.w1).firing = params.hab.fire_winner(f);
        }

        // 3. Prune stale edges around the winner; drop orphaned units.
        orphan_buf.clear();
        net.prune_old_edges(w.w1, params.adapt.max_age, orphan_buf);
        for i in 0..orphan_buf.len() {
            let o = orphan_buf[i];
            if net.is_alive(o) && net.degree(o) == 0 && net.len() > 2 {
                let pos = net.pos(o);
                net.remove(o);
                log.removed.push((o, pos));
            }
        }
        true
    }

    /// Seed with two units at random surface points (GWR §3 init).
    pub(super) fn seed_two(net: &mut Network, sampler: &SurfaceSampler, rng: &mut Rng, threshold: f32) {
        let a = net.insert(sampler.sample(rng), threshold);
        let b = net.insert(sampler.sample(rng), threshold);
        net.connect(a, b);
    }

    /// Read-only mirror of [`Self::gwr_update`]'s branch structure:
    /// predicts which branch the update would take in the *current* state.
    ///
    /// - Insertion branch with a provably no-op post-insert prune →
    ///   [`UpdateKind::Insert`]: the whole update is confined to
    ///   `{w1, w2, new unit} ∪ N(w1)` (the winner keeps its fresh age-0
    ///   edge to the new unit, so no orphan removal either) and splits
    ///   into a sequential allocation + a deferrable edge commit. The
    ///   `w1`–`w2` edge is exempt from the prune prediction here because
    ///   the insertion branch *disconnects* it before the prune runs.
    /// - Insertion branch whose prune could fire → `Structural`.
    /// - Adapt branch with a provably no-op prune → `Adapt` (the winner
    ///   keeps at least the age-0 `w1`–`w2` edge, so no orphan removal).
    /// - Anything else (a possible prune, stale winners) → `Structural`.
    pub(super) fn gwr_classify(
        net: &Network,
        params: &GwrParams,
        w: &Winners,
        per_unit_threshold: bool,
    ) -> UpdateKind {
        if !net.is_alive(w.w1) || !net.is_alive(w.w2) || w.w1 == w.w2 {
            // Degenerate (stale winners): let `update` discard it inline.
            return UpdateKind::Structural;
        }
        // Prune prediction: `update` ages every edge of w1 by 1.0 and then
        // drops edges older than max_age; the w1–w2 edge is exempt on
        // *both* branches (the adapt branch resets its age to 0, the
        // insertion branch disconnects it). Same float expression as the
        // prune.
        let will_prune = net
            .edges_of(w.w1)
            .iter()
            .any(|e| e.to != w.w2 && e.age + 1.0 > params.adapt.max_age);
        let d1 = w.d1_sq.sqrt();
        let threshold = if per_unit_threshold {
            net.unit(w.w1).threshold
        } else {
            params.insertion_threshold
        };
        let habituated = params.hab.is_habituated(net.unit(w.w1).firing);
        if d1 > threshold && habituated && net.len() < params.max_units {
            // Insertion branch.
            if will_prune {
                UpdateKind::Structural
            } else {
                UpdateKind::Insert
            }
        } else if will_prune {
            UpdateKind::Structural
        } else {
            UpdateKind::Adapt
        }
    }

    /// Pure-function half of the adapt branch of [`Self::gwr_update`]:
    /// computes every position and firing write into `plan` without
    /// mutating the network. Only valid after [`Self::gwr_classify`]
    /// returned [`UpdateKind::Adapt`] for unchanged state.
    pub(super) fn gwr_plan(
        net: &Network,
        params: &GwrParams,
        signal: Vec3,
        w: &Winners,
        plan: &mut UpdatePlan,
    ) {
        plan.clear();
        plan.w1 = w.w1;
        plan.w2 = w.w2;
        plan.d1_sq = w.d1_sq;

        let hw = net.unit(w.w1).firing;
        let mod_b = if params.adapt.firing_modulation { hw } else { 1.0 };
        let old = net.pos(w.w1);
        plan.moves
            .push((w.w1, old + (signal - old) * (params.adapt.eps_b * mod_b)));

        // Neighbor order must match `update`: the existing adjacency of w1,
        // plus w2 appended at the end when the competitive-Hebbian connect
        // would create (not reset) the w1–w2 edge.
        let mut neighbor = |n: UnitId| {
            let hn = net.unit(n).firing;
            let mod_n = if params.adapt.firing_modulation { hn } else { 1.0 };
            let old_n = net.pos(n);
            plan.moves
                .push((n, old_n + (signal - old_n) * (params.adapt.eps_n * mod_n)));
            plan.firing.push((n, params.hab.fire_neighbor(hn)));
        };
        for e in net.edges_of(w.w1) {
            neighbor(e.to);
        }
        if !net.has_edge(w.w1, w.w2) {
            neighbor(w.w2);
        }
        plan.firing.push((w.w1, params.hab.fire_winner(hw)));
    }

    /// Sequential half of an `Insert`-class update: allocate the new unit
    /// now (slab-id order is admission order — identical ids to the
    /// sequential driver by the free lists' global-LIFO property) and fill
    /// `plan` with the edge work the concurrent commit applies later
    /// ([`super::ShardWriter::commit_insert`]). The position and threshold
    /// expressions are verbatim from [`Self::gwr_update`]'s insertion
    /// branch, so the stored bits match the inline path exactly.
    pub(super) fn gwr_begin_insert(
        net: &mut Network,
        params: &GwrParams,
        signal: Vec3,
        w: &Winners,
        plan: &mut UpdatePlan,
        per_unit_threshold: bool,
    ) {
        plan.clear();
        plan.kind = PlanKind::Insert;
        plan.w1 = w.w1;
        plan.w2 = w.w2;
        plan.d1_sq = w.d1_sq;
        let pos = (net.pos(w.w1) + signal) * 0.5;
        let new_threshold = if per_unit_threshold {
            (net.unit(w.w1).threshold + net.unit(w.w2).threshold) * 0.5
        } else {
            params.insertion_threshold
        };
        plan.new_unit = net.insert(pos, new_threshold);
    }

    /// Debug check shared by the GWR-family scalar replays: by the time
    /// `commit_scalars` runs, [`super::ShardWriter::commit_adapt`] /
    /// [`super::ShardWriter::commit_insert`] has
    /// replayed the aging + connect, so an `Adapt`/`Insert` classification
    /// implies no edge of the winner can be over age.
    pub(super) fn debug_check_no_prune(net: &Network, params: &GwrParams, plan: &UpdatePlan) {
        debug_assert!(
            net.edges_of(plan.w1)
                .iter()
                .all(|e| e.age <= params.adapt.max_age),
            "classified Adapt but the prune would fire"
        );
    }
}

impl GrowingNetwork for Gwr {
    fn name(&self) -> &'static str {
        "gwr"
    }

    fn net(&self) -> &Network {
        &self.net
    }

    fn net_mut(&mut self) -> &mut Network {
        &mut self.net
    }

    fn init(&mut self, sampler: &SurfaceSampler, rng: &mut Rng) {
        Self::seed_two(&mut self.net, sampler, rng, self.params.insertion_threshold);
    }

    fn update(&mut self, signal: Vec3, winners: &Winners, log: &mut ChangeLog) {
        if Self::gwr_update(
            &mut self.net,
            &self.params,
            signal,
            winners,
            log,
            &mut self.orphan_buf,
            false,
        ) {
            self.qe.push(winners.d1_sq);
        }
    }

    fn housekeeping(&mut self, _log: &mut ChangeLog) -> bool {
        self.qe.value() < self.params.target_qe
    }

    fn quantization_error(&self) -> f32 {
        self.qe.value()
    }

    fn classify_update(&self, _signal: Vec3, w: &Winners, _pending_commits: usize) -> UpdateKind {
        Self::gwr_classify(&self.net, &self.params, w, false)
    }

    fn plan_update(&self, signal: Vec3, w: &Winners, plan: &mut UpdatePlan) {
        Self::gwr_plan(&self.net, &self.params, signal, w, plan);
    }

    fn begin_insert(&mut self, signal: Vec3, w: &Winners, plan: &mut UpdatePlan) {
        let params = self.params;
        Self::gwr_begin_insert(&mut self.net, &params, signal, w, plan, false);
    }

    fn commit_scalars(&mut self, plan: &UpdatePlan, _log: &mut ChangeLog) {
        Self::debug_check_no_prune(&self.net, &self.params, plan);
        self.qe.push(plan.d1_sq);
    }

    fn save_state(&self, w: &mut ByteWriter) {
        w.str("gwr");
        let (ema, samples) = self.qe.raw();
        w.f32(ema);
        w.u64(samples);
        self.net.write_state(w);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let tag = r.str().map_err(|e| e.to_string())?;
        if tag != "gwr" {
            return Err(format!("snapshot algorithm {tag:?} is not gwr"));
        }
        let ema = r.f32().map_err(|e| e.to_string())?;
        let samples = r.u64().map_err(|e| e.to_string())?;
        self.qe.restore(ema, samples);
        self.net = Network::read_state(r)?;
        self.orphan_buf.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findwinners::{FindWinners, Scalar};
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    fn run_gwr(signals: u64, threshold: f32) -> Gwr {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 24);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(42);
        let mut gwr = Gwr::new(GwrParams {
            insertion_threshold: threshold,
            ..GwrParams::default()
        });
        gwr.init(&sampler, &mut rng);
        let mut fw = Scalar::new();
        let mut log = ChangeLog::default();
        for _ in 0..signals {
            let s = sampler.sample(&mut rng);
            let w = fw.find2(&gwr.net, s).unwrap();
            log.clear();
            gwr.update(s, &w, &mut log);
        }
        gwr
    }

    #[test]
    fn grows_and_stays_consistent() {
        let gwr = run_gwr(5_000, 0.1);
        assert!(gwr.net().len() > 10, "only {} units", gwr.net().len());
        gwr.net().check_invariants().unwrap();
    }

    #[test]
    fn smaller_threshold_more_units() {
        let coarse = run_gwr(8_000, 0.15).net().len();
        let fine = run_gwr(8_000, 0.06).net().len();
        assert!(fine > coarse, "fine {fine} <= coarse {coarse}");
    }

    #[test]
    fn quantization_error_decreases() {
        let gwr = run_gwr(8_000, 0.08);
        // After growth the EMA of squared winner distance must be well below
        // the squared mesh diameter (~1 in the unit cube).
        assert!(gwr.quantization_error() < 0.02, "{}", gwr.quantization_error());
    }

    #[test]
    fn stale_winners_ignored() {
        let mut gwr = run_gwr(500, 0.1);
        let mut log = ChangeLog::default();
        let dead = Winners { w1: 9999, w2: 0, d1_sq: 0.1, d2_sq: 0.2 };
        let units_before = gwr.net().len();
        gwr.update(Vec3::ZERO, &dead, &mut log);
        assert_eq!(gwr.net().len(), units_before);
        assert!(log.is_empty());
    }
}
