//! Spatial region partition of the bounding volume — the structural step
//! toward sharding Find Winners + Update across regions (and, later,
//! across whole networks/backends).
//!
//! Two layers, split by what they need to stay exact:
//!
//! - [`RegionMap`] is pure, immutable geometry: the bounding volume cut
//!   into `dims[0]·dims[1]·dims[2]` axis-aligned cells by per-axis plane
//!   arrays. Cell membership is decided by **binary search over the stored
//!   `f32` planes**, never by re-deriving the cell from a division — that
//!   is what makes the neighborhood scan's early exit provable in `f32`
//!   (see *Exactness* below). The map is cheap to clone and shared by both
//!   consumers: the region-neighborhood Find Winners scan
//!   ([`crate::findwinners::region_top2`]) and the executor's
//!   region-granular conflict domains
//!   ([`crate::coordinator::BatchExecutor::set_regions`]).
//! - [`RegionGrid`] adds the mutable state: a per-region roster of alive
//!   unit ids plus the inverse `slot → region` table, maintained
//!   incrementally from the drivers' merged per-batch [`ChangeLog`]s
//!   (insert / remove / move — reconciled against the network's *final*
//!   state, the same contract `findwinners::Indexed` follows), with a
//!   region-crossing counter for bookkeeping.
//!
//! ## Exactness
//!
//! The region scan reads only the rosters of the 3×3×3 cell block around a
//! signal and must still return **exactly** the exhaustive scan's top-2
//! (bit-identical distances, lowest-index tie-break). The argument hinges
//! on two invariants of the plane-search cell assignment (`planes[a]` is
//! non-decreasing; `cell = clamp(upper_bound(planes, x) - 1)`):
//!
//! 1. a position in a cell `c < lo` on some axis satisfies
//!    `x < planes[lo]` (it is below every plane of the scanned block);
//! 2. a position in a cell `c > hi` satisfies `x ≥ planes[hi + 1]`.
//!
//! For a signal `s` inside the block, `t = s − planes[lo]` (resp.
//! `planes[hi+1] − s`) is a non-negative `f32` with `|s − x| ≥ t` for
//! every unit `x` outside the block on that axis — rounding is monotone,
//! so the ordering survives each correctly-rounded subtraction — and the
//! squared-distance expression `dx·dx + dy·dy + dz·dz` only ever rounds
//! sums of non-negative terms, so `dist²(s, x) ≥ t·t` holds in `f32`
//! exactly ([`RegionMap::outside_dist2`] returns the minimum such `t·t`
//! over the block's interior faces; faces at the grid border contribute
//! `+inf` because border cells swallow everything beyond the bounds).
//! Whenever the local second-best distance is `< outside_dist2` strictly,
//! no unscanned unit can enter the top-2 — not even on an exact distance
//! tie — and the local result is the global result. Otherwise the scan
//! falls back to the exhaustive path; correctness never depends on the
//! grid resolution, only the fallback rate does.

use crate::geometry::{Aabb, Vec3};

use super::network::{ChangeLog, Network, UnitId};

/// `slot_region` value for slots that are dead (or beyond the tracked
/// range): the unit is in no roster.
pub const NO_REGION: u32 = u32::MAX;

/// Hard cap on the region count (a runaway `regions` knob must not
/// allocate an absurd roster table).
const MAX_REGIONS: usize = 1 << 20;

/// Immutable region geometry: per-axis split planes over a bounding
/// volume. See the module docs for the exactness contract.
#[derive(Clone, Debug)]
pub struct RegionMap {
    dims: [usize; 3],
    /// `planes[a]` has `dims[a] + 1` non-decreasing entries; cell `c` on
    /// axis `a` nominally spans `[planes[a][c], planes[a][c+1])`, with the
    /// first and last cells extended to ±∞ by the clamped lookup.
    planes: [Vec<f32>; 3],
}

impl RegionMap {
    /// Cut `bounds` into at least `regions` cells (capped at
    /// [`MAX_REGIONS`]): the axis with the largest current cell extent is
    /// split one step at a time, so the cells stay near-isotropic for any
    /// target count. Degenerate bounds collapse to a single region.
    pub fn new(bounds: Aabb, regions: usize) -> Self {
        let regions = regions.clamp(1, MAX_REGIONS);
        let mut dims = [1usize; 3];
        let ext = if bounds.is_empty() {
            [0.0f32; 3]
        } else {
            let e = bounds.extent();
            [e.x.max(0.0), e.y.max(0.0), e.z.max(0.0)]
        };
        if ext.iter().any(|v| v.is_finite() && *v > 0.0) {
            while dims[0] * dims[1] * dims[2] < regions {
                // Axis with the widest current cell; ties break to the
                // lowest axis index (deterministic).
                let mut axis = 0;
                let mut widest = f32::MIN;
                for a in 0..3 {
                    let cell = ext[a] / dims[a] as f32;
                    if cell.is_finite() && cell > widest {
                        widest = cell;
                        axis = a;
                    }
                }
                dims[axis] += 1;
            }
        }
        let min = if bounds.is_empty() { Vec3::ZERO } else { bounds.min };
        let lo = [min.x, min.y, min.z];
        let planes: [Vec<f32>; 3] = std::array::from_fn(|a| {
            let cell = ext[a] / dims[a] as f32;
            (0..=dims[a]).map(|k| lo[a] + k as f32 * cell).collect()
        });
        Self { dims, planes }
    }

    /// Total number of regions (`≥ 1`).
    pub fn region_count(&self) -> usize {
        self.dims[0] * self.dims[1] * self.dims[2]
    }

    /// Per-axis cell counts.
    pub fn dims(&self) -> [usize; 3] {
        self.dims
    }

    /// Cell index on one axis: `upper_bound(planes, x) - 1`, clamped into
    /// `[0, dims - 1]` so out-of-bounds positions land in a border cell
    /// (growing networks adapt toward surface signals, but an f32 step can
    /// overshoot the bounds by an ulp).
    #[inline]
    fn axis_cell(&self, a: usize, x: f32) -> usize {
        let pp = self.planes[a].partition_point(|p| *p <= x);
        pp.saturating_sub(1).min(self.dims[a] - 1)
    }

    /// Flatten per-axis cell coordinates to a region id.
    #[inline]
    pub fn index(&self, c: [usize; 3]) -> u32 {
        ((c[0] * self.dims[1] + c[1]) * self.dims[2] + c[2]) as u32
    }

    /// Region containing `p` (total: every position maps somewhere).
    #[inline]
    pub fn region_of(&self, p: Vec3) -> u32 {
        self.index([
            self.axis_cell(0, p.x),
            self.axis_cell(1, p.y),
            self.axis_cell(2, p.z),
        ])
    }

    /// Per-axis `[lo, hi]` cell ranges of the 3×3×3 neighborhood block
    /// around `p`'s cell, clamped to the grid.
    #[inline]
    pub fn neighborhood(&self, p: Vec3) -> ([usize; 3], [usize; 3]) {
        let c = [
            self.axis_cell(0, p.x),
            self.axis_cell(1, p.y),
            self.axis_cell(2, p.z),
        ];
        let lo = [
            c[0].saturating_sub(1),
            c[1].saturating_sub(1),
            c[2].saturating_sub(1),
        ];
        let hi = [
            (c[0] + 1).min(self.dims[0] - 1),
            (c[1] + 1).min(self.dims[1] - 1),
            (c[2] + 1).min(self.dims[2] - 1),
        ];
        (lo, hi)
    }

    /// Lower bound (in exact `f32`, see the module docs) on the squared
    /// distance from `s` — which must lie inside the block — to any
    /// position whose cell lies outside the block `[lo, hi]`. Faces at the
    /// grid border contribute `+inf` (border cells extend to infinity).
    pub fn outside_dist2(&self, lo: [usize; 3], hi: [usize; 3], s: Vec3) -> f32 {
        let sv = [s.x, s.y, s.z];
        let mut best = f32::INFINITY;
        for a in 0..3 {
            if lo[a] > 0 {
                let t = (sv[a] - self.planes[a][lo[a]]).max(0.0);
                best = best.min(t * t);
            }
            if hi[a] + 1 < self.dims[a] {
                let t = (self.planes[a][hi[a] + 1] - sv[a]).max(0.0);
                best = best.min(t * t);
            }
        }
        best
    }
}

/// Region grid with per-region alive-unit rosters (see module docs).
#[derive(Clone, Debug)]
pub struct RegionGrid {
    map: RegionMap,
    /// `rosters[r]` holds the alive unit ids currently assigned to region
    /// `r`, in arbitrary order (the scan merges candidates under the
    /// explicit lexicographic order, so roster order never matters).
    rosters: Vec<Vec<UnitId>>,
    /// Inverse table: the region each slab slot is rostered in
    /// ([`NO_REGION`] for dead slots).
    slot_region: Vec<u32>,
    /// How many roster moves crossed a region boundary (a live unit
    /// reassigned from one region to another) — the region-crossing
    /// bookkeeping used by benches and diagnostics.
    crossings: u64,
    /// Slab capacity / live count as of the last `rebuild`/`sync` — the
    /// staleness guard for callers that mutate the network structurally
    /// without honoring the sync contract (see [`Self::is_stale`]).
    seen_capacity: usize,
    seen_live: usize,
    /// Reused id scratch for `sync` (dedup of the merged change log).
    scratch: Vec<UnitId>,
}

impl RegionGrid {
    pub fn new(map: RegionMap) -> Self {
        let regions = map.region_count();
        Self {
            map,
            rosters: vec![Vec::new(); regions],
            slot_region: Vec::new(),
            crossings: 0,
            seen_capacity: 0,
            seen_live: 0,
            scratch: Vec::new(),
        }
    }

    /// Has the network changed structurally since this grid last saw it
    /// (`rebuild`/`sync`)? True means some caller violated the sync
    /// contract — the rosters can no longer be trusted and must be
    /// rebuilt. The same best-effort guard the tile cache uses: pure
    /// position moves without a sync stay undetectable for both.
    pub fn is_stale(&self, net: &Network) -> bool {
        self.seen_capacity != net.capacity() || self.seen_live != net.len()
    }

    /// The shared geometry.
    pub fn map(&self) -> &RegionMap {
        &self.map
    }

    /// Roster of one region (alive unit ids, arbitrary order).
    #[inline]
    pub fn roster(&self, region: u32) -> &[UnitId] {
        &self.rosters[region as usize]
    }

    /// Live units whose roster assignment crossed a region boundary so far.
    pub fn crossings(&self) -> u64 {
        self.crossings
    }

    /// Rebuild every roster from scratch (after `init`, or as the defense
    /// path when a caller mutated the network without honoring the sync
    /// contract). Does not count crossings.
    pub fn rebuild(&mut self, net: &Network) {
        for r in &mut self.rosters {
            r.clear();
        }
        self.slot_region.clear();
        self.slot_region.resize(net.capacity(), NO_REGION);
        for id in net.ids() {
            let r = self.map.region_of(net.pos(id));
            self.rosters[r as usize].push(id);
            self.slot_region[id as usize] = r;
        }
        self.seen_capacity = net.capacity();
        self.seen_live = net.len();
    }

    /// Apply one merged per-batch change log: every unit mentioned in any
    /// list is reconciled against the network's **final** state (a unit may
    /// appear several times and in several lists — moved twice, moved then
    /// removed, removed with its slot reused by a later insert).
    pub fn sync(&mut self, net: &Network, changes: &ChangeLog) {
        let mut ids = std::mem::take(&mut self.scratch);
        ids.clear();
        ids.extend(changes.moved.iter().map(|&(id, _)| id));
        ids.extend(changes.inserted.iter().copied());
        ids.extend(changes.removed.iter().map(|&(id, _)| id));
        ids.sort_unstable();
        ids.dedup();
        if self.slot_region.len() < net.capacity() {
            self.slot_region.resize(net.capacity(), NO_REGION);
        }
        let before = self.crossings;
        for &id in &ids {
            self.reconcile(net, id);
        }
        crate::telemetry::add(
            crate::telemetry::Counter::RegionCrossings,
            self.crossings - before,
        );
        self.scratch = ids;
        self.seen_capacity = net.capacity();
        self.seen_live = net.len();
    }

    /// Reconcile one slot against the network's current state.
    fn reconcile(&mut self, net: &Network, id: UnitId) {
        let i = id as usize;
        debug_assert!(i < self.slot_region.len(), "unsized slot {id}");
        let want = if net.is_alive(id) {
            self.map.region_of(net.pos(id))
        } else {
            NO_REGION
        };
        let have = self.slot_region[i];
        if have == want {
            return;
        }
        if have != NO_REGION {
            let roster = &mut self.rosters[have as usize];
            if let Some(at) = roster.iter().position(|&u| u == id) {
                roster.swap_remove(at);
            } else {
                debug_assert!(false, "unit {id} missing from roster {have}");
            }
            if want != NO_REGION {
                self.crossings += 1;
            }
        }
        if want != NO_REGION {
            self.rosters[want as usize].push(id);
        }
        self.slot_region[i] = want;
    }

    /// Roster invariants (the region analogue of
    /// [`Network::check_invariants`], which cannot see this grid): every
    /// live unit rostered exactly once, in the region of its current
    /// position; no dead, duplicate, foreign or leaked entries; the inverse
    /// table consistent with the rosters.
    pub fn check_invariants(&self, net: &Network) -> Result<(), String> {
        if self.rosters.len() != self.map.region_count() {
            return Err(format!(
                "{} rosters != {} regions",
                self.rosters.len(),
                self.map.region_count()
            ));
        }
        if self.slot_region.len() < net.capacity() {
            return Err(format!(
                "slot_region len {} < slab capacity {}",
                self.slot_region.len(),
                net.capacity()
            ));
        }
        let mut seen = std::collections::HashSet::new();
        let mut total = 0usize;
        for (r, roster) in self.rosters.iter().enumerate() {
            for &id in roster {
                total += 1;
                if !net.is_alive(id) {
                    return Err(format!("dead unit {id} in roster {r}"));
                }
                if !seen.insert(id) {
                    return Err(format!("unit {id} rostered twice"));
                }
                let want = self.map.region_of(net.pos(id));
                if want as usize != r {
                    return Err(format!(
                        "unit {id} rostered in {r} but positioned in {want}"
                    ));
                }
                if self.slot_region[id as usize] != r as u32 {
                    return Err(format!(
                        "slot_region[{id}] = {} but rostered in {r}",
                        self.slot_region[id as usize]
                    ));
                }
            }
        }
        if total != net.len() {
            return Err(format!("{total} rostered units != {} live (leak)", net.len()));
        }
        for (i, &r) in self.slot_region.iter().enumerate() {
            if r == NO_REGION {
                if net.is_alive(i as UnitId) {
                    return Err(format!("live unit {i} has NO_REGION"));
                }
            } else if !net.is_alive(i as UnitId) {
                return Err(format!("dead slot {i} stamped with region {r}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn cube() -> Aabb {
        Aabb::new(Vec3::ZERO, Vec3::ONE)
    }

    fn random_net(n: usize, seed: u64, kill_every: usize) -> Network {
        let mut rng = Rng::seed_from(seed);
        let mut net = Network::new();
        let mut ids = Vec::new();
        for _ in 0..n {
            ids.push(net.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1));
        }
        if kill_every > 0 {
            for (k, &id) in ids.iter().enumerate() {
                if k % kill_every == kill_every - 1 && net.len() > 2 {
                    net.remove(id);
                }
            }
        }
        net
    }

    #[test]
    fn map_reaches_target_and_stays_near_isotropic() {
        for regions in [1usize, 2, 3, 8, 27, 64, 100, 1000] {
            let map = RegionMap::new(cube(), regions);
            assert!(map.region_count() >= regions, "target {regions}");
            assert!(map.region_count() <= 2 * regions.max(1), "overshoot {regions}");
            let d = map.dims();
            let (lo, hi) = (d.iter().min().copied().unwrap(), d.iter().max().copied().unwrap());
            // Greedy widest-axis splitting on a cube never lets one axis
            // run more than one split ahead.
            assert!(hi - lo <= 1, "dims {d:?} for target {regions}");
        }
    }

    #[test]
    fn degenerate_bounds_collapse_to_one_region() {
        assert_eq!(RegionMap::new(Aabb::EMPTY, 64).region_count(), 1);
        let flat = Aabb::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0));
        let map = RegionMap::new(flat, 8);
        // Only the x axis has extent: every split lands there.
        assert_eq!(map.dims(), [8, 1, 1]);
        let point = Aabb::new(Vec3::ONE, Vec3::ONE);
        assert_eq!(RegionMap::new(point, 16).region_count(), 1);
    }

    #[test]
    fn region_of_is_total_and_clamps() {
        let map = RegionMap::new(cube(), 27);
        let n = map.region_count() as u32;
        let mut rng = Rng::seed_from(5);
        for _ in 0..2000 {
            // Include far-out-of-bounds and boundary-ish points.
            let p = Vec3::new(
                rng.f32() * 4.0 - 1.5,
                rng.f32() * 4.0 - 1.5,
                rng.f32() * 4.0 - 1.5,
            );
            assert!(map.region_of(p) < n);
        }
        assert!(map.region_of(Vec3::splat(f32::INFINITY)) < n);
        assert!(map.region_of(Vec3::splat(f32::NEG_INFINITY)) < n);
        assert!(map.region_of(Vec3::splat(f32::NAN)) < n, "NaN maps somewhere");
    }

    #[test]
    fn neighborhood_contains_own_cell_and_clamps() {
        let map = RegionMap::new(cube(), 64);
        let mut rng = Rng::seed_from(7);
        for _ in 0..500 {
            let p = Vec3::new(rng.f32(), rng.f32(), rng.f32());
            let (lo, hi) = map.neighborhood(p);
            let r = map.region_of(p);
            let d = map.dims();
            // Recover per-axis coords of r and check block membership.
            let c = [
                r as usize / (d[1] * d[2]),
                (r as usize / d[2]) % d[1],
                r as usize % d[2],
            ];
            for a in 0..3 {
                assert!(lo[a] <= c[a] && c[a] <= hi[a]);
                assert!(hi[a] < d[a]);
                assert!(hi[a] - lo[a] <= 2);
            }
        }
    }

    #[test]
    fn outside_dist2_lower_bounds_out_of_block_units() {
        // The load-bearing property: for every unit in a cell outside the
        // block, dist²(s, unit) >= outside_dist2 — in f32, not just in
        // reals. Exercised with points ON the split planes.
        let map = RegionMap::new(cube(), 64);
        let mut rng = Rng::seed_from(11);
        for _ in 0..300 {
            let snap = |rng: &mut Rng| {
                let raw = rng.f32();
                if rng.below(4) == 0 {
                    // Snap to a plane-aligned coordinate (boundary case).
                    (raw * 4.0).floor() / 4.0
                } else {
                    raw
                }
            };
            let s = Vec3::new(snap(&mut rng), snap(&mut rng), snap(&mut rng));
            let (lo, hi) = map.neighborhood(s);
            let bound = map.outside_dist2(lo, hi, s);
            for _ in 0..64 {
                let u = Vec3::new(snap(&mut rng), snap(&mut rng), snap(&mut rng));
                let r = map.region_of(u);
                let d = map.dims();
                let c = [
                    r as usize / (d[1] * d[2]),
                    (r as usize / d[2]) % d[1],
                    r as usize % d[2],
                ];
                let inside = (0..3).all(|a| lo[a] <= c[a] && c[a] <= hi[a]);
                if !inside {
                    assert!(
                        s.dist2(u) >= bound,
                        "unit {u:?} outside block but closer ({} < {bound}) to {s:?}",
                        s.dist2(u)
                    );
                }
            }
        }
    }

    #[test]
    fn rebuild_and_invariants() {
        let net = random_net(200, 3, 7);
        let mut grid = RegionGrid::new(RegionMap::new(cube(), 27));
        grid.rebuild(&net);
        grid.check_invariants(&net).unwrap();
        assert_eq!(grid.crossings(), 0);
        let total: usize = (0..grid.map().region_count())
            .map(|r| grid.roster(r as u32).len())
            .sum();
        assert_eq!(total, net.len());
    }

    #[test]
    fn sync_reconciles_merged_logs() {
        let mut net = random_net(64, 9, 0);
        let mut grid = RegionGrid::new(RegionMap::new(cube(), 27));
        grid.rebuild(&net);

        // One merged log: a move within the cell, a boundary-crossing move,
        // a removal, a removal whose slot is reused, and a fresh insert.
        let ids: Vec<UnitId> = net.ids().collect();
        let mut log = ChangeLog::default();

        let stay = ids[0];
        let old = net.pos(stay);
        net.set_pos(stay, old); // no-op move (same region by construction)
        log.moved.push((stay, old));

        let cross = ids[1];
        let old = net.pos(cross);
        net.set_pos(cross, Vec3::ONE - old); // mirror: almost surely crosses
        log.moved.push((cross, old));

        let gone = ids[2];
        let pos = net.pos(gone);
        net.remove(gone);
        log.removed.push((gone, pos));

        let reused_src = ids[3];
        let pos = net.pos(reused_src);
        net.remove(reused_src);
        log.removed.push((reused_src, pos));
        let reborn = net.insert(Vec3::new(0.9, 0.9, 0.9), 0.1);
        assert_eq!(reborn, reused_src, "slot reuse");
        log.inserted.push(reborn);

        let fresh = net.insert(Vec3::new(0.05, 0.5, 0.95), 0.1);
        log.inserted.push(fresh);

        grid.sync(&net, &log);
        grid.check_invariants(&net).unwrap();
        assert!(!net.is_alive(gone));
        assert_eq!(grid.slot_region[gone as usize], NO_REGION, "removed unit left a roster entry");
        // A second sync of the same (now stale) log must be a no-op.
        let crossings = grid.crossings();
        grid.sync(&net, &log);
        grid.check_invariants(&net).unwrap();
        assert_eq!(grid.crossings(), crossings);
    }

    #[test]
    fn crossings_count_boundary_moves_only() {
        let mut net = Network::new();
        let a = net.insert(Vec3::new(0.1, 0.1, 0.1), 0.1);
        let b = net.insert(Vec3::new(0.9, 0.9, 0.9), 0.1);
        let mut grid = RegionGrid::new(RegionMap::new(cube(), 8));
        grid.rebuild(&net);

        // In-region wiggle: no crossing.
        let mut log = ChangeLog::default();
        let old = net.pos(a);
        net.set_pos(a, Vec3::new(0.12, 0.1, 0.1));
        log.moved.push((a, old));
        grid.sync(&net, &log);
        assert_eq!(grid.crossings(), 0);

        // Boundary-crossing move: one crossing.
        let mut log = ChangeLog::default();
        let old = net.pos(b);
        net.set_pos(b, Vec3::new(0.1, 0.9, 0.9));
        log.moved.push((b, old));
        grid.sync(&net, &log);
        assert_eq!(grid.crossings(), 1);
        grid.check_invariants(&net).unwrap();
    }

    #[test]
    fn check_invariants_rejects_corruption() {
        let net = random_net(32, 21, 5);
        let build = || {
            let mut g = RegionGrid::new(RegionMap::new(cube(), 27));
            g.rebuild(&net);
            g
        };

        // Duplicate roster entry.
        let mut g = build();
        let id = net.ids().next().unwrap();
        let r = g.slot_region[id as usize];
        g.rosters[r as usize].push(id);
        assert!(g.check_invariants(&net).unwrap_err().contains("twice"));

        // Entry in a foreign roster.
        let mut g = build();
        let foreign = (r as usize + 1) % g.map().region_count();
        let at = g.rosters[r as usize].iter().position(|&u| u == id).unwrap();
        g.rosters[r as usize].swap_remove(at);
        g.rosters[foreign].push(id);
        let err = g.check_invariants(&net).unwrap_err();
        assert!(err.contains("positioned in") || err.contains("slot_region"), "{err}");

        // Leaked (missing) unit.
        let mut g = build();
        let at = g.rosters[r as usize].iter().position(|&u| u == id).unwrap();
        g.rosters[r as usize].swap_remove(at);
        assert!(g.check_invariants(&net).unwrap_err().contains("leak"));
    }
}
