//! Habituation (firing-counter) dynamics, after Marsland's GWR.
//!
//! Each unit carries a habituation level `h ∈ (h_min, 1]` that decays every
//! time the unit fires (wins or neighbors a winner):
//!
//! `dh/dt = τ · (α·(1 − h) − 1)`
//!
//! discretized with unit time step. `h` decays from 1 toward the fixed point
//! `h* = 1 − 1/α < h_threshold`; a unit is *habituated* ("trained often
//! enough that inserting next to it is meaningful") once `h < h_threshold`.
//! Winners habituate faster than neighbors (`τ_b > τ_n`).

/// Habituation parameters (defaults follow the GWR paper's regime).
#[derive(Clone, Copy, Debug)]
pub struct Habituation {
    /// Curve steepness; fixed point is `1 − 1/alpha`.
    pub alpha: f32,
    /// Winner decay rate.
    pub tau_b: f32,
    /// Neighbor decay rate.
    pub tau_n: f32,
    /// A unit is habituated when `h < threshold`.
    pub threshold: f32,
}

impl Default for Habituation {
    fn default() -> Self {
        Self { alpha: 1.05, tau_b: 0.3, tau_n: 0.1, threshold: 0.1 }
    }
}

impl Habituation {
    /// Fixed point of the decay (lowest reachable habituation).
    pub fn floor(&self) -> f32 {
        1.0 - 1.0 / self.alpha
    }

    /// One firing step at rate `tau`; returns the new level.
    #[inline]
    pub fn step(&self, h: f32, tau: f32) -> f32 {
        (h + tau * (self.alpha * (1.0 - h) - 1.0)).max(self.floor())
    }

    #[inline]
    pub fn fire_winner(&self, h: f32) -> f32 {
        self.step(h, self.tau_b)
    }

    #[inline]
    pub fn fire_neighbor(&self, h: f32) -> f32 {
        self.step(h, self.tau_n)
    }

    #[inline]
    pub fn is_habituated(&self, h: f32) -> bool {
        h < self.threshold
    }

    /// Number of winner firings to habituate a fresh unit (used by tests
    /// and to sanity-check parameter presets).
    pub fn firings_to_habituate(&self) -> u32 {
        let mut h = 1.0f32;
        for k in 0..10_000 {
            if self.is_habituated(h) {
                return k;
            }
            h = self.fire_winner(h);
        }
        u32::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decays_monotonically_to_floor() {
        let hab = Habituation::default();
        let mut h = 1.0f32;
        let mut prev = h;
        for _ in 0..200 {
            h = hab.fire_winner(h);
            assert!(h <= prev);
            prev = h;
        }
        assert!((h - hab.floor()).abs() < 1e-3);
    }

    #[test]
    fn floor_below_threshold() {
        // Habituation must be *reachable*: the fixed point lies below the
        // habituated threshold.
        let hab = Habituation::default();
        assert!(hab.floor() < hab.threshold);
    }

    #[test]
    fn winner_habituates_faster_than_neighbor() {
        let hab = Habituation::default();
        let w = hab.fire_winner(1.0);
        let n = hab.fire_neighbor(1.0);
        assert!(w < n);
    }

    #[test]
    fn habituates_in_reasonable_firings() {
        let k = Habituation::default().firings_to_habituate();
        assert!((5..30).contains(&k), "{k} firings");
    }

    #[test]
    fn fresh_unit_not_habituated() {
        let hab = Habituation::default();
        assert!(!hab.is_habituated(1.0));
        assert!(hab.is_habituated(hab.floor() + 1e-4));
    }
}
