//! Parameter sets for the three algorithms.
//!
//! Following the paper's experimental protocol (§3.1): "All the shared input
//! parameters have been set to the same values for all the tests … only the
//! crucial *insertion threshold* has been tuned for each mesh". The presets
//! in `config::presets` do exactly that: one `AdaptParams`/`Habituation` for
//! everything, a per-mesh `insertion_threshold`.

use super::habituation::Habituation;

/// Adaptation-law parameters shared by all algorithms (paper eq. (1)).
#[derive(Clone, Copy, Debug)]
pub struct AdaptParams {
    /// Winner learning rate ε_b (paper: ε_b ≫ ε_i).
    pub eps_b: f32,
    /// Neighbor learning rate ε_n.
    pub eps_n: f32,
    /// Edges older than this are pruned (aging mechanism, paper footnote 3).
    pub max_age: f32,
    /// Scale adaptation by the unit's habituation level (GWR-style): trained
    /// units move less, which stabilizes the final triangulation.
    pub firing_modulation: bool,
}

impl Default for AdaptParams {
    fn default() -> Self {
        Self { eps_b: 0.1, eps_n: 0.01, max_age: 250.0, firing_modulation: true }
    }
}

/// Growing-When-Required (Marsland et al. 2002).
#[derive(Clone, Copy, Debug)]
pub struct GwrParams {
    pub adapt: AdaptParams,
    pub hab: Habituation,
    /// Insert when the winner distance exceeds this and the winner is
    /// habituated.
    pub insertion_threshold: f32,
    pub max_units: usize,
    /// Converged when the quantization-error EMA drops below this.
    pub target_qe: f32,
}

impl Default for GwrParams {
    fn default() -> Self {
        Self {
            adapt: AdaptParams::default(),
            hab: Habituation::default(),
            insertion_threshold: 0.05,
            max_units: 50_000,
            target_qe: 1e-4,
        }
    }
}

/// Growing Neural Gas (Fritzke 1995).
#[derive(Clone, Copy, Debug)]
pub struct GngParams {
    pub adapt: AdaptParams,
    /// Insert a unit every `lambda` signals.
    pub lambda: u64,
    /// Error decay applied to the split units at insertion.
    pub alpha: f32,
    /// Global error decay per signal: every unit's accumulated error is
    /// multiplied by `1 - beta` once per applied signal. Applied *lazily*
    /// (epoch-stamped, materialized on read — see `som::gng` module docs),
    /// bit-identical to the eager per-signal sweep. `0.0` disables decay.
    pub beta: f32,
    pub max_units: usize,
    /// Converged when the quantization-error EMA drops below this.
    pub target_qe: f32,
}

impl Default for GngParams {
    fn default() -> Self {
        Self {
            adapt: AdaptParams::default(),
            lambda: 100,
            alpha: 0.5,
            beta: 0.0005,
            max_units: 50_000,
            target_qe: 1e-4,
        }
    }
}

/// Self-Organizing Adaptive Map (Piastra 2012) — GWR-style growth plus the
/// topological state machine and the LFS-adaptive per-unit threshold.
#[derive(Clone, Copy, Debug)]
pub struct SoamParams {
    pub adapt: AdaptParams,
    pub hab: Habituation,
    /// Initial (global) insertion threshold — the one knob tuned per mesh.
    pub insertion_threshold: f32,
    /// Multiplier applied to a unit's threshold while its link stays
    /// non-manifold in a mature (fully habituated) neighborhood — the
    /// optional LFS-refinement mechanism ("the threshold may vary … to
    /// reflect the local feature size", §2.1). `1.0` disables it — the
    /// DEFAULT, because with uniform dense sampling the calibrated initial
    /// threshold already resolves every feature, and active decay measurably
    /// drives runaway growth (units ∝ 1/threshold²): on the blob preset,
    /// decay 0.97 ⇒ 3,749 units and no convergence in 2M signals; decay
    /// off ⇒ 277 units, converged. See DESIGN.md §4.
    pub threshold_decay: f32,
    /// Per-unit thresholds never drop below
    /// `threshold_floor_frac * insertion_threshold`.
    pub threshold_floor_frac: f32,
    pub max_units: usize,
}

impl Default for SoamParams {
    fn default() -> Self {
        Self {
            adapt: AdaptParams::default(),
            hab: Habituation::default(),
            insertion_threshold: 0.08,
            threshold_decay: 1.0,
            threshold_floor_frac: 0.25,
            max_units: 50_000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let a = AdaptParams::default();
        assert!(a.eps_b > a.eps_n * 5.0, "paper: eps_b >> eps_n");
        let s = SoamParams::default();
        assert!(s.threshold_decay <= 1.0 && s.threshold_decay > 0.5);
        assert!(s.threshold_floor_frac > 0.0 && s.threshold_floor_frac < 1.0);
        let g = GngParams::default();
        assert!(g.alpha < 1.0 && g.beta < 1.0);
    }

    #[test]
    fn habituation_reachable_for_defaults() {
        let s = SoamParams::default();
        assert!(s.hab.firings_to_habituate() < 50);
    }
}
