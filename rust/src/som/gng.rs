//! Growing Neural Gas (Fritzke 1995).
//!
//! Insertion is *scheduled*: every `lambda` signals a unit is inserted
//! between the unit with the largest accumulated error and that unit's
//! worst-error neighbor. Included for framework completeness (the paper
//! discusses GNG as the main prior growing network and the GPU baselines
//! [6], [18] parallelize it) and exercised by the `gng_clustering` example.
//!
//! GNG keeps the default `Structural` classification for every update (see
//! [`super::GrowingNetwork::classify_update`]): its global error decay
//! (`beta`) touches every unit on every signal and its insertion schedule
//! depends on the global signal counter, so no update's effects are
//! confined to the winner's neighborhood. Under the `Parallel` driver GNG
//! therefore runs sequentially — identical to `Multi` by definition.

use crate::geometry::Vec3;
use crate::mesh::SurfaceSampler;
use crate::rng::Rng;

use super::network::{ChangeLog, Network, UnitId};
use super::params::GngParams;
use super::{GrowingNetwork, QeTracker, Winners};

/// GNG algorithm state.
pub struct Gng {
    pub params: GngParams,
    net: Network,
    qe: QeTracker,
    signals_seen: u64,
    orphan_buf: Vec<UnitId>,
}

impl Gng {
    pub fn new(params: GngParams) -> Self {
        Self {
            params,
            net: Network::new(),
            qe: QeTracker::new(0.001),
            signals_seen: 0,
            orphan_buf: Vec::new(),
        }
    }

    /// Scheduled insertion: split the worst edge of the worst unit.
    fn insert_scheduled(&mut self, log: &mut ChangeLog) {
        if self.net.len() >= self.params.max_units {
            return;
        }
        // Unit q with the largest accumulated error.
        let q = match self
            .net
            .ids()
            .max_by(|&a, &b| {
                self.net
                    .unit(a)
                    .error
                    .partial_cmp(&self.net.unit(b).error)
                    .unwrap()
            }) {
            Some(q) => q,
            None => return,
        };
        // Its neighbor f with the largest error.
        let f = match self
            .net
            .edges_of(q)
            .iter()
            .map(|e| e.to)
            .max_by(|&a, &b| {
                self.net
                    .unit(a)
                    .error
                    .partial_cmp(&self.net.unit(b).error)
                    .unwrap()
            }) {
            Some(f) => f,
            None => return,
        };
        let pos = (self.net.pos(q) + self.net.pos(f)) * 0.5;
        let r = self.net.insert(pos, 0.0);
        self.net.disconnect(q, f);
        self.net.connect(q, r);
        self.net.connect(r, f);
        // Decay the split errors; seed the new unit's error.
        let alpha = self.params.alpha;
        self.net.unit_mut(q).error *= alpha;
        self.net.unit_mut(f).error *= alpha;
        let seed_err = (self.net.unit(q).error + self.net.unit(f).error) * 0.5;
        self.net.unit_mut(r).error = seed_err;
        log.inserted.push(r);
    }
}

impl GrowingNetwork for Gng {
    fn name(&self) -> &'static str {
        "gng"
    }

    fn net(&self) -> &Network {
        &self.net
    }

    fn net_mut(&mut self) -> &mut Network {
        &mut self.net
    }

    fn init(&mut self, sampler: &SurfaceSampler, rng: &mut Rng) {
        let a = self.net.insert(sampler.sample(rng), 0.0);
        let b = self.net.insert(sampler.sample(rng), 0.0);
        self.net.connect(a, b);
    }

    fn update(&mut self, signal: Vec3, w: &Winners, log: &mut ChangeLog) {
        if !self.net.is_alive(w.w1) || !self.net.is_alive(w.w2) || w.w1 == w.w2 {
            return;
        }
        self.signals_seen += 1;
        self.qe.push(w.d1_sq);

        // Standard GNG update.
        self.net.age_edges_of(w.w1, 1.0);
        self.net.unit_mut(w.w1).error += w.d1_sq;
        let old = self.net.pos(w.w1);
        let new = old + (signal - old) * self.params.adapt.eps_b;
        self.net.set_pos(w.w1, new);
        log.moved.push((w.w1, old));
        let nbrs: Vec<UnitId> = self.net.edges_of(w.w1).iter().map(|e| e.to).collect();
        for n in nbrs {
            let old_n = self.net.pos(n);
            let new_n = old_n + (signal - old_n) * self.params.adapt.eps_n;
            self.net.set_pos(n, new_n);
            log.moved.push((n, old_n));
        }
        self.net.connect(w.w1, w.w2);

        self.orphan_buf.clear();
        self.net
            .prune_old_edges(w.w1, self.params.adapt.max_age, &mut self.orphan_buf);
        for i in 0..self.orphan_buf.len() {
            let o = self.orphan_buf[i];
            if self.net.is_alive(o) && self.net.degree(o) == 0 && self.net.len() > 2 {
                let pos = self.net.pos(o);
                self.net.remove(o);
                log.removed.push((o, pos));
            }
        }

        // Scheduled insertion + global error decay.
        if self.signals_seen % self.params.lambda == 0 {
            self.insert_scheduled(log);
        }
        let beta = self.params.beta;
        if beta > 0.0 {
            let ids: Vec<UnitId> = self.net.ids().collect();
            for id in ids {
                self.net.unit_mut(id).error *= 1.0 - beta;
            }
        }
    }

    fn housekeeping(&mut self, _log: &mut ChangeLog) -> bool {
        self.qe.value() < self.params.target_qe
    }

    fn quantization_error(&self) -> f32 {
        self.qe.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findwinners::{FindWinners, Scalar};
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    fn run_gng(signals: u64, lambda: u64) -> Gng {
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 24);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(3);
        let mut gng = Gng::new(GngParams { lambda, ..GngParams::default() });
        gng.init(&sampler, &mut rng);
        let mut fw = Scalar::new();
        let mut log = ChangeLog::default();
        for _ in 0..signals {
            let s = sampler.sample(&mut rng);
            let w = fw.find2(gng.net(), s).unwrap();
            log.clear();
            gng.update(s, &w, &mut log);
        }
        gng
    }

    #[test]
    fn grows_on_schedule() {
        let gng = run_gng(2_000, 100);
        // 2 seeds + one insertion per 100 signals (minus any orphan removals).
        assert!(gng.net().len() > 15, "{} units", gng.net().len());
        assert!(gng.net().len() <= 22);
        gng.net().check_invariants().unwrap();
    }

    #[test]
    fn error_accumulates_on_winner() {
        let mut gng = run_gng(50, 1_000_000); // no insertion
        let total_error: f32 = gng.net().ids().map(|i| gng.net().unit(i).error).sum();
        assert!(total_error > 0.0);
        let _ = gng.housekeeping(&mut ChangeLog::default());
    }

    #[test]
    fn qe_improves_with_growth() {
        let early = run_gng(500, 100).quantization_error();
        let late = run_gng(10_000, 100).quantization_error();
        assert!(late < early, "late {late} vs early {early}");
    }
}
