//! Growing Neural Gas (Fritzke 1995).
//!
//! Insertion is *scheduled*: every `lambda` signals a unit is inserted
//! between the unit with the largest accumulated error and that unit's
//! worst-error neighbor. Included for framework completeness (the paper
//! discusses GNG as the main prior growing network and the GPU baselines
//! [6], [18] parallelize it) and exercised by the `gng_clustering` example.
//!
//! ## Lazy multiplicative error decay
//!
//! Fritzke's rule decays **every** unit's accumulated error by `1 - beta`
//! on **every** signal — an `O(N)` sweep that used to classify every GNG
//! update as `Structural` and lock the algorithm out of the executor's
//! parallel plan pass entirely. The sweep is now *lazy*: a global
//! [`Gng::decay_epoch`] counts applied signals, each slab slot carries the
//! epoch its stored error is exact for (`error_epoch`), and reads
//! materialize `error · (1-beta)^(epoch - error_epoch)` through a
//! **repeated-multiply ladder** — one `f32` multiply per elapsed epoch, the
//! exact operation sequence of the eager sweep, so materialized values are
//! **bit-identical** to it (a `powf` would round differently). The ladder
//! short-circuits at multiplicative fixed points (`e·d == e`, reached at
//! `0.0` and in the subnormal tail), and the only full-network
//! materialization sits on an `O(N)`-anyway path: the error `max_by` scan
//! of a scheduled insertion (housekeeping deliberately does *not* sweep —
//! a per-batch sweep would re-accumulate the eager cost). Nothing `O(N)`
//! is left in the per-signal or per-batch path, so `classify_update` can
//! return [`UpdateKind::Adapt`] for non-insertion signals and GNG joins
//! the parallel plan pass like GWR/SOAM. Total multiply count never
//! exceeds eager's: each unit pays exactly its elapsed epochs, in bursts
//! when next read, and the fixed-point exit caps a long-dormant unit's
//! burst at the steps its error needs to underflow to zero.
//!
//! The one global input to classification — does this signal hit the
//! `lambda` insertion schedule? — is resolved through the executor's
//! `pending_commits` argument: deferred adapt signals are guaranteed to
//! commit (each bumping `signals_seen`) before the signal being classified
//! applies, so `signals_seen + pending_commits + 1` is exactly the
//! sequential counter value.

use crate::geometry::Vec3;
use crate::mesh::SurfaceSampler;
use crate::rng::Rng;
use crate::runtime::bytes::{ByteReader, ByteWriter};

use super::network::{ChangeLog, Network, UnitId};
use super::params::GngParams;
use super::{GrowingNetwork, QeTracker, UpdateKind, UpdatePlan, Winners};

/// GNG algorithm state.
pub struct Gng {
    pub params: GngParams,
    net: Network,
    qe: QeTracker,
    signals_seen: u64,
    orphan_buf: Vec<UnitId>,
    /// Global decay epoch: the number of applied signals whose `1 - beta`
    /// decay has been *scheduled* (incremented once per applied signal
    /// while `beta > 0`; never incremented when `beta == 0`).
    decay_epoch: u64,
    /// Per-slab-slot epoch stamp: `units[i].error` is exact as of
    /// `error_epoch[i]`; the pending decays are `decay_epoch -
    /// error_epoch[i]` ladder steps. Slots are (re)stamped on insertion,
    /// so slab reuse never inherits a stale stamp.
    error_epoch: Vec<u64>,
}

impl Gng {
    pub fn new(params: GngParams) -> Self {
        Self {
            params,
            net: Network::new(),
            qe: QeTracker::new(0.001),
            signals_seen: 0,
            orphan_buf: Vec::new(),
            decay_epoch: 0,
            error_epoch: Vec::new(),
        }
    }

    /// Apply `steps` eager decay multiplications to `e` — the exact `f32`
    /// sequence `((e·d)·d)·…` of the per-signal sweep, short-circuited at
    /// multiplicative fixed points (`0.0`, and the subnormal floor where
    /// rounding makes `e·d == e`), where every further step is the
    /// identity bit pattern.
    #[inline]
    fn decay_ladder(mut e: f32, d: f32, mut steps: u64) -> f32 {
        while steps > 0 {
            let next = e * d;
            if next.to_bits() == e.to_bits() {
                return e;
            }
            e = next;
            steps -= 1;
        }
        e
    }

    /// Stamp a (newly inserted) slot as exact at the current epoch.
    fn stamp(&mut self, id: UnitId) {
        let i = id as usize;
        if i >= self.error_epoch.len() {
            self.error_epoch.resize(i + 1, 0);
        }
        self.error_epoch[i] = self.decay_epoch;
    }

    /// Bring one unit's stored error up to the current epoch in place.
    fn materialize(&mut self, id: UnitId) {
        let i = id as usize;
        debug_assert!(i < self.error_epoch.len(), "unstamped slot {id}");
        let steps = self.decay_epoch - self.error_epoch[i];
        if steps > 0 {
            let d = 1.0 - self.params.beta;
            let u = self.net.unit_mut(id);
            u.error = Self::decay_ladder(u.error, d, steps);
            self.error_epoch[i] = self.decay_epoch;
        }
    }

    /// The unit's error as the eager sweep would store it right now —
    /// read-only materialization (used by reporting and the parity tests).
    pub fn materialized_error(&self, id: UnitId) -> f32 {
        let steps = self.decay_epoch - self.error_epoch[id as usize];
        Self::decay_ladder(self.net.unit(id).error, 1.0 - self.params.beta, steps)
    }

    /// Materialize every live unit — only called where an `O(N)` error
    /// scan happens anyway (the insertion `max_by`). Never on a per-batch
    /// cadence: that would re-accumulate the eager sweep's total cost.
    fn materialize_all(&mut self) {
        if self.decay_epoch == 0 {
            return;
        }
        let ids: Vec<UnitId> = self.net.ids().collect();
        for id in ids {
            self.materialize(id);
        }
    }

    /// Scheduled insertion: split the worst edge of the worst unit.
    fn insert_scheduled(&mut self, log: &mut ChangeLog) {
        if self.net.len() >= self.params.max_units {
            return;
        }
        // The error comparisons below must see eager-exact values.
        self.materialize_all();
        // Unit q with the largest accumulated error.
        let q = match self
            .net
            .ids()
            .max_by(|&a, &b| {
                self.net
                    .unit(a)
                    .error
                    .partial_cmp(&self.net.unit(b).error)
                    .unwrap()
            }) {
            Some(q) => q,
            None => return,
        };
        // Its neighbor f with the largest error.
        let f = match self
            .net
            .edges_of(q)
            .iter()
            .map(|e| e.to)
            .max_by(|&a, &b| {
                self.net
                    .unit(a)
                    .error
                    .partial_cmp(&self.net.unit(b).error)
                    .unwrap()
            }) {
            Some(f) => f,
            None => return,
        };
        let pos = (self.net.pos(q) + self.net.pos(f)) * 0.5;
        let r = self.net.insert(pos, 0.0);
        self.stamp(r);
        self.net.disconnect(q, f);
        self.net.connect(q, r);
        self.net.connect(r, f);
        // Decay the split errors; seed the new unit's error.
        let alpha = self.params.alpha;
        self.net.unit_mut(q).error *= alpha;
        self.net.unit_mut(f).error *= alpha;
        let seed_err = (self.net.unit(q).error + self.net.unit(f).error) * 0.5;
        self.net.unit_mut(r).error = seed_err;
        log.inserted.push(r);
    }
}

impl GrowingNetwork for Gng {
    fn name(&self) -> &'static str {
        "gng"
    }

    fn net(&self) -> &Network {
        &self.net
    }

    fn net_mut(&mut self) -> &mut Network {
        &mut self.net
    }

    fn init(&mut self, sampler: &SurfaceSampler, rng: &mut Rng) {
        let a = self.net.insert(sampler.sample(rng), 0.0);
        self.stamp(a);
        let b = self.net.insert(sampler.sample(rng), 0.0);
        self.stamp(b);
        self.net.connect(a, b);
    }

    fn update(&mut self, signal: Vec3, w: &Winners, log: &mut ChangeLog) {
        if !self.net.is_alive(w.w1) || !self.net.is_alive(w.w2) || w.w1 == w.w2 {
            return;
        }
        self.signals_seen += 1;
        self.qe.push(w.d1_sq);

        // Standard GNG update (winner error read-modify-write materializes
        // its pending decays first, so the add lands on the eager value).
        self.net.age_edges_of(w.w1, 1.0);
        self.materialize(w.w1);
        self.net.unit_mut(w.w1).error += w.d1_sq;
        let old = self.net.pos(w.w1);
        let new = old + (signal - old) * self.params.adapt.eps_b;
        self.net.set_pos(w.w1, new);
        log.moved.push((w.w1, old));
        let nbrs: Vec<UnitId> = self.net.edges_of(w.w1).iter().map(|e| e.to).collect();
        for n in nbrs {
            let old_n = self.net.pos(n);
            let new_n = old_n + (signal - old_n) * self.params.adapt.eps_n;
            self.net.set_pos(n, new_n);
            log.moved.push((n, old_n));
        }
        self.net.connect(w.w1, w.w2);

        self.orphan_buf.clear();
        self.net
            .prune_old_edges(w.w1, self.params.adapt.max_age, &mut self.orphan_buf);
        for i in 0..self.orphan_buf.len() {
            let o = self.orphan_buf[i];
            if self.net.is_alive(o) && self.net.degree(o) == 0 && self.net.len() > 2 {
                let pos = self.net.pos(o);
                self.net.remove(o);
                log.removed.push((o, pos));
            }
        }

        // Scheduled insertion + (lazy) global error decay: instead of the
        // eager O(N) sweep, one epoch bump schedules this signal's
        // `1 - beta` factor for every unit.
        if self.signals_seen % self.params.lambda == 0 {
            self.insert_scheduled(log);
        }
        if self.params.beta > 0.0 {
            self.decay_epoch += 1;
        }
    }

    fn housekeeping(&mut self, _log: &mut ChangeLog) -> bool {
        // Deliberately does NOT materialize errors: the multi-signal
        // drivers call housekeeping once per batch, so a sweep here would
        // redo the eager per-signal sweep's total multiply count and undo
        // the lazy scheme's win. Nothing below needs errors (the
        // convergence test reads only the QE EMA); external readers use
        // `materialized_error`, and the insertion scan materializes on its
        // own O(N) path.
        self.qe.value() < self.params.target_qe
    }

    fn quantization_error(&self) -> f32 {
        self.qe.value()
    }

    fn classify_update(&self, _signal: Vec3, w: &Winners, pending_commits: usize) -> UpdateKind {
        if !self.net.is_alive(w.w1) || !self.net.is_alive(w.w2) || w.w1 == w.w2 {
            // Degenerate (stale winners): let `update` discard it inline.
            return UpdateKind::Structural;
        }
        // Insertion schedule: the deferred adapts commit (and count) before
        // this signal applies, so it will be applied signal number
        // `signals_seen + pending_commits + 1`. GNG never classifies
        // `Insert`: its scheduled insertion reads *global* state (the
        // error max_by scan), so it cannot be confined to a winner
        // neighborhood and always runs inline.
        if (self.signals_seen + pending_commits as u64 + 1) % self.params.lambda == 0 {
            return UpdateKind::Structural;
        }
        // Prune prediction: `update` ages every edge of w1 by 1.0 and then
        // drops edges older than max_age; the w1–w2 edge is exempt (connect
        // resets it to age 0 first). Same float expression as the prune.
        let will_prune = self
            .net
            .edges_of(w.w1)
            .iter()
            .any(|e| e.to != w.w2 && e.age + 1.0 > self.params.adapt.max_age);
        if will_prune {
            UpdateKind::Structural
        } else {
            UpdateKind::Adapt
        }
    }

    fn plan_update(&self, signal: Vec3, w: &Winners, plan: &mut UpdatePlan) {
        plan.clear();
        plan.w1 = w.w1;
        plan.w2 = w.w2;
        plan.d1_sq = w.d1_sq;
        // Winner first, then the *pre-connect* neighbors — GNG connects
        // w1–w2 after adaptation, so (unlike GWR) a fresh w2 does not move
        // on the signal that creates its edge.
        let old = self.net.pos(w.w1);
        plan.moves
            .push((w.w1, old + (signal - old) * self.params.adapt.eps_b));
        for e in self.net.edges_of(w.w1) {
            let old_n = self.net.pos(e.to);
            plan.moves
                .push((e.to, old_n + (signal - old_n) * self.params.adapt.eps_n));
        }
        // No firing writes: GNG has no habituation.
    }

    fn commit_scalars(&mut self, plan: &UpdatePlan, _log: &mut ChangeLog) {
        self.signals_seen += 1;
        debug_assert!(
            self.signals_seen % self.params.lambda != 0,
            "classified Adapt on an insertion-schedule signal"
        );
        self.qe.push(plan.d1_sq);
        self.materialize(plan.w1);
        self.net.unit_mut(plan.w1).error += plan.d1_sq;
        if self.params.beta > 0.0 {
            self.decay_epoch += 1;
        }
    }

    fn save_state(&self, w: &mut ByteWriter) {
        w.str("gng");
        let (ema, samples) = self.qe.raw();
        w.f32(ema);
        w.u64(samples);
        w.u64(self.signals_seen);
        // The lazy-decay state: stored errors are only meaningful together
        // with their epoch stamps (error · (1-beta)^(epoch - stamp)), so
        // both travel — materializing before saving would change WHEN each
        // unit's ladder runs and thus the bits of later reads.
        w.u64(self.decay_epoch);
        w.u32(self.error_epoch.len() as u32);
        for &e in &self.error_epoch {
            w.u64(e);
        }
        self.net.write_state(w);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let tag = r.str().map_err(|e| e.to_string())?;
        if tag != "gng" {
            return Err(format!("snapshot algorithm {tag:?} is not gng"));
        }
        let ema = r.f32().map_err(|e| e.to_string())?;
        let samples = r.u64().map_err(|e| e.to_string())?;
        self.qe.restore(ema, samples);
        self.signals_seen = r.u64().map_err(|e| e.to_string())?;
        self.decay_epoch = r.u64().map_err(|e| e.to_string())?;
        let n = r.len_prefix(8).map_err(|e| e.to_string())?;
        self.error_epoch.clear();
        for _ in 0..n {
            let e = r.u64().map_err(|e| e.to_string())?;
            if e > self.decay_epoch {
                return Err(format!("error epoch {e} beyond decay epoch {}", self.decay_epoch));
            }
            self.error_epoch.push(e);
        }
        self.net = Network::read_state(r)?;
        for id in self.net.ids() {
            if id as usize >= self.error_epoch.len() {
                return Err(format!("live unit {id} has no error-epoch stamp"));
            }
        }
        self.orphan_buf.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findwinners::{FindWinners, Scalar};
    use crate::mesh::{benchmark_mesh, BenchmarkShape};
    use crate::proptest::{sized_usize, Prop};

    fn run_gng(signals: u64, lambda: u64) -> Gng {
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 24);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(3);
        let mut gng = Gng::new(GngParams { lambda, ..GngParams::default() });
        gng.init(&sampler, &mut rng);
        let mut fw = Scalar::new();
        let mut log = ChangeLog::default();
        for _ in 0..signals {
            let s = sampler.sample(&mut rng);
            let w = fw.find2(gng.net(), s).unwrap();
            log.clear();
            gng.update(s, &w, &mut log);
        }
        gng
    }

    #[test]
    fn grows_on_schedule() {
        let gng = run_gng(2_000, 100);
        // 2 seeds + one insertion per 100 signals (minus any orphan removals).
        assert!(gng.net().len() > 15, "{} units", gng.net().len());
        assert!(gng.net().len() <= 22);
        gng.net().check_invariants().unwrap();
    }

    #[test]
    fn error_accumulates_on_winner() {
        let mut gng = run_gng(50, 1_000_000); // no insertion
        let total_error: f32 = gng.net().ids().map(|i| gng.materialized_error(i)).sum();
        assert!(total_error > 0.0);
        // Housekeeping must NOT sweep the errors (that would re-accumulate
        // the eager cost per batch) — the lazy state is untouched by it.
        let epoch_before = gng.decay_epoch;
        let _ = gng.housekeeping(&mut ChangeLog::default());
        assert_eq!(gng.decay_epoch, epoch_before);
        let after: f32 = gng.net().ids().map(|i| gng.materialized_error(i)).sum();
        assert_eq!(after.to_bits(), total_error.to_bits());
    }

    #[test]
    fn qe_improves_with_growth() {
        let early = run_gng(500, 100).quantization_error();
        let late = run_gng(10_000, 100).quantization_error();
        assert!(late < early, "late {late} vs early {early}");
    }

    #[test]
    fn decay_ladder_fixed_points_terminate() {
        // 0.0 is a fixed point; huge step counts must return immediately
        // with the identical bit pattern.
        assert_eq!(Gng::decay_ladder(0.0, 0.9995, u64::MAX).to_bits(), 0.0f32.to_bits());
        // The subnormal floor is reached and then held exactly.
        let tiny = Gng::decay_ladder(1.0, 0.5, 200);
        assert_eq!(tiny.to_bits(), 0.0f32.to_bits(), "1.0 · 0.5^200 underflows to zero");
        // Finite ladders match the literal loop.
        let mut e = 0.7f32;
        for _ in 0..13 {
            e *= 0.9995;
        }
        assert_eq!(Gng::decay_ladder(0.7, 0.9995, 13).to_bits(), e.to_bits());
    }

    /// The pre-refactor update rule, verbatim — kept as the executable
    /// specification of the eager per-signal sweep. The only difference
    /// from [`Gng::update`] is the trailing decay: the eager twin keeps
    /// `decay_epoch` at 0 forever (so every materialization inside the
    /// shared helpers is a no-op on it) and multiplies every unit's stored
    /// error by `1 - beta` inline.
    fn eager_update(g: &mut Gng, signal: Vec3, w: &Winners, log: &mut ChangeLog) {
        if !g.net.is_alive(w.w1) || !g.net.is_alive(w.w2) || w.w1 == w.w2 {
            return;
        }
        g.signals_seen += 1;
        g.qe.push(w.d1_sq);

        g.net.age_edges_of(w.w1, 1.0);
        g.net.unit_mut(w.w1).error += w.d1_sq;
        let old = g.net.pos(w.w1);
        let new = old + (signal - old) * g.params.adapt.eps_b;
        g.net.set_pos(w.w1, new);
        log.moved.push((w.w1, old));
        let nbrs: Vec<UnitId> = g.net.edges_of(w.w1).iter().map(|e| e.to).collect();
        for n in nbrs {
            let old_n = g.net.pos(n);
            let new_n = old_n + (signal - old_n) * g.params.adapt.eps_n;
            g.net.set_pos(n, new_n);
            log.moved.push((n, old_n));
        }
        g.net.connect(w.w1, w.w2);

        g.orphan_buf.clear();
        g.net
            .prune_old_edges(w.w1, g.params.adapt.max_age, &mut g.orphan_buf);
        for i in 0..g.orphan_buf.len() {
            let o = g.orphan_buf[i];
            if g.net.is_alive(o) && g.net.degree(o) == 0 && g.net.len() > 2 {
                let pos = g.net.pos(o);
                g.net.remove(o);
                log.removed.push((o, pos));
            }
        }

        if g.signals_seen % g.params.lambda == 0 {
            g.insert_scheduled(log);
        }
        let beta = g.params.beta;
        if beta > 0.0 {
            let ids: Vec<UnitId> = g.net.ids().collect();
            for id in ids {
                g.net.unit_mut(id).error *= 1.0 - beta;
            }
        }
    }

    /// Property: across random signal counts, betas, insertion schedules
    /// (slab-slot reuse through orphan removal, insertions that reset unit
    /// error), the lazy materialization is bit-identical to the eager
    /// per-signal sweep — on every unit, at every probe point.
    #[test]
    fn prop_lazy_decay_matches_eager_sweep_bitwise() {
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 16);
        let sampler = SurfaceSampler::new(&mesh);
        Prop::new(20, 31).run(
            |rng, size| {
                let steps = sized_usize(rng, size, 50, 2_500);
                let lambda = sized_usize(rng, size, 5, 400) as u64;
                // Include beta = 0 (decay disabled) and aggressive decay.
                let beta = match rng.below(4) {
                    0 => 0.0,
                    1 => 0.01,
                    2 => 0.0005,
                    _ => 0.1,
                };
                (rng.next_u64(), steps, lambda, beta)
            },
            |&(seed, steps, lambda, beta)| {
                let params = GngParams {
                    lambda,
                    beta,
                    // Tight max_age provokes prunes → orphan removals →
                    // slab-slot reuse by later insertions.
                    adapt: crate::som::AdaptParams {
                        max_age: 40.0,
                        ..crate::som::AdaptParams::default()
                    },
                    ..GngParams::default()
                };
                let mut lazy = Gng::new(params);
                let mut eager = Gng::new(params);
                let mut rng_a = Rng::seed_from(seed);
                let mut rng_b = Rng::seed_from(seed);
                lazy.init(&sampler, &mut rng_a);
                eager.init(&sampler, &mut rng_b);
                let mut fw = Scalar::new();
                let mut log = ChangeLog::default();
                for k in 0..steps {
                    let s = sampler.sample(&mut rng_a);
                    let s_b = sampler.sample(&mut rng_b);
                    assert_eq!(s, s_b, "sampler streams diverged");
                    // Winners from the lazy net; identical nets ⇒ identical
                    // winners (checked below).
                    let w = fw.find2(lazy.net(), s).unwrap();
                    log.clear();
                    lazy.update(s, &w, &mut log);
                    log.clear();
                    eager_update(&mut eager, s, &w, &mut log);
                    if k % 97 == 0 || k + 1 == steps {
                        compare(&lazy, &eager).map_err(|e| format!("after {k}: {e}"))?;
                    }
                }
                // Final bit-exactness on every unit (also covered at the
                // probes above, incl. the k + 1 == steps probe).
                compare(&lazy, &eager).map_err(|e| format!("final: {e}"))?;
                lazy.net().check_invariants()?;
                Ok(())
            },
        );

        fn compare(lazy: &Gng, eager: &Gng) -> Result<(), String> {
            if lazy.net().capacity() != eager.net().capacity() {
                return Err(format!(
                    "slab divergence: {} vs {}",
                    lazy.net().capacity(),
                    eager.net().capacity()
                ));
            }
            if lazy.signals_seen != eager.signals_seen {
                return Err("signal counters diverged".into());
            }
            for id in 0..lazy.net().capacity() as UnitId {
                if lazy.net().is_alive(id) != eager.net().is_alive(id) {
                    return Err(format!("aliveness of {id} diverged"));
                }
                if !lazy.net().is_alive(id) {
                    continue;
                }
                let (a, b) = (lazy.materialized_error(id), eager.net().unit(id).error);
                if a.to_bits() != b.to_bits() {
                    return Err(format!("materialized error of {id}: {a:e} vs {b:e}"));
                }
                let (pa, pb) = (lazy.net().pos(id), eager.net().pos(id));
                if pa != pb {
                    return Err(format!("position of {id} diverged"));
                }
            }
            Ok(())
        }
    }

    #[test]
    fn classify_agrees_with_update_for_gng() {
        // Adapt-classified signals must produce structure-free updates and
        // never land on the insertion schedule.
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(11);
        let mut gng = Gng::new(GngParams { lambda: 50, ..GngParams::default() });
        gng.init(&sampler, &mut rng);
        let mut fw = Scalar::new();
        let mut log = ChangeLog::default();
        let (mut adapt_seen, mut structural_seen) = (0u32, 0u32);
        for _ in 0..20_000 {
            let s = sampler.sample(&mut rng);
            let Some(w) = fw.find2(gng.net(), s) else { continue };
            let kind = gng.classify_update(s, &w, 0);
            log.clear();
            gng.update(s, &w, &mut log);
            match kind {
                UpdateKind::Adapt => {
                    adapt_seen += 1;
                    assert!(
                        log.inserted.is_empty() && log.removed.is_empty(),
                        "Adapt-classified GNG update changed structure"
                    );
                }
                UpdateKind::Insert => {
                    panic!("GNG must never classify Insert (global insertion scan)")
                }
                UpdateKind::Structural => structural_seen += 1,
            }
        }
        assert!(adapt_seen > 0, "GNG never classified Adapt");
        assert!(structural_seen > 0, "GNG never classified Structural");
        // With lambda = 50, roughly 1 in 50 applied signals is structural —
        // the vast majority must now be plannable off-thread.
        assert!(
            adapt_seen > structural_seen * 10,
            "adapt {adapt_seen} vs structural {structural_seen}"
        );
    }
}
