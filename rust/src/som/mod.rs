//! Growing self-organizing networks: the shared store, the spatial region
//! partition, the three algorithms (GNG, GWR, SOAM) and the update-rule
//! trait the drivers run against.
//!
//! The split mirrors the paper's §2.1: a growing network is the *basic
//! iteration* `Sample → Find Winners → Update` where Sample and Find Winners
//! are algorithm-independent (they live in [`crate::engine`] /
//! [`crate::findwinners`]) and Update is the algorithm: aging + competitive
//! Hebbian edges + adaptation + insertion/removal, `O(1)` per signal.
//!
//! ## Region topology (what is per-region, what stays global)
//!
//! [`regions`] partitions the bounding volume into spatial cells. The
//! partition is an *overlay*, never a source of truth:
//!
//! - **per-region**: the alive-unit rosters ([`regions::RegionGrid`]) that
//!   let Find Winners scan only a signal's 3×3×3 cell neighborhood, and the
//!   executor's conflict domains (signals whose touched regions are
//!   disjoint flow through plan *and* structural commit concurrently);
//! - **global**: the slab itself (unit ids, the sharded free lists and
//!   their LIFO allocation order), the adjacency, the SoA mirrors, every
//!   shared scalar (edge count, QE, GNG error/epoch) and the sequential
//!   scalar replay — the bit-parity spine that keeps `regions = R` results
//!   identical to `regions = 1` for every `R`.

mod gng;
mod gwr;
pub mod habituation;
mod network;
mod params;
pub mod regions;
mod soam;

pub use gng::Gng;
pub use gwr::Gwr;
pub use habituation::Habituation;
pub use network::{
    ChangeLog, Edge, Network, ShardWriter, Unit, UnitId, DEAD_POS, FREE_SHARDS, SOA_LANES,
};
pub use params::{AdaptParams, GngParams, GwrParams, SoamParams};
pub use regions::{RegionGrid, RegionMap};
pub use soam::{Soam, SoamState};

use crate::geometry::Vec3;
use crate::mesh::SurfaceSampler;
use crate::rng::Rng;
use crate::runtime::bytes::{ByteReader, ByteWriter};

/// Result of the Find Winners phase for one signal: the two nearest units
/// and their *squared* distances (squared to stay bit-compatible with the
/// L1 kernel; take `sqrt` only where the algorithm needs a length).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Winners {
    pub w1: UnitId,
    pub w2: UnitId,
    pub d1_sq: f32,
    pub d2_sq: f32,
}

/// Predicted effect class of one update, as reported by
/// [`GrowingNetwork::classify_update`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    /// Pure adaptation: position / firing / edge-age bookkeeping confined
    /// to `{w1, w2} ∪ N(w1)`; provably no unit insertion, no unit removal,
    /// no edge pruning. Safe to plan off-thread and commit later.
    Adapt,
    /// Provably **insertion-only** structural update: exactly one new unit
    /// is created, every other effect (edge aging, the Hebbian
    /// connect/disconnect) stays inside `{w1, w2, new unit} ∪ N(w1)`, and
    /// the post-insert prune is a no-op. The executor's region schedule
    /// splits such updates into a *sequential allocation* at admission
    /// ([`GrowingNetwork::begin_insert`] — slab ids keep their global LIFO
    /// order) and a *deferred edge commit* that runs concurrently with
    /// other touched-disjoint plans. Without a region map attached the
    /// executor treats this exactly like [`UpdateKind::Structural`].
    Insert,
    /// May insert or remove units or prune edges — or the algorithm cannot
    /// cheaply prove it won't. Must run inline on the driver thread (the
    /// conservative default).
    Structural,
}

/// What a deferred [`UpdatePlan`] commits as: a pure adaptation
/// ([`ShardWriter::commit_adapt`]) or the edge half of an insertion-only
/// update ([`ShardWriter::commit_insert`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlanKind {
    #[default]
    Adapt,
    Insert,
}

/// A deferred update: the pure-function half of the deferred-commit split
/// used by the `Parallel` driver. `Adapt` plans are produced off-thread by
/// [`GrowingNetwork::plan_update`]; `Insert` plans are produced on the
/// driver thread by [`GrowingNetwork::begin_insert`] (which also performs
/// the sequential unit allocation). Either way the network writes are
/// applied (possibly concurrently, touched-sets disjoint) by
/// [`ShardWriter::commit_adapt`] / [`ShardWriter::commit_insert`], and the
/// shared-scalar residue is replayed in admission order by
/// [`GrowingNetwork::commit_scalars`]. Buffers are reused across signals.
#[derive(Clone, Debug, Default)]
pub struct UpdatePlan {
    /// Which commit routine applies this plan's network writes.
    pub kind: PlanKind,
    pub w1: UnitId,
    pub w2: UnitId,
    pub d1_sq: f32,
    /// `(unit, new position)` in the exact order `update` would move them
    /// (winner first, then the winner's neighbors in adjacency order).
    pub moves: Vec<(UnitId, Vec3)>,
    /// `(unit, new firing level)`, winner last — mirrors `update`.
    pub firing: Vec<(UnitId, f32)>,
    /// Pre-move positions, one per entry of `moves` in the same order —
    /// filled by [`ShardWriter::commit_adapt`] so the sequential replay can
    /// emit the change-log entries without re-reading racing state.
    pub old_pos: Vec<Vec3>,
    /// [`PlanKind::Insert`] only: the slab slot allocated (sequentially, at
    /// admission) by [`GrowingNetwork::begin_insert`] — the deferred commit
    /// wires its edges, the replay logs it as inserted.
    pub new_unit: UnitId,
    /// Undirected edges the commit created — filled by
    /// `commit_adapt`/`commit_insert`, folded into the shared edge counter
    /// during the sequential replay.
    pub new_edges: u32,
    /// Undirected edges the commit removed (the insertion path's
    /// `w1`–`w2` disconnect) — replayed like `new_edges`.
    pub removed_edges: u32,
}

impl UpdatePlan {
    pub fn clear(&mut self) {
        self.kind = PlanKind::Adapt;
        self.w1 = 0;
        self.w2 = 0;
        self.d1_sq = 0.0;
        self.moves.clear();
        self.firing.clear();
        self.old_pos.clear();
        self.new_unit = 0;
        self.new_edges = 0;
        self.removed_edges = 0;
    }
}

/// The Update phase of a growing self-organizing network.
///
/// Implementations must treat `update` as *the single-signal update rule*:
/// the multi-signal driver reproduces the paper's semantics by calling it
/// sequentially under the winner-lock discipline (DESIGN.md §4), so any
/// state an implementation keeps must be valid under interleaved signals.
///
/// The `Send + Sync` bound exists for the `Parallel` driver, which shares
/// `&self` across worker threads during the read-only plan pass; every
/// implementation here is plain data, so the bound is free.
pub trait GrowingNetwork: Send + Sync {
    /// Algorithm name, as printed in reports.
    fn name(&self) -> &'static str;

    fn net(&self) -> &Network;

    fn net_mut(&mut self) -> &mut Network;

    /// Seed the network (usually two units at sampled positions).
    fn init(&mut self, sampler: &SurfaceSampler, rng: &mut Rng);

    /// Apply the update rule for one signal whose winners were already
    /// found. `log` receives every structural change (for spatial-index
    /// maintenance); implementations must append, not clear.
    ///
    /// `winners` may be stale under multi-signal batching (computed before
    /// earlier signals of the same batch were applied); implementations
    /// must ignore signals whose winners died (`Network::is_alive`).
    fn update(&mut self, signal: Vec3, winners: &Winners, log: &mut ChangeLog);

    /// Periodic housekeeping + convergence test (called every
    /// `check_interval` signals by the drivers, NOT once per signal — the
    /// scan is `O(N)`). Structural changes (e.g. SOAM's removal of
    /// persistently under-connected units) are appended to `log` so spatial
    /// indexes can follow. Returns `true` when the algorithm's termination
    /// criterion is met.
    fn housekeeping(&mut self, log: &mut ChangeLog) -> bool;

    /// Running quantization error (EMA of the squared winner distance) —
    /// the convergence measure of GNG/GWR and a reported metric for SOAM.
    fn quantization_error(&self) -> f32;

    /// Read-only prediction of what `update` would do for this signal in
    /// the *current* state. Returning [`UpdateKind::Adapt`] is a promise
    /// that `update` would neither insert nor remove units nor prune edges
    /// and that every read and write stays inside `{w1, w2} ∪ N(w1)` plus
    /// the algorithm's own per-signal scalars — the `Parallel` driver
    /// relies on it to plan such updates off-thread.
    ///
    /// `pending_commits` is the number of already-admitted `Adapt` signals
    /// the executor has deferred but not yet committed; they are guaranteed
    /// to commit (in admission order) before this signal applies.
    /// Algorithms whose classification depends on a global signal counter
    /// (GNG's `lambda` insertion schedule) must classify against
    /// `signals_seen + pending_commits`; neighborhood-local rules ignore
    /// it.
    ///
    /// Default: [`UpdateKind::Structural`], which is always safe (the
    /// driver then degenerates to the sequential `Multi` semantics).
    fn classify_update(&self, _signal: Vec3, _w: &Winners, _pending_commits: usize) -> UpdateKind {
        UpdateKind::Structural
    }

    /// Compute the effect of an `Adapt`-class update without mutating
    /// anything. Called (possibly from a worker thread) only after
    /// [`Self::classify_update`] returned `Adapt` and only while the
    /// touched units are guaranteed unchanged since classification.
    fn plan_update(&self, _signal: Vec3, _w: &Winners, _plan: &mut UpdatePlan) {
        unreachable!("plan_update on an algorithm that never classifies Adapt");
    }

    /// Apply the *sequential half* of an [`UpdateKind::Insert`]-class
    /// update now — allocate the new unit (slab ids must be assigned in
    /// admission order, so this runs on the driver thread at the signal's
    /// exact position in the permutation) — and fill `plan` with the
    /// deferrable edge work ([`PlanKind::Insert`]). Called only after
    /// [`Self::classify_update`] returned `Insert`, with no deferred plan
    /// touching `{w1, w2} ∪ N(w1)` (the executor flushes first), so every
    /// value read here equals the sequential driver's.
    ///
    /// The network writes left to the deferred commit
    /// ([`ShardWriter::commit_insert`]): edge aging on the winner, the
    /// net effect of the Hebbian connect + insertion-path disconnect of
    /// `w1`–`w2`, and the new unit's two edges. The shared-scalar residue
    /// (QE) stays in [`Self::commit_scalars`], and the executor replays
    /// the change-log entry and the edge-count deltas in admission order.
    fn begin_insert(&mut self, _signal: Vec3, _w: &Winners, _plan: &mut UpdatePlan) {
        unreachable!("begin_insert on an algorithm that never classifies Insert");
    }

    /// Replay the shared-scalar residue of a committed plan, in admission
    /// order on the driver thread. The network writes were already applied
    /// by [`ShardWriter::commit_adapt`] / [`ShardWriter::commit_insert`]
    /// (possibly on a worker thread) and
    /// the change-log/edge-count replay is the executor's; what remains is
    /// the algorithm's own per-signal state — the QE stream, and for GNG
    /// the signal counter, the winner's lazily-decayed error and the decay
    /// epoch. Together the three steps must leave everything bit-identical
    /// to having called `update` directly at this point in the signal
    /// order.
    fn commit_scalars(&mut self, _plan: &UpdatePlan, _log: &mut ChangeLog) {
        unreachable!("commit_scalars on an algorithm that never classifies Adapt");
    }

    /// Serialize the algorithm's **complete** state — the network slab
    /// (via [`Network::write_state`]) plus every per-algorithm scalar a
    /// later update reads (QE tracker, counters, GNG's decay epochs,
    /// SOAM's strike tables) — for the fleet snapshot format
    /// (`fleet::snapshot`). The contract is bit-exactness: restoring into
    /// a freshly constructed instance (same params) and continuing must be
    /// bit-identical to never having stopped.
    fn save_state(&self, w: &mut ByteWriter);

    /// Restore [`Self::save_state`] bytes into `self` (freshly constructed
    /// with the same parameters). Transient per-update buffers need not
    /// round-trip — they are empty at every batch boundary, the only
    /// points snapshots are taken at. Returns `Err` on any structural or
    /// tag mismatch; `self` may be left partially overwritten then (the
    /// caller discards it).
    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String>;
}

/// Shared helper: exponential moving average of the quantization error.
#[derive(Clone, Copy, Debug)]
pub(crate) struct QeTracker {
    ema: f32,
    beta: f32,
    samples: u64,
}

impl QeTracker {
    pub fn new(beta: f32) -> Self {
        Self { ema: f32::INFINITY, beta, samples: 0 }
    }

    #[inline]
    pub fn push(&mut self, d_sq: f32) {
        self.samples += 1;
        if self.ema.is_infinite() {
            self.ema = d_sq;
        } else {
            self.ema += self.beta * (d_sq - self.ema);
        }
    }

    pub fn value(&self) -> f32 {
        self.ema
    }

    #[allow(dead_code)]
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Snapshot the mutable half `(ema, samples)` — `beta` is a construction
    /// parameter and comes back from the restored instance's own config.
    pub fn raw(&self) -> (f32, u64) {
        (self.ema, self.samples)
    }

    /// Restore [`Self::raw`] state bit-exactly.
    pub fn restore(&mut self, ema: f32, samples: u64) {
        self.ema = ema;
        self.samples = samples;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qe_tracker_converges_to_constant() {
        let mut q = QeTracker::new(0.05);
        for _ in 0..500 {
            q.push(2.0);
        }
        assert!((q.value() - 2.0).abs() < 1e-3);
        assert_eq!(q.samples(), 500);
    }

    #[test]
    fn qe_tracker_tracks_drop() {
        let mut q = QeTracker::new(0.1);
        for _ in 0..100 {
            q.push(10.0);
        }
        for _ in 0..200 {
            q.push(1.0);
        }
        assert!(q.value() < 1.1);
    }
}
