//! The network store shared by all growing self-organizing algorithms.
//!
//! Units live in a slab with sharded free lists so unit ids stay stable
//! across removals (ids are what the winner-lock table, the hash index and
//! the AOT batch buffers key on). Adjacency is a per-unit edge vector with
//! ages — growing networks create, reset, age and destroy edges constantly,
//! and the neighbor sets are small (≈6 on a 2-manifold), so linear scans
//! beat hash sets here.
//!
//! Two concurrency seams live here for the batch-update executor:
//!
//! - the free list is split into [`FREE_SHARDS`] per-shard stacks keyed by
//!   `slot % FREE_SHARDS`, each entry stamped with a global free counter;
//!   allocation pops the globally most-recent stamp, which reproduces the
//!   old single-stack LIFO order *exactly* (same unit ids for any caller),
//!   while giving conflict-disjoint commit groups distinct stacks to drain
//!   once structural commits move off the driver thread;
//! - [`ShardWriter`] is the raw-access view the executor's concurrent
//!   commit pass writes through: workers apply touched-disjoint
//!   [`super::UpdatePlan`]s (positions, firing, edge ages, the competitive
//!   Hebbian connect) in parallel, deferring every shared scalar (edge
//!   count, QE, GNG error/epoch) to the sequential replay.

use crate::geometry::Vec3;
use crate::runtime::bytes::{ByteReader, ByteWriter};
use crate::topology::{classify_link, LinkClass};

use super::UpdatePlan;

/// Stable unit identifier (slab slot).
pub type UnitId = u32;

/// One unit of the network.
#[derive(Clone, Copy, Debug)]
pub struct Unit {
    /// Reference vector in input space.
    pub pos: Vec3,
    /// Habituation / firing counter: 1 = fresh, decays toward ~0 as the
    /// unit wins (see [`super::habituation`]).
    pub firing: f32,
    /// GNG-style accumulated quantization error.
    pub error: f32,
    /// SOAM per-unit insertion threshold (tracks local feature size).
    pub threshold: f32,
    pub alive: bool,
}

/// One directed half of an undirected aged edge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub to: UnitId,
    pub age: f32,
}

/// What an update did to the network — consumed by spatial-index
/// maintenance and by the metrics layer. Reused across calls.
#[derive(Clone, Debug, Default)]
pub struct ChangeLog {
    pub moved: Vec<(UnitId, Vec3)>, // (id, old position)
    pub inserted: Vec<UnitId>,
    pub removed: Vec<(UnitId, Vec3)>, // (id, last position)
}

impl ChangeLog {
    pub fn clear(&mut self) {
        self.moved.clear();
        self.inserted.clear();
        self.removed.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.moved.is_empty() && self.inserted.is_empty() && self.removed.is_empty()
    }
}

/// Padding sentinel mirrored from the AOT contract: dead slots in the dense
/// position array hold this value, so their squared distances overflow to
/// `+inf` and they can never win a Find-Winners scan.
pub const DEAD_POS: Vec3 = Vec3 { x: 1e30, y: 1e30, z: 1e30 };

/// Lane width of the structure-of-arrays position mirror. The SoA arrays
/// are always padded to a multiple of this, poisoned with [`DEAD_POS`], so
/// every Find Winners block kernel scans whole blocks with no scalar tail:
/// the portable lane kernel (`findwinners::lanes`, fixed
/// `LANES = SOA_LANES`) *and* every explicit-SIMD dispatch tier
/// (`findwinners::simd` — widths 4/8/16 all divide this). 16 f32 lanes =
/// one AVX-512 register, the widest dispatched kernel; on narrower hosts
/// LLVM simply unrolls.
pub const SOA_LANES: usize = 16;

/// Number of free-list shards. A freed slot always lands in shard
/// `slot % FREE_SHARDS`, so membership is a pure function of the id —
/// deterministic no matter which thread (or commit group) frees it.
pub const FREE_SHARDS: usize = 8;

/// One freed slab slot: the slot id plus the global free-order stamp that
/// lets allocation reproduce the single-stack LIFO order across shards.
#[derive(Clone, Copy, Debug)]
struct FreeSlot {
    slot: UnitId,
    stamp: u64,
}

/// Slab-allocated unit graph.
#[derive(Clone, Debug)]
pub struct Network {
    units: Vec<Unit>,
    adjacency: Vec<Vec<Edge>>,
    /// Sharded free lists (see module docs): `free_shards[s]` holds freed
    /// slots with `slot % FREE_SHARDS == s`, each a stack in free order.
    free_shards: Vec<Vec<FreeSlot>>,
    /// Monotone stamp source for [`FreeSlot::stamp`].
    free_stamp: u64,
    alive: usize,
    edges: usize,
    /// Dense position mirror (one row per slab slot, dead slots = DEAD_POS).
    /// This is the hot-path view: the exhaustive/batched Find-Winners scans
    /// walk this 12-byte-stride array instead of the 28-byte `Unit` slab
    /// (~1.6× on the memory-bound scan), and `fill_positions` for the PJRT
    /// marshalling is a straight copy of it.
    positions: Vec<Vec3>,
    /// Structure-of-arrays mirror of `positions` for the lane-blocked
    /// Find-Winners kernel: one coordinate stream per axis, padded to a
    /// multiple of [`SOA_LANES`], dead and padding slots poisoned with the
    /// [`DEAD_POS`] coordinates so their distances overflow to `+inf`.
    xs: Vec<f32>,
    ys: Vec<f32>,
    zs: Vec<f32>,
}

impl Default for Network {
    fn default() -> Self {
        Self {
            units: Vec::new(),
            adjacency: Vec::new(),
            free_shards: vec![Vec::new(); FREE_SHARDS],
            free_stamp: 0,
            alive: 0,
            edges: 0,
            positions: Vec::new(),
            xs: Vec::new(),
            ys: Vec::new(),
            zs: Vec::new(),
        }
    }
}

impl Network {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live units.
    #[inline]
    pub fn len(&self) -> usize {
        self.alive
    }

    pub fn is_empty(&self) -> bool {
        self.alive == 0
    }

    /// Number of undirected edges ("connections" in the paper's tables).
    #[inline]
    pub fn edge_count(&self) -> usize {
        self.edges
    }

    /// Slab high-water mark: ids are always `< capacity()`. This is the `n`
    /// the batched Find-Winners pads to.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.units.len()
    }

    #[inline]
    pub fn is_alive(&self, id: UnitId) -> bool {
        (id as usize) < self.units.len() && self.units[id as usize].alive
    }

    #[inline]
    pub fn unit(&self, id: UnitId) -> &Unit {
        debug_assert!(self.is_alive(id), "dead unit {id}");
        &self.units[id as usize]
    }

    #[inline]
    pub fn unit_mut(&mut self, id: UnitId) -> &mut Unit {
        debug_assert!(self.is_alive(id), "dead unit {id}");
        &mut self.units[id as usize]
    }

    #[inline]
    pub fn pos(&self, id: UnitId) -> Vec3 {
        self.positions[id as usize]
    }

    /// Move a unit's reference vector (keeps the dense mirror in sync —
    /// always use this instead of writing `unit_mut(id).pos`).
    #[inline]
    pub fn set_pos(&mut self, id: UnitId, p: Vec3) {
        debug_assert!(self.is_alive(id));
        self.units[id as usize].pos = p;
        self.positions[id as usize] = p;
        self.soa_write(id as usize, p);
    }

    /// The dense position mirror (len == `capacity()`, dead slots =
    /// [`DEAD_POS`]). The hot-path view for Find-Winners scans.
    #[inline]
    pub fn positions(&self) -> &[Vec3] {
        &self.positions
    }

    /// The SoA position mirror `(xs, ys, zs)`: one coordinate stream per
    /// axis, length `capacity()` rounded up to a multiple of [`SOA_LANES`],
    /// dead and padding slots poisoned with the [`DEAD_POS`] coordinates.
    /// This is the view the lane-blocked Find-Winners kernel consumes.
    #[inline]
    pub fn soa(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.xs, &self.ys, &self.zs)
    }

    /// Write one slot of the SoA mirror, growing it (poison-filled) to the
    /// next lane multiple when `i` is a fresh slab slot.
    #[inline]
    fn soa_write(&mut self, i: usize, p: Vec3) {
        if i >= self.xs.len() {
            let len = (i + 1).next_multiple_of(SOA_LANES);
            self.xs.resize(len, DEAD_POS.x);
            self.ys.resize(len, DEAD_POS.y);
            self.zs.resize(len, DEAD_POS.z);
        }
        self.xs[i] = p.x;
        self.ys[i] = p.y;
        self.zs[i] = p.z;
    }

    /// Iterate live unit ids (slab order — deterministic).
    pub fn ids(&self) -> impl Iterator<Item = UnitId> + '_ {
        self.units
            .iter()
            .enumerate()
            .filter(|(_, u)| u.alive)
            .map(|(i, _)| i as UnitId)
    }

    /// Neighbors (with edge ages) of a live unit.
    #[inline]
    pub fn edges_of(&self, id: UnitId) -> &[Edge] {
        &self.adjacency[id as usize]
    }

    pub fn degree(&self, id: UnitId) -> usize {
        self.adjacency[id as usize].len()
    }

    pub fn has_edge(&self, a: UnitId, b: UnitId) -> bool {
        self.adjacency[a as usize].iter().any(|e| e.to == b)
    }

    /// Pop the most recently freed slot across all shards (the exact pop
    /// order of the pre-shard single free stack), or `None` when every
    /// shard is empty. O(FREE_SHARDS) top-of-stack scan.
    fn pop_most_recent_free(&mut self) -> Option<UnitId> {
        let best = self
            .free_shards
            .iter()
            .enumerate()
            .filter_map(|(s, shard)| shard.last().map(|f| (f.stamp, s)))
            .max_by_key(|&(stamp, _)| stamp)?;
        Some(self.free_shards[best.1].pop().unwrap().slot)
    }

    /// Insert a unit, reusing a free slot when available. Allocation order
    /// is deterministic (global LIFO over the sharded free lists), so unit
    /// ids are a pure function of the insert/remove sequence — never of
    /// thread counts or commit grouping.
    pub fn insert(&mut self, pos: Vec3, threshold: f32) -> UnitId {
        let unit = Unit { pos, firing: 1.0, error: 0.0, threshold, alive: true };
        self.alive += 1;
        if let Some(id) = self.pop_most_recent_free() {
            self.units[id as usize] = unit;
            self.positions[id as usize] = pos;
            self.soa_write(id as usize, pos);
            debug_assert!(self.adjacency[id as usize].is_empty());
            id
        } else {
            self.units.push(unit);
            self.positions.push(pos);
            self.adjacency.push(Vec::new());
            let id = self.units.len() - 1;
            self.soa_write(id, pos);
            id as UnitId
        }
    }

    /// Remove a unit and all its edges. The slot joins its home free-list
    /// shard (`id % FREE_SHARDS`) stamped with the global free order.
    pub fn remove(&mut self, id: UnitId) {
        debug_assert!(self.is_alive(id));
        let nbrs: Vec<UnitId> = self.adjacency[id as usize].iter().map(|e| e.to).collect();
        for n in nbrs {
            self.disconnect(id, n);
        }
        self.units[id as usize].alive = false;
        self.positions[id as usize] = DEAD_POS;
        self.soa_write(id as usize, DEAD_POS);
        self.alive -= 1;
        self.free_stamp += 1;
        let stamp = self.free_stamp;
        self.free_shards[id as usize % FREE_SHARDS].push(FreeSlot { slot: id, stamp });
    }

    /// Fold freshly created edge halves into the undirected edge count —
    /// the sequential-replay half of [`ShardWriter::connect`], which cannot
    /// touch this shared counter from worker threads.
    pub(crate) fn note_edges_created(&mut self, n: usize) {
        self.edges += n;
    }

    /// Fold concurrently removed edges into the undirected edge count —
    /// the sequential-replay half of [`ShardWriter::disconnect`].
    pub(crate) fn note_edges_removed(&mut self, n: usize) {
        debug_assert!(n <= self.edges, "removing {n} of {} edges", self.edges);
        self.edges -= n;
    }

    /// Create the edge `a`–`b` (age 0) or reset its age if present.
    /// This is the competitive-Hebbian step of the Update phase.
    pub fn connect(&mut self, a: UnitId, b: UnitId) {
        debug_assert!(a != b, "self edge on {a}");
        debug_assert!(self.is_alive(a) && self.is_alive(b));
        let mut found = false;
        for e in &mut self.adjacency[a as usize] {
            if e.to == b {
                e.age = 0.0;
                found = true;
                break;
            }
        }
        if found {
            for e in &mut self.adjacency[b as usize] {
                if e.to == a {
                    e.age = 0.0;
                    break;
                }
            }
        } else {
            self.adjacency[a as usize].push(Edge { to: b, age: 0.0 });
            self.adjacency[b as usize].push(Edge { to: a, age: 0.0 });
            self.edges += 1;
        }
    }

    /// Remove the edge `a`–`b` if present.
    pub fn disconnect(&mut self, a: UnitId, b: UnitId) {
        let la = &mut self.adjacency[a as usize];
        let before = la.len();
        la.retain(|e| e.to != b);
        if la.len() != before {
            self.adjacency[b as usize].retain(|e| e.to != a);
            self.edges -= 1;
        }
    }

    /// Age all edges incident to `id` by `amount` (paper's aging mechanism;
    /// the symmetric copies stay in sync).
    pub fn age_edges_of(&mut self, id: UnitId, amount: f32) {
        // Split borrows: collect targets first (degrees are tiny).
        for k in 0..self.adjacency[id as usize].len() {
            self.adjacency[id as usize][k].age += amount;
            let to = self.adjacency[id as usize][k].to;
            for e in &mut self.adjacency[to as usize] {
                if e.to == id {
                    e.age += amount;
                    break;
                }
            }
        }
    }

    /// Drop edges of `id` older than `max_age`; returns neighbors that lost
    /// their last edge (candidates for removal) into `orphans`.
    pub fn prune_old_edges(&mut self, id: UnitId, max_age: f32, orphans: &mut Vec<UnitId>) {
        let stale: Vec<UnitId> = self.adjacency[id as usize]
            .iter()
            .filter(|e| e.age > max_age)
            .map(|e| e.to)
            .collect();
        for n in stale {
            self.disconnect(id, n);
            if self.adjacency[n as usize].is_empty() {
                orphans.push(n);
            }
        }
        if self.adjacency[id as usize].is_empty() {
            orphans.push(id);
        }
    }

    /// Classify the link (induced neighbor subgraph) of a unit.
    pub fn link_class(&self, id: UnitId) -> LinkClass {
        let nbrs: Vec<u32> = self.adjacency[id as usize].iter().map(|e| e.to).collect();
        classify_link(&nbrs, |a, b| self.has_edge(a, b))
    }

    /// Adjacency as a hash map (for `topology::euler_characteristic` and
    /// mesh export at convergence).
    pub fn adjacency_map(&self) -> std::collections::HashMap<u32, Vec<u32>> {
        self.ids()
            .map(|id| (id, self.adjacency[id as usize].iter().map(|e| e.to).collect()))
            .collect()
    }

    /// Export the reconstruction as a triangle mesh (3-cliques as faces).
    pub fn to_mesh(&self) -> crate::mesh::Mesh {
        let adj = self.adjacency_map();
        let tris = crate::topology::triangles(&adj);
        let vertices: Vec<Vec3> = (0..self.units.len())
            .map(|i| self.units[i].pos)
            .collect();
        let mut mesh = crate::mesh::Mesh::new(vertices, tris);
        mesh.compact();
        mesh
    }

    /// Write live unit positions into a dense `[cap, 3]` f32 row-major
    /// buffer, dead slots filled with `pad` (the AOT padding sentinel).
    /// Returns the number of rows written (== `capacity()`).
    pub fn fill_positions(&self, buf: &mut Vec<f32>, pad: f32) -> usize {
        let cap = self.units.len();
        buf.clear();
        buf.reserve(cap * 3);
        for (i, p) in self.positions.iter().enumerate() {
            if self.units[i].alive {
                buf.extend_from_slice(&[p.x, p.y, p.z]);
            } else {
                buf.extend_from_slice(&[pad, pad, pad]);
            }
        }
        cap
    }

    /// Structural invariants (used by tests and the property harness):
    /// symmetry, no self loops, no edges to dead units, consistent counts.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut alive = 0;
        let mut halves = 0usize;
        for (i, u) in self.units.iter().enumerate() {
            let id = i as UnitId;
            if u.alive {
                alive += 1;
            } else if !self.adjacency[i].is_empty() {
                return Err(format!("dead unit {id} has edges"));
            }
            for e in &self.adjacency[i] {
                halves += 1;
                if e.to == id {
                    return Err(format!("self edge on {id}"));
                }
                if !self.is_alive(e.to) {
                    return Err(format!("edge {id}->{} to dead unit", e.to));
                }
                let back = self.adjacency[e.to as usize]
                    .iter()
                    .find(|r| r.to == id)
                    .ok_or_else(|| format!("asymmetric edge {id}->{}", e.to))?;
                if (back.age - e.age).abs() > 1e-5 {
                    return Err(format!(
                        "age mismatch on edge {id}<->{}: {} vs {}",
                        e.to, e.age, back.age
                    ));
                }
            }
            // Duplicate neighbor check.
            for (k, e) in self.adjacency[i].iter().enumerate() {
                if self.adjacency[i][k + 1..].iter().any(|r| r.to == e.to) {
                    return Err(format!("duplicate edge {id}->{}", e.to));
                }
            }
        }
        if alive != self.alive {
            return Err(format!("alive count {} != {}", self.alive, alive));
        }
        if halves != 2 * self.edges {
            return Err(format!("edge halves {halves} != 2*{}", self.edges));
        }
        if self.positions.len() != self.units.len() {
            return Err(format!(
                "position mirror len {} != slab len {}",
                self.positions.len(),
                self.units.len()
            ));
        }
        for (i, u) in self.units.iter().enumerate() {
            if u.alive && self.positions[i] != u.pos {
                return Err(format!("position mirror diverged at slot {i}"));
            }
            if !u.alive && self.positions[i] != DEAD_POS {
                return Err(format!("dead slot {i} not DEAD_POS in mirror"));
            }
        }
        let soa_len = self.positions.len().next_multiple_of(SOA_LANES);
        if self.xs.len() != soa_len || self.ys.len() != soa_len || self.zs.len() != soa_len {
            return Err(format!(
                "SoA mirror lens {}/{}/{} != padded capacity {soa_len}",
                self.xs.len(),
                self.ys.len(),
                self.zs.len()
            ));
        }
        for i in 0..soa_len {
            let want = self.positions.get(i).copied().unwrap_or(DEAD_POS);
            let got = Vec3::new(self.xs[i], self.ys[i], self.zs[i]);
            if got != want {
                return Err(format!("SoA mirror diverged at slot {i}: {got:?} != {want:?}"));
            }
        }
        // Sharded free lists: every entry dead, in its home shard, stamped
        // within bounds and in stack order; no slot listed twice across
        // *any* pair of shards; no stamp reused; and no dead slot missing
        // from every list (a leaked slot would never be reallocated).
        if self.free_shards.len() != FREE_SHARDS {
            return Err(format!(
                "{} free shards != FREE_SHARDS ({FREE_SHARDS})",
                self.free_shards.len()
            ));
        }
        let mut free_seen = std::collections::HashSet::new();
        let mut stamps_seen = std::collections::HashSet::new();
        let mut free_total = 0usize;
        for (s, shard) in self.free_shards.iter().enumerate() {
            let mut prev_stamp = 0u64;
            for f in shard {
                free_total += 1;
                if f.slot as usize >= self.units.len() {
                    return Err(format!("free slot {} beyond slab", f.slot));
                }
                if f.slot as usize % FREE_SHARDS != s {
                    return Err(format!("free slot {} in foreign shard {s}", f.slot));
                }
                if self.units[f.slot as usize].alive {
                    return Err(format!("free slot {} is alive", f.slot));
                }
                if !free_seen.insert(f.slot) {
                    return Err(format!("slot {} twice across free shards", f.slot));
                }
                if f.stamp == 0 || f.stamp > self.free_stamp {
                    return Err(format!(
                        "free slot {} stamp {} outside (0, {}]",
                        f.slot, f.stamp, self.free_stamp
                    ));
                }
                if !stamps_seen.insert(f.stamp) {
                    return Err(format!("free stamp {} reused", f.stamp));
                }
                if f.stamp <= prev_stamp {
                    return Err(format!(
                        "shard {s} not in stack order at slot {}",
                        f.slot
                    ));
                }
                prev_stamp = f.stamp;
            }
        }
        let dead = self.units.len() - self.alive;
        if free_total != dead {
            return Err(format!(
                "{free_total} free-list entries != {dead} dead slots (leak)"
            ));
        }
        Ok(())
    }

    /// Serialize the complete slab state for the fleet snapshot format:
    /// every slot (alive or dead) with its scalars and adjacency **in list
    /// order** — adjacency order drives neighbor iteration and therefore
    /// the f32 operation order of every later update, so it must survive
    /// the round trip exactly — plus the sharded free lists with their
    /// stamps (allocation order of future insertions). The dense and SoA
    /// position mirrors are derived state and are rebuilt on read.
    pub fn write_state(&self, w: &mut ByteWriter) {
        w.u32(self.units.len() as u32);
        for (i, u) in self.units.iter().enumerate() {
            w.bool(u.alive);
            w.f32(u.pos.x);
            w.f32(u.pos.y);
            w.f32(u.pos.z);
            w.f32(u.firing);
            w.f32(u.error);
            w.f32(u.threshold);
            let adj = &self.adjacency[i];
            w.u32(adj.len() as u32);
            for e in adj {
                w.u32(e.to);
                w.f32(e.age);
            }
        }
        w.u64(self.free_stamp);
        for shard in &self.free_shards {
            w.u32(shard.len() as u32);
            for f in shard {
                w.u32(f.slot);
                w.u64(f.stamp);
            }
        }
    }

    /// Rebuild a network from [`Self::write_state`] bytes. The mirrors
    /// (dense positions, SoA lanes) and counters (alive, edges) are
    /// re-derived, then [`Self::check_invariants`] validates the whole
    /// store — a corrupt snapshot comes back as `Err`, never as a network
    /// that fails later.
    pub fn read_state(r: &mut ByteReader) -> Result<Network, String> {
        let cap = r.len_prefix(28).map_err(|e| e.to_string())?;
        let mut net = Network::new();
        let mut halves = 0usize;
        for _ in 0..cap {
            let alive = r.bool().map_err(|e| e.to_string())?;
            let pos = Vec3::new(
                r.f32().map_err(|e| e.to_string())?,
                r.f32().map_err(|e| e.to_string())?,
                r.f32().map_err(|e| e.to_string())?,
            );
            let firing = r.f32().map_err(|e| e.to_string())?;
            let error = r.f32().map_err(|e| e.to_string())?;
            let threshold = r.f32().map_err(|e| e.to_string())?;
            let deg = r.len_prefix(8).map_err(|e| e.to_string())?;
            let mut adj = Vec::with_capacity(deg);
            for _ in 0..deg {
                let to = r.u32().map_err(|e| e.to_string())?;
                let age = r.f32().map_err(|e| e.to_string())?;
                if to as usize >= cap {
                    return Err(format!("edge target {to} beyond slab {cap}"));
                }
                adj.push(Edge { to, age });
            }
            halves += adj.len();
            let slot = net.units.len();
            net.units.push(Unit { pos, firing, error, threshold, alive });
            net.positions.push(if alive { pos } else { DEAD_POS });
            net.soa_write(slot, if alive { pos } else { DEAD_POS });
            net.adjacency.push(adj);
            if alive {
                net.alive += 1;
            }
        }
        if halves % 2 != 0 {
            return Err(format!("odd edge-half count {halves}"));
        }
        net.edges = halves / 2;
        net.free_stamp = r.u64().map_err(|e| e.to_string())?;
        for s in 0..FREE_SHARDS {
            let n = r.len_prefix(12).map_err(|e| e.to_string())?;
            for _ in 0..n {
                let slot = r.u32().map_err(|e| e.to_string())?;
                let stamp = r.u64().map_err(|e| e.to_string())?;
                net.free_shards[s].push(FreeSlot { slot, stamp });
            }
        }
        net.check_invariants()
            .map_err(|e| format!("restored network fails invariants: {e}"))?;
        Ok(net)
    }

    /// Raw-access commit view for the executor's concurrent commit pass
    /// (see [`ShardWriter`]). Taking `&mut self` proves the caller holds
    /// exclusive access when the writer is created; the writer itself is
    /// lifetime-erased so the executor can share it across pool workers.
    pub fn shard_writer(&mut self) -> ShardWriter {
        ShardWriter {
            units: self.units.as_mut_ptr(),
            positions: self.positions.as_mut_ptr(),
            xs: self.xs.as_mut_ptr(),
            ys: self.ys.as_mut_ptr(),
            zs: self.zs.as_mut_ptr(),
            adjacency: self.adjacency.as_mut_ptr(),
            len: self.units.len(),
        }
    }
}

/// The network view of the executor's **concurrent commit** pass: plans
/// whose touched sets (`{w1, w2} ∪ N(w1)`) are pairwise disjoint — the
/// invariant the executor's conflict check enforces before deferring — are
/// applied by pool workers in parallel through this writer.
///
/// # Safety contract
///
/// The writer holds raw pointers into the slab buffers, so between
/// [`Network::shard_writer`] and the last use:
///
/// - the `Network` must not be touched through any other path (no inserts,
///   removals, or reads — structural changes would reallocate the buffers
///   under the pointers);
/// - concurrent calls must target disjoint unit sets: every write and read
///   goes to `{w1, w2} ∪ N(w1)` of the plan being committed (plus the
///   freshly allocated `new_unit` for insert plans — which no other plan
///   can touch, since it was not a winner of any same-batch signal), and
///   the executor only defers plans whose touched sets are mutually
///   disjoint;
/// - all ids must be live slab slots (`< capacity()` and alive).
///
/// Shared scalars (the undirected edge count, QE, GNG's error/epoch state)
/// are *not* reachable through the writer — [`Self::connect`] reports
/// created edges back through the plan and the executor folds them in
/// during the sequential scalar replay ([`Network::note_edges_created`]).
/// The worker-pool barrier (`WorkerPool::run` returns only after every
/// active worker acked) is what publishes these writes to the driver
/// thread before the replay reads anything.
pub struct ShardWriter {
    units: *mut Unit,
    positions: *mut Vec3,
    xs: *mut f32,
    ys: *mut f32,
    zs: *mut f32,
    adjacency: *mut Vec<Edge>,
    len: usize,
}

// SAFETY: the writer is only a capability to perform element-disjoint
// writes; disjointness and the no-structural-change window are the
// caller's contract (see the type docs).
unsafe impl Send for ShardWriter {}
unsafe impl Sync for ShardWriter {}

impl ShardWriter {
    #[inline]
    fn check(&self, id: UnitId) -> usize {
        let i = id as usize;
        debug_assert!(i < self.len, "ShardWriter id {id} beyond slab");
        i
    }

    /// Current position of a live unit (pre-write read for the change log).
    #[inline]
    pub fn pos(&self, id: UnitId) -> Vec3 {
        let i = self.check(id);
        unsafe { *self.positions.add(i) }
    }

    /// Mirror-coherent position write (`Unit::pos`, dense mirror, SoA
    /// lanes) — the writer twin of [`Network::set_pos`]. Never grows the
    /// SoA arrays: commits move existing units only.
    #[inline]
    pub fn set_pos(&self, id: UnitId, p: Vec3) {
        let i = self.check(id);
        unsafe {
            (*self.units.add(i)).pos = p;
            *self.positions.add(i) = p;
            *self.xs.add(i) = p.x;
            *self.ys.add(i) = p.y;
            *self.zs.add(i) = p.z;
        }
    }

    #[inline]
    pub fn set_firing(&self, id: UnitId, firing: f32) {
        let i = self.check(id);
        unsafe { (*self.units.add(i)).firing = firing };
    }

    #[inline]
    #[allow(clippy::mut_from_ref)] // the whole point of the writer; see type docs
    unsafe fn adj_mut(&self, id: UnitId) -> &mut Vec<Edge> {
        let i = self.check(id);
        unsafe { &mut *self.adjacency.add(i) }
    }

    /// Age every edge incident to `id` by `amount`, both halves — the
    /// writer twin of [`Network::age_edges_of`]. Neighbors of `id` are in
    /// the plan's touched set, so the back-half writes stay disjoint.
    pub fn age_edges_of(&self, id: UnitId, amount: f32) {
        unsafe {
            for half in self.adj_mut(id).iter_mut() {
                half.age += amount;
                // `half.to != id` (no self edges), so this second raw-derived
                // view targets a different element of the adjacency slab.
                for e in self.adj_mut(half.to).iter_mut() {
                    if e.to == id {
                        e.age += amount;
                        break;
                    }
                }
            }
        }
    }

    /// Create or age-reset the edge `a`–`b` — the writer twin of
    /// [`Network::connect`], except the shared undirected edge counter is
    /// *not* bumped here (workers cannot touch it): the return value says
    /// whether a new edge was created, for the sequential replay to fold in
    /// via [`Network::note_edges_created`].
    pub fn connect(&self, a: UnitId, b: UnitId) -> bool {
        debug_assert!(a != b, "self edge on {a}");
        unsafe {
            let mut found = false;
            for e in self.adj_mut(a).iter_mut() {
                if e.to == b {
                    e.age = 0.0;
                    found = true;
                    break;
                }
            }
            if found {
                for e in self.adj_mut(b).iter_mut() {
                    if e.to == a {
                        e.age = 0.0;
                        break;
                    }
                }
                false
            } else {
                self.adj_mut(a).push(Edge { to: b, age: 0.0 });
                self.adj_mut(b).push(Edge { to: a, age: 0.0 });
                true
            }
        }
    }

    /// Remove the edge `a`–`b` if present, both halves — the writer twin
    /// of [`Network::disconnect`], except the shared undirected edge
    /// counter is *not* decremented here (workers cannot touch it): the
    /// return value says whether an edge was removed, for the sequential
    /// replay to fold in via [`Network::note_edges_removed`].
    pub fn disconnect(&self, a: UnitId, b: UnitId) -> bool {
        unsafe {
            let la = self.adj_mut(a);
            let before = la.len();
            la.retain(|e| e.to != b);
            if la.len() != before {
                self.adj_mut(b).retain(|e| e.to != a);
                true
            } else {
                false
            }
        }
    }

    /// Apply the network-write half of one `Adapt`-class plan: edge aging
    /// on the winner, the competitive-Hebbian connect, the precomputed
    /// position moves and firing levels. Algorithm-independent — every
    /// adapt rule in this crate (GWR, SOAM, GNG) is exactly this shape,
    /// with the differences (which units move, whether firing changes)
    /// already encoded in the plan by `plan_update`.
    ///
    /// Fills `plan.old_pos` (pre-move positions, for the change-log replay)
    /// and `plan.new_edges` (for the edge-count replay); everything else an
    /// update does — QE, per-algorithm counters, GNG's lazy error decay —
    /// belongs to `GrowingNetwork::commit_scalars`.
    pub fn commit_adapt(&self, plan: &mut UpdatePlan) {
        self.age_edges_of(plan.w1, 1.0);
        plan.new_edges = u32::from(self.connect(plan.w1, plan.w2));
        plan.removed_edges = 0;
        plan.old_pos.clear();
        for &(id, new_pos) in &plan.moves {
            plan.old_pos.push(self.pos(id));
            self.set_pos(id, new_pos);
        }
        for &(id, firing) in &plan.firing {
            self.set_firing(id, firing);
        }
    }

    /// Apply the network-write half of one `Insert`-class plan. The unit
    /// itself (`plan.new_unit`) was already allocated — position, firing,
    /// threshold, mirrors — sequentially at admission by
    /// `GrowingNetwork::begin_insert`; what remains is exactly the
    /// insertion branch's edge work, whose final state is bit-identical to
    /// the sequential `age → connect(w1,w2) → insert → connect(r,w1) →
    /// connect(r,w2) → disconnect(w1,w2)` sequence:
    ///
    /// - aging first (the new unit's edges do not exist yet, so they are
    ///   not aged — as in the sequential order);
    /// - the sequential connect-then-disconnect of `w1`–`w2` nets to
    ///   *removing the edge if it was present* (the age reset is destroyed
    ///   by the removal), and `retain` preserves the relative order of the
    ///   surviving adjacency entries, so a plain disconnect leaves the
    ///   same lists;
    /// - the new unit's adjacency is empty, so both connects always
    ///   create.
    ///
    /// Fills `plan.new_edges`/`plan.removed_edges` for the edge-count
    /// replay; the change-log entry is the executor's replay, the QE push
    /// is `commit_scalars`.
    pub fn commit_insert(&self, plan: &mut UpdatePlan) {
        self.age_edges_of(plan.w1, 1.0);
        plan.removed_edges = u32::from(self.disconnect(plan.w1, plan.w2));
        let a = self.connect(plan.new_unit, plan.w1);
        let b = self.connect(plan.new_unit, plan.w2);
        debug_assert!(a && b, "fresh unit {} had edges", plan.new_unit);
        plan.new_edges = 2;
        plan.old_pos.clear();
        debug_assert!(plan.moves.is_empty() && plan.firing.is_empty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(x: f32) -> Vec3 {
        Vec3::new(x, 0.0, 0.0)
    }

    #[test]
    fn insert_connect_counts() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(b, c);
        assert_eq!(n.len(), 3);
        assert_eq!(n.edge_count(), 2);
        assert!(n.has_edge(a, b) && n.has_edge(b, a));
        assert!(!n.has_edge(a, c));
        n.check_invariants().unwrap();
    }

    #[test]
    fn connect_twice_resets_age() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        n.connect(a, b);
        n.age_edges_of(a, 5.0);
        assert_eq!(n.edges_of(a)[0].age, 5.0);
        n.connect(a, b);
        assert_eq!(n.edges_of(a)[0].age, 0.0);
        assert_eq!(n.edges_of(b)[0].age, 0.0);
        assert_eq!(n.edge_count(), 1);
        n.check_invariants().unwrap();
    }

    #[test]
    fn remove_unit_cleans_edges_and_reuses_slot() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(b, c);
        n.remove(b);
        assert_eq!(n.len(), 2);
        assert_eq!(n.edge_count(), 0);
        assert!(!n.is_alive(b));
        n.check_invariants().unwrap();
        let d = n.insert(v(3.0), 1.0);
        assert_eq!(d, b, "slot reuse");
        assert_eq!(n.capacity(), 3);
        n.check_invariants().unwrap();
    }

    #[test]
    fn aging_is_symmetric() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(a, c);
        n.age_edges_of(a, 1.5);
        assert_eq!(n.edges_of(b)[0].age, 1.5);
        assert_eq!(n.edges_of(c)[0].age, 1.5);
        n.check_invariants().unwrap();
    }

    #[test]
    fn prune_collects_orphans() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(a, c);
        n.connect(b, c);
        n.age_edges_of(a, 10.0); // ages a-b and a-c
        let mut orphans = Vec::new();
        n.prune_old_edges(a, 5.0, &mut orphans);
        assert_eq!(n.edge_count(), 1); // b-c survives
        assert_eq!(orphans, vec![a]); // a lost all edges
        n.check_invariants().unwrap();
    }

    #[test]
    fn fill_positions_pads_dead_slots() {
        let mut n = Network::new();
        let a = n.insert(v(1.0), 1.0);
        let b = n.insert(v(2.0), 1.0);
        let _c = n.insert(v(3.0), 1.0);
        n.connect(a, b);
        n.remove(b);
        let mut buf = Vec::new();
        let cap = n.fill_positions(&mut buf, 1e30);
        assert_eq!(cap, 3);
        assert_eq!(buf.len(), 9);
        assert_eq!(buf[0], 1.0);
        assert_eq!(buf[3], 1e30);
        assert_eq!(buf[6], 3.0);
    }

    #[test]
    fn link_class_of_triangle_fan() {
        let mut n = Network::new();
        let hub = n.insert(v(0.0), 1.0);
        let r1 = n.insert(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let r2 = n.insert(Vec3::new(0.0, 1.0, 0.0), 1.0);
        let r3 = n.insert(Vec3::new(-1.0, 0.0, 0.0), 1.0);
        for r in [r1, r2, r3] {
            n.connect(hub, r);
        }
        assert_eq!(n.link_class(hub), LinkClass::Dust);
        n.connect(r1, r2);
        n.connect(r2, r3);
        assert_eq!(n.link_class(hub), LinkClass::HalfDisk);
        n.connect(r3, r1);
        assert_eq!(n.link_class(hub), LinkClass::Disk);
    }

    #[test]
    fn to_mesh_exports_cliques() {
        let mut n = Network::new();
        let a = n.insert(Vec3::new(0.0, 0.0, 0.0), 1.0);
        let b = n.insert(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let c = n.insert(Vec3::new(0.0, 1.0, 0.0), 1.0);
        n.connect(a, b);
        n.connect(b, c);
        n.connect(c, a);
        let m = n.to_mesh();
        assert_eq!(m.faces.len(), 1);
        assert_eq!(m.vertices.len(), 3);
    }

    #[test]
    fn soa_mirror_tracks_mutations_and_pads_to_lanes() {
        let mut n = Network::new();
        let mut ids = Vec::new();
        // Cross a lane boundary so both the padded tail and a full lane
        // block are exercised.
        for k in 0..SOA_LANES + 3 {
            ids.push(n.insert(v(k as f32), 1.0));
        }
        n.check_invariants().unwrap();
        let (xs, ys, zs) = n.soa();
        assert_eq!(xs.len(), 2 * SOA_LANES);
        assert_eq!(ys.len(), 2 * SOA_LANES);
        assert_eq!(zs.len(), 2 * SOA_LANES);
        assert_eq!(xs[3], 3.0);
        // The widest dispatched kernel (16 f32 lanes) reads the whole pad:
        // every slot past the slab must be poisoned on all three axes.
        assert!(SOA_LANES >= 16, "pad must cover the widest SIMD tier");
        for slot in SOA_LANES + 3..2 * SOA_LANES {
            assert_eq!(xs[slot], DEAD_POS.x, "padding poisoned (x, slot {slot})");
            assert_eq!(ys[slot], DEAD_POS.y, "padding poisoned (y, slot {slot})");
            assert_eq!(zs[slot], DEAD_POS.z, "padding poisoned (z, slot {slot})");
        }

        n.set_pos(ids[2], Vec3::new(7.0, 8.0, 9.0));
        n.remove(ids[4]);
        n.check_invariants().unwrap();
        let (xs, ys, zs) = n.soa();
        assert_eq!((xs[2], ys[2], zs[2]), (7.0, 8.0, 9.0));
        assert_eq!(xs[4], DEAD_POS.x, "dead slot poisoned");

        let reused = n.insert(v(42.0), 1.0);
        assert_eq!(reused, ids[4], "slot reuse");
        n.check_invariants().unwrap();
        assert_eq!(n.soa().0[4], 42.0);
    }

    #[test]
    fn sharded_free_lists_reproduce_global_lifo_order() {
        // Free slots landing in different home shards must still be
        // reallocated in exact reverse-free order (the old single stack's
        // pop order — what keeps unit ids driver-independent).
        let mut n = Network::new();
        let ids: Vec<UnitId> = (0..2 * FREE_SHARDS as u32 + 3)
            .map(|k| n.insert(v(k as f32), 1.0))
            .collect();
        // Remove a spread of slots across shards, in a scrambled order.
        let freed = [
            ids[3],
            ids[FREE_SHARDS + 3], // same home shard as ids[3]
            ids[0],
            ids[7 % ids.len()],
            ids[FREE_SHARDS - 1],
        ];
        let mut freed_in_order = Vec::new();
        for &id in &freed {
            // Skip duplicates in the scrambled pick (already removed).
            if n.is_alive(id) {
                n.remove(id);
                freed_in_order.push(id);
            }
        }
        n.check_invariants().unwrap();
        // Reinsert: must pop most-recently-freed first, across shards.
        for &want in freed_in_order.iter().rev() {
            let got = n.insert(v(99.0), 1.0);
            assert_eq!(got, want, "global LIFO order across shards");
        }
        n.check_invariants().unwrap();
    }

    #[test]
    fn check_invariants_rejects_corrupt_free_lists() {
        let base = {
            let mut n = Network::new();
            let a = n.insert(v(0.0), 1.0);
            let b = n.insert(v(1.0), 1.0);
            let _c = n.insert(v(2.0), 1.0);
            n.connect(a, b);
            n.remove(b);
            n.check_invariants().unwrap();
            n
        };

        // Alive entry in a shard list.
        let mut n = base.clone();
        let alive_id = n.ids().next().unwrap();
        n.free_stamp += 1;
        let stamp = n.free_stamp;
        n.free_shards[alive_id as usize % FREE_SHARDS].push(FreeSlot { slot: alive_id, stamp });
        assert!(n.check_invariants().unwrap_err().contains("alive"));

        // The same dead slot listed twice, across two different shards.
        let mut n = base.clone();
        let dead = n.free_shards.iter().flatten().next().unwrap().slot;
        n.free_stamp += 1;
        let stamp = n.free_stamp;
        let foreign = (dead as usize + 1) % FREE_SHARDS;
        n.free_shards[foreign].push(FreeSlot { slot: dead, stamp });
        let err = n.check_invariants().unwrap_err();
        assert!(
            err.contains("foreign") || err.contains("twice"),
            "cross-shard duplicate must be rejected: {err}"
        );

        // A dead slot missing from every list (leak).
        let mut n = base.clone();
        for shard in &mut n.free_shards {
            shard.clear();
        }
        assert!(n.check_invariants().unwrap_err().contains("leak"));

        // Reused stamp across shards.
        let mut n = base.clone();
        let d = n.insert(v(5.0), 1.0); // reuses the freed slot
        let e = n.insert(v(6.0), 1.0);
        n.remove(d);
        n.remove(e);
        if d as usize % FREE_SHARDS != e as usize % FREE_SHARDS {
            // Force both shards' stamps equal.
            let s = n.free_shards[d as usize % FREE_SHARDS].last().unwrap().stamp;
            n.free_shards[e as usize % FREE_SHARDS].last_mut().unwrap().stamp = s;
            let err = n.check_invariants().unwrap_err();
            assert!(err.contains("reused") || err.contains("stack order"), "{err}");
        }
    }

    #[test]
    fn shard_writer_matches_network_mutators() {
        // The raw writer's aging/connect/moves/firing must be bit-identical
        // to the safe Network mutators (modulo the deferred edge counter).
        let build = || {
            let mut n = Network::new();
            let a = n.insert(v(0.0), 1.0);
            let b = n.insert(v(1.0), 1.0);
            let c = n.insert(v(2.0), 1.0);
            n.connect(a, b);
            n.connect(a, c);
            (n, a, b, c)
        };
        let (mut safe, a, b, c) = build();
        let (mut raw, _, _, _) = build();

        safe.age_edges_of(a, 1.5);
        safe.connect(a, b); // age reset, no new edge
        safe.connect(b, c); // new edge
        safe.set_pos(c, Vec3::new(9.0, 8.0, 7.0));
        safe.unit_mut(b).firing = 0.25;

        let w = raw.shard_writer();
        w.age_edges_of(a, 1.5);
        assert!(!w.connect(a, b), "existing edge only resets");
        assert!(w.connect(b, c), "new edge reported for the replay");
        w.set_pos(c, Vec3::new(9.0, 8.0, 7.0));
        w.set_firing(b, 0.25);
        raw.note_edges_created(1);

        assert_eq!(safe.edge_count(), raw.edge_count());
        for id in [a, b, c] {
            assert_eq!(safe.pos(id), raw.pos(id));
            assert_eq!(safe.unit(id).firing.to_bits(), raw.unit(id).firing.to_bits());
            let mut ea: Vec<(u32, u32)> =
                safe.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            let mut eb: Vec<(u32, u32)> =
                raw.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "edges of {id}");
        }
        raw.check_invariants().unwrap();
        // The SoA mirror followed the raw set_pos too.
        assert_eq!(raw.soa().0[c as usize], 9.0);
    }

    #[test]
    fn shard_writer_commit_insert_matches_sequential_insertion() {
        use crate::som::PlanKind;
        // The raw insert commit must be bit-identical to the sequential
        // insertion branch: age → connect(w1,w2) → insert → connect(r,w1)
        // → connect(r,w2) → disconnect(w1,w2) — with and without a
        // pre-existing w1–w2 edge.
        for preconnected in [true, false] {
            let build = |wired: bool| {
                let mut n = Network::new();
                let a = n.insert(v(0.0), 1.0);
                let b = n.insert(v(1.0), 1.0);
                let c = n.insert(v(2.0), 1.0);
                if wired {
                    n.connect(a, b);
                }
                n.connect(a, c);
                (n, a, b, c)
            };
            let (mut safe, a, b, _c) = build(preconnected);
            let (mut raw, ra, rb, _rc) = build(preconnected);
            let mid = Vec3::new(0.5, 0.0, 0.0);

            safe.age_edges_of(a, 1.0);
            safe.connect(a, b);
            let r = safe.insert(mid, 0.7);
            safe.connect(r, a);
            safe.connect(r, b);
            safe.disconnect(a, b);

            let r2 = raw.insert(mid, 0.7);
            assert_eq!(r2, r);
            let mut plan = UpdatePlan {
                kind: PlanKind::Insert,
                w1: ra,
                w2: rb,
                new_unit: r2,
                ..UpdatePlan::default()
            };
            let w = raw.shard_writer();
            w.commit_insert(&mut plan);
            assert_eq!(plan.new_edges, 2);
            assert_eq!(plan.removed_edges, u32::from(preconnected));
            raw.note_edges_created(plan.new_edges as usize);
            raw.note_edges_removed(plan.removed_edges as usize);

            assert_eq!(safe.edge_count(), raw.edge_count(), "pre={preconnected}");
            for id in 0..safe.capacity() as UnitId {
                assert_eq!(safe.is_alive(id), raw.is_alive(id));
                if !safe.is_alive(id) {
                    continue;
                }
                assert_eq!(safe.pos(id), raw.pos(id));
                let ea: Vec<(u32, u32)> =
                    safe.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
                let eb: Vec<(u32, u32)> =
                    raw.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
                assert_eq!(ea, eb, "edges of {id} (pre={preconnected})");
            }
            raw.check_invariants().unwrap();
        }
    }

    #[test]
    fn state_roundtrip_is_bit_exact() {
        // Build a network with every tricky feature: removals (sharded free
        // lists, stamps), slab reuse, aged edges in a specific adjacency
        // order, and f32 values that would not survive a lossy round trip.
        let mut n = Network::new();
        let ids: Vec<UnitId> = (0..2 * FREE_SHARDS as u32 + 5)
            .map(|k| n.insert(v(k as f32 * 0.37), 0.1 + k as f32 * 1e-3))
            .collect();
        for win in ids.windows(2) {
            n.connect(win[0], win[1]);
        }
        n.connect(ids[0], ids[4]);
        n.age_edges_of(ids[2], 1.5);
        n.remove(ids[3]);
        n.remove(ids[FREE_SHARDS + 3]);
        n.remove(ids[7]);
        let reused = n.insert(v(42.0), 0.5);
        assert_eq!(reused, ids[7], "global LIFO reuse");
        n.unit_mut(ids[1]).error = f32::from_bits(0x0000_0001); // subnormal
        n.unit_mut(ids[1]).firing = -0.0;
        n.check_invariants().unwrap();

        let mut w = ByteWriter::new();
        n.write_state(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let back = Network::read_state(&mut r).unwrap();
        r.expect_end().unwrap();

        assert_eq!(back.capacity(), n.capacity());
        assert_eq!(back.len(), n.len());
        assert_eq!(back.edge_count(), n.edge_count());
        for id in 0..n.capacity() as UnitId {
            assert_eq!(back.is_alive(id), n.is_alive(id), "unit {id}");
            if !n.is_alive(id) {
                continue;
            }
            let (a, b) = (n.unit(id), back.unit(id));
            assert_eq!(a.pos.x.to_bits(), b.pos.x.to_bits());
            assert_eq!(a.firing.to_bits(), b.firing.to_bits());
            assert_eq!(a.error.to_bits(), b.error.to_bits());
            assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
            // Adjacency LIST ORDER (not just the set) must survive: it
            // decides neighbor iteration order in every later update.
            let ea: Vec<(u32, u32)> =
                n.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            let eb: Vec<(u32, u32)> =
                back.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            assert_eq!(ea, eb, "adjacency order of {id}");
        }
        // Future allocations must pop the same slots: drain both free lists.
        let mut n2 = n.clone();
        let mut b2 = back;
        for _ in 0..2 {
            assert_eq!(n2.insert(v(9.0), 0.1), b2.insert(v(9.0), 0.1));
        }
        b2.check_invariants().unwrap();
    }

    #[test]
    fn read_state_rejects_corruption() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        n.connect(a, b);
        n.remove(b);
        let mut w = ByteWriter::new();
        n.write_state(&mut w);
        let buf = w.into_inner();
        // Truncation at every prefix must error, never panic.
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut]);
            assert!(Network::read_state(&mut r).is_err(), "cut at {cut}");
        }
        // A flipped aliveness byte breaks the free-list invariants.
        let mut bad = buf.clone();
        bad[4] ^= 1; // first slot's alive flag
        let mut r = ByteReader::new(&bad);
        assert!(Network::read_state(&mut r).is_err());
    }

    #[test]
    fn ids_iterates_alive_only() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        n.remove(a);
        let ids: Vec<UnitId> = n.ids().collect();
        assert_eq!(ids, vec![b]);
    }
}
