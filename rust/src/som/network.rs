//! The network store shared by all growing self-organizing algorithms.
//!
//! Units live in a slab with a free list so unit ids stay stable across
//! removals (ids are what the winner-lock table, the hash index and the AOT
//! batch buffers key on). Adjacency is a per-unit edge vector with ages —
//! growing networks create, reset, age and destroy edges constantly, and the
//! neighbor sets are small (≈6 on a 2-manifold), so linear scans beat hash
//! sets here.

use crate::geometry::Vec3;
use crate::topology::{classify_link, LinkClass};

/// Stable unit identifier (slab slot).
pub type UnitId = u32;

/// One unit of the network.
#[derive(Clone, Copy, Debug)]
pub struct Unit {
    /// Reference vector in input space.
    pub pos: Vec3,
    /// Habituation / firing counter: 1 = fresh, decays toward ~0 as the
    /// unit wins (see [`super::habituation`]).
    pub firing: f32,
    /// GNG-style accumulated quantization error.
    pub error: f32,
    /// SOAM per-unit insertion threshold (tracks local feature size).
    pub threshold: f32,
    pub alive: bool,
}

/// One directed half of an undirected aged edge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub to: UnitId,
    pub age: f32,
}

/// What an update did to the network — consumed by spatial-index
/// maintenance and by the metrics layer. Reused across calls.
#[derive(Clone, Debug, Default)]
pub struct ChangeLog {
    pub moved: Vec<(UnitId, Vec3)>, // (id, old position)
    pub inserted: Vec<UnitId>,
    pub removed: Vec<(UnitId, Vec3)>, // (id, last position)
}

impl ChangeLog {
    pub fn clear(&mut self) {
        self.moved.clear();
        self.inserted.clear();
        self.removed.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.moved.is_empty() && self.inserted.is_empty() && self.removed.is_empty()
    }
}

/// Padding sentinel mirrored from the AOT contract: dead slots in the dense
/// position array hold this value, so their squared distances overflow to
/// `+inf` and they can never win a Find-Winners scan.
pub const DEAD_POS: Vec3 = Vec3 { x: 1e30, y: 1e30, z: 1e30 };

/// Lane width of the structure-of-arrays position mirror. The SoA arrays
/// are always padded to a multiple of this, so the lane-blocked Find
/// Winners kernel (`findwinners::lanes`, fixed `LANES = SOA_LANES`) can use
/// `chunks_exact` with no scalar tail. 8 f32 lanes = one AVX2 register; on
/// narrower targets LLVM simply unrolls.
pub const SOA_LANES: usize = 8;

/// Slab-allocated unit graph.
#[derive(Clone, Debug, Default)]
pub struct Network {
    units: Vec<Unit>,
    adjacency: Vec<Vec<Edge>>,
    free: Vec<UnitId>,
    alive: usize,
    edges: usize,
    /// Dense position mirror (one row per slab slot, dead slots = DEAD_POS).
    /// This is the hot-path view: the exhaustive/batched Find-Winners scans
    /// walk this 12-byte-stride array instead of the 28-byte `Unit` slab
    /// (~1.6× on the memory-bound scan), and `fill_positions` for the PJRT
    /// marshalling is a straight copy of it.
    positions: Vec<Vec3>,
    /// Structure-of-arrays mirror of `positions` for the lane-blocked
    /// Find-Winners kernel: one coordinate stream per axis, padded to a
    /// multiple of [`SOA_LANES`], dead and padding slots poisoned with the
    /// [`DEAD_POS`] coordinates so their distances overflow to `+inf`.
    xs: Vec<f32>,
    ys: Vec<f32>,
    zs: Vec<f32>,
}

impl Network {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live units.
    #[inline]
    pub fn len(&self) -> usize {
        self.alive
    }

    pub fn is_empty(&self) -> bool {
        self.alive == 0
    }

    /// Number of undirected edges ("connections" in the paper's tables).
    #[inline]
    pub fn edge_count(&self) -> usize {
        self.edges
    }

    /// Slab high-water mark: ids are always `< capacity()`. This is the `n`
    /// the batched Find-Winners pads to.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.units.len()
    }

    #[inline]
    pub fn is_alive(&self, id: UnitId) -> bool {
        (id as usize) < self.units.len() && self.units[id as usize].alive
    }

    #[inline]
    pub fn unit(&self, id: UnitId) -> &Unit {
        debug_assert!(self.is_alive(id), "dead unit {id}");
        &self.units[id as usize]
    }

    #[inline]
    pub fn unit_mut(&mut self, id: UnitId) -> &mut Unit {
        debug_assert!(self.is_alive(id), "dead unit {id}");
        &mut self.units[id as usize]
    }

    #[inline]
    pub fn pos(&self, id: UnitId) -> Vec3 {
        self.positions[id as usize]
    }

    /// Move a unit's reference vector (keeps the dense mirror in sync —
    /// always use this instead of writing `unit_mut(id).pos`).
    #[inline]
    pub fn set_pos(&mut self, id: UnitId, p: Vec3) {
        debug_assert!(self.is_alive(id));
        self.units[id as usize].pos = p;
        self.positions[id as usize] = p;
        self.soa_write(id as usize, p);
    }

    /// The dense position mirror (len == `capacity()`, dead slots =
    /// [`DEAD_POS`]). The hot-path view for Find-Winners scans.
    #[inline]
    pub fn positions(&self) -> &[Vec3] {
        &self.positions
    }

    /// The SoA position mirror `(xs, ys, zs)`: one coordinate stream per
    /// axis, length `capacity()` rounded up to a multiple of [`SOA_LANES`],
    /// dead and padding slots poisoned with the [`DEAD_POS`] coordinates.
    /// This is the view the lane-blocked Find-Winners kernel consumes.
    #[inline]
    pub fn soa(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.xs, &self.ys, &self.zs)
    }

    /// Write one slot of the SoA mirror, growing it (poison-filled) to the
    /// next lane multiple when `i` is a fresh slab slot.
    #[inline]
    fn soa_write(&mut self, i: usize, p: Vec3) {
        if i >= self.xs.len() {
            let len = (i + 1).next_multiple_of(SOA_LANES);
            self.xs.resize(len, DEAD_POS.x);
            self.ys.resize(len, DEAD_POS.y);
            self.zs.resize(len, DEAD_POS.z);
        }
        self.xs[i] = p.x;
        self.ys[i] = p.y;
        self.zs[i] = p.z;
    }

    /// Iterate live unit ids (slab order — deterministic).
    pub fn ids(&self) -> impl Iterator<Item = UnitId> + '_ {
        self.units
            .iter()
            .enumerate()
            .filter(|(_, u)| u.alive)
            .map(|(i, _)| i as UnitId)
    }

    /// Neighbors (with edge ages) of a live unit.
    #[inline]
    pub fn edges_of(&self, id: UnitId) -> &[Edge] {
        &self.adjacency[id as usize]
    }

    pub fn degree(&self, id: UnitId) -> usize {
        self.adjacency[id as usize].len()
    }

    pub fn has_edge(&self, a: UnitId, b: UnitId) -> bool {
        self.adjacency[a as usize].iter().any(|e| e.to == b)
    }

    /// Insert a unit, reusing a free slot when available.
    pub fn insert(&mut self, pos: Vec3, threshold: f32) -> UnitId {
        let unit = Unit { pos, firing: 1.0, error: 0.0, threshold, alive: true };
        self.alive += 1;
        if let Some(id) = self.free.pop() {
            self.units[id as usize] = unit;
            self.positions[id as usize] = pos;
            self.soa_write(id as usize, pos);
            debug_assert!(self.adjacency[id as usize].is_empty());
            id
        } else {
            self.units.push(unit);
            self.positions.push(pos);
            self.adjacency.push(Vec::new());
            let id = self.units.len() - 1;
            self.soa_write(id, pos);
            id as UnitId
        }
    }

    /// Remove a unit and all its edges.
    pub fn remove(&mut self, id: UnitId) {
        debug_assert!(self.is_alive(id));
        let nbrs: Vec<UnitId> = self.adjacency[id as usize].iter().map(|e| e.to).collect();
        for n in nbrs {
            self.disconnect(id, n);
        }
        self.units[id as usize].alive = false;
        self.positions[id as usize] = DEAD_POS;
        self.soa_write(id as usize, DEAD_POS);
        self.alive -= 1;
        self.free.push(id);
    }

    /// Create the edge `a`–`b` (age 0) or reset its age if present.
    /// This is the competitive-Hebbian step of the Update phase.
    pub fn connect(&mut self, a: UnitId, b: UnitId) {
        debug_assert!(a != b, "self edge on {a}");
        debug_assert!(self.is_alive(a) && self.is_alive(b));
        let mut found = false;
        for e in &mut self.adjacency[a as usize] {
            if e.to == b {
                e.age = 0.0;
                found = true;
                break;
            }
        }
        if found {
            for e in &mut self.adjacency[b as usize] {
                if e.to == a {
                    e.age = 0.0;
                    break;
                }
            }
        } else {
            self.adjacency[a as usize].push(Edge { to: b, age: 0.0 });
            self.adjacency[b as usize].push(Edge { to: a, age: 0.0 });
            self.edges += 1;
        }
    }

    /// Remove the edge `a`–`b` if present.
    pub fn disconnect(&mut self, a: UnitId, b: UnitId) {
        let la = &mut self.adjacency[a as usize];
        let before = la.len();
        la.retain(|e| e.to != b);
        if la.len() != before {
            self.adjacency[b as usize].retain(|e| e.to != a);
            self.edges -= 1;
        }
    }

    /// Age all edges incident to `id` by `amount` (paper's aging mechanism;
    /// the symmetric copies stay in sync).
    pub fn age_edges_of(&mut self, id: UnitId, amount: f32) {
        // Split borrows: collect targets first (degrees are tiny).
        for k in 0..self.adjacency[id as usize].len() {
            self.adjacency[id as usize][k].age += amount;
            let to = self.adjacency[id as usize][k].to;
            for e in &mut self.adjacency[to as usize] {
                if e.to == id {
                    e.age += amount;
                    break;
                }
            }
        }
    }

    /// Drop edges of `id` older than `max_age`; returns neighbors that lost
    /// their last edge (candidates for removal) into `orphans`.
    pub fn prune_old_edges(&mut self, id: UnitId, max_age: f32, orphans: &mut Vec<UnitId>) {
        let stale: Vec<UnitId> = self.adjacency[id as usize]
            .iter()
            .filter(|e| e.age > max_age)
            .map(|e| e.to)
            .collect();
        for n in stale {
            self.disconnect(id, n);
            if self.adjacency[n as usize].is_empty() {
                orphans.push(n);
            }
        }
        if self.adjacency[id as usize].is_empty() {
            orphans.push(id);
        }
    }

    /// Classify the link (induced neighbor subgraph) of a unit.
    pub fn link_class(&self, id: UnitId) -> LinkClass {
        let nbrs: Vec<u32> = self.adjacency[id as usize].iter().map(|e| e.to).collect();
        classify_link(&nbrs, |a, b| self.has_edge(a, b))
    }

    /// Adjacency as a hash map (for `topology::euler_characteristic` and
    /// mesh export at convergence).
    pub fn adjacency_map(&self) -> std::collections::HashMap<u32, Vec<u32>> {
        self.ids()
            .map(|id| (id, self.adjacency[id as usize].iter().map(|e| e.to).collect()))
            .collect()
    }

    /// Export the reconstruction as a triangle mesh (3-cliques as faces).
    pub fn to_mesh(&self) -> crate::mesh::Mesh {
        let adj = self.adjacency_map();
        let tris = crate::topology::triangles(&adj);
        let vertices: Vec<Vec3> = (0..self.units.len())
            .map(|i| self.units[i].pos)
            .collect();
        let mut mesh = crate::mesh::Mesh::new(vertices, tris);
        mesh.compact();
        mesh
    }

    /// Write live unit positions into a dense `[cap, 3]` f32 row-major
    /// buffer, dead slots filled with `pad` (the AOT padding sentinel).
    /// Returns the number of rows written (== `capacity()`).
    pub fn fill_positions(&self, buf: &mut Vec<f32>, pad: f32) -> usize {
        let cap = self.units.len();
        buf.clear();
        buf.reserve(cap * 3);
        for (i, p) in self.positions.iter().enumerate() {
            if self.units[i].alive {
                buf.extend_from_slice(&[p.x, p.y, p.z]);
            } else {
                buf.extend_from_slice(&[pad, pad, pad]);
            }
        }
        cap
    }

    /// Structural invariants (used by tests and the property harness):
    /// symmetry, no self loops, no edges to dead units, consistent counts.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut alive = 0;
        let mut halves = 0usize;
        for (i, u) in self.units.iter().enumerate() {
            let id = i as UnitId;
            if u.alive {
                alive += 1;
            } else if !self.adjacency[i].is_empty() {
                return Err(format!("dead unit {id} has edges"));
            }
            for e in &self.adjacency[i] {
                halves += 1;
                if e.to == id {
                    return Err(format!("self edge on {id}"));
                }
                if !self.is_alive(e.to) {
                    return Err(format!("edge {id}->{} to dead unit", e.to));
                }
                let back = self.adjacency[e.to as usize]
                    .iter()
                    .find(|r| r.to == id)
                    .ok_or_else(|| format!("asymmetric edge {id}->{}", e.to))?;
                if (back.age - e.age).abs() > 1e-5 {
                    return Err(format!(
                        "age mismatch on edge {id}<->{}: {} vs {}",
                        e.to, e.age, back.age
                    ));
                }
            }
            // Duplicate neighbor check.
            for (k, e) in self.adjacency[i].iter().enumerate() {
                if self.adjacency[i][k + 1..].iter().any(|r| r.to == e.to) {
                    return Err(format!("duplicate edge {id}->{}", e.to));
                }
            }
        }
        if alive != self.alive {
            return Err(format!("alive count {} != {}", self.alive, alive));
        }
        if halves != 2 * self.edges {
            return Err(format!("edge halves {halves} != 2*{}", self.edges));
        }
        if self.positions.len() != self.units.len() {
            return Err(format!(
                "position mirror len {} != slab len {}",
                self.positions.len(),
                self.units.len()
            ));
        }
        for (i, u) in self.units.iter().enumerate() {
            if u.alive && self.positions[i] != u.pos {
                return Err(format!("position mirror diverged at slot {i}"));
            }
            if !u.alive && self.positions[i] != DEAD_POS {
                return Err(format!("dead slot {i} not DEAD_POS in mirror"));
            }
        }
        let soa_len = self.positions.len().next_multiple_of(SOA_LANES);
        if self.xs.len() != soa_len || self.ys.len() != soa_len || self.zs.len() != soa_len {
            return Err(format!(
                "SoA mirror lens {}/{}/{} != padded capacity {soa_len}",
                self.xs.len(),
                self.ys.len(),
                self.zs.len()
            ));
        }
        for i in 0..soa_len {
            let want = self.positions.get(i).copied().unwrap_or(DEAD_POS);
            let got = Vec3::new(self.xs[i], self.ys[i], self.zs[i]);
            if got != want {
                return Err(format!("SoA mirror diverged at slot {i}: {got:?} != {want:?}"));
            }
        }
        let mut free_seen = std::collections::HashSet::new();
        for &f in &self.free {
            if self.units[f as usize].alive {
                return Err(format!("free slot {f} is alive"));
            }
            if !free_seen.insert(f) {
                return Err(format!("slot {f} twice in free list"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(x: f32) -> Vec3 {
        Vec3::new(x, 0.0, 0.0)
    }

    #[test]
    fn insert_connect_counts() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(b, c);
        assert_eq!(n.len(), 3);
        assert_eq!(n.edge_count(), 2);
        assert!(n.has_edge(a, b) && n.has_edge(b, a));
        assert!(!n.has_edge(a, c));
        n.check_invariants().unwrap();
    }

    #[test]
    fn connect_twice_resets_age() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        n.connect(a, b);
        n.age_edges_of(a, 5.0);
        assert_eq!(n.edges_of(a)[0].age, 5.0);
        n.connect(a, b);
        assert_eq!(n.edges_of(a)[0].age, 0.0);
        assert_eq!(n.edges_of(b)[0].age, 0.0);
        assert_eq!(n.edge_count(), 1);
        n.check_invariants().unwrap();
    }

    #[test]
    fn remove_unit_cleans_edges_and_reuses_slot() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(b, c);
        n.remove(b);
        assert_eq!(n.len(), 2);
        assert_eq!(n.edge_count(), 0);
        assert!(!n.is_alive(b));
        n.check_invariants().unwrap();
        let d = n.insert(v(3.0), 1.0);
        assert_eq!(d, b, "slot reuse");
        assert_eq!(n.capacity(), 3);
        n.check_invariants().unwrap();
    }

    #[test]
    fn aging_is_symmetric() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(a, c);
        n.age_edges_of(a, 1.5);
        assert_eq!(n.edges_of(b)[0].age, 1.5);
        assert_eq!(n.edges_of(c)[0].age, 1.5);
        n.check_invariants().unwrap();
    }

    #[test]
    fn prune_collects_orphans() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        let c = n.insert(v(2.0), 1.0);
        n.connect(a, b);
        n.connect(a, c);
        n.connect(b, c);
        n.age_edges_of(a, 10.0); // ages a-b and a-c
        let mut orphans = Vec::new();
        n.prune_old_edges(a, 5.0, &mut orphans);
        assert_eq!(n.edge_count(), 1); // b-c survives
        assert_eq!(orphans, vec![a]); // a lost all edges
        n.check_invariants().unwrap();
    }

    #[test]
    fn fill_positions_pads_dead_slots() {
        let mut n = Network::new();
        let a = n.insert(v(1.0), 1.0);
        let b = n.insert(v(2.0), 1.0);
        let _c = n.insert(v(3.0), 1.0);
        n.connect(a, b);
        n.remove(b);
        let mut buf = Vec::new();
        let cap = n.fill_positions(&mut buf, 1e30);
        assert_eq!(cap, 3);
        assert_eq!(buf.len(), 9);
        assert_eq!(buf[0], 1.0);
        assert_eq!(buf[3], 1e30);
        assert_eq!(buf[6], 3.0);
    }

    #[test]
    fn link_class_of_triangle_fan() {
        let mut n = Network::new();
        let hub = n.insert(v(0.0), 1.0);
        let r1 = n.insert(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let r2 = n.insert(Vec3::new(0.0, 1.0, 0.0), 1.0);
        let r3 = n.insert(Vec3::new(-1.0, 0.0, 0.0), 1.0);
        for r in [r1, r2, r3] {
            n.connect(hub, r);
        }
        assert_eq!(n.link_class(hub), LinkClass::Dust);
        n.connect(r1, r2);
        n.connect(r2, r3);
        assert_eq!(n.link_class(hub), LinkClass::HalfDisk);
        n.connect(r3, r1);
        assert_eq!(n.link_class(hub), LinkClass::Disk);
    }

    #[test]
    fn to_mesh_exports_cliques() {
        let mut n = Network::new();
        let a = n.insert(Vec3::new(0.0, 0.0, 0.0), 1.0);
        let b = n.insert(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let c = n.insert(Vec3::new(0.0, 1.0, 0.0), 1.0);
        n.connect(a, b);
        n.connect(b, c);
        n.connect(c, a);
        let m = n.to_mesh();
        assert_eq!(m.faces.len(), 1);
        assert_eq!(m.vertices.len(), 3);
    }

    #[test]
    fn soa_mirror_tracks_mutations_and_pads_to_lanes() {
        let mut n = Network::new();
        let mut ids = Vec::new();
        // Cross a lane boundary so both the padded tail and a full lane
        // block are exercised.
        for k in 0..SOA_LANES + 3 {
            ids.push(n.insert(v(k as f32), 1.0));
        }
        n.check_invariants().unwrap();
        let (xs, ys, zs) = n.soa();
        assert_eq!(xs.len(), 2 * SOA_LANES);
        assert_eq!(ys.len(), 2 * SOA_LANES);
        assert_eq!(zs.len(), 2 * SOA_LANES);
        assert_eq!(xs[3], 3.0);
        assert_eq!(xs[2 * SOA_LANES - 1], DEAD_POS.x, "padding poisoned");

        n.set_pos(ids[2], Vec3::new(7.0, 8.0, 9.0));
        n.remove(ids[4]);
        n.check_invariants().unwrap();
        let (xs, ys, zs) = n.soa();
        assert_eq!((xs[2], ys[2], zs[2]), (7.0, 8.0, 9.0));
        assert_eq!(xs[4], DEAD_POS.x, "dead slot poisoned");

        let reused = n.insert(v(42.0), 1.0);
        assert_eq!(reused, ids[4], "slot reuse");
        n.check_invariants().unwrap();
        assert_eq!(n.soa().0[4], 42.0);
    }

    #[test]
    fn ids_iterates_alive_only() {
        let mut n = Network::new();
        let a = n.insert(v(0.0), 1.0);
        let b = n.insert(v(1.0), 1.0);
        n.remove(a);
        let ids: Vec<UnitId> = n.ids().collect();
        assert_eq!(ids, vec![b]);
    }
}
