//! Convergence drivers — the paper's four experimental implementations
//! plus this reproduction's two Update-phase drivers.
//!
//! Every driver shares one Update-phase implementation,
//! [`crate::coordinator::BatchExecutor`] (winner locks, staleness guard,
//! random order, merged per-batch index sync); the single-signal drivers
//! are its degenerate `m = 1` case. The six-driver matrix:
//!
//! | driver | iteration | Find Winners | Update phase |
//! |---|---|---|---|
//! | single | basic (m = 1) | `Scalar` dispatched-SIMD exhaustive | executor, m = 1 |
//! | indexed | basic (m = 1) | `Indexed` spatial hash | executor, m = 1 |
//! | multi | multi-signal (§2.2) | `BatchRust` SoA-tiled scan (`find_threads` sharding) | executor, sequential |
//! | pjrt | multi-signal (§2.2) | `runtime::PjrtFindWinners` (AOT/PJRT) — quarantined at config level, programmatic only | executor, sequential |
//! | pipelined | multi-signal, Sample(k+1) overlaps Update(k) | `BatchRust` | executor, pooled (`update_threads`) |
//! | parallel | multi-signal (§2.2) | `BatchRust` | executor, pooled (`update_threads`) |
//!
//! The `Scalar`/`BatchRust` scans run on the runtime-dispatched
//! explicit-SIMD block kernel (`fw_isa` knob, resolved in
//! [`make_findwinners`]; see [`crate::findwinners::simd`]) — every tier
//! bit-identical, so the dispatch never shows up in results.
//!
//! The batched drivers share one persistent [`WorkerPool`] per run (created
//! in [`run_convergence`]): the `Parallel` and `Pipelined` executors plan
//! and commit on it and `BatchRust` shards `find2_batch` signals across it
//! (`find_threads`), all through work-stealing chunk claims. They also
//! share the run's optional region partition (`regions` knob, built in
//! [`run_convergence`] over the sampler's bounding volume): `BatchRust`
//! scans only each signal's region neighborhood (exact, global fallback)
//! and the executors run the region-aware admission/plan/commit schedule
//! in which insertion-only structural updates commit concurrently —
//! bit-identical to `multi` for any region count
//! (`rust/tests/executor_parity.rs`).
//!
//! The first four are the paper's experimental columns (§3.1). `pipelined`
//! and `parallel` answer its future-work note ("the parallelization of the
//! Update phase"): the former hides the Sample phase behind Update via a
//! prefetching sampler thread (`queue_depth` backpressure) — composed, as
//! of PR 3, with the same pooled Update split as `parallel` — the latter
//! plans conflict-disjoint adapt updates on `update_threads` workers,
//! commits their network writes concurrently through the sharded slab and
//! replays the shared scalars in admission order — producing final
//! networks bit-identical to `multi` for any thread count
//! (`rust/tests/executor_parity.rs`).
//!
//! `Multi` and `Pjrt` share every line of driver code and every RNG draw, so
//! they replicate the paper's property that the multi-signal reference and
//! the accelerated implementation "reach exactly the same final
//! configuration, since they are meant to replicate the same behavior by
//! design" (§3.1) — enforced by `rust/tests/parity.rs`.
//!
//! Since PR 5 every entrypoint above is a thin wrapper over the resumable
//! [`SessionCore`] loop (see [`session`](self::ConvergenceSession)): the
//! same iteration bodies, steppable at batch granularity — which is what
//! the fleet scheduler ([`crate::fleet`]) multiplexes and the snapshot
//! format ([`crate::fleet::snapshot`]) checkpoints bit-exactly.

mod report;
mod session;

pub use report::{RunReport, TracePoint};
pub use session::{ConvergenceSession, SessionCore, SessionMode};

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::{Algorithm, Driver, Limits, RunConfig};
use crate::coordinator::BatchExecutor;
use crate::findwinners::{BatchRust, FindWinners, Indexed, Scalar};
use crate::mesh::{Mesh, SurfaceSampler};
use crate::rng::Rng;
use crate::runtime::{resolve_threads, WorkerPool};
use crate::som::{Gng, GrowingNetwork, Gwr, RegionMap, Soam};

/// The paper's parallelism schedule (§3.1): "the level of parallelism m at
/// each iteration … is set to the minimum power of two greater than the
/// current number of units", capped at `max_parallelism`.
/// (Thin wrapper over [`crate::coordinator::MSchedule`].)
#[inline]
pub fn m_schedule(units: usize, max_parallelism: usize) -> usize {
    crate::coordinator::MSchedule::new(max_parallelism).m(units)
}

/// Run the single-signal basic iteration to convergence — the degenerate
/// `m = 1` case of the shared [`BatchExecutor`] (the one-element batch
/// draws no permutation RNG, its lock always succeeds and its staleness
/// guard is empty, so this is the classic loop exactly). A thin wrapper
/// over [`SessionCore`] in `SingleSignal` mode.
pub fn run_single_signal(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
) -> RunReport {
    let impl_name = fw.name();
    let mut core = SessionCore::start(
        SessionMode::SingleSignal,
        impl_name,
        BatchExecutor::new(1),
        *limits,
        algo,
        sampler,
        fw,
        rng,
    );
    core.run_to_end(algo, sampler, fw, rng);
    core.finish(algo)
}

/// Shared multi-signal convergence loop: Sample m → batched Find Winners →
/// Update through the executor → housekeeping. `run_multi_signal` and
/// `run_parallel` are thin wrappers differing only in the executor's
/// thread count (and the report's implementation label) — both drive one
/// [`SessionCore`] in `Batched` mode to completion.
fn run_batched_loop(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
    impl_name: &str,
    executor: BatchExecutor,
) -> RunReport {
    let mut core = SessionCore::start(
        SessionMode::Batched,
        impl_name,
        executor,
        *limits,
        algo,
        sampler,
        fw,
        rng,
    );
    core.run_to_end(algo, sampler, fw, rng);
    core.finish(algo)
}

/// Run the multi-signal iteration (§2.2) to convergence.
///
/// Collision rule: an "implicit lock on the winner unit" — of all signals in
/// the batch sharing a winner, only the first in a random order is applied;
/// the rest are discarded and counted. Signals whose winners died earlier in
/// the same batch (stale winners) are likewise discarded.
pub fn run_multi_signal(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
) -> RunReport {
    let name = fw.name();
    run_batched_loop(algo, sampler, fw, limits, rng, name, BatchExecutor::new(1))
}

/// Run the multi-signal iteration with the Update phase's adapt plans
/// computed on `update_threads` workers (0 = auto-detect). Admission,
/// commit order and every floating-point result match [`run_multi_signal`]
/// bit-for-bit regardless of the thread count — see
/// `coordinator::executor` for the protocol.
pub fn run_parallel(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
    update_threads: usize,
) -> RunReport {
    run_batched_loop(
        algo,
        sampler,
        fw,
        limits,
        rng,
        "parallel",
        BatchExecutor::new(update_threads),
    )
}

/// Resolved `(find_threads, update_threads)` worker widths for a config —
/// the single source of the driver → thread mapping, shared by
/// [`run_convergence`], [`ConvergenceSession`] and the fleet's shared-pool
/// sizing (`fleet::Fleet::new`). `find_threads` only applies to the
/// drivers whose batched scan runs in `BatchRust` (single-signal drivers
/// have no batch to shard; the pjrt scan runs inside the XLA executable),
/// `update_threads` only to the drivers with a pooled Update split.
pub fn resolve_run_threads(cfg: &RunConfig) -> (usize, usize) {
    let find_threads = match cfg.driver {
        Driver::Multi | Driver::Pipelined | Driver::Parallel => {
            resolve_threads(cfg.find_threads)
        }
        Driver::Single | Driver::Indexed | Driver::Pjrt => 1,
    };
    let update_threads = match cfg.driver {
        Driver::Parallel | Driver::Pipelined => resolve_threads(cfg.update_threads),
        _ => 1,
    };
    (find_threads, update_threads)
}

/// The run's region partition for a config over `bounds` — the single
/// source of the driver/knob → region gating (shared like
/// [`resolve_run_threads`]). `None` when the driver has no `BatchRust`
/// scan, the knob is off, or degenerate bounds collapse the grid to one
/// region (a one-region schedule would coarsen every conflict to
/// "always", flushing per signal).
pub fn build_region_map(cfg: &RunConfig, bounds: crate::geometry::Aabb) -> Option<RegionMap> {
    match cfg.driver {
        Driver::Multi | Driver::Pipelined | Driver::Parallel if cfg.regions > 1 => {
            let map = RegionMap::new(bounds, cfg.regions);
            (map.region_count() > 1).then_some(map)
        }
        _ => None,
    }
}

/// Build the algorithm selected by `cfg`.
pub fn make_algorithm(cfg: &RunConfig) -> Box<dyn GrowingNetwork> {
    match cfg.algorithm {
        Algorithm::Soam => Box::new(Soam::new(cfg.soam)),
        Algorithm::Gwr => Box::new(Gwr::new(cfg.gwr)),
        Algorithm::Gng => Box::new(Gng::new(cfg.gng)),
    }
}

/// Build the Find-Winners strategy selected by `cfg` (Pjrt requires the AOT
/// artifacts; fails with a pointer to `make artifacts` when missing).
///
/// Also resolves the Find-Winners SIMD dispatch tier (`cfg.fw_isa`) before
/// any kernel runs: a forced tier the host cannot execute fails the build
/// loudly instead of hitting undefined behavior later. Every construction
/// path — [`run`], [`ConvergenceSession::new`], fleet jobs — funnels
/// through here, so the knob applies everywhere.
pub fn make_findwinners(cfg: &RunConfig) -> Result<Box<dyn FindWinners>> {
    crate::findwinners::simd::set_override(cfg.fw_isa).map_err(|e| anyhow!(e))?;
    Ok(match cfg.driver {
        Driver::Single => Box::new(Scalar::new()),
        Driver::Indexed => Box::new(Indexed::new(cfg.index_cell)),
        Driver::Multi | Driver::Pipelined | Driver::Parallel => {
            Box::new(BatchRust::new(cfg.batch_tile))
        }
        Driver::Pjrt => Box::new(crate::runtime::PjrtFindWinners::from_config(cfg)?),
    })
}

/// Dispatch to the convergence driver selected by `cfg.driver`, reusing a
/// caller-built algorithm and Find-Winners strategy (the CLI's
/// `--save-mesh` re-run needs the algorithm back; [`run`] wraps this).
///
/// This is where the run's one persistent [`WorkerPool`] is created: sized
/// for `max(update_threads, find_threads)`, attached to the Find-Winners
/// strategy for `find_threads` signal sharding and handed to the
/// `Parallel`/`Pipelined` drivers' executor for the plan pass and the
/// concurrent commit. Workers are created once here and live for the
/// whole run — no driver spawns threads per flush.
///
/// It is also where the run's region partition (`cfg.regions > 1`) is
/// built — one [`RegionMap`] over the sampler's bounding volume, shared by
/// the Find-Winners region scan and the executors' region-aware schedule —
/// for the same driver set as `find_threads` (the scan lives in
/// `BatchRust`; pjrt scans inside the XLA executable).
pub fn run_convergence(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    cfg: &RunConfig,
    rng: &mut Rng,
) -> RunReport {
    let (find_threads, update_threads) = resolve_run_threads(cfg);
    let region_map = build_region_map(cfg, sampler.bounds());
    if let Some(map) = &region_map {
        fw.attach_regions(map.clone());
    }
    let pool = (find_threads > 1 || update_threads > 1)
        .then(|| Arc::new(WorkerPool::new(find_threads.max(update_threads))));
    if find_threads > 1 {
        let pool = pool.as_ref().expect("pool sized for find_threads");
        fw.attach_pool(Arc::clone(pool), find_threads);
    }
    let make_executor = |pool: Option<Arc<WorkerPool>>| {
        let mut exec = BatchExecutor::with_pool(update_threads, pool);
        if let Some(map) = region_map.clone() {
            exec.set_regions(map);
        }
        exec
    };
    match cfg.driver {
        Driver::Pipelined => crate::coordinator::run_pipelined(
            algo,
            sampler,
            fw,
            &cfg.limits,
            rng,
            cfg.queue_depth,
            make_executor(pool),
        ),
        Driver::Parallel => run_batched_loop(
            algo,
            sampler,
            fw,
            &cfg.limits,
            rng,
            "parallel",
            make_executor(pool),
        ),
        Driver::Multi | Driver::Pjrt => run_multi_signal(algo, sampler, fw, &cfg.limits, rng),
        Driver::Single | Driver::Indexed => {
            run_single_signal(algo, sampler, fw, &cfg.limits, rng)
        }
    }
}

/// End-to-end convenience: build sampler/algorithm/strategy from `cfg` and
/// run the appropriate driver on `mesh`.
pub fn run(mesh: &Mesh, driver: Driver, cfg: &RunConfig, rng: &mut Rng) -> Result<RunReport> {
    if mesh.is_empty() {
        bail!("cannot run on an empty mesh");
    }
    let mut cfg = cfg.clone();
    cfg.driver = driver;
    let sampler = SurfaceSampler::new(mesh);
    let mut algo = make_algorithm(&cfg);
    let mut fw = make_findwinners(&cfg)?;
    let mut report = run_convergence(algo.as_mut(), &sampler, fw.as_mut(), &cfg, rng);
    report.mesh = Some(cfg.shape.name().to_string());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    #[test]
    fn m_schedule_matches_paper() {
        assert_eq!(m_schedule(5, 8192), 8);
        assert_eq!(m_schedule(7, 8192), 8);
        assert_eq!(m_schedule(8, 8192), 16, "strictly greater than units");
        assert_eq!(m_schedule(330, 8192), 512);
        assert_eq!(m_schedule(15_638, 8192), 8192, "capped at 8192");
        assert_eq!(m_schedule(0, 8192), 2);
    }

    fn quick_cfg(shape: BenchmarkShape) -> RunConfig {
        let mut cfg = RunConfig::preset(shape);
        cfg.soam.insertion_threshold = 0.15;
        cfg.gwr.insertion_threshold = 0.15;
        cfg.limits.max_signals = 30_000;
        cfg.limits.check_interval = 500;
        cfg
    }

    #[test]
    fn single_driver_runs_and_accounts() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng = Rng::seed_from(1);
        let r = run(&mesh, Driver::Single, &cfg, &mut rng).unwrap();
        assert_eq!(r.signals, r.iterations);
        assert_eq!(r.discarded, 0, "single-signal never discards");
        assert!(r.units > 4);
        assert!(r.total.as_nanos() > 0);
    }

    #[test]
    fn multi_driver_accounts_signals_and_discards() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng = Rng::seed_from(1);
        let r = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
        assert!(r.iterations < r.signals, "m >> 1");
        assert!(r.discarded > 0, "winner locks must discard some signals");
        assert!(r.discarded < r.signals);
        assert!(r.units > 4);
    }

    #[test]
    fn indexed_driver_matches_single_roughly() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng1 = Rng::seed_from(7);
        let mut rng2 = Rng::seed_from(7);
        let a = run(&mesh, Driver::Single, &cfg, &mut rng1).unwrap();
        let b = run(&mesh, Driver::Indexed, &cfg, &mut rng2).unwrap();
        // Same seed, approximate index: unit counts in the same regime.
        let ratio = a.units as f64 / b.units as f64;
        assert!((0.5..2.0).contains(&ratio), "{} vs {}", a.units, b.units);
    }

    #[test]
    fn multi_equals_batchrust_configuration_under_same_seed() {
        // The exact-parity test against PJRT lives in rust/tests/parity.rs;
        // here: the multi driver is deterministic for a fixed seed.
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng1 = Rng::seed_from(3);
        let mut rng2 = Rng::seed_from(3);
        let a = run(&mesh, Driver::Multi, &cfg, &mut rng1).unwrap();
        let b = run(&mesh, Driver::Multi, &cfg, &mut rng2).unwrap();
        assert_eq!(a.units, b.units);
        assert_eq!(a.connections, b.connections);
        assert_eq!(a.signals, b.signals);
        assert_eq!(a.discarded, b.discarded);
    }

    #[test]
    fn parallel_driver_matches_multi_reports() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let mut cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng1 = Rng::seed_from(13);
        let a = run(&mesh, Driver::Multi, &cfg, &mut rng1).unwrap();
        for update_threads in [0, 1, 3] {
            cfg.update_threads = update_threads;
            let mut rng2 = Rng::seed_from(13);
            let b = run(&mesh, Driver::Parallel, &cfg, &mut rng2).unwrap();
            assert_eq!(a.units, b.units, "threads={update_threads}");
            assert_eq!(a.connections, b.connections, "threads={update_threads}");
            assert_eq!(a.signals, b.signals, "threads={update_threads}");
            assert_eq!(a.discarded, b.discarded, "threads={update_threads}");
            assert_eq!(a.iterations, b.iterations, "threads={update_threads}");
            assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "threads={update_threads}");
        }
    }

    #[test]
    fn find_threads_does_not_change_results() {
        // Sharding Find Winners across the pool computes each signal
        // independently — any shard count must reproduce the sequential
        // run exactly, for both the multi and parallel drivers.
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let mut cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng = Rng::seed_from(17);
        let a = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
        for (driver, find_threads, update_threads) in [
            (Driver::Multi, 2, 1),
            (Driver::Multi, 7, 1),
            (Driver::Parallel, 2, 3),
            (Driver::Parallel, 0, 0),
        ] {
            cfg.find_threads = find_threads;
            cfg.update_threads = update_threads;
            let mut rng2 = Rng::seed_from(17);
            let b = run(&mesh, driver, &cfg, &mut rng2).unwrap();
            let label = format!("{} find={find_threads} upd={update_threads}", driver.name());
            assert_eq!(a.units, b.units, "{label}");
            assert_eq!(a.connections, b.connections, "{label}");
            assert_eq!(a.signals, b.signals, "{label}");
            assert_eq!(a.discarded, b.discarded, "{label}");
            assert_eq!(a.iterations, b.iterations, "{label}");
            assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        }
    }

    #[test]
    fn regions_do_not_change_results() {
        // The region partition gates both the Find Winners scan (exact
        // with fallback) and the executor schedule (flush timing only) —
        // any region count must reproduce the no-region run exactly, for
        // the multi AND parallel drivers.
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let mut cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng = Rng::seed_from(23);
        let a = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
        // (Pipelined is not a bit-replica of multi — its m-schedule lags a
        // batch — so its invariance in `regions` is covered by
        // rust/tests/executor_parity.rs instead.)
        for (driver, regions, update_threads) in [
            (Driver::Multi, 8usize, 1usize),
            (Driver::Multi, 64, 1),
            (Driver::Parallel, 8, 3),
            (Driver::Parallel, 64, 0),
        ] {
            cfg.regions = regions;
            cfg.update_threads = update_threads;
            let mut rng2 = Rng::seed_from(23);
            let b = run(&mesh, driver, &cfg, &mut rng2).unwrap();
            let label = format!("{} regions={regions} upd={update_threads}", driver.name());
            assert_eq!(a.units, b.units, "{label}");
            assert_eq!(a.connections, b.connections, "{label}");
            assert_eq!(a.signals, b.signals, "{label}");
            assert_eq!(a.discarded, b.discarded, "{label}");
            assert_eq!(a.iterations, b.iterations, "{label}");
            assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        }
    }

    #[test]
    fn pipelined_driver_runs_from_config() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let mut cfg = quick_cfg(BenchmarkShape::Blob);
        cfg.queue_depth = 3;
        let mut rng = Rng::seed_from(2);
        let r = run(&mesh, Driver::Pipelined, &cfg, &mut rng).unwrap();
        assert_eq!(r.implementation, "pipelined");
        assert!(r.units > 4);
        assert!(r.discarded > 0);
    }

    #[test]
    fn gng_runs_under_both_drivers() {
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
        let mut cfg = quick_cfg(BenchmarkShape::Eight);
        cfg.algorithm = Algorithm::Gng;
        cfg.limits.max_signals = 5_000;
        let mut rng = Rng::seed_from(5);
        let r1 = run(&mesh, Driver::Single, &cfg, &mut rng).unwrap();
        let r2 = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
        assert!(r1.units > 10);
        assert!(r2.units > 10);
    }
}
