//! Convergence drivers — the paper's four experimental implementations.
//!
//! [`run_single_signal`] is the classic basic iteration (one signal per
//! iteration); [`run_multi_signal`] is the paper's contribution (§2.2): `m`
//! signals per iteration, batched Find Winners, sequential Update under the
//! winner-lock collision rule. Both are generic over the
//! [`FindWinners`] strategy, which yields the paper's grid:
//!
//! | paper column | driver | strategy |
//! |---|---|---|
//! | Single-signal | single | `Scalar` |
//! | Indexed | single | `Indexed` |
//! | Multi-signal | multi | `BatchRust` |
//! | GPU-based | multi | `runtime::PjrtFindWinners` |
//!
//! `Multi` and `Pjrt` share every line of driver code and every RNG draw, so
//! they replicate the paper's property that the multi-signal reference and
//! the accelerated implementation "reach exactly the same final
//! configuration, since they are meant to replicate the same behavior by
//! design" (§3.1) — enforced by `rust/tests/parity.rs`.

mod report;

pub use report::{RunReport, TracePoint};

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{Algorithm, Driver, Limits, RunConfig};
use crate::findwinners::{BatchRust, FindWinners, Indexed, Scalar};
use crate::geometry::Vec3;
use crate::mesh::{Mesh, SurfaceSampler};
use crate::metrics::{Phase, PhaseClock, PhaseTimes};
use crate::rng::Rng;
use crate::som::{ChangeLog, Gng, GrowingNetwork, Gwr, Soam, Winners};

/// The paper's parallelism schedule (§3.1): "the level of parallelism m at
/// each iteration … is set to the minimum power of two greater than the
/// current number of units", capped at `max_parallelism`.
/// (Thin wrapper over [`crate::coordinator::MSchedule`].)
#[inline]
pub fn m_schedule(units: usize, max_parallelism: usize) -> usize {
    crate::coordinator::MSchedule::new(max_parallelism).m(units)
}

/// Run the single-signal basic iteration to convergence.
pub fn run_single_signal(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
) -> RunReport {
    let start = Instant::now();
    let mut phase = PhaseTimes::default();
    let mut report = RunReport::new(algo.name(), fw.name());
    let mut log = ChangeLog::default();
    algo.init(sampler, rng);
    fw.rebuild(algo.net());

    loop {
        // 1. Sample.
        let clock = PhaseClock::start();
        let signal = sampler.sample(rng);
        clock.stop(&mut phase, Phase::Sample);

        // 2. Find Winners.
        let clock = PhaseClock::start();
        let winners = fw.find2(algo.net(), signal);
        clock.stop(&mut phase, Phase::FindWinners);

        // 3. Update.
        let clock = PhaseClock::start();
        if let Some(w) = winners {
            log.clear();
            algo.update(signal, &w, &mut log);
            fw.sync(algo.net(), &log);
        }
        clock.stop(&mut phase, Phase::Update);

        report.signals += 1;
        report.iterations += 1;

        if report.signals % limits.check_interval == 0 {
            log.clear();
            let converged = algo.housekeeping(&mut log);
            if !log.is_empty() {
                fw.sync(algo.net(), &log);
            }
            if limits.trace {
                report.push_trace(algo, &phase);
            }
            if converged {
                report.converged = true;
                break;
            }
        }
        if report.signals >= limits.max_signals {
            break;
        }
    }

    report.finish(algo, phase, start.elapsed());
    report
}

/// Run the multi-signal iteration (§2.2) to convergence.
///
/// Collision rule: an "implicit lock on the winner unit" — of all signals in
/// the batch sharing a winner, only the first in a random order is applied;
/// the rest are discarded and counted. Signals whose winners died earlier in
/// the same batch (stale winners) are likewise discarded.
pub fn run_multi_signal(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
) -> RunReport {
    let start = Instant::now();
    let mut phase = PhaseTimes::default();
    let mut report = RunReport::new(algo.name(), fw.name());
    let mut log = ChangeLog::default();
    algo.init(sampler, rng);
    fw.rebuild(algo.net());

    // Reused buffers (allocation-free steady state).
    let mut signals: Vec<Vec3> = Vec::new();
    let mut winners: Vec<Option<Winners>> = Vec::new();
    let mut order: Vec<u32> = Vec::new();
    // "Implicit lock on the winner unit" (paper §2.2).
    let mut locks = crate::coordinator::LockTable::new();
    // Units inserted during the current batch: a later signal whose stale
    // winners are farther than one of these has effectively been won by the
    // new unit — apply the paper's staleness policy and discard it
    // (otherwise several stale winners around one gap each insert a unit
    // into it and the network over-grows).
    let mut batch_inserted: Vec<Vec3> = Vec::new();

    loop {
        report.iterations += 1;
        let m = m_schedule(algo.net().len(), limits.max_parallelism);

        // 1. Sample m signals.
        let clock = PhaseClock::start();
        sampler.sample_batch(rng, m, &mut signals);
        clock.stop(&mut phase, Phase::Sample);

        // 2. Batched Find Winners.
        let clock = PhaseClock::start();
        fw.find2_batch(algo.net(), &signals, &mut winners);
        clock.stop(&mut phase, Phase::FindWinners);

        // 3. Update in random order under winner locks.
        let clock = PhaseClock::start();
        rng.permutation(m, &mut order);
        locks.next_batch();
        locks.ensure_capacity(algo.net().capacity());
        batch_inserted.clear();
        for &j in &order {
            let w = match winners[j as usize] {
                Some(w) => w,
                None => {
                    report.discarded += 1;
                    continue;
                }
            };
            let signal = signals[j as usize];
            // Stale winners (removed earlier in this batch, or superseded
            // by a unit inserted earlier in this batch) and locked winners
            // all discard the signal.
            if !algo.net().is_alive(w.w1)
                || !algo.net().is_alive(w.w2)
                || batch_inserted.iter().any(|p| signal.dist2(*p) < w.d1_sq)
                || !locks.try_lock(w.w1)
            {
                report.discarded += 1;
                continue;
            }
            log.clear();
            algo.update(signal, &w, &mut log);
            for &id in &log.inserted {
                batch_inserted.push(algo.net().pos(id));
            }
            fw.sync(algo.net(), &log);
        }
        clock.stop(&mut phase, Phase::Update);

        report.signals += m as u64;

        log.clear();
        let converged = algo.housekeeping(&mut log);
        if !log.is_empty() {
            fw.sync(algo.net(), &log);
        }
        if limits.trace {
            report.push_trace(algo, &phase);
        }
        if converged {
            report.converged = true;
            break;
        }
        if report.signals >= limits.max_signals {
            break;
        }
    }

    report.finish(algo, phase, start.elapsed());
    report
}

/// Build the algorithm selected by `cfg`.
pub fn make_algorithm(cfg: &RunConfig) -> Box<dyn GrowingNetwork> {
    match cfg.algorithm {
        Algorithm::Soam => Box::new(Soam::new(cfg.soam)),
        Algorithm::Gwr => Box::new(Gwr::new(cfg.gwr)),
        Algorithm::Gng => Box::new(Gng::new(cfg.gng)),
    }
}

/// Build the Find-Winners strategy selected by `cfg` (Pjrt requires the AOT
/// artifacts; fails with a pointer to `make artifacts` when missing).
pub fn make_findwinners(cfg: &RunConfig) -> Result<Box<dyn FindWinners>> {
    Ok(match cfg.driver {
        Driver::Single => Box::new(Scalar::new()),
        Driver::Indexed => Box::new(Indexed::new(cfg.index_cell)),
        Driver::Multi => Box::new(BatchRust::new(cfg.batch_tile)),
        Driver::Pjrt => Box::new(crate::runtime::PjrtFindWinners::from_config(cfg)?),
    })
}

/// End-to-end convenience: build sampler/algorithm/strategy from `cfg` and
/// run the appropriate driver on `mesh`.
pub fn run(mesh: &Mesh, driver: Driver, cfg: &RunConfig, rng: &mut Rng) -> Result<RunReport> {
    if mesh.is_empty() {
        bail!("cannot run on an empty mesh");
    }
    let mut cfg = cfg.clone();
    cfg.driver = driver;
    let sampler = SurfaceSampler::new(mesh);
    let mut algo = make_algorithm(&cfg);
    let mut fw = make_findwinners(&cfg)?;
    let mut report = if driver.is_multi_signal() {
        run_multi_signal(algo.as_mut(), &sampler, fw.as_mut(), &cfg.limits, rng)
    } else {
        run_single_signal(algo.as_mut(), &sampler, fw.as_mut(), &cfg.limits, rng)
    };
    report.mesh = Some(cfg.shape.name().to_string());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    #[test]
    fn m_schedule_matches_paper() {
        assert_eq!(m_schedule(5, 8192), 8);
        assert_eq!(m_schedule(7, 8192), 8);
        assert_eq!(m_schedule(8, 8192), 16, "strictly greater than units");
        assert_eq!(m_schedule(330, 8192), 512);
        assert_eq!(m_schedule(15_638, 8192), 8192, "capped at 8192");
        assert_eq!(m_schedule(0, 8192), 2);
    }

    fn quick_cfg(shape: BenchmarkShape) -> RunConfig {
        let mut cfg = RunConfig::preset(shape);
        cfg.soam.insertion_threshold = 0.15;
        cfg.gwr.insertion_threshold = 0.15;
        cfg.limits.max_signals = 30_000;
        cfg.limits.check_interval = 500;
        cfg
    }

    #[test]
    fn single_driver_runs_and_accounts() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng = Rng::seed_from(1);
        let r = run(&mesh, Driver::Single, &cfg, &mut rng).unwrap();
        assert_eq!(r.signals, r.iterations);
        assert_eq!(r.discarded, 0, "single-signal never discards");
        assert!(r.units > 4);
        assert!(r.total.as_nanos() > 0);
    }

    #[test]
    fn multi_driver_accounts_signals_and_discards() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng = Rng::seed_from(1);
        let r = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
        assert!(r.iterations < r.signals, "m >> 1");
        assert!(r.discarded > 0, "winner locks must discard some signals");
        assert!(r.discarded < r.signals);
        assert!(r.units > 4);
    }

    #[test]
    fn indexed_driver_matches_single_roughly() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng1 = Rng::seed_from(7);
        let mut rng2 = Rng::seed_from(7);
        let a = run(&mesh, Driver::Single, &cfg, &mut rng1).unwrap();
        let b = run(&mesh, Driver::Indexed, &cfg, &mut rng2).unwrap();
        // Same seed, approximate index: unit counts in the same regime.
        let ratio = a.units as f64 / b.units as f64;
        assert!((0.5..2.0).contains(&ratio), "{} vs {}", a.units, b.units);
    }

    #[test]
    fn multi_equals_batchrust_configuration_under_same_seed() {
        // The exact-parity test against PJRT lives in rust/tests/parity.rs;
        // here: the multi driver is deterministic for a fixed seed.
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let cfg = quick_cfg(BenchmarkShape::Blob);
        let mut rng1 = Rng::seed_from(3);
        let mut rng2 = Rng::seed_from(3);
        let a = run(&mesh, Driver::Multi, &cfg, &mut rng1).unwrap();
        let b = run(&mesh, Driver::Multi, &cfg, &mut rng2).unwrap();
        assert_eq!(a.units, b.units);
        assert_eq!(a.connections, b.connections);
        assert_eq!(a.signals, b.signals);
        assert_eq!(a.discarded, b.discarded);
    }

    #[test]
    fn gng_runs_under_both_drivers() {
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
        let mut cfg = quick_cfg(BenchmarkShape::Eight);
        cfg.algorithm = Algorithm::Gng;
        cfg.limits.max_signals = 5_000;
        let mut rng = Rng::seed_from(5);
        let r1 = run(&mesh, Driver::Single, &cfg, &mut rng).unwrap();
        let r2 = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
        assert!(r1.units > 10);
        assert!(r2.units > 10);
    }
}
