//! Run reports: everything one row of the paper's Tables 1–4 needs, plus
//! trace points for the figures.

use std::time::Duration;

use crate::metrics::{fmt_sci, fmt_secs, PhaseTimes, Table};
use crate::som::GrowingNetwork;

/// One trace sample (recorded at housekeeping scans when `limits.trace`).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub signals: u64,
    pub units: usize,
    pub qe: f32,
    /// Cumulative Find-Winners seconds per signal so far.
    pub find_per_signal: f64,
}

/// Result of one driver run — the paper's per-column table data.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algorithm: String,
    pub implementation: String,
    pub mesh: Option<String>,
    pub iterations: u64,
    pub signals: u64,
    /// Signals dropped by the winner-lock collision rule (multi-signal only).
    pub discarded: u64,
    pub units: usize,
    pub connections: usize,
    pub converged: bool,
    pub qe: f32,
    pub phase: PhaseTimes,
    pub total: Duration,
    pub trace: Vec<TracePoint>,
}

impl RunReport {
    pub(crate) fn new(algorithm: &str, implementation: &str) -> Self {
        Self {
            algorithm: algorithm.to_string(),
            implementation: implementation.to_string(),
            mesh: None,
            iterations: 0,
            signals: 0,
            discarded: 0,
            units: 0,
            connections: 0,
            converged: false,
            qe: f32::INFINITY,
            phase: PhaseTimes::default(),
            total: Duration::ZERO,
            trace: Vec::new(),
        }
    }

    pub(crate) fn push_trace(&mut self, algo: &dyn GrowingNetwork, phase: &PhaseTimes) {
        self.trace.push(TracePoint {
            signals: self.signals,
            units: algo.net().len(),
            qe: algo.quantization_error(),
            find_per_signal: if self.signals == 0 {
                0.0
            } else {
                phase.find.as_secs_f64() / self.signals as f64
            },
        });
    }

    pub(crate) fn finish(
        &mut self,
        algo: &dyn GrowingNetwork,
        phase: PhaseTimes,
        total: Duration,
    ) {
        self.units = algo.net().len();
        self.connections = algo.net().edge_count();
        self.qe = algo.quantization_error();
        self.phase = phase;
        self.total = total;
    }

    /// Signals that actually changed the network.
    pub fn effective_signals(&self) -> u64 {
        self.signals - self.discarded
    }

    /// Seconds per signal, total (paper's "Time per Signal").
    pub fn time_per_signal(&self) -> f64 {
        if self.signals == 0 {
            0.0
        } else {
            self.total.as_secs_f64() / self.signals as f64
        }
    }

    /// Seconds per signal in Find Winners (paper's per-phase row; the Fig 9
    /// series).
    pub fn find_per_signal(&self) -> f64 {
        if self.signals == 0 {
            0.0
        } else {
            self.phase.find.as_secs_f64() / self.signals as f64
        }
    }

    /// Render as one paper-style table (row labels match Tables 1–4).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["Algorithm".into(), self.algorithm.clone()]);
        t.row(vec!["Implementation".into(), self.implementation.clone()]);
        if let Some(mesh) = &self.mesh {
            t.row(vec!["Mesh".into(), mesh.clone()]);
        }
        t.row(vec!["Iterations".into(), self.iterations.to_string()]);
        t.row(vec!["Signals".into(), self.signals.to_string()]);
        t.row(vec!["Discarded Signals".into(), self.discarded.to_string()]);
        t.row(vec!["Units".into(), self.units.to_string()]);
        t.row(vec!["Connections".into(), self.connections.to_string()]);
        t.row(vec!["Converged".into(), self.converged.to_string()]);
        t.row(vec!["Total Time".into(), fmt_secs(self.total)]);
        t.row(vec!["Sample".into(), fmt_secs(self.phase.sample)]);
        t.row(vec!["Find Winners".into(), fmt_secs(self.phase.find)]);
        t.row(vec!["Update".into(), fmt_secs(self.phase.update)]);
        t.row(vec!["Time per Signal".into(), fmt_sci(self.time_per_signal())]);
        t.row(vec![
            "Find Winners per Signal".into(),
            fmt_sci(self.find_per_signal()),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_signals_subtracts_discards() {
        let mut r = RunReport::new("soam", "multi");
        r.signals = 100;
        r.discarded = 37;
        assert_eq!(r.effective_signals(), 63);
    }

    #[test]
    fn per_signal_rates() {
        let mut r = RunReport::new("soam", "single");
        r.signals = 1000;
        r.total = Duration::from_secs(2);
        r.phase.find = Duration::from_secs(1);
        assert!((r.time_per_signal() - 2e-3).abs() < 1e-12);
        assert!((r.find_per_signal() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn zero_signals_safe() {
        let r = RunReport::new("soam", "single");
        assert_eq!(r.time_per_signal(), 0.0);
        assert_eq!(r.find_per_signal(), 0.0);
    }

    #[test]
    fn table_has_paper_rows() {
        let r = RunReport::new("soam", "multi");
        let rendered = r.to_table().render();
        for row in ["Iterations", "Discarded Signals", "Connections", "Find Winners"] {
            assert!(rendered.contains(row), "missing {row}");
        }
    }
}
