//! Resumable convergence sessions — the loop body of every driver in
//! [`crate::engine`], factored into a state machine that can stop at any
//! batch boundary and continue later (or in another process, via
//! [`crate::fleet::snapshot`]) bit-identically.
//!
//! Two layers:
//!
//! - [`SessionCore`] owns the *loop state*: the [`BatchExecutor`], the
//!   progress counters ([`RunReport`] in the making), phase clocks, the
//!   reused signal/winner buffers, and the mode-specific extras (the
//!   pipelined sampler stream and its lagged batch size). Long-lived
//!   resources — algorithm, sampler, Find-Winners backend, RNG — are
//!   passed into [`SessionCore::step`], which lets the classic borrowed
//!   `run_*` entrypoints and the owning session share one implementation.
//! - [`ConvergenceSession`] owns everything: algorithm, sampler, backend,
//!   RNG and core, wired from a [`RunConfig`] exactly as
//!   [`super::run_convergence`] wires a run (one shared [`WorkerPool`],
//!   one [`crate::som::RegionMap`]). This is the unit the fleet scheduler
//!   multiplexes and the snapshot format captures.
//!
//! ## Modes
//!
//! | mode | one `step(1)` is | housekeeping | used by drivers |
//! |---|---|---|---|
//! | `SingleSignal` | one signal | every `check_interval` signals | single, indexed |
//! | `Batched` | one m-schedule batch | every batch | multi, pjrt, parallel |
//! | `Pipelined` | one batch, lagged m | every batch | pipelined (fleet) |
//!
//! `Pipelined` here is the *synchronous equivalent* of
//! [`crate::coordinator::run_pipelined`]: the sampler thread's forked RNG
//! stream and the one-batch m-schedule lag are reproduced inline, without
//! the thread. The threaded driver's results are a pure function of the
//! request sequence (its own `queue_depth`-invariance property), so the
//! two are bit-identical — enforced by `rust/tests/fleet.rs` — while the
//! synchronous form can stop between any two batches.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{Driver, Limits, RunConfig};
use crate::coordinator::BatchExecutor;
use crate::findwinners::FindWinners;
use crate::geometry::Vec3;
use crate::mesh::{Mesh, SurfaceSampler};
use crate::metrics::{Phase, PhaseClock, PhaseTimes};
use crate::rng::Rng;
use crate::runtime::bytes::{ByteReader, ByteWriter};
use crate::runtime::WorkerPool;
use crate::som::{ChangeLog, GrowingNetwork, Winners};
use crate::telemetry::{self, Counter};

use super::report::RunReport;
use super::{
    build_region_map, m_schedule, make_algorithm, make_findwinners, resolve_run_threads,
};

/// Iteration cadence of a session (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionMode {
    /// One signal per iteration; housekeeping every `check_interval`.
    SingleSignal,
    /// One multi-signal batch per iteration (m from the current unit count).
    Batched,
    /// One batch per iteration with the pipelined driver's semantics: the
    /// batch size lags one iteration and signals come from a forked
    /// sampler stream.
    Pipelined,
}

impl SessionMode {
    /// The cadence a driver runs at.
    pub fn for_driver(driver: Driver) -> SessionMode {
        match driver {
            Driver::Single | Driver::Indexed => SessionMode::SingleSignal,
            Driver::Multi | Driver::Pjrt | Driver::Parallel => SessionMode::Batched,
            Driver::Pipelined => SessionMode::Pipelined,
        }
    }
}

/// The resumable loop state shared by every driver (see module docs).
pub struct SessionCore {
    mode: SessionMode,
    executor: BatchExecutor,
    limits: Limits,
    report: RunReport,
    phase: PhaseTimes,
    log: ChangeLog,
    signals: Vec<Vec3>,
    winners: Vec<Option<Winners>>,
    /// `Pipelined`: the prefetching sampler's forked RNG stream.
    sampler_rng: Option<Rng>,
    /// `Pipelined`: the batch size requested before the previous Update
    /// (the one-batch m-schedule lag of the threaded driver).
    next_m: usize,
    /// Wall time accumulated across `start`/`step` calls (a resumed
    /// session restarts this at the snapshot's value).
    elapsed: Duration,
    done: bool,
}

impl SessionCore {
    /// Initialize the run exactly as the classic drivers do: seed the
    /// algorithm, build the Find-Winners structures, and (pipelined) fork
    /// the sampler stream and request the first batch size.
    #[allow(clippy::too_many_arguments)] // the run's full resource set, by design
    pub fn start(
        mode: SessionMode,
        impl_name: &str,
        executor: BatchExecutor,
        limits: Limits,
        algo: &mut dyn GrowingNetwork,
        sampler: &SurfaceSampler,
        fw: &mut dyn FindWinners,
        rng: &mut Rng,
    ) -> Self {
        let t0 = Instant::now();
        let report = RunReport::new(algo.name(), impl_name);
        algo.init(sampler, rng);
        fw.rebuild(algo.net());
        let (sampler_rng, next_m) = if mode == SessionMode::Pipelined {
            (Some(rng.fork()), m_schedule(algo.net().len(), limits.max_parallelism))
        } else {
            (None, 0)
        };
        Self {
            mode,
            executor,
            limits,
            report,
            phase: PhaseTimes::default(),
            log: ChangeLog::default(),
            signals: Vec::new(),
            winners: Vec::new(),
            sampler_rng,
            next_m,
            elapsed: t0.elapsed(),
            done: false,
        }
    }

    pub fn mode(&self) -> SessionMode {
        self.mode
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Progress counters so far (finalized values come from
    /// [`Self::finish`]).
    pub fn report_so_far(&self) -> &RunReport {
        &self.report
    }

    /// Run up to `iterations` loop iterations (signals or batches,
    /// depending on the mode), stopping early on convergence or the signal
    /// cap. Returns `true` while the run has more work.
    pub fn step(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        sampler: &SurfaceSampler,
        fw: &mut dyn FindWinners,
        rng: &mut Rng,
        iterations: u64,
    ) -> bool {
        if self.done {
            return false;
        }
        let t0 = Instant::now();
        for _ in 0..iterations {
            match self.mode {
                SessionMode::SingleSignal => self.step_single(algo, sampler, fw, rng),
                SessionMode::Batched | SessionMode::Pipelined => {
                    self.step_batched(algo, sampler, fw, rng)
                }
            }
            if self.done {
                break;
            }
        }
        self.elapsed += t0.elapsed();
        !self.done
    }

    /// Drive the run to termination (the classic blocking entrypoints).
    pub fn run_to_end(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        sampler: &SurfaceSampler,
        fw: &mut dyn FindWinners,
        rng: &mut Rng,
    ) {
        while self.step(algo, sampler, fw, rng, u64::MAX) {}
    }

    /// Finalize the report (units, connections, QE, timings). The core
    /// stays usable for inspection but steps no further.
    pub fn finish(&mut self, algo: &dyn GrowingNetwork) -> RunReport {
        self.done = true;
        let mut report = self.report.clone();
        report.finish(algo, self.phase, self.elapsed);
        report
    }

    /// One single-signal iteration — the exact pre-session
    /// `run_single_signal` loop body.
    fn step_single(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        sampler: &SurfaceSampler,
        fw: &mut dyn FindWinners,
        rng: &mut Rng,
    ) {
        let clock = PhaseClock::start();
        let signal = sampler.sample(rng);
        let d = clock.stop(&mut self.phase, Phase::Sample);
        telemetry::add(Counter::PhaseSampleNanos, d.as_nanos() as u64);

        let clock = PhaseClock::start();
        let winners = fw.find2(algo.net(), signal);
        let d = clock.stop(&mut self.phase, Phase::FindWinners);
        telemetry::add(Counter::PhaseFindNanos, d.as_nanos() as u64);

        let clock = PhaseClock::start();
        self.report.discarded +=
            self.executor.run_batch(algo, fw, &[signal], &[winners], rng);
        let d = clock.stop(&mut self.phase, Phase::Update);
        telemetry::add(Counter::PhaseUpdateNanos, d.as_nanos() as u64);

        self.report.signals += 1;
        self.report.iterations += 1;
        telemetry::add(Counter::SignalsProcessed, 1);

        if self.report.signals % self.limits.check_interval == 0 {
            self.log.clear();
            let converged = algo.housekeeping(&mut self.log);
            if !self.log.is_empty() {
                fw.sync(algo.net(), &self.log);
            }
            if self.limits.trace {
                self.report.push_trace(algo, &self.phase);
            }
            if converged {
                self.report.converged = true;
                self.done = true;
            }
        }
        if self.report.signals >= self.limits.max_signals {
            self.done = true;
        }
    }

    /// One batched iteration — the exact pre-session `run_batched_loop`
    /// body, with the pipelined lag folded in for `SessionMode::Pipelined`.
    fn step_batched(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        sampler: &SurfaceSampler,
        fw: &mut dyn FindWinners,
        rng: &mut Rng,
    ) {
        self.report.iterations += 1;
        let m = if self.mode == SessionMode::Pipelined {
            // The threaded driver samples batch k from the forked stream at
            // the size requested BEFORE batch k-1's update, then requests
            // batch k+1 at the pre-update unit count — reproduced inline.
            let m = self.next_m;
            let clock = PhaseClock::start();
            let srng = self.sampler_rng.as_mut().expect("pipelined sampler stream");
            sampler.sample_batch(srng, m, &mut self.signals);
            let d = clock.stop(&mut self.phase, Phase::Sample);
            telemetry::add(Counter::PhaseSampleNanos, d.as_nanos() as u64);
            self.next_m = m_schedule(algo.net().len(), self.limits.max_parallelism);
            m
        } else {
            let m = m_schedule(algo.net().len(), self.limits.max_parallelism);
            let clock = PhaseClock::start();
            sampler.sample_batch(rng, m, &mut self.signals);
            let d = clock.stop(&mut self.phase, Phase::Sample);
            telemetry::add(Counter::PhaseSampleNanos, d.as_nanos() as u64);
            m
        };

        let clock = PhaseClock::start();
        fw.find2_batch(algo.net(), &self.signals, &mut self.winners);
        let d = clock.stop(&mut self.phase, Phase::FindWinners);
        telemetry::add(Counter::PhaseFindNanos, d.as_nanos() as u64);

        let clock = PhaseClock::start();
        self.report.discarded +=
            self.executor.run_batch(algo, fw, &self.signals, &self.winners, rng);
        let d = clock.stop(&mut self.phase, Phase::Update);
        telemetry::add(Counter::PhaseUpdateNanos, d.as_nanos() as u64);

        self.report.signals += m as u64;
        telemetry::add(Counter::SignalsProcessed, m as u64);
        telemetry::add(Counter::Batches, 1);

        self.log.clear();
        let converged = algo.housekeeping(&mut self.log);
        if !self.log.is_empty() {
            fw.sync(algo.net(), &self.log);
        }
        if self.limits.trace {
            self.report.push_trace(algo, &self.phase);
        }
        if converged {
            self.report.converged = true;
            self.done = true;
        } else if self.report.signals >= self.limits.max_signals {
            self.done = true;
        }
    }

    /// Serialize the resumable loop state (counters, pipelined stream,
    /// termination flag). The executor, buffers and phase breakdown are
    /// reconstructed — the executor holds no cross-batch semantic state
    /// and the timing breakdown restarts (wall totals carry over).
    pub fn write_state(&self, w: &mut ByteWriter) {
        w.bool(self.done);
        w.bool(self.report.converged);
        w.u64(self.report.iterations);
        w.u64(self.report.signals);
        w.u64(self.report.discarded);
        w.u64(self.next_m as u64);
        match &self.sampler_rng {
            Some(r) => {
                w.bool(true);
                for s in r.state() {
                    w.u64(s);
                }
            }
            None => w.bool(false),
        }
        w.u64(self.elapsed.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Restore [`Self::write_state`] into a freshly started core. Only
    /// valid right after [`Self::start`] with the same configuration (the
    /// caller re-runs the deterministic init, then overwrites algorithm
    /// and RNG state from the snapshot).
    ///
    /// Termination is *recomputed* against the current limits rather than
    /// trusted from the snapshot: `done` is only ever a function of
    /// convergence and the signal cap, so a run that stopped at
    /// `max_signals` resumes — and continues bit-identically to an
    /// uninterrupted run under the larger cap — when the restored config
    /// raises it (the "give the job a bigger budget" serving knob).
    pub fn read_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let _stored_done = r.bool().map_err(|e| e.to_string())?;
        self.report.converged = r.bool().map_err(|e| e.to_string())?;
        self.report.iterations = r.u64().map_err(|e| e.to_string())?;
        self.report.signals = r.u64().map_err(|e| e.to_string())?;
        self.report.discarded = r.u64().map_err(|e| e.to_string())?;
        self.next_m = r.u64().map_err(|e| e.to_string())? as usize;
        // m_schedule never exceeds max_parallelism, so a larger value is a
        // corrupt snapshot — reject it here instead of letting the first
        // step drive an absurd sample_batch allocation.
        if self.next_m > self.limits.max_parallelism.max(2) {
            return Err(format!(
                "snapshot batch size {} exceeds max_parallelism {}",
                self.next_m, self.limits.max_parallelism
            ));
        }
        let has_stream = r.bool().map_err(|e| e.to_string())?;
        if has_stream != (self.mode == SessionMode::Pipelined) {
            return Err(format!(
                "snapshot sampler stream ({has_stream}) does not match mode {:?}",
                self.mode
            ));
        }
        if has_stream {
            let mut s = [0u64; 4];
            for slot in &mut s {
                *slot = r.u64().map_err(|e| e.to_string())?;
            }
            self.sampler_rng = Some(Rng::from_state(s).map_err(|e| e.to_string())?);
        }
        self.elapsed = Duration::from_nanos(r.u64().map_err(|e| e.to_string())?);
        self.report.trace.clear(); // trace points do not survive a resume
        self.done =
            self.report.converged || self.report.signals >= self.limits.max_signals;
        Ok(())
    }
}

/// A fully-owned resumable run: algorithm + sampler + Find-Winners backend
/// + RNG + [`SessionCore`], wired from a [`RunConfig`] exactly as
/// [`super::run_convergence`] wires a blocking run. This is the unit the
/// fleet scheduler multiplexes over one shared [`WorkerPool`] and the unit
/// [`crate::fleet::snapshot`] checkpoints.
pub struct ConvergenceSession {
    driver: Driver,
    seed: u64,
    /// FNV-1a digest of the semantics-affecting configuration + mesh
    /// identity (see [`semantic_fingerprint`]) — pinned by the snapshot
    /// header so a restore into a *different* run fails loudly.
    fingerprint: u64,
    algo: Box<dyn GrowingNetwork>,
    sampler: SurfaceSampler,
    fw: Box<dyn FindWinners>,
    rng: Rng,
    core: SessionCore,
    /// Diagnostic identity (the fleet sets the job name): names this
    /// session at the `session_step` fault point and in crash reports.
    /// Deliberately **not** part of the fingerprint or the snapshot — a
    /// rename must never invalidate a checkpoint.
    label: Option<String>,
}

/// Digest the parts of a run that change its *results*: the sampled
/// surface (area + bounds — the mesh identity as the sampler sees it) and
/// every semantics-carrying parameter of the active algorithm, plus the
/// housekeeping cadence and the m-schedule cap. Deliberately **excluded**:
/// `max_signals` (raising the cap and resuming is the serving knob — see
/// [`SessionCore::read_state`]), `trace`, and the semantics-free
/// performance knobs (`update_threads`, `find_threads`, `regions`,
/// `queue_depth`, `batch_tile` — all proven bit-invisible by the parity
/// suites). Floats are digested by bit pattern.
fn semantic_fingerprint(cfg: &RunConfig, sampler: &SurfaceSampler) -> u64 {
    // FNV-1a, 64-bit: tiny, dependency-free, stable across builds (unlike
    // `DefaultHasher`, whose algorithm is unspecified).
    struct Fnv(u64);
    impl Fnv {
        fn eat(&mut self, bytes: &[u8]) {
            for &b in bytes {
                self.0 ^= u64::from(b);
                self.0 = self.0.wrapping_mul(0x100_0000_01b3);
            }
        }
        fn f32s(&mut self, vals: &[f32]) {
            for v in vals {
                self.eat(&v.to_bits().to_le_bytes());
            }
        }
        fn u64v(&mut self, v: u64) {
            self.eat(&v.to_le_bytes());
        }
        fn adapt(&mut self, a: &crate::som::AdaptParams) {
            self.f32s(&[a.eps_b, a.eps_n, a.max_age]);
            self.eat(&[u8::from(a.firing_modulation)]);
        }
        fn hab(&mut self, h: &crate::som::Habituation) {
            self.f32s(&[h.alpha, h.tau_b, h.tau_n, h.threshold]);
        }
    }
    let mut fnv = Fnv(0xcbf2_9ce4_8422_2325);
    fnv.u64v(sampler.total_area().to_bits());
    let b = sampler.bounds();
    fnv.f32s(&[b.min.x, b.min.y, b.min.z, b.max.x, b.max.y, b.max.z]);
    fnv.u64v(cfg.limits.check_interval);
    fnv.u64v(cfg.limits.max_parallelism as u64);
    match cfg.algorithm {
        crate::config::Algorithm::Soam => {
            let p = &cfg.soam;
            fnv.adapt(&p.adapt);
            fnv.hab(&p.hab);
            fnv.f32s(&[p.insertion_threshold, p.threshold_decay, p.threshold_floor_frac]);
            fnv.u64v(p.max_units as u64);
        }
        crate::config::Algorithm::Gwr => {
            let p = &cfg.gwr;
            fnv.adapt(&p.adapt);
            fnv.hab(&p.hab);
            fnv.f32s(&[p.insertion_threshold, p.target_qe]);
            fnv.u64v(p.max_units as u64);
        }
        crate::config::Algorithm::Gng => {
            let p = &cfg.gng;
            fnv.adapt(&p.adapt);
            fnv.u64v(p.lambda);
            fnv.f32s(&[p.alpha, p.beta, p.target_qe]);
            fnv.u64v(p.max_units as u64);
        }
    }
    // The Indexed driver's cube size changes its (approximate) results.
    if cfg.driver == Driver::Indexed {
        fnv.f32s(&[cfg.index_cell]);
    }
    fnv.0
}

impl ConvergenceSession {
    /// Build a session for `cfg` over `mesh`. `shared_pool` is the fleet's
    /// one worker pool (sized for the widest job); `None` makes the
    /// session create its own when the resolved thread counts need one —
    /// the exact wiring of [`super::run_convergence`], so a solo session
    /// is bit-identical to the blocking entrypoint.
    pub fn new(cfg: &RunConfig, mesh: &Mesh, shared_pool: Option<Arc<WorkerPool>>) -> Result<Self> {
        if mesh.is_empty() {
            bail!("cannot run on an empty mesh");
        }
        if mesh.total_area() <= 0.0 {
            bail!("cannot sample a zero-area mesh");
        }
        let sampler = SurfaceSampler::new(mesh);
        let mut algo = make_algorithm(cfg);
        let mut fw = make_findwinners(cfg)?;
        let mut rng = Rng::seed_from(cfg.seed);

        // Thread/region wiring — the same resolvers `run_convergence` uses
        // (one source of truth; see `engine::resolve_run_threads`).
        let (find_threads, update_threads) = resolve_run_threads(cfg);
        let region_map = build_region_map(cfg, sampler.bounds());
        if let Some(map) = &region_map {
            fw.attach_regions(map.clone());
        }
        let pool = if find_threads > 1 || update_threads > 1 {
            Some(shared_pool.unwrap_or_else(|| {
                Arc::new(WorkerPool::new(find_threads.max(update_threads)))
            }))
        } else {
            None
        };
        if find_threads > 1 {
            let pool = pool.as_ref().expect("pool sized for find_threads");
            fw.attach_pool(Arc::clone(pool), find_threads);
        }
        let mut executor = BatchExecutor::with_pool(update_threads, pool);
        if let Some(map) = region_map {
            executor.set_regions(map);
        }

        let mode = SessionMode::for_driver(cfg.driver);
        let impl_name = match cfg.driver {
            Driver::Parallel => "parallel",
            Driver::Pipelined => "pipelined",
            _ => fw.name(),
        };
        let core = SessionCore::start(
            mode,
            impl_name,
            executor,
            cfg.limits,
            algo.as_mut(),
            &sampler,
            fw.as_mut(),
            &mut rng,
        );
        let fingerprint = semantic_fingerprint(cfg, &sampler);
        Ok(Self {
            driver: cfg.driver,
            seed: cfg.seed,
            fingerprint,
            algo,
            sampler,
            fw,
            rng,
            core,
            label: None,
        })
    }

    /// Set the diagnostic label (see the `label` field). The fleet passes
    /// the job name so a `session_step` fault scope targets one job.
    pub fn set_label(&mut self, label: impl Into<String>) {
        self.label = Some(label.into());
    }

    /// Run up to `iterations` loop iterations (batches for the batched
    /// modes, signals for single-signal). Returns `true` while more work
    /// remains.
    pub fn step(&mut self, iterations: u64) -> bool {
        // The poison-input simulation point: scope = the fleet job name
        // (None for solo sessions), turn = the session's own monotone
        // iteration counter, so `session_step/<job>:panic@turn=N` crashes
        // deterministically at the same point on every retry.
        crate::runtime::fault::maybe_panic(
            crate::runtime::fault::FaultPoint::SessionStep,
            self.label.as_deref(),
            Some(self.core.report_so_far().iterations),
        );
        self.core.step(
            self.algo.as_mut(),
            &self.sampler,
            self.fw.as_mut(),
            &mut self.rng,
            iterations,
        )
    }

    /// Drive to termination and return the finalized report.
    pub fn run_to_end(&mut self) -> RunReport {
        self.core
            .run_to_end(self.algo.as_mut(), &self.sampler, self.fw.as_mut(), &mut self.rng);
        self.finish()
    }

    /// Finalize the report (idempotent; the session steps no further).
    pub fn finish(&mut self) -> RunReport {
        self.core.finish(self.algo.as_ref())
    }

    pub fn is_done(&self) -> bool {
        self.core.is_done()
    }

    pub fn driver(&self) -> Driver {
        self.driver
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Digest of the semantics-affecting config + mesh identity (see
    /// [`semantic_fingerprint`]'s doc for what is in and what is
    /// deliberately out). Pinned by the snapshot header.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The algorithm (and through it the network) — read access for parity
    /// tests and reporting.
    pub fn algo(&self) -> &dyn GrowingNetwork {
        self.algo.as_ref()
    }

    pub fn report_so_far(&self) -> &RunReport {
        self.core.report_so_far()
    }

    /// Serialize the session's complete resumable state: loop counters,
    /// driver RNG, algorithm + network. (The snapshot file format with its
    /// header/validation lives in [`crate::fleet::snapshot`].)
    pub fn write_state(&self, w: &mut ByteWriter) {
        self.core.write_state(w);
        for s in self.rng.state() {
            w.u64(s);
        }
        self.algo.save_state(w);
    }

    /// Restore [`Self::write_state`] bytes into this freshly-built session
    /// (same config + mesh). The Find-Winners structures are rebuilt from
    /// the restored network, so the next `step` continues bit-identically.
    pub fn read_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        self.core.read_state(r)?;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = r.u64().map_err(|e| e.to_string())?;
        }
        self.rng = Rng::from_state(s).map_err(|e| e.to_string())?;
        self.algo.load_state(r)?;
        self.fw.rebuild(self.algo.net());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::mesh::{benchmark_mesh, BenchmarkShape};

    fn quick_cfg(driver: Driver) -> RunConfig {
        let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
        cfg.driver = driver;
        cfg.soam.insertion_threshold = 0.15;
        cfg.limits.max_signals = 20_000;
        cfg.seed = 11;
        cfg
    }

    /// Stepping a session in arbitrary chunks must equal the blocking
    /// driver bit-for-bit (same config, same seed).
    #[test]
    fn chunked_stepping_matches_blocking_run() {
        for driver in [Driver::Multi, Driver::Parallel, Driver::Single] {
            let cfg = quick_cfg(driver);
            let mesh = benchmark_mesh(cfg.shape, 20);
            let blocking = {
                let mut rng = Rng::seed_from(cfg.seed);
                super::super::run(&mesh, driver, &cfg, &mut rng).unwrap()
            };
            let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            let mut chunk = 1u64;
            while session.step(chunk) {
                chunk = (chunk * 3 + 1) % 17 + 1; // irregular chunking
            }
            let r = session.finish();
            let label = format!("driver {}", driver.name());
            assert_eq!(blocking.iterations, r.iterations, "{label}");
            assert_eq!(blocking.signals, r.signals, "{label}");
            assert_eq!(blocking.discarded, r.discarded, "{label}");
            assert_eq!(blocking.units, r.units, "{label}");
            assert_eq!(blocking.connections, r.connections, "{label}");
            assert_eq!(blocking.qe.to_bits(), r.qe.to_bits(), "{label}");
            assert_eq!(blocking.converged, r.converged, "{label}");
        }
    }

    #[test]
    fn session_reports_driver_metadata() {
        let mut cfg = quick_cfg(Driver::Multi);
        cfg.algorithm = Algorithm::Gng;
        cfg.limits.max_signals = 3_000;
        let mesh = benchmark_mesh(cfg.shape, 20);
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        assert_eq!(session.driver(), Driver::Multi);
        assert_eq!(session.seed(), 11);
        assert!(!session.is_done());
        let r = session.run_to_end();
        assert!(session.is_done());
        assert_eq!(r.algorithm, "gng");
        assert!(r.signals >= 3_000);
    }
}
