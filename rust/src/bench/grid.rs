//! The experiment grid: meshes × implementations, each a full run to
//! convergence (or the scale's signal cap).

use std::path::PathBuf;

use anyhow::Result;

use crate::config::Driver;
use crate::engine::{run, RunReport};
use crate::mesh::{benchmark_mesh, BenchmarkShape};
use crate::rng::Rng;

use super::scale::Scale;

/// One completed cell of the grid.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub shape: BenchmarkShape,
    pub driver: Driver,
    /// Thread knobs the cell ran with (`update_threads`/`find_threads`
    /// from the scale's config — 0 = auto-detect), recorded so the CSV
    /// rows are self-describing.
    pub update_threads: usize,
    pub find_threads: usize,
    pub report: RunReport,
}

/// All completed runs of one reproduction session.
#[derive(Clone, Debug)]
pub struct Grid {
    pub scale: Scale,
    pub seed: u64,
    pub cells: Vec<GridCell>,
}

impl Grid {
    pub fn get(&self, shape: BenchmarkShape, driver: Driver) -> Option<&RunReport> {
        self.cells
            .iter()
            .find(|c| c.shape == shape && c.driver == driver)
            .map(|c| &c.report)
    }

    pub fn shapes(&self) -> Vec<BenchmarkShape> {
        let mut out = Vec::new();
        for c in &self.cells {
            if !out.contains(&c.shape) {
                out.push(c.shape);
            }
        }
        out
    }

    /// The grid rows as one CSV (results/grid-<scale>.csv).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "mesh,driver,scale,seed,iterations,signals,discarded,units,\
             connections,converged,total_s,sample_s,find_s,update_s,\
             time_per_signal,find_per_signal,qe,update_threads,find_threads\n",
        );
        for c in &self.cells {
            let r = &c.report;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6e},{:.6e},{:.6e},{},{}\n",
                c.shape.name(),
                c.driver.name(),
                self.scale.name,
                self.seed,
                r.iterations,
                r.signals,
                r.discarded,
                r.units,
                r.connections,
                r.converged,
                r.total.as_secs_f64(),
                r.phase.sample.as_secs_f64(),
                r.phase.find.as_secs_f64(),
                r.phase.update.as_secs_f64(),
                r.time_per_signal(),
                r.find_per_signal(),
                r.qe,
                c.update_threads,
                c.find_threads,
            ));
        }
        out
    }
}

/// Run every (shape, driver) combination. `progress` receives one line per
/// started/finished run (the CLI prints them; tests pass a sink).
pub fn run_grid(
    shapes: &[BenchmarkShape],
    drivers: &[Driver],
    scale: &Scale,
    seed: u64,
    artifacts_dir: Option<PathBuf>,
    mut progress: impl FnMut(&str),
) -> Result<Grid> {
    let mut cells = Vec::new();
    for &shape in shapes {
        let cfg0 = scale.configure(shape);
        progress(&format!(
            "mesh {} (threshold {:.4}, resolution {})",
            shape.name(),
            cfg0.soam.insertion_threshold,
            if cfg0.mesh_resolution == 0 {
                shape.default_resolution()
            } else {
                cfg0.mesh_resolution
            },
        ));
        let mesh = benchmark_mesh(shape, cfg0.mesh_resolution);
        for &driver in drivers {
            let mut cfg = cfg0.clone();
            if let Some(dir) = &artifacts_dir {
                cfg.artifacts_dir = dir.clone();
            }
            // Every driver sees the same seed — the paper's protocol (same
            // shared parameters, same signal distribution).
            let mut rng = Rng::seed_from(seed);
            let t0 = std::time::Instant::now();
            let report = run(&mesh, driver, &cfg, &mut rng)?;
            progress(&format!(
                "  {:8} {:>9} units={} conns={} signals={} discarded={} {}",
                driver.name(),
                format!("{:.2}s", t0.elapsed().as_secs_f64()),
                report.units,
                report.connections,
                report.signals,
                report.discarded,
                if report.converged { "converged" } else { "CAP HIT" },
            ));
            cells.push(GridCell {
                shape,
                driver,
                update_threads: cfg.update_threads,
                find_threads: cfg.find_threads,
                report,
            });
        }
    }
    Ok(Grid { scale: *scale, seed, cells })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_single_and_multi() {
        let grid = run_grid(
            &[BenchmarkShape::Blob],
            &[Driver::Single, Driver::Multi],
            &Scale::SMOKE,
            1,
            None,
            |_| {},
        )
        .unwrap();
        assert_eq!(grid.cells.len(), 2);
        assert!(grid.get(BenchmarkShape::Blob, Driver::Single).is_some());
        assert!(grid.get(BenchmarkShape::Blob, Driver::Pjrt).is_none());
        let csv = grid.to_csv();
        assert!(csv.lines().count() == 3, "{csv}");
        assert!(csv.contains("blob,single,smoke"));
    }

    #[test]
    fn smoke_grid_update_phase_drivers() {
        // The comparison grid must carry the pipelined/parallel columns,
        // and parallel must agree with multi cell-for-cell (same semantics).
        let grid = run_grid(
            &[BenchmarkShape::Blob],
            &[Driver::Multi, Driver::Pipelined, Driver::Parallel],
            &Scale::SMOKE,
            3,
            None,
            |_| {},
        )
        .unwrap();
        assert_eq!(grid.cells.len(), 3);
        let multi = grid.get(BenchmarkShape::Blob, Driver::Multi).unwrap();
        let par = grid.get(BenchmarkShape::Blob, Driver::Parallel).unwrap();
        assert_eq!(multi.units, par.units);
        assert_eq!(multi.connections, par.connections);
        assert_eq!(multi.discarded, par.discarded);
        let pipe = grid.get(BenchmarkShape::Blob, Driver::Pipelined).unwrap();
        assert!(pipe.units > 4);
        let csv = grid.to_csv();
        assert!(csv.contains("blob,pipelined,smoke"));
        assert!(csv.contains("blob,parallel,smoke"));
    }

    #[test]
    fn shapes_listed_in_order() {
        let grid = run_grid(
            &[BenchmarkShape::Blob, BenchmarkShape::Eight],
            &[Driver::Single],
            &Scale::SMOKE,
            2,
            None,
            |_| {},
        )
        .unwrap();
        assert_eq!(
            grid.shapes(),
            vec![BenchmarkShape::Blob, BenchmarkShape::Eight]
        );
    }
}
