//! Renderers: one paper table / figure per function, all derived from the
//! experiment [`Grid`].
//!
//! Output is text (paper-style rows, ASCII charts for the figures) plus a
//! CSV per artifact under `results/` for downstream plotting.

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::Driver;
use crate::engine::RunReport;
use crate::mesh::BenchmarkShape;
use crate::metrics::{fmt_sci, Table};

use super::grid::Grid;

/// Paper table number for a mesh (Table 1 = Bunny … Table 4 = Heptoroid).
pub fn table_shape(table: u32) -> Option<BenchmarkShape> {
    match table {
        1 => Some(BenchmarkShape::Blob),
        2 => Some(BenchmarkShape::Eight),
        3 => Some(BenchmarkShape::Hand),
        4 => Some(BenchmarkShape::Heptoroid),
        _ => None,
    }
}

/// Drivers in the paper's column order, then this reproduction's two
/// Update-phase drivers. A driver absent from the grid is skipped, so
/// paper-only grids still render the paper's four columns exactly.
const COLUMNS: [Driver; 6] = [
    Driver::Single,
    Driver::Indexed,
    Driver::Multi,
    Driver::Pjrt,
    Driver::Pipelined,
    Driver::Parallel,
];

fn secs(r: &RunReport) -> f64 {
    r.total.as_secs_f64()
}

/// Render paper Table `n` ("Execution time and statistics on the … data-set").
pub fn render_table(grid: &Grid, n: u32) -> Result<(String, String)> {
    let shape =
        table_shape(n).with_context(|| format!("no paper table {n} (have 1-4)"))?;
    let mut cols: Vec<(&'static str, &RunReport)> = Vec::new();
    for d in COLUMNS {
        if let Some(r) = grid.get(shape, d) {
            cols.push((d.paper_name(), r));
        }
    }
    if cols.is_empty() {
        bail!("grid has no runs for {}", shape.name());
    }

    let mut header = vec!["Algorithm Version"];
    header.extend(cols.iter().map(|(name, _)| *name));
    let mut t = Table::new(&header);
    let row = |t: &mut Table, label: &str, f: &dyn Fn(&RunReport) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(cols.iter().map(|(_, r)| f(r)));
        t.row(cells);
    };
    row(&mut t, "Iterations", &|r| group(r.iterations));
    row(&mut t, "Signals", &|r| group(r.signals));
    row(&mut t, "Discarded Signals", &|r| group(r.discarded));
    row(&mut t, "Units", &|r| group(r.units as u64));
    row(&mut t, "Connections", &|r| group(r.connections as u64));
    row(&mut t, "Converged", &|r| r.converged.to_string());
    row(&mut t, "Total Time", &|r| format!("{:.4}", secs(r)));
    row(&mut t, "Sample", &|r| {
        format!("{:.4}", r.phase.sample.as_secs_f64())
    });
    row(&mut t, "Find Winners", &|r| {
        format!("{:.4}", r.phase.find.as_secs_f64())
    });
    row(&mut t, "Update", &|r| {
        format!("{:.4}", r.phase.update.as_secs_f64())
    });
    row(&mut t, "Time per Signal", &|r| fmt_sci(r.time_per_signal()));
    row(&mut t, "Find Winners /sig", &|r| fmt_sci(r.find_per_signal()));

    let title = format!(
        "Table {n}: Execution time and statistics on the {} data-set\n\
         (proxy mesh `{}`, scale `{}`, seed {})\n\n",
        shape.paper_name(),
        shape.name(),
        grid.scale.name,
        grid.seed,
    );
    Ok((title + &t.render(), t.to_csv()))
}

fn group(x: u64) -> String {
    // 1,234,567 formatting as in the paper's tables.
    let s = x.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Log-scaled ASCII bar (the paper's figures use log axes).
fn bar(value: f64, max: f64, width: usize) -> String {
    if value <= 0.0 || max <= 0.0 {
        return String::new();
    }
    // Map [max/1e4, max] log-range onto [1, width].
    let lo = (max / 1e4).max(f64::MIN_POSITIVE);
    let t = ((value / lo).ln() / (max / lo).ln()).clamp(0.0, 1.0);
    "#".repeat((1.0 + t * (width as f64 - 1.0)).round() as usize)
}

/// Fig. 2: single-signal per-phase share of total time vs mesh (shows the
/// Find Winners dominance growing with network size).
pub fn render_figure2(grid: &Grid) -> Result<(String, String)> {
    let mut text = String::from(
        "Figure 2: Single-phase time to convergence of the SOAM algorithm\n\
         (share of total time per phase, Single-signal implementation)\n\n",
    );
    let mut csv = String::from("mesh,units,sample_pct,find_pct,update_pct\n");
    let mut t = Table::new(&["mesh", "units", "Sample %", "Find Winners %", "Update %"]);
    for shape in grid.shapes() {
        let Some(r) = grid.get(shape, Driver::Single) else { continue };
        let total = secs(r).max(1e-12);
        let pct = |x: std::time::Duration| 100.0 * x.as_secs_f64() / total;
        t.row(vec![
            shape.name().into(),
            r.units.to_string(),
            format!("{:.1}", pct(r.phase.sample)),
            format!("{:.1}", pct(r.phase.find)),
            format!("{:.1}", pct(r.phase.update)),
        ]);
        writeln!(
            csv,
            "{},{},{:.2},{:.2},{:.2}",
            shape.name(),
            r.units,
            pct(r.phase.sample),
            pct(r.phase.find),
            pct(r.phase.update)
        )
        .unwrap();
    }
    text += &t.render();
    text += "\nPaper shape: Find Winners ~50-60% for small nets (bunny), \
             rising to 95%+ for heptoroid.\n";
    Ok((text, csv))
}

/// Fig. 7: time to convergence, Single-signal vs Multi-signal.
pub fn render_figure7(grid: &Grid) -> Result<(String, String)> {
    let mut text = String::from(
        "Figure 7: Time to convergence of the Single-signal and Multi-signal\n\
         implementations (both sequential; the behavioral difference)\n\n",
    );
    let mut csv = String::from("mesh,single_s,multi_s,ratio\n");
    let max = grid
        .shapes()
        .iter()
        .filter_map(|&s| grid.get(s, Driver::Single).map(secs))
        .fold(0.0f64, f64::max);
    for shape in grid.shapes() {
        let (Some(a), Some(b)) = (
            grid.get(shape, Driver::Single),
            grid.get(shape, Driver::Multi),
        ) else {
            continue;
        };
        writeln!(
            text,
            "{:10} single {:>10.3}s |{}",
            shape.name(),
            secs(a),
            bar(secs(a), max, 40)
        )
        .unwrap();
        writeln!(
            text,
            "{:10} multi  {:>10.3}s |{}",
            "",
            secs(b),
            bar(secs(b), max, 40)
        )
        .unwrap();
        writeln!(csv, "{},{:.6},{:.6},{:.3}", shape.name(), secs(a), secs(b), secs(a) / secs(b))
            .unwrap();
    }
    text += "\nPaper shape: Multi-signal always converges faster, and the gap \
             widens with mesh complexity.\n";
    Ok((text, csv))
}

/// Fig. 8: per-phase stacked times for the two most complex meshes,
/// Single-signal / Indexed / GPU-based.
pub fn render_figure8(grid: &Grid) -> Result<(String, String)> {
    let mut text = String::from(
        "Figure 8: Single-phase time to convergence for the two more complex\n\
         meshes (hand, heptoroid)\n\n",
    );
    let mut csv = String::from("mesh,impl,sample_s,find_s,update_s,total_s\n");
    for shape in [BenchmarkShape::Hand, BenchmarkShape::Heptoroid] {
        if !grid.shapes().contains(&shape) {
            continue;
        }
        let mut t = Table::new(&["impl", "Sample", "Find Winners", "Update", "Total"]);
        for d in [Driver::Single, Driver::Indexed, Driver::Pjrt] {
            let Some(r) = grid.get(shape, d) else { continue };
            t.row(vec![
                d.paper_name().into(),
                format!("{:.3}", r.phase.sample.as_secs_f64()),
                format!("{:.3}", r.phase.find.as_secs_f64()),
                format!("{:.3}", r.phase.update.as_secs_f64()),
                format!("{:.3}", secs(r)),
            ]);
            writeln!(
                csv,
                "{},{},{:.6},{:.6},{:.6},{:.6}",
                shape.name(),
                d.name(),
                r.phase.sample.as_secs_f64(),
                r.phase.find.as_secs_f64(),
                r.phase.update.as_secs_f64(),
                secs(r)
            )
            .unwrap();
        }
        writeln!(text, "[{}]\n{}", shape.name(), t.render()).unwrap();
    }
    text += "Paper shape: in the GPU-based column Find Winners ceases to be \
             dominant and Update becomes the most time-consuming phase.\n";
    Ok((text, csv))
}

/// Fig. 9: (a) Find-Winners time per signal; (b) speedups vs Single-signal.
pub fn render_figure9(grid: &Grid) -> Result<(String, String)> {
    let mut text = String::from(
        "Figure 9a: Times per signal in the Find Winners phase\n\
         Figure 9b: Speed-up factors vs the Single-signal implementation\n\n",
    );
    let mut csv =
        String::from("mesh,units,single_fps,indexed_fps,pjrt_fps,indexed_speedup,pjrt_speedup\n");
    let mut t = Table::new(&[
        "mesh",
        "units",
        "single s/sig",
        "indexed s/sig",
        "pjrt s/sig",
        "indexed x",
        "pjrt x",
    ]);
    for shape in grid.shapes() {
        let (Some(s), Some(i), Some(p)) = (
            grid.get(shape, Driver::Single),
            grid.get(shape, Driver::Indexed),
            grid.get(shape, Driver::Pjrt),
        ) else {
            continue;
        };
        let (fs, fi, fp) = (s.find_per_signal(), i.find_per_signal(), p.find_per_signal());
        t.row(vec![
            shape.name().into(),
            s.units.to_string(),
            fmt_sci(fs),
            fmt_sci(fi),
            fmt_sci(fp),
            format!("{:.1}", fs / fi.max(1e-12)),
            format!("{:.1}", fs / fp.max(1e-12)),
        ]);
        writeln!(
            csv,
            "{},{},{:.6e},{:.6e},{:.6e},{:.3},{:.3}",
            shape.name(),
            s.units,
            fs,
            fi,
            fp,
            fs / fi.max(1e-12),
            fs / fp.max(1e-12)
        )
        .unwrap();
    }
    text += &t.render();
    text += "\nPaper shape: speedups grow with network size; GPU-based reaches \
             165x on Heptoroid.\n";
    Ok((text, csv))
}

/// Fig. 10: (a) total times to convergence; (b) speedups vs Single-signal.
pub fn render_figure10(grid: &Grid) -> Result<(String, String)> {
    let mut text = String::from(
        "Figure 10a: Times to convergence\n\
         Figure 10b: Speed-up factors vs the Single-signal implementation\n\n",
    );
    let mut csv =
        String::from("mesh,single_s,indexed_s,pjrt_s,indexed_speedup,pjrt_speedup\n");
    let mut t = Table::new(&[
        "mesh", "single s", "indexed s", "pjrt s", "indexed x", "pjrt x",
    ]);
    for shape in grid.shapes() {
        let (Some(s), Some(i), Some(p)) = (
            grid.get(shape, Driver::Single),
            grid.get(shape, Driver::Indexed),
            grid.get(shape, Driver::Pjrt),
        ) else {
            continue;
        };
        t.row(vec![
            shape.name().into(),
            format!("{:.3}", secs(s)),
            format!("{:.3}", secs(i)),
            format!("{:.3}", secs(p)),
            format!("{:.1}", secs(s) / secs(i).max(1e-12)),
            format!("{:.1}", secs(s) / secs(p).max(1e-12)),
        ]);
        writeln!(
            csv,
            "{},{:.6},{:.6},{:.6},{:.3},{:.3}",
            shape.name(),
            secs(s),
            secs(i),
            secs(p),
            secs(s) / secs(i).max(1e-12),
            secs(s) / secs(p).max(1e-12)
        )
        .unwrap();
    }
    text += &t.render();
    text += "\nPaper shape: speedups from 2.5x (bunny) to 129x (heptoroid), \
             growing with mesh complexity.\n";
    Ok((text, csv))
}

/// Render one figure by paper number.
pub fn render_figure(grid: &Grid, n: u32) -> Result<(String, String)> {
    match n {
        2 => render_figure2(grid),
        7 => render_figure7(grid),
        8 => render_figure8(grid),
        9 => render_figure9(grid),
        10 => render_figure10(grid),
        _ => bail!("no paper figure {n} (have 2, 7, 8, 9, 10)"),
    }
}

/// Write every requested artifact under `out_dir`; returns written paths.
pub fn write_all(
    grid: &Grid,
    out_dir: &Path,
    tables: &[u32],
    figures: &[u32],
) -> Result<Vec<PathBuf>> {
    fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let mut written = Vec::new();
    let mut save = |name: String, content: &str| -> Result<()> {
        let path = out_dir.join(name);
        fs::write(&path, content).with_context(|| format!("writing {}", path.display()))?;
        written.push(path);
        Ok(())
    };
    save(format!("grid-{}.csv", grid.scale.name), &grid.to_csv())?;
    for &n in tables {
        let (text, csv) = render_table(grid, n)?;
        save(format!("table{n}-{}.txt", grid.scale.name), &text)?;
        save(format!("table{n}-{}.csv", grid.scale.name), &csv)?;
    }
    for &n in figures {
        let (text, csv) = render_figure(grid, n)?;
        save(format!("figure{n}-{}.txt", grid.scale.name), &text)?;
        save(format!("figure{n}-{}.csv", grid.scale.name), &csv)?;
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::super::grid::run_grid;
    use super::super::scale::Scale;
    use super::*;

    fn tiny_grid() -> Grid {
        run_grid(
            &[BenchmarkShape::Blob],
            &[Driver::Single, Driver::Indexed, Driver::Multi],
            &Scale::SMOKE,
            3,
            None,
            |_| {},
        )
        .unwrap()
    }

    #[test]
    fn table1_renders_with_available_columns() {
        let grid = tiny_grid();
        let (text, csv) = render_table(&grid, 1).unwrap();
        assert!(text.contains("Stanford Bunny"));
        assert!(text.contains("Discarded Signals"));
        assert!(text.contains("Multi-signal"));
        assert!(!text.contains("GPU-based"), "pjrt not in this grid");
        assert!(csv.lines().count() > 10);
    }

    #[test]
    fn unknown_table_and_figure_error() {
        let grid = tiny_grid();
        assert!(render_table(&grid, 5).is_err());
        assert!(render_figure(&grid, 3).is_err());
    }

    #[test]
    fn figure2_and_7_render() {
        let grid = tiny_grid();
        let (t2, c2) = render_figure2(&grid).unwrap();
        assert!(t2.contains("Find Winners %"));
        assert!(c2.starts_with("mesh,units"));
        let (t7, c7) = render_figure7(&grid).unwrap();
        assert!(t7.contains("single"));
        assert!(c7.lines().count() == 2);
    }

    #[test]
    fn grouping_matches_paper_style() {
        assert_eq!(group(620_000), "620,000");
        assert_eq!(group(1_296), "1,296");
        assert_eq!(group(42), "42");
        assert_eq!(group(0), "0");
    }

    #[test]
    fn write_all_produces_files() {
        let grid = tiny_grid();
        let dir = std::env::temp_dir().join("msgsn_render_test");
        let _ = fs::remove_dir_all(&dir);
        let written = write_all(&grid, &dir, &[1], &[2, 7]).unwrap();
        assert_eq!(written.len(), 1 + 2 + 4); // grid + table(2) + figures(4)
        for p in &written {
            assert!(p.exists());
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bar_is_monotone() {
        let a = bar(1.0, 100.0, 40).len();
        let b = bar(10.0, 100.0, 40).len();
        let c = bar(100.0, 100.0, 40).len();
        assert!(a <= b && b <= c);
        assert_eq!(c, 40);
        assert_eq!(bar(0.0, 100.0, 40), "");
    }
}
