//! Reproduction harness: regenerates every table and figure of the paper's
//! evaluation (§3) from one experiment grid.
//!
//! The paper's entire evaluation is a 4×4 grid — four meshes (Bunny, Eight,
//! Hand, Heptoroid) × four implementations (Single-signal, Indexed,
//! Multi-signal, GPU-based). Tables 1–4 are the grid's columns per mesh;
//! Figs 2, 7, 8, 9, 10 are projections of the same runs. [`grid::run_grid`]
//! executes the grid once; [`render`] derives every artifact from it.
//!
//! Because the original testbed ran for hours (Table 3: 18,548 s single-
//! signal), the harness supports [`scale::Scale`] presets: `paper` uses the
//! calibrated per-mesh thresholds (paper-sized networks), `quick` (default)
//! scales thresholds up ~2× for minute-scale runs with the same qualitative
//! shape, `smoke` is a seconds-scale CI check. EXPERIMENTS.md records which
//! scale produced which numbers.

pub mod ablate;
pub mod grid;
pub mod render;
pub mod scale;

pub use ablate::{
    ablate_collision_policy, ablate_index_cell, ablate_m_schedule, ablate_update_executor,
    MultiPolicy,
};
pub use grid::{Grid, GridCell};
pub use render::{render_figure, render_table, write_all};
pub use scale::Scale;
