//! Ablations of the multi-signal design choices (DESIGN.md §6 "ablation
//! benches for the design choices").
//!
//! The paper motivates three mechanisms without isolating them:
//! the **winner lock** (§2.2 — "only the first incoming signal … will
//! produce the corresponding effect"), the **m-schedule** (§3.1 — least
//! power of two above the unit count, "to avoid discarding an excessive
//! number of signals"), and our staleness guard (DESIGN.md §11.3). Each
//! ablation below switches one mechanism off and reruns the same workload.

use anyhow::Result;

use crate::config::{Driver, Limits, RunConfig};
use crate::coordinator::InsertedGuard;
use crate::engine::RunReport;
use crate::findwinners::{BatchRust, FindWinners, Indexed, Scalar};
use crate::geometry::Vec3;
use crate::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
use crate::metrics::Table;
use crate::rng::Rng;
use crate::som::{ChangeLog, GrowingNetwork, Soam, SoamParams, Winners};

/// Policy knobs for the ablatable multi-signal driver.
#[derive(Clone, Copy, Debug)]
pub struct MultiPolicy {
    /// The §2.2 implicit winner lock. Off ⇒ every signal is applied.
    pub winner_lock: bool,
    /// Discard signals superseded by same-batch insertions (§11.3).
    pub staleness_guard: bool,
    /// `None` = the paper's power-of-two schedule; `Some(m)` = constant m.
    pub fixed_m: Option<usize>,
}

impl Default for MultiPolicy {
    fn default() -> Self {
        Self { winner_lock: true, staleness_guard: true, fixed_m: None }
    }
}

/// `run_multi_signal` with switchable collision policies (kept separate from
/// the engine driver so the production loop stays branch-free).
pub fn run_multi_with_policy(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
    policy: MultiPolicy,
) -> RunReport {
    let start = std::time::Instant::now();
    let mut report = RunReport::new(algo.name(), "ablate");
    let mut log = ChangeLog::default();
    algo.init(sampler, rng);
    fw.rebuild(algo.net());

    let mut locks = crate::coordinator::LockTable::new();
    let mut signals: Vec<Vec3> = Vec::new();
    let mut winners: Vec<Option<Winners>> = Vec::new();
    let mut order: Vec<u32> = Vec::new();
    let mut batch_inserted = InsertedGuard::new();

    loop {
        report.iterations += 1;
        let m = policy
            .fixed_m
            .unwrap_or_else(|| crate::engine::m_schedule(algo.net().len(), limits.max_parallelism));

        sampler.sample_batch(rng, m, &mut signals);
        fw.find2_batch(algo.net(), &signals, &mut winners);
        rng.permutation(m, &mut order);
        locks.next_batch();
        locks.ensure_capacity(algo.net().capacity());
        batch_inserted.clear();
        for &j in &order {
            let w = match winners[j as usize] {
                Some(w) => w,
                None => {
                    report.discarded += 1;
                    continue;
                }
            };
            let signal = signals[j as usize];
            if !algo.net().is_alive(w.w1) || !algo.net().is_alive(w.w2) {
                report.discarded += 1;
                continue;
            }
            if policy.staleness_guard && batch_inserted.supersedes(signal, w.d1_sq) {
                report.discarded += 1;
                continue;
            }
            if policy.winner_lock && !locks.try_lock(w.w1) {
                report.discarded += 1;
                continue;
            }
            log.clear();
            algo.update(signal, &w, &mut log);
            for &id in &log.inserted {
                batch_inserted.push(algo.net().pos(id));
            }
            fw.sync(algo.net(), &log);
        }
        report.signals += m as u64;

        log.clear();
        let converged = algo.housekeeping(&mut log);
        if !log.is_empty() {
            fw.sync(algo.net(), &log);
        }
        if converged {
            report.converged = true;
            break;
        }
        if report.signals >= limits.max_signals {
            break;
        }
    }
    report.finish(algo, Default::default(), start.elapsed());
    report
}

fn soam_run(policy: MultiPolicy, max_signals: u64, seed: u64) -> RunReport {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    let sampler = SurfaceSampler::new(&mesh);
    let mut soam = Soam::new(SoamParams {
        insertion_threshold: 0.15,
        ..SoamParams::default()
    });
    let mut fw = BatchRust::default();
    let limits = Limits { max_signals, ..Limits::default() };
    let mut rng = Rng::seed_from(seed);
    run_multi_with_policy(&mut soam, &sampler, &mut fw, &limits, &mut rng, policy)
}

/// Ablation 1: the winner lock and the staleness guard.
pub fn ablate_collision_policy(max_signals: u64, seed: u64) -> Table {
    let mut t = Table::new(&[
        "policy", "converged", "units", "connections", "signals", "discarded",
    ]);
    for (name, policy) in [
        ("no collision handling", MultiPolicy { winner_lock: false, staleness_guard: false, fixed_m: None }),
        ("winner lock only", MultiPolicy { winner_lock: true, staleness_guard: false, fixed_m: None }),
        ("lock + staleness guard", MultiPolicy::default()),
    ] {
        let r = soam_run(policy, max_signals, seed);
        t.row(vec![
            name.into(),
            r.converged.to_string(),
            r.units.to_string(),
            r.connections.to_string(),
            r.signals.to_string(),
            r.discarded.to_string(),
        ]);
    }
    t
}

/// Ablation 2: the m-schedule vs fixed batch sizes.
pub fn ablate_m_schedule(max_signals: u64, seed: u64) -> Table {
    let mut t = Table::new(&[
        "schedule", "converged", "units", "signals", "discarded", "discard %",
    ]);
    let mut run = |name: &str, fixed: Option<usize>| {
        let r = soam_run(
            MultiPolicy { fixed_m: fixed, ..MultiPolicy::default() },
            max_signals,
            seed,
        );
        let pct = 100.0 * r.discarded as f64 / r.signals.max(1) as f64;
        t.row(vec![
            name.into(),
            r.converged.to_string(),
            r.units.to_string(),
            r.signals.to_string(),
            r.discarded.to_string(),
            format!("{pct:.1}"),
        ]);
    };
    run("pow2 schedule (paper)", None);
    run("fixed m = 64", Some(64));
    run("fixed m = 1024", Some(1024));
    run("fixed m = 8192", Some(8192));
    t
}

/// Ablation 4: the Update-phase execution strategy — the same multi-signal
/// semantics run sequentially (`multi`), with the Sample phase prefetched
/// (`pipelined`, now composed with the pooled Update split), with the
/// pooled plan + concurrent-commit split (`parallel`), and with Find
/// Winners sharded across the same pool (`find_threads`). The GNG rows
/// exist because the lazy error decay removed the per-signal O(N) sweep
/// that used to classify every GNG update as Structural — before PR 3 the
/// `parallel` driver degenerated to sequential for GNG by definition.
/// Units/connections/discards must agree across every row of one algorithm
/// except `pipelined` (bit parity by construction); the Find/Update
/// columns show where the time goes.
pub fn ablate_update_executor(max_signals: u64, seed: u64) -> Result<Table> {
    use crate::config::Algorithm;
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.soam.insertion_threshold = 0.15;
    cfg.gng.lambda = 100;
    cfg.limits.max_signals = max_signals;
    let mut t = Table::new(&[
        "algo",
        "driver",
        "upd threads",
        "find threads",
        "converged",
        "units",
        "connections",
        "discarded",
        "find_s",
        "update_s",
        "total_s",
    ]);
    let fmt_threads = |n: usize| match n {
        0 => "auto".to_string(),
        n => n.to_string(),
    };
    let runs: [(Algorithm, Driver, usize, usize); 10] = [
        (Algorithm::Soam, Driver::Multi, 1, 1),
        (Algorithm::Soam, Driver::Multi, 1, 0), // sharded find, sequential update
        (Algorithm::Soam, Driver::Pipelined, 1, 1),
        (Algorithm::Soam, Driver::Pipelined, 0, 1), // prefetch + pooled update
        (Algorithm::Soam, Driver::Parallel, 1, 1),
        (Algorithm::Soam, Driver::Parallel, 0, 1), // pooled plan + commit
        (Algorithm::Soam, Driver::Parallel, 0, 0), // shared pool: + sharded find
        // GNG under the parallel executor — enabled by the lazy decay.
        (Algorithm::Gng, Driver::Multi, 1, 1),
        (Algorithm::Gng, Driver::Parallel, 0, 1),
        (Algorithm::Gng, Driver::Parallel, 0, 0),
    ];
    for (algorithm, driver, update_threads, find_threads) in runs {
        cfg.algorithm = algorithm;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        let mut rng = Rng::seed_from(seed);
        let r = crate::engine::run(&mesh, driver, &cfg, &mut rng)?;
        t.row(vec![
            algorithm.name().into(),
            driver.name().into(),
            fmt_threads(update_threads),
            fmt_threads(find_threads),
            r.converged.to_string(),
            r.units.to_string(),
            r.connections.to_string(),
            r.discarded.to_string(),
            format!("{:.3}", r.phase.find.as_secs_f64()),
            format!("{:.3}", r.phase.update.as_secs_f64()),
            format!("{:.3}", r.total.as_secs_f64()),
        ]);
    }
    Ok(t)
}

/// Ablation 3: the Indexed variant's cube size (the paper tunes it "for
/// maximum performances"; mistuned cells either scan too many units or fall
/// back to exhaustive).
pub fn ablate_index_cell(seed: u64) -> Result<Table> {
    let mesh = benchmark_mesh(BenchmarkShape::Eight, 48);
    let sampler = SurfaceSampler::new(&mesh);
    // Grow a realistic network once.
    let mut soam = Soam::new(SoamParams {
        insertion_threshold: 0.04,
        ..SoamParams::default()
    });
    let mut rng = Rng::seed_from(seed);
    soam.init(&sampler, &mut rng);
    let mut fw = Scalar::new();
    let mut log = ChangeLog::default();
    for _ in 0..400_000 {
        let s = sampler.sample(&mut rng);
        let w = fw.find2(soam.net(), s).unwrap();
        log.clear();
        soam.update(s, &w, &mut log);
    }
    let net = soam.net();

    let mut t = Table::new(&["cell size", "ns/query", "fallback %", "agreement %"]);
    let queries: Vec<Vec3> = (0..20_000).map(|_| sampler.sample(&mut rng)).collect();
    let mut scalar = Scalar::new();
    let truth: Vec<_> = queries.iter().map(|q| scalar.find2(net, *q)).collect();
    for cell in [0.02f32, 0.04, 0.08, 0.16, 0.32] {
        let mut idx = Indexed::new(cell);
        idx.rebuild(net);
        let t0 = std::time::Instant::now();
        let mut agree = 0usize;
        for (q, want) in queries.iter().zip(&truth) {
            let got = idx.find2(net, *q);
            if got.map(|w| w.w1) == want.map(|w| w.w1) {
                agree += 1;
            }
        }
        let per = t0.elapsed().as_secs_f64() / queries.len() as f64;
        t.row(vec![
            format!("{cell:.2}"),
            format!("{:.0}", per * 1e9),
            format!("{:.2}", 100.0 * idx.fallback_rate()),
            format!("{:.2}", 100.0 * agree as f64 / queries.len() as f64),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_default_matches_production_semantics() {
        let p = MultiPolicy::default();
        assert!(p.winner_lock && p.staleness_guard && p.fixed_m.is_none());
    }

    #[test]
    fn no_lock_applies_everything() {
        let r = soam_run(
            MultiPolicy { winner_lock: false, staleness_guard: false, fixed_m: None },
            20_000,
            1,
        );
        // Without collision handling nothing is discarded (stale-dead
        // winners aside, which are rare at this scale).
        assert!(r.discarded * 20 < r.signals, "{} of {}", r.discarded, r.signals);
    }

    #[test]
    fn lock_discards_substantially() {
        let r = soam_run(MultiPolicy::default(), 20_000, 1);
        assert!(r.discarded * 4 > r.signals, "{} of {}", r.discarded, r.signals);
    }

    #[test]
    fn fixed_m_runs() {
        let r = soam_run(
            MultiPolicy { fixed_m: Some(256), ..MultiPolicy::default() },
            10_000,
            2,
        );
        assert!(r.iterations >= 10_000 / 256);
    }
}
