//! Workload scales for the reproduction harness.

use crate::config::RunConfig;
use crate::mesh::BenchmarkShape;

/// A workload scale: how big the networks get and how long runs may last.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scale {
    pub name: &'static str,
    /// Multiplier on the per-mesh calibrated insertion threshold. Units
    /// scale like `1/factor²` (spacing ∝ threshold).
    pub threshold_factor: f32,
    /// Signal cap per run (a run that hits the cap reports
    /// `converged = false` and is labeled accordingly).
    pub max_signals: u64,
    /// Marching resolution override (0 = shape default).
    pub mesh_resolution: u32,
}

impl Scale {
    /// Seconds-scale smoke run (CI): tiny networks, short cap.
    pub const SMOKE: Scale = Scale {
        name: "smoke",
        threshold_factor: 3.0,
        max_signals: 60_000,
        mesh_resolution: 24,
    };

    /// Minute-scale runs that preserve the paper's qualitative shape
    /// (default for `msgsn reproduce`).
    pub const QUICK: Scale = Scale {
        name: "quick",
        threshold_factor: 2.0,
        max_signals: 25_000_000,
        mesh_resolution: 0,
    };

    /// Paper-sized networks (hour-scale on one CPU — the original testbed
    /// also ran for hours; see Table 3's 18,548 s).
    pub const PAPER: Scale = Scale {
        name: "paper",
        threshold_factor: 1.0,
        max_signals: 400_000_000,
        mesh_resolution: 0,
    };

    pub fn from_name(name: &str) -> Option<Scale> {
        match name {
            "smoke" => Some(Self::SMOKE),
            "quick" => Some(Self::QUICK),
            "paper" | "full" => Some(Self::PAPER),
            _ => None,
        }
    }

    /// Apply this scale to a mesh preset.
    pub fn configure(&self, shape: BenchmarkShape) -> RunConfig {
        let mut cfg = RunConfig::preset(shape);
        cfg.soam.insertion_threshold *= self.threshold_factor;
        cfg.gwr.insertion_threshold *= self.threshold_factor;
        // The index cube tracks the unit spacing (presets set it from the
        // unscaled threshold).
        cfg.index_cell = (2.0 * cfg.soam.insertion_threshold).clamp(0.02, 0.3);
        cfg.limits.max_signals = self.max_signals;
        if self.mesh_resolution != 0 {
            cfg.mesh_resolution = self.mesh_resolution;
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for s in [Scale::SMOKE, Scale::QUICK, Scale::PAPER] {
            assert_eq!(Scale::from_name(s.name), Some(s));
        }
        assert_eq!(Scale::from_name("full"), Some(Scale::PAPER));
        assert!(Scale::from_name("nope").is_none());
    }

    #[test]
    fn configure_scales_thresholds() {
        let base = RunConfig::preset(BenchmarkShape::Eight);
        let cfg = Scale::QUICK.configure(BenchmarkShape::Eight);
        assert!(
            (cfg.soam.insertion_threshold
                - base.soam.insertion_threshold * 2.0)
                .abs()
                < 1e-6
        );
        assert_eq!(cfg.limits.max_signals, 25_000_000);
    }

    #[test]
    fn paper_scale_is_identity_on_thresholds() {
        let base = RunConfig::preset(BenchmarkShape::Hand);
        let cfg = Scale::PAPER.configure(BenchmarkShape::Hand);
        assert_eq!(cfg.soam.insertion_threshold, base.soam.insertion_threshold);
    }
}
