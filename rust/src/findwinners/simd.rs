//! Explicit-SIMD Find-Winners kernels with runtime ISA dispatch — the
//! hardware-limit CPU answer to the ROADMAP's "make pjrt real" decision
//! (the accelerator stub stays quarantined; this path pushes the paper's
//! dominant kernel to peak on every x86 and ARM host instead).
//!
//! ## Dispatch tiers
//!
//! | tier | `std::arch` kernel | width | detected via |
//! |---|---|---|---|
//! | `avx512` | AVX-512F, `__mmask16` index blends | f32×16 | `is_x86_feature_detected!("avx512f")` |
//! | `avx2` | AVX2, `blendv` index blends | f32×8 | `is_x86_feature_detected!("avx2")` |
//! | `neon` | NEON, `vbsl` index blends | f32×4 | `is_aarch64_feature_detected!("neon")` |
//! | `fallback` | [`super::lanes`] auto-vectorized blocks | f32×[`SOA_LANES`] | always available |
//!
//! The best supported tier is detected once (first use) and cached in an
//! atomic; every tier is selectable explicitly through the `fw_isa`
//! RunConfig knob or the `MSGSN_FW_ISA` environment variable (resolution
//! order: knob > env > detection — see [`set_override`]). The choice is
//! process-global, which is safe precisely because every tier returns the
//! same bits — switching tiers can only change wall time, never results.
//!
//! ## Exactness
//!
//! Each kernel is a **fused single pass**: squared distance and candidate
//! id travel together through the in-register top-2 update, so there is no
//! separate tie-break fixup to get wrong. The argument that every tier is
//! bit-identical to [`super::exhaustive_top2`]:
//!
//! 1. **No f32 reassociation.** The distance is computed with explicit
//!    `mul`/`add` intrinsics in exactly [`crate::geometry::Vec3::dist2`]'s
//!    association, `(dx·dx + dy·dy) + dz·dz` — never an FMA contraction
//!    (which would round once instead of twice), never a reordered sum.
//!    Each lane therefore produces the same f32 distance bits as the
//!    scalar scan.
//! 2. **Per-lane lex order for free.** Within a lane, candidate ids
//!    strictly ascend (lane `l` sees ids `l, l+W, l+2W, …`), so the strict
//!    `<` compare-masked blends (`d2' = m1 ? d1 : (m2 ? d : d2)`, ids
//!    blended by the same masks) keep the lane-local running top-2 in
//!    lexicographic `(distance, id)` order — identical to the update rule
//!    of [`super::lanes::lane_block_top2`], just with the select in a
//!    register instead of per element.
//! 3. **Width-invariant horizontal merge.** The `2·W` lane candidates are
//!    merged through the existing exact [`Top2::lex_push`] reduce, which
//!    orders by the full `(distance, id)` pair. That merge is invariant to
//!    how candidates were partitioned into lanes, so any width (4, 8, 16,
//!    [`SOA_LANES`]) yields the same two winners with the same distance
//!    bits — including exact ties (lowest index wins) and the `None` rule
//!    (dead/padding slots hold [`crate::som::DEAD_POS`], whose squared
//!    distance overflows to `+inf` and never passes a strict `<`).
//!
//! Every compiled-and-detected tier is property-tested bit-identical to
//! the exhaustive scan (random clouds, forced ties, dead and padded
//! slots) in this module's tests; `rust/tests/executor_parity.rs` runs
//! whole convergence runs fallback-vs-dispatched.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::geometry::Vec3;
use crate::som::{Network, Winners, SOA_LANES};

use super::lanes::{self, Top2};

// Every kernel width must divide the SoA padding width, so no tier ever
// needs a scalar tail over the mirror or the batch tiles.
const _: () = assert!(SOA_LANES % 16 == 0);

/// One Find-Winners kernel tier. All variants exist on every target so
/// config files parse everywhere; [`FwIsa::is_supported`] reports whether
/// the running host can actually execute a tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FwIsa {
    /// The portable auto-vectorized lane kernel ([`super::lanes`]).
    Fallback = 1,
    /// AVX2 f32×8 (x86_64).
    Avx2 = 2,
    /// AVX-512F f32×16 with per-lane `u32` index blends (x86_64).
    Avx512 = 3,
    /// NEON f32×4 (aarch64).
    Neon = 4,
}

impl FwIsa {
    pub const ALL: [FwIsa; 4] = [FwIsa::Fallback, FwIsa::Avx2, FwIsa::Avx512, FwIsa::Neon];

    /// Accepted values for the `fw_isa` config knob / `MSGSN_FW_ISA` env.
    pub const CONFIG_NAMES: &'static str = "auto|fallback|avx2|avx512|neon";

    pub fn name(self) -> &'static str {
        match self {
            FwIsa::Fallback => "fallback",
            FwIsa::Avx2 => "avx2",
            FwIsa::Avx512 => "avx512",
            FwIsa::Neon => "neon",
        }
    }

    pub fn from_name(s: &str) -> Option<FwIsa> {
        match s {
            "fallback" => Some(FwIsa::Fallback),
            "avx2" => Some(FwIsa::Avx2),
            "avx512" | "avx512f" => Some(FwIsa::Avx512),
            "neon" => Some(FwIsa::Neon),
            _ => None,
        }
    }

    /// Can the running host execute this tier? (Compile-target gate plus
    /// runtime feature detection.)
    pub fn is_supported(self) -> bool {
        match self {
            FwIsa::Fallback => true,
            #[cfg(target_arch = "x86_64")]
            FwIsa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            FwIsa::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            FwIsa::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)] // reachable for foreign-arch tiers
            _ => false,
        }
    }

    /// The widest tier the running host supports.
    pub fn detect_best() -> FwIsa {
        for isa in [FwIsa::Avx512, FwIsa::Avx2, FwIsa::Neon] {
            if isa.is_supported() {
                return isa;
            }
        }
        FwIsa::Fallback
    }
}

/// Process-global active tier; 0 = not yet resolved. Every tier returns
/// identical bits, so relaxed ordering (and last-writer-wins between
/// concurrent runs) can only perturb wall time, never results.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn from_code(code: u8) -> Option<FwIsa> {
    FwIsa::ALL.into_iter().find(|isa| *isa as u8 == code)
}

/// `MSGSN_FW_ISA` request, read once per process. Empty or `auto` means
/// unset; unknown or unsupported values warn once and fall back to
/// detection (an env override must never abort a run the default would
/// have completed).
fn env_request() -> Option<FwIsa> {
    static ENV: OnceLock<Option<FwIsa>> = OnceLock::new();
    *ENV.get_or_init(|| {
        let raw = std::env::var("MSGSN_FW_ISA").ok()?;
        let raw = raw.trim();
        if raw.is_empty() || raw == "auto" {
            return None;
        }
        match FwIsa::from_name(raw) {
            Some(isa) if isa.is_supported() => Some(isa),
            Some(isa) => {
                eprintln!(
                    "MSGSN_FW_ISA={}: not supported on this host — using {}",
                    isa.name(),
                    FwIsa::detect_best().name()
                );
                None
            }
            None => {
                eprintln!(
                    "MSGSN_FW_ISA={raw:?}: unknown tier (expected {}) — using {}",
                    FwIsa::CONFIG_NAMES,
                    FwIsa::detect_best().name()
                );
                None
            }
        }
    })
}

fn default_isa() -> FwIsa {
    env_request().unwrap_or_else(FwIsa::detect_best)
}

/// The tier [`block_top2`]/[`top2`] currently dispatch to. Resolved on
/// first use (env request, else detection) and after every
/// [`set_override`]. Always a supported tier.
pub fn active_isa() -> FwIsa {
    match from_code(ACTIVE.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => {
            let isa = default_isa();
            ACTIVE.store(isa as u8, Ordering::Relaxed);
            isa
        }
    }
}

/// Apply the `fw_isa` knob: `Some(tier)` forces that tier (error when the
/// host cannot execute it — a *config* request, unlike the env hint, must
/// fail loudly), `None` re-resolves the default (env request, else
/// detection). Returns the tier now active. The engine calls this from
/// `make_findwinners`, so the knob flows through every driver, session and
/// fleet job.
pub fn set_override(request: Option<FwIsa>) -> Result<FwIsa, String> {
    let isa = match request {
        Some(isa) if !isa.is_supported() => {
            return Err(format!(
                "fw_isa \"{}\" is not supported on this host (detected best: \"{}\")",
                isa.name(),
                FwIsa::detect_best().name()
            ));
        }
        Some(isa) => isa,
        None => default_isa(),
    };
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    Ok(isa)
}

/// Dispatched top-2 over one lane-padded SoA block (the drop-in for
/// [`lanes::lane_block_top2`] at every call site). Returns block-local
/// indices; `xs`/`ys`/`zs` must have equal lengths that are a multiple of
/// [`SOA_LANES`] (the SoA mirror and the batch gather both guarantee
/// this — and every kernel width divides `SOA_LANES`, so no tier needs a
/// scalar tail).
#[inline]
pub fn block_top2(xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    // `active_isa()` only ever holds supported tiers, so the unsafe
    // target-feature calls below are sound.
    dispatch(active_isa(), xs, ys, zs, signal)
}

/// [`block_top2`] on an explicitly forced tier — the property-test and
/// per-ISA bench entry. Panics when the host cannot execute `isa` (callers
/// gate on [`FwIsa::is_supported`]).
pub fn block_top2_with(isa: FwIsa, xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    assert!(isa.is_supported(), "{} not supported on this host", isa.name());
    dispatch(isa, xs, ys, zs, signal)
}

/// Dispatched top-2 over the network's SoA position mirror — the
/// vectorized drop-in for [`super::exhaustive_top2`] (block-local indices
/// == slab ids for the identity mapping).
#[inline]
pub fn top2(net: &Network, signal: Vec3) -> Option<Winners> {
    let (xs, ys, zs) = net.soa();
    block_top2(xs, ys, zs, signal).winners()
}

#[inline]
fn dispatch(isa: FwIsa, xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    debug_assert_eq!(xs.len(), ys.len());
    debug_assert_eq!(xs.len(), zs.len());
    debug_assert_eq!(xs.len() % SOA_LANES, 0, "SoA block not lane-padded");
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa.is_supported()` held (checked by the caller or by
        // the `active_isa` invariant), so the required CPU feature is
        // present.
        FwIsa::Avx2 => unsafe { avx2_block_top2(xs, ys, zs, signal) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above, avx512f is present.
        FwIsa::Avx512 => unsafe { avx512_block_top2(xs, ys, zs, signal) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, neon is present.
        FwIsa::Neon => unsafe { neon_block_top2(xs, ys, zs, signal) },
        _ => lanes::lane_block_top2(xs, ys, zs, signal),
    }
}

/// Merge the `2·W` per-lane candidates under the full lexicographic
/// order — the same width-invariant horizontal reduce as the portable
/// kernel ([`Top2::lex_push`] ignores the `(+inf, u32::MAX)` sentinels).
#[inline]
fn reduce_lanes<const W: usize>(
    d1: [f32; W],
    w1: [u32; W],
    d2: [f32; W],
    w2: [u32; W],
) -> Top2 {
    let mut acc = Top2::EMPTY;
    for l in 0..W {
        acc.lex_push(d1[l], w1[l]);
        acc.lex_push(d2[l], w2[l]);
    }
    acc
}

/// AVX2 f32×8 fused distance + top-2 pass. Index vectors ride through
/// `blendv` selects on the float-compare masks (a pure bitwise lane
/// select — integer bit patterns pass through untouched).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_block_top2(xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    use std::arch::x86_64::*;
    const W: usize = 8;
    let sx = _mm256_set1_ps(signal.x);
    let sy = _mm256_set1_ps(signal.y);
    let sz = _mm256_set1_ps(signal.z);
    let mut d1 = _mm256_set1_ps(f32::INFINITY);
    let mut d2 = _mm256_set1_ps(f32::INFINITY);
    let mut w1 = _mm256_set1_epi32(-1);
    let mut w2 = _mm256_set1_epi32(-1);
    let mut idx = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
    let step = _mm256_set1_epi32(W as i32);
    for base in (0..xs.len()).step_by(W) {
        let dx = _mm256_sub_ps(sx, _mm256_loadu_ps(xs.as_ptr().add(base)));
        let dy = _mm256_sub_ps(sy, _mm256_loadu_ps(ys.as_ptr().add(base)));
        let dz = _mm256_sub_ps(sz, _mm256_loadu_ps(zs.as_ptr().add(base)));
        // (dx·dx + dy·dy) + dz·dz — explicit mul/add in Vec3::dist2's
        // association; deliberately NOT an FMA (different rounding).
        let d = _mm256_add_ps(
            _mm256_add_ps(_mm256_mul_ps(dx, dx), _mm256_mul_ps(dy, dy)),
            _mm256_mul_ps(dz, dz),
        );
        let m1 = _mm256_cmp_ps::<_CMP_LT_OQ>(d, d1);
        let m2 = _mm256_cmp_ps::<_CMP_LT_OQ>(d, d2);
        // d2' = m1 ? d1 : (m2 ? d : d2); the id lanes follow the same
        // masks, keeping (distance, id) fused through the update.
        let d2n = _mm256_blendv_ps(_mm256_blendv_ps(d2, d, m2), d1, m1);
        let w2n = _mm256_castps_si256(_mm256_blendv_ps(
            _mm256_blendv_ps(_mm256_castsi256_ps(w2), _mm256_castsi256_ps(idx), m2),
            _mm256_castsi256_ps(w1),
            m1,
        ));
        d1 = _mm256_blendv_ps(d1, d, m1);
        w1 = _mm256_castps_si256(_mm256_blendv_ps(
            _mm256_castsi256_ps(w1),
            _mm256_castsi256_ps(idx),
            m1,
        ));
        d2 = d2n;
        w2 = w2n;
        idx = _mm256_add_epi32(idx, step);
    }
    let (mut hd1, mut hd2) = ([0.0f32; W], [0.0f32; W]);
    let (mut hw1, mut hw2) = ([0u32; W], [0u32; W]);
    _mm256_storeu_ps(hd1.as_mut_ptr(), d1);
    _mm256_storeu_ps(hd2.as_mut_ptr(), d2);
    _mm256_storeu_si256(hw1.as_mut_ptr().cast(), w1);
    _mm256_storeu_si256(hw2.as_mut_ptr().cast(), w2);
    reduce_lanes(hd1, hw1, hd2, hw2)
}

/// AVX-512F f32×16 fused distance + top-2 pass: compare-to-`__mmask16`,
/// then masked blends keep the `u32` id lanes fused with their distances
/// in-register (`_mm512_mask_blend_*`: `k ? b : a`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn avx512_block_top2(xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    use std::arch::x86_64::*;
    const W: usize = 16;
    let sx = _mm512_set1_ps(signal.x);
    let sy = _mm512_set1_ps(signal.y);
    let sz = _mm512_set1_ps(signal.z);
    let mut d1 = _mm512_set1_ps(f32::INFINITY);
    let mut d2 = _mm512_set1_ps(f32::INFINITY);
    let mut w1 = _mm512_set1_epi32(-1);
    let mut w2 = _mm512_set1_epi32(-1);
    let mut idx = _mm512_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15);
    let step = _mm512_set1_epi32(W as i32);
    for base in (0..xs.len()).step_by(W) {
        let dx = _mm512_sub_ps(sx, _mm512_loadu_ps(xs.as_ptr().add(base)));
        let dy = _mm512_sub_ps(sy, _mm512_loadu_ps(ys.as_ptr().add(base)));
        let dz = _mm512_sub_ps(sz, _mm512_loadu_ps(zs.as_ptr().add(base)));
        // Explicit mul/add (no FMA), Vec3::dist2's association.
        let d = _mm512_add_ps(
            _mm512_add_ps(_mm512_mul_ps(dx, dx), _mm512_mul_ps(dy, dy)),
            _mm512_mul_ps(dz, dz),
        );
        let m1 = _mm512_cmp_ps_mask::<_CMP_LT_OQ>(d, d1);
        let m2 = _mm512_cmp_ps_mask::<_CMP_LT_OQ>(d, d2);
        let d2n = _mm512_mask_blend_ps(m1, _mm512_mask_blend_ps(m2, d2, d), d1);
        let w2n = _mm512_mask_blend_epi32(m1, _mm512_mask_blend_epi32(m2, w2, idx), w1);
        d1 = _mm512_mask_blend_ps(m1, d1, d);
        w1 = _mm512_mask_blend_epi32(m1, w1, idx);
        d2 = d2n;
        w2 = w2n;
        idx = _mm512_add_epi32(idx, step);
    }
    let (mut hd1, mut hd2) = ([0.0f32; W], [0.0f32; W]);
    let (mut hw1, mut hw2) = ([0u32; W], [0u32; W]);
    _mm512_storeu_ps(hd1.as_mut_ptr(), d1);
    _mm512_storeu_ps(hd2.as_mut_ptr(), d2);
    _mm512_storeu_si512(hw1.as_mut_ptr().cast(), w1);
    _mm512_storeu_si512(hw2.as_mut_ptr().cast(), w2);
    reduce_lanes(hd1, hw1, hd2, hw2)
}

/// NEON f32×4 fused distance + top-2 pass. `vbslq` is a per-bit select
/// (`mask ? a : b`), so the `u32` id lanes blend on the same `vcltq_f32`
/// masks as the distances.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_block_top2(xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    use std::arch::aarch64::*;
    const W: usize = 4;
    let sx = vdupq_n_f32(signal.x);
    let sy = vdupq_n_f32(signal.y);
    let sz = vdupq_n_f32(signal.z);
    let mut d1 = vdupq_n_f32(f32::INFINITY);
    let mut d2 = vdupq_n_f32(f32::INFINITY);
    let mut w1 = vdupq_n_u32(u32::MAX);
    let mut w2 = vdupq_n_u32(u32::MAX);
    let lane_ids: [u32; W] = [0, 1, 2, 3];
    let mut idx = vld1q_u32(lane_ids.as_ptr());
    let step = vdupq_n_u32(W as u32);
    for base in (0..xs.len()).step_by(W) {
        let dx = vsubq_f32(sx, vld1q_f32(xs.as_ptr().add(base)));
        let dy = vsubq_f32(sy, vld1q_f32(ys.as_ptr().add(base)));
        let dz = vsubq_f32(sz, vld1q_f32(zs.as_ptr().add(base)));
        // Explicit mul/add (no vfmaq fusion), Vec3::dist2's association.
        let d = vaddq_f32(
            vaddq_f32(vmulq_f32(dx, dx), vmulq_f32(dy, dy)),
            vmulq_f32(dz, dz),
        );
        let m1 = vcltq_f32(d, d1);
        let m2 = vcltq_f32(d, d2);
        let d2n = vbslq_f32(m1, d1, vbslq_f32(m2, d, d2));
        let w2n = vbslq_u32(m1, w1, vbslq_u32(m2, idx, w2));
        d1 = vbslq_f32(m1, d, d1);
        w1 = vbslq_u32(m1, idx, w1);
        d2 = d2n;
        w2 = w2n;
        idx = vaddq_u32(idx, step);
    }
    let (mut hd1, mut hd2) = ([0.0f32; W], [0.0f32; W]);
    let (mut hw1, mut hw2) = ([0u32; W], [0u32; W]);
    vst1q_f32(hd1.as_mut_ptr(), d1);
    vst1q_f32(hd2.as_mut_ptr(), d2);
    vst1q_u32(hw1.as_mut_ptr(), w1);
    vst1q_u32(hw2.as_mut_ptr(), w2);
    reduce_lanes(hd1, hw1, hd2, hw2)
}

#[cfg(test)]
mod tests {
    use super::super::exhaustive_top2;
    use super::super::testutil::{random_net, random_signals};
    use super::*;
    use crate::rng::Rng;

    /// Tiers the host can actually execute, with a skip note for the rest
    /// (satellite: skip-with-note when the ISA is absent).
    fn testable_isas() -> Vec<FwIsa> {
        let mut isas = Vec::new();
        for isa in FwIsa::ALL {
            if isa.is_supported() {
                isas.push(isa);
            } else {
                println!("note: {} not supported on this host — skipped", isa.name());
            }
        }
        isas
    }

    fn compare(isa: FwIsa, net: &Network, signal: Vec3, label: &str) -> Result<(), String> {
        let (xs, ys, zs) = net.soa();
        let want = exhaustive_top2(net, signal);
        let got = block_top2_with(isa, xs, ys, zs, signal).winners();
        match (want, got) {
            (None, None) => Ok(()),
            (Some(a), Some(b))
                if a.w1 == b.w1
                    && a.w2 == b.w2
                    && a.d1_sq.to_bits() == b.d1_sq.to_bits()
                    && a.d2_sq.to_bits() == b.d2_sq.to_bits() =>
            {
                Ok(())
            }
            (a, b) => Err(format!("{label} [{}]: {a:?} vs {b:?}", isa.name())),
        }
    }

    #[test]
    fn every_supported_isa_matches_exhaustive_on_random_nets() {
        let isas = testable_isas();
        // Sizes straddle every kernel width (4/8/16); kill_every exercises
        // dead slots (poisoned with DEAD_POS in the mirror).
        for (n, kill) in [(1, 0), (2, 0), (7, 0), (15, 0), (16, 0), (17, 0), (64, 3), (131, 5)] {
            let net = random_net(n, n as u64, kill);
            for (k, s) in random_signals(40, 99 + n as u64).into_iter().enumerate() {
                for &isa in &isas {
                    compare(isa, &net, s, &format!("n={n} kill={kill} sig={k}")).unwrap();
                }
            }
        }
    }

    /// Satellite (PR 6): over random clouds with forced exact distance
    /// ties and dead/padded slots, every compiled-and-detected tier is
    /// bit-identical to the exhaustive scan — tie-breaks, poisoning and
    /// the `None` rule included.
    #[test]
    fn prop_every_supported_isa_bit_identical_to_exhaustive() {
        use crate::proptest::{sized_usize, Prop};
        let isas = testable_isas();
        Prop::new(48, 0x51D).run(
            |rng, size| {
                let n = sized_usize(rng, size, 1, 300);
                let kill = [0usize, 2, 3, 7][rng.index(4)];
                // Half the cases snap everything to a coarse grid, forcing
                // many exact distance ties across lanes and blocks.
                let snap = rng.below(2) == 0;
                (rng.next_u64(), n, kill, snap)
            },
            |&(seed, n, kill, snap)| {
                let net = if snap {
                    let mut rng = Rng::seed_from(seed);
                    let mut net = Network::new();
                    let mut ids = Vec::new();
                    for _ in 0..n {
                        let p = Vec3::new(
                            rng.index(3) as f32 * 0.5,
                            rng.index(3) as f32 * 0.5,
                            rng.index(3) as f32 * 0.5,
                        );
                        ids.push(net.insert(p, 0.1));
                    }
                    if kill > 0 {
                        for (k, &id) in ids.iter().enumerate() {
                            if k % kill == kill - 1 && net.len() > 2 {
                                net.remove(id);
                            }
                        }
                    }
                    net
                } else {
                    random_net(n, seed, kill)
                };
                let mut rng = Rng::seed_from(seed ^ 0xC0FFEE);
                for k in 0..40 {
                    let s = if snap {
                        Vec3::new(
                            rng.index(5) as f32 * 0.25,
                            rng.index(5) as f32 * 0.25,
                            rng.index(5) as f32 * 0.25,
                        )
                    } else {
                        Vec3::new(rng.f32(), rng.f32(), rng.f32())
                    };
                    for &isa in &isas {
                        compare(isa, &net, s, &format!("snap={snap} sig={k}"))?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gathered_tile_indices_map_through_id_tables_on_every_isa() {
        // A batch-gather tile with non-identity ids and poisoned padding
        // (u32::MAX ids are never read: poison never becomes a candidate).
        let mut xs = [1e30f32; SOA_LANES];
        let ys = [0.0f32; SOA_LANES];
        let zs = [0.0f32; SOA_LANES];
        xs[..4].copy_from_slice(&[0.0, 1.0, 2.0, 0.0]);
        let mut ids = [u32::MAX; SOA_LANES];
        ids[..4].copy_from_slice(&[10, 20, 30, 40]);
        for isa in testable_isas() {
            let t = block_top2_with(isa, &xs, &ys, &zs, Vec3::ZERO);
            // Distance 0 twice (locals 0 and 3): lowest local index wins.
            assert_eq!(t.w1, 0, "{}", isa.name());
            assert_eq!(t.w2, 3, "{}", isa.name());
            assert_eq!(ids[t.w1 as usize], 10);
            assert_eq!(ids[t.w2 as usize], 40);
            assert_eq!(t.d1, 0.0);
            assert_eq!(t.d2, 0.0);
        }
    }

    #[test]
    fn tiny_and_empty_nets_yield_none_on_every_isa() {
        let isas = testable_isas();
        let empty = Network::new();
        let one = random_net(1, 3, 0);
        // Two inserted, one removed: a single live unit across a dead slot.
        let mut lone = Network::new();
        let a = lone.insert(Vec3::ZERO, 0.1);
        lone.insert(Vec3::ONE, 0.1);
        lone.remove(a);
        for &isa in &isas {
            for (net, label) in [(&empty, "empty"), (&one, "one"), (&lone, "lone")] {
                let (xs, ys, zs) = net.soa();
                assert!(
                    block_top2_with(isa, xs, ys, zs, Vec3::ZERO).winners().is_none(),
                    "{label} [{}]",
                    isa.name()
                );
            }
        }
    }

    #[test]
    fn names_roundtrip_and_unknown_rejected() {
        for isa in FwIsa::ALL {
            assert_eq!(FwIsa::from_name(isa.name()), Some(isa));
        }
        assert_eq!(FwIsa::from_name("avx512f"), Some(FwIsa::Avx512));
        assert_eq!(FwIsa::from_name("sse9"), None);
        assert_eq!(FwIsa::from_name("auto"), None, "auto is the knob's None");
        // Every advertised config name except `auto` parses.
        for name in FwIsa::CONFIG_NAMES.split('|').filter(|n| *n != "auto") {
            assert!(FwIsa::from_name(name).is_some(), "{name}");
        }
    }

    /// The only test that touches the process-global dispatch state (the
    /// others force tiers per call), keeping intra-process races out.
    #[test]
    fn override_resolution_and_dispatch() {
        // Forcing the always-supported fallback must stick…
        assert_eq!(set_override(Some(FwIsa::Fallback)), Ok(FwIsa::Fallback));
        assert_eq!(active_isa(), FwIsa::Fallback);
        let net = random_net(37, 7, 3);
        let s = Vec3::new(0.3, 0.4, 0.5);
        assert_eq!(top2(&net, s), exhaustive_top2(&net, s));
        // …an unsupported tier must error without disturbing the state…
        if let Some(&foreign) = FwIsa::ALL.iter().find(|isa| !isa.is_supported()) {
            assert!(set_override(Some(foreign)).unwrap_err().contains(foreign.name()));
            assert_eq!(active_isa(), FwIsa::Fallback);
        }
        // …and None re-resolves the default (no MSGSN_FW_ISA in the test
        // env ⇒ detection; with it, the env request — supported either
        // way).
        let restored = set_override(None).unwrap();
        assert!(restored.is_supported());
        assert_eq!(active_isa(), restored);
        assert_eq!(top2(&net, s), exhaustive_top2(&net, s));
    }
}
