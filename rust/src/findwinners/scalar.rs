//! `Scalar`: the paper's **Single-signal** reference implementation — one
//! exhaustive O(N) scan per signal, no auxiliary structure. The scan runs
//! on the runtime-dispatched SIMD block kernel over the network's SoA
//! mirror, which is bit-identical to [`super::exhaustive_top2`] on every
//! tier (see [`super::simd`]), so the baseline semantics are untouched —
//! it is just no longer slower than the hardware requires.

use crate::geometry::Vec3;
use crate::som::{Network, Winners};

use super::{simd, FindWinners};

/// Exhaustive per-signal Find Winners (the baseline every speedup in
/// Figs. 9–10 is measured against).
#[derive(Clone, Copy, Debug, Default)]
pub struct Scalar;

impl Scalar {
    pub fn new() -> Self {
        Scalar
    }
}

impl FindWinners for Scalar {
    fn name(&self) -> &'static str {
        "single"
    }

    #[inline]
    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners> {
        simd::top2(net, signal)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn batch_default_matches_single() {
        let net = random_net(64, 10, 5);
        let signals = random_signals(33, 11);
        let mut fw = Scalar::new();
        let mut out = Vec::new();
        fw.find2_batch(&net, &signals, &mut out);
        assert_eq!(out.len(), signals.len());
        for (s, got) in signals.iter().zip(&out) {
            assert_eq!(*got, fw.find2(&net, *s));
        }
    }
}
