//! The lane-blocked Find-Winners kernel: the CPU counterpart of the Pallas
//! tile kernel, written so stable-Rust LLVM auto-vectorizes it (fixed-width
//! `[f32; LANES]` accumulators, branchless select updates, `chunks_exact`
//! blocks — no nightly features, no intrinsics, no new dependencies).
//!
//! Since PR 6 this is the **portable fallback tier** of the runtime ISA
//! dispatch in [`super::simd`] (which adds explicit AVX-512F/AVX2/NEON
//! kernels); it also remains the semantic model the explicit kernels
//! mirror — same per-lane update rule, same [`Top2::lex_push`] horizontal
//! reduce.
//!
//! ## Exactness
//!
//! [`super::exhaustive_top2`]'s sequential scan with strict `<` comparisons
//! computes exactly the two **lexicographically smallest `(distance, id)`
//! pairs**: an equal distance never displaces an earlier (lower-id) entry,
//! so ties resolve to the lowest index. The lane kernel computes the same
//! set a different way — per-lane running top-2 (ids ascend within a lane,
//! so strict `<` keeps the lane-local lex order) followed by one horizontal
//! reduce per block that merges the `2·LANES` lane candidates under the
//! explicit lexicographic order. Both reductions are exact in f32 (no
//! reassociation of the distance arithmetic, same `dx·dx + dy·dy + dz·dz`
//! expression as [`crate::geometry::Vec3::dist2`]), so the result is
//! bit-identical to the exhaustive scan — including the lowest-index
//! tie-break and the `None` answer for networks with fewer than two units.
//!
//! Dead and padding slots hold [`crate::som::DEAD_POS`], whose squared
//! distance overflows to `+inf`; `+inf < +inf` is false, so they can never
//! enter an accumulator.

use crate::geometry::Vec3;
use crate::som::{Network, Winners, SOA_LANES};

/// Lane width of the blocked scan. Fixed at the SoA mirror's padding width
/// (one AVX-512 f32 register; two AVX2 registers on narrower hosts, where
/// LLVM simply unrolls) so blocks need no scalar tail on any dispatch tier.
pub const LANES: usize = SOA_LANES;

/// `(d_a, i_a) < (d_b, i_b)` in the lexicographic order that encodes the
/// lowest-index tie-break. Distances are never NaN here (worst case `+inf`).
#[inline]
fn lex_less(d_a: f32, i_a: u32, d_b: f32, i_b: u32) -> bool {
    d_a < d_b || (d_a == d_b && i_a < i_b)
}

/// Running top-2 of `(distance, index)` pairs under the lexicographic
/// order. Indices are block-local; callers map them through their id table
/// (the mapping is monotone, so block-local lex order == global lex order).
#[derive(Clone, Copy, Debug)]
pub struct Top2 {
    pub w1: u32,
    pub w2: u32,
    pub d1: f32,
    pub d2: f32,
}

impl Top2 {
    pub const EMPTY: Top2 =
        Top2 { w1: u32::MAX, w2: u32::MAX, d1: f32::INFINITY, d2: f32::INFINITY };

    /// Insert one candidate under the full lexicographic order (order of
    /// insertion does not matter — used by the horizontal reduce, where
    /// lane candidates arrive in arbitrary id order, and by the
    /// region-neighborhood scan, where roster order is arbitrary).
    #[inline]
    pub fn lex_push(&mut self, d: f32, id: u32) {
        if lex_less(d, id, self.d1, self.w1) {
            self.d2 = self.d1;
            self.w2 = self.w1;
            self.d1 = d;
            self.w1 = id;
        } else if lex_less(d, id, self.d2, self.w2) {
            self.d2 = d;
            self.w2 = id;
        }
    }

    /// The exhaustive scan's `None` rule: fewer than two finite candidates.
    #[inline]
    pub fn winners(self) -> Option<Winners> {
        if self.w2 == u32::MAX || self.d2 == f32::INFINITY {
            None
        } else {
            Some(Winners { w1: self.w1, w2: self.w2, d1_sq: self.d1, d2_sq: self.d2 })
        }
    }
}

/// Lane-blocked top-2 over one lane-padded SoA block: `LANES` per-lane
/// running minima through the whole block, one horizontal reduce at the
/// end. Returns block-local indices ([`Top2::EMPTY`] when nothing finite).
///
/// `xs`/`ys`/`zs` must have equal lengths that are a multiple of [`LANES`]
/// (the SoA mirror and the batch gather both guarantee this).
#[inline]
pub fn lane_block_top2(xs: &[f32], ys: &[f32], zs: &[f32], signal: Vec3) -> Top2 {
    debug_assert_eq!(xs.len(), ys.len());
    debug_assert_eq!(xs.len(), zs.len());
    debug_assert_eq!(xs.len() % LANES, 0, "SoA block not lane-padded");

    let mut d1 = [f32::INFINITY; LANES];
    let mut d2 = [f32::INFINITY; LANES];
    let mut w1 = [u32::MAX; LANES];
    let mut w2 = [u32::MAX; LANES];

    let mut base = 0u32;
    for ((cx, cy), cz) in xs
        .chunks_exact(LANES)
        .zip(ys.chunks_exact(LANES))
        .zip(zs.chunks_exact(LANES))
    {
        for l in 0..LANES {
            let dx = signal.x - cx[l];
            let dy = signal.y - cy[l];
            let dz = signal.z - cz[l];
            // Exactly Vec3::dist2 — no reassociation, no FMA contraction
            // surprises (rustc does not contract without fast-math).
            let d = dx * dx + dy * dy + dz * dz;
            let idx = base + l as u32;
            // Branchless two-slot insert: strict `<` keeps the lane-local
            // lowest-index tie-break (ids ascend within a lane).
            let better1 = d < d1[l];
            let better2 = d < d2[l];
            d2[l] = if better1 {
                d1[l]
            } else if better2 {
                d
            } else {
                d2[l]
            };
            w2[l] = if better1 {
                w1[l]
            } else if better2 {
                idx
            } else {
                w2[l]
            };
            d1[l] = if better1 { d } else { d1[l] };
            w1[l] = if better1 { idx } else { w1[l] };
        }
        base += LANES as u32;
    }

    // One horizontal reduce per block: merge the 2·LANES lane candidates
    // under the explicit lexicographic order (lane ids interleave, so the
    // strict-< shortcut is not enough here).
    let mut acc = Top2::EMPTY;
    for l in 0..LANES {
        acc.lex_push(d1[l], w1[l]);
        acc.lex_push(d2[l], w2[l]);
    }
    acc
}

/// Lane-blocked top-2 over the network's SoA position mirror — the
/// vectorized drop-in for [`super::exhaustive_top2`] (block-local indices
/// == slab ids for the identity mapping).
#[inline]
pub fn lane_top2(net: &Network, signal: Vec3) -> Option<Winners> {
    let (xs, ys, zs) = net.soa();
    lane_block_top2(xs, ys, zs, signal).winners()
}

#[cfg(test)]
mod tests {
    use super::super::exhaustive_top2;
    use super::super::testutil::{random_net, random_signals};
    use super::*;
    use crate::rng::Rng;

    fn assert_bit_identical(net: &Network, signal: Vec3, label: &str) {
        let want = exhaustive_top2(net, signal);
        let got = lane_top2(net, signal);
        match (want, got) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.w1, b.w1, "{label}: w1");
                assert_eq!(a.w2, b.w2, "{label}: w2");
                assert_eq!(a.d1_sq.to_bits(), b.d1_sq.to_bits(), "{label}: d1");
                assert_eq!(a.d2_sq.to_bits(), b.d2_sq.to_bits(), "{label}: d2");
            }
            (a, b) => panic!("{label}: {a:?} vs {b:?}"),
        }
    }

    #[test]
    fn matches_exhaustive_on_random_nets() {
        // Sizes straddle lane boundaries; kill_every exercises dead slots.
        for (n, kill) in [(1, 0), (2, 0), (7, 0), (8, 0), (9, 0), (64, 3), (131, 5)] {
            let net = random_net(n, n as u64, kill);
            for (k, s) in random_signals(40, 99 + n as u64).into_iter().enumerate() {
                assert_bit_identical(&net, s, &format!("n={n} kill={kill} sig={k}"));
            }
        }
    }

    #[test]
    fn ties_resolve_to_lowest_index() {
        // Many units at few distinct grid positions force exact distance
        // ties across lanes.
        let mut rng = Rng::seed_from(5);
        let mut net = Network::new();
        for _ in 0..50 {
            let p = Vec3::new(
                rng.index(3) as f32 * 0.5,
                rng.index(3) as f32 * 0.5,
                rng.index(3) as f32 * 0.5,
            );
            net.insert(p, 0.1);
        }
        for k in 0..30 {
            let s = Vec3::new(
                rng.index(5) as f32 * 0.25,
                rng.index(5) as f32 * 0.25,
                rng.index(5) as f32 * 0.25,
            );
            assert_bit_identical(&net, s, &format!("tie sig={k}"));
        }
    }

    #[test]
    fn tiny_and_empty_nets_yield_none() {
        let empty = Network::new();
        assert!(lane_top2(&empty, Vec3::ZERO).is_none());
        let one = random_net(1, 3, 0);
        assert!(lane_top2(&one, Vec3::ZERO).is_none());
        // Two inserted, one removed: a single live unit across a dead slot.
        let mut net = Network::new();
        let a = net.insert(Vec3::ZERO, 0.1);
        net.insert(Vec3::ONE, 0.1);
        net.remove(a);
        assert!(lane_top2(&net, Vec3::ZERO).is_none());
    }

    #[test]
    fn block_indices_map_through_id_tables() {
        // A gathered tile with non-identity ids: block-local lex order must
        // survive the (monotone) mapping.
        let mut xs = [1e30f32; LANES];
        let ys = [0.0; LANES];
        let zs = [0.0; LANES];
        xs[..4].copy_from_slice(&[0.0, 1.0, 2.0, 0.0]);
        let mut ids = [u32::MAX; LANES];
        ids[..4].copy_from_slice(&[10, 20, 30, 40]);
        let t = lane_block_top2(&xs, &ys, &zs, Vec3::ZERO);
        // Distance 0 twice (locals 0 and 3): lowest local index wins slot 1.
        assert_eq!(t.w1, 0);
        assert_eq!(t.w2, 3);
        assert_eq!(ids[t.w1 as usize], 10);
        assert_eq!(ids[t.w2 as usize], 40);
        assert_eq!(t.d1, 0.0);
        assert_eq!(t.d2, 0.0);
    }
}
