//! `Indexed`: the paper's hash-indexed single-signal variant (§3.1).
//!
//! Query = top-2 over the 27-cell neighborhood of the signal; when fewer
//! than two units live there, fall back to the exhaustive scan. As in the
//! paper this is *slightly approximate*: a true winner hiding outside the
//! neighborhood is missed. Index maintenance rides on the Update phase via
//! [`FindWinners::sync`].

use crate::coordinator::LockTable;
use crate::geometry::{Aabb, Vec3};
use crate::index::HashGrid;
use crate::som::{ChangeLog, Network, Winners};

use super::{exhaustive_top2, FindWinners};

/// Hash-grid-accelerated Find Winners.
pub struct Indexed {
    grid: HashGrid,
    /// Count of queries answered by the exhaustive fallback (reported by the
    /// benches; large values mean the cell size is mistuned).
    pub fallbacks: u64,
    pub queries: u64,
    /// Scratch stamp set for per-batch sync deduplication ([`LockTable`]
    /// doubles as a generic O(1)-clear id set: `try_lock` =
    /// insert-if-unseen, `next_batch` = clear — the same reuse the batch
    /// executor makes for its touched set).
    seen: LockTable,
}

impl Indexed {
    /// Meshes are normalized to the unit cube; `cell` is the index cube
    /// size (tuned for performance, paper §3.1).
    pub fn new(cell: f32) -> Self {
        // Slightly inflated bounds so adapted units that drift out of
        // [0,1]³ still clamp into a valid boundary cell.
        let bounds = Aabb::new(Vec3::splat(0.0), Vec3::splat(1.0));
        Self {
            grid: HashGrid::new(bounds, cell),
            fallbacks: 0,
            queries: 0,
            seen: LockTable::new(),
        }
    }

    pub fn fallback_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.fallbacks as f64 / self.queries as f64
        }
    }
}

impl FindWinners for Indexed {
    fn name(&self) -> &'static str {
        "indexed"
    }

    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners> {
        self.queries += 1;
        let mut w1 = u32::MAX;
        let mut w2 = u32::MAX;
        let mut d1 = f32::INFINITY;
        let mut d2 = f32::INFINITY;
        self.grid.for_neighborhood(signal, |id| {
            let d = signal.dist2(net.pos(id));
            // Strict `<` + id-order visit is not guaranteed by bucket order,
            // so break distance ties toward the lower id explicitly to keep
            // parity with the exhaustive reference.
            if d < d1 || (d == d1 && id < w1) {
                if w1 != id {
                    d2 = d1;
                    w2 = w1;
                }
                d1 = d;
                w1 = id;
            } else if (d < d2 || (d == d2 && id < w2)) && id != w1 {
                d2 = d;
                w2 = id;
            }
        });
        if w2 == u32::MAX {
            // Paper: "If this search fails, the exhaustive search is
            // performed instead."
            self.fallbacks += 1;
            return exhaustive_top2(net, signal);
        }
        Some(Winners { w1, w2, d1_sq: d1, d2_sq: d2 })
    }

    fn sync(&mut self, net: &Network, changes: &ChangeLog) {
        self.sync_with_net(net, changes);
    }

    fn rebuild(&mut self, net: &Network) {
        self.grid.rebuild(net);
    }
}

impl Indexed {
    /// Index maintenance (the Update phase's bookkeeping).
    ///
    /// Drivers hand over one *merged* change log per batch (a single `sync`
    /// instead of one per signal), so a unit may appear several times and
    /// in overlapping roles: moved twice, moved then removed, removed and
    /// its slab slot reused by a later insert. Replaying such a log as
    /// edits would corrupt the grid, so entries are treated as *membership
    /// hints*, not edits: every mentioned id is reconciled once against its
    /// final state (`indexed?` × `alive?` decides insert / re-bucket /
    /// remove / nothing). This is idempotent, order-independent, and for
    /// single-signal logs it degenerates to the classic per-entry
    /// maintenance.
    pub fn sync_with_net(&mut self, net: &Network, changes: &ChangeLog) {
        self.seen.next_batch();
        let mentioned = changes
            .inserted
            .iter()
            .copied()
            .chain(changes.moved.iter().map(|&(id, _)| id))
            .chain(changes.removed.iter().map(|&(id, _)| id));
        for id in mentioned {
            if !self.seen.try_lock(id) {
                continue;
            }
            match (self.grid.contains(id), net.is_alive(id)) {
                (true, true) => self.grid.update(id, net.pos(id)),
                (true, false) => self.grid.remove(id),
                (false, true) => self.grid.insert(id, net.pos(id)),
                (false, false) => {}
            }
        }
    }

    pub fn grid(&self) -> &HashGrid {
        &self.grid
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::Scalar;
    use super::*;

    fn build_indexed(net: &Network, cell: f32) -> Indexed {
        let mut idx = Indexed::new(cell);
        idx.rebuild(net);
        idx
    }

    #[test]
    fn dense_net_matches_exhaustive() {
        // With a dense uniform net and a reasonable cell size the 27-cell
        // neighborhood almost always contains the true winners.
        let net = random_net(2000, 21, 0);
        let mut idx = build_indexed(&net, 0.08);
        let mut scalar = Scalar::new();
        let mut agree = 0;
        let signals = random_signals(500, 22);
        for &s in &signals {
            let a = idx.find2(&net, s).unwrap();
            let b = scalar.find2(&net, s).unwrap();
            if a.w1 == b.w1 {
                agree += 1;
            }
            // d1 can exceed the true minimum only when approximation missed.
            assert!(a.d1_sq >= b.d1_sq - 1e-9);
        }
        assert!(agree as f64 / signals.len() as f64 > 0.99, "agree {agree}/500");
    }

    #[test]
    fn sparse_net_falls_back() {
        let net = random_net(2, 23, 0);
        let mut idx = build_indexed(&net, 0.05);
        let s = Vec3::new(0.5, 0.5, 0.5);
        let got = idx.find2(&net, s).unwrap();
        assert!(idx.fallbacks > 0, "expected exhaustive fallback");
        let want = Scalar::new().find2(&net, s).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn maintenance_tracks_changes() {
        let mut net = random_net(100, 25, 0);
        let mut idx = build_indexed(&net, 0.1);
        // Simulate an update: move one unit far away, insert one, remove one.
        let moved_id = net.ids().next().unwrap();
        let removed_id = net.ids().nth(1).unwrap();
        let mut log = ChangeLog::default();
        let old = net.pos(moved_id);
        net.set_pos(moved_id, Vec3::new(0.99, 0.99, 0.99));
        log.moved.push((moved_id, old));
        let new_id = net.insert(Vec3::new(0.01, 0.5, 0.5), 0.1);
        log.inserted.push(new_id);
        let rpos = net.pos(removed_id);
        net.remove(removed_id);
        log.removed.push((removed_id, rpos));
        idx.sync_with_net(&net, &log);
        idx.grid().check_invariants().unwrap();
        // Index agrees with exhaustive after maintenance.
        let mut scalar = Scalar::new();
        for &s in &random_signals(100, 26) {
            let a = idx.find2(&net, s).unwrap();
            let b = scalar.find2(&net, s).unwrap();
            assert!(a.d1_sq >= b.d1_sq - 1e-9);
        }
    }

    #[test]
    fn merged_log_with_slot_reuse_reconciles() {
        // The hard merged-batch case: remove a unit, then insert another
        // that reuses its slab slot — one merged log mentions the id in
        // both `removed` and `inserted`. A replay-style sync would
        // double-bucket; the reconciling sync must land on the final state.
        let mut net = random_net(50, 31, 0);
        let mut idx = build_indexed(&net, 0.1);
        let victim = net.ids().next().unwrap();
        let mut log = ChangeLog::default();

        let vpos = net.pos(victim);
        net.remove(victim);
        log.removed.push((victim, vpos));
        let reborn = net.insert(Vec3::new(0.9, 0.1, 0.9), 0.1);
        assert_eq!(reborn, victim, "slab must reuse the slot for this test");
        log.inserted.push(reborn);
        // And move it within the same batch for good measure.
        let old = net.pos(reborn);
        net.set_pos(reborn, Vec3::new(0.1, 0.9, 0.1));
        log.moved.push((reborn, old));

        idx.sync_with_net(&net, &log);
        idx.grid().check_invariants().unwrap();
        assert_eq!(idx.grid().len(), 50);
        let mut seen = Vec::new();
        idx.grid().for_neighborhood(Vec3::new(0.1, 0.9, 0.1), |id| seen.push(id));
        assert!(seen.contains(&reborn), "reborn unit must sit in its final cell");
    }

    #[test]
    fn merged_log_insert_then_remove_is_noop() {
        let mut net = random_net(20, 33, 0);
        let mut idx = build_indexed(&net, 0.1);
        let mut log = ChangeLog::default();
        let ghost = net.insert(Vec3::new(0.5, 0.5, 0.5), 0.1);
        log.inserted.push(ghost);
        let gpos = net.pos(ghost);
        net.remove(ghost);
        log.removed.push((ghost, gpos));
        idx.sync_with_net(&net, &log);
        idx.grid().check_invariants().unwrap();
        assert_eq!(idx.grid().len(), 20);
        assert!(!idx.grid().contains(ghost));
    }

    #[test]
    fn merged_log_double_move_lands_on_final_cell() {
        let mut net = random_net(10, 35, 0);
        let mut idx = build_indexed(&net, 0.1);
        let id = net.ids().next().unwrap();
        let mut log = ChangeLog::default();
        let p0 = net.pos(id);
        net.set_pos(id, Vec3::new(0.95, 0.95, 0.95));
        log.moved.push((id, p0));
        let p1 = net.pos(id);
        net.set_pos(id, Vec3::new(0.05, 0.05, 0.05));
        log.moved.push((id, p1));
        idx.sync_with_net(&net, &log);
        idx.grid().check_invariants().unwrap();
        let mut seen = Vec::new();
        idx.grid().for_neighborhood(Vec3::new(0.05, 0.05, 0.05), |u| seen.push(u));
        assert!(seen.contains(&id));
    }

    #[test]
    fn fallback_rate_reported() {
        let net = random_net(2, 27, 0);
        let mut idx = build_indexed(&net, 0.02);
        for &s in &random_signals(50, 28) {
            idx.find2(&net, s);
        }
        assert!(idx.fallback_rate() > 0.5);
    }
}
