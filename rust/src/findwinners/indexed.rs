//! `Indexed`: the paper's hash-indexed single-signal variant (§3.1).
//!
//! Query = top-2 over the 27-cell neighborhood of the signal; when fewer
//! than two units live there, fall back to the exhaustive scan. As in the
//! paper this is *slightly approximate*: a true winner hiding outside the
//! neighborhood is missed. Index maintenance rides on the Update phase via
//! [`FindWinners::sync`].

use crate::geometry::{Aabb, Vec3};
use crate::index::HashGrid;
use crate::som::{ChangeLog, Network, Winners};

use super::{exhaustive_top2, FindWinners};

/// Hash-grid-accelerated Find Winners.
pub struct Indexed {
    grid: HashGrid,
    /// Count of queries answered by the exhaustive fallback (reported by the
    /// benches; large values mean the cell size is mistuned).
    pub fallbacks: u64,
    pub queries: u64,
}

impl Indexed {
    /// Meshes are normalized to the unit cube; `cell` is the index cube
    /// size (tuned for performance, paper §3.1).
    pub fn new(cell: f32) -> Self {
        // Slightly inflated bounds so adapted units that drift out of
        // [0,1]³ still clamp into a valid boundary cell.
        let bounds = Aabb::new(Vec3::splat(0.0), Vec3::splat(1.0));
        Self { grid: HashGrid::new(bounds, cell), fallbacks: 0, queries: 0 }
    }

    pub fn fallback_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.fallbacks as f64 / self.queries as f64
        }
    }
}

impl FindWinners for Indexed {
    fn name(&self) -> &'static str {
        "indexed"
    }

    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners> {
        self.queries += 1;
        let mut w1 = u32::MAX;
        let mut w2 = u32::MAX;
        let mut d1 = f32::INFINITY;
        let mut d2 = f32::INFINITY;
        self.grid.for_neighborhood(signal, |id| {
            let d = signal.dist2(net.pos(id));
            // Strict `<` + id-order visit is not guaranteed by bucket order,
            // so break distance ties toward the lower id explicitly to keep
            // parity with the exhaustive reference.
            if d < d1 || (d == d1 && id < w1) {
                if w1 != id {
                    d2 = d1;
                    w2 = w1;
                }
                d1 = d;
                w1 = id;
            } else if (d < d2 || (d == d2 && id < w2)) && id != w1 {
                d2 = d;
                w2 = id;
            }
        });
        if w2 == u32::MAX {
            // Paper: "If this search fails, the exhaustive search is
            // performed instead."
            self.fallbacks += 1;
            return exhaustive_top2(net, signal);
        }
        Some(Winners { w1, w2, d1_sq: d1, d2_sq: d2 })
    }

    fn sync(&mut self, net: &Network, changes: &ChangeLog) {
        self.sync_with_net(net, changes);
    }

    fn rebuild(&mut self, net: &Network) {
        self.grid.rebuild(net);
    }
}

impl Indexed {
    /// Index maintenance (the Update phase's bookkeeping): `moved` units are
    /// re-bucketed, `inserted` added, `removed` dropped.
    pub fn sync_with_net(&mut self, net: &Network, changes: &ChangeLog) {
        for &id in &changes.inserted {
            self.grid.insert(id, net.pos(id));
        }
        for &(id, _old) in &changes.moved {
            // A unit may have been moved and then removed within the same
            // signal (orphan pruning); skip those — the removed loop handles
            // them.
            if net.is_alive(id) {
                self.grid.update(id, net.pos(id));
            }
        }
        for &(id, _pos) in &changes.removed {
            self.grid.remove(id);
        }
    }

    pub fn grid(&self) -> &HashGrid {
        &self.grid
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::Scalar;
    use super::*;

    fn build_indexed(net: &Network, cell: f32) -> Indexed {
        let mut idx = Indexed::new(cell);
        idx.rebuild(net);
        idx
    }

    #[test]
    fn dense_net_matches_exhaustive() {
        // With a dense uniform net and a reasonable cell size the 27-cell
        // neighborhood almost always contains the true winners.
        let net = random_net(2000, 21, 0);
        let mut idx = build_indexed(&net, 0.08);
        let mut scalar = Scalar::new();
        let mut agree = 0;
        let signals = random_signals(500, 22);
        for &s in &signals {
            let a = idx.find2(&net, s).unwrap();
            let b = scalar.find2(&net, s).unwrap();
            if a.w1 == b.w1 {
                agree += 1;
            }
            // d1 can exceed the true minimum only when approximation missed.
            assert!(a.d1_sq >= b.d1_sq - 1e-9);
        }
        assert!(agree as f64 / signals.len() as f64 > 0.99, "agree {agree}/500");
    }

    #[test]
    fn sparse_net_falls_back() {
        let net = random_net(2, 23, 0);
        let mut idx = build_indexed(&net, 0.05);
        let s = Vec3::new(0.5, 0.5, 0.5);
        let got = idx.find2(&net, s).unwrap();
        assert!(idx.fallbacks > 0, "expected exhaustive fallback");
        let want = Scalar::new().find2(&net, s).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn maintenance_tracks_changes() {
        let mut net = random_net(100, 25, 0);
        let mut idx = build_indexed(&net, 0.1);
        // Simulate an update: move one unit far away, insert one, remove one.
        let moved_id = net.ids().next().unwrap();
        let removed_id = net.ids().nth(1).unwrap();
        let mut log = ChangeLog::default();
        let old = net.pos(moved_id);
        net.unit_mut(moved_id).pos = Vec3::new(0.99, 0.99, 0.99);
        log.moved.push((moved_id, old));
        let new_id = net.insert(Vec3::new(0.01, 0.5, 0.5), 0.1);
        log.inserted.push(new_id);
        let rpos = net.pos(removed_id);
        net.remove(removed_id);
        log.removed.push((removed_id, rpos));
        idx.sync_with_net(&net, &log);
        idx.grid().check_invariants().unwrap();
        // Index agrees with exhaustive after maintenance.
        let mut scalar = Scalar::new();
        for &s in &random_signals(100, 26) {
            let a = idx.find2(&net, s).unwrap();
            let b = scalar.find2(&net, s).unwrap();
            assert!(a.d1_sq >= b.d1_sq - 1e-9);
        }
    }

    #[test]
    fn fallback_rate_reported() {
        let net = random_net(2, 27, 0);
        let mut idx = build_indexed(&net, 0.02);
        for &s in &random_signals(50, 28) {
            idx.find2(&net, s);
        }
        assert!(idx.fallback_rate() > 0.5);
    }
}
