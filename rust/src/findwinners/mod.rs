//! The Find Winners phase — the paper's compute hot-spot — behind one trait
//! with four implementations, serving the six-driver matrix of
//! [`crate::engine`]:
//!
//! | impl | strategy | data layout / kernel | used by drivers |
//! |---|---|---|---|
//! | [`Scalar`] | one scan per signal | SoA mirror, dispatched SIMD block scan ([`simd`]) | single |
//! | [`Indexed`] | spatial hash, 27-cell query, exhaustive fallback | AoS mirror | indexed |
//! | [`BatchRust`] | batched scan, unit-tiled for cache reuse; optional region-neighborhood scan (`regions`, exact with global fallback) | cached SoA tiles, dispatched SIMD block scan, optional [`crate::runtime::WorkerPool`] sharding (`find_threads`) | multi, pipelined, parallel |
//! | `runtime::PjrtFindWinners` | AOT Pallas/XLA artifact via PJRT | VMEM tiles | pjrt (quarantined at config level — programmatic use only) |
//!
//! The first four driver columns are the paper's (§3.1); `pipelined` and
//! `parallel` are this reproduction's Update-phase drivers and reuse the
//! `BatchRust` scan unchanged. The block scan runs on a runtime-dispatched
//! explicit-SIMD kernel — AVX-512F, AVX2 or NEON, with the auto-vectorized
//! [`lanes`] kernel as the portable fallback (`fw_isa` knob /
//! `MSGSN_FW_ISA` env override; see [`simd`]) — every tier bit-identical
//! to [`exhaustive_top2`] (see the `simd` and `lanes` module docs for the
//! argument), so the layout/kernel column is pure performance — semantics
//! never change.
//!
//! All implementations share *exact* semantics (squared distances in f32 via
//! the naive difference form, lowest-index tie-break); `Indexed` is the one
//! documented exception (the paper calls it "slightly approximate": the
//! 27-cell query can miss the true winner when a closer unit lies outside
//! the neighborhood — exactly as in the original).

mod batch;
mod indexed;
pub mod lanes;
mod scalar;
pub mod simd;

use std::sync::Arc;

pub use batch::BatchRust;
pub use indexed::Indexed;
pub use scalar::Scalar;
pub use simd::FwIsa;

use crate::geometry::Vec3;
use crate::runtime::WorkerPool;
use crate::som::{ChangeLog, Network, RegionGrid, RegionMap, Winners};

/// Strategy for the Find Winners phase.
pub trait FindWinners {
    /// Implementation name (report column).
    fn name(&self) -> &'static str;

    /// Top-2 nearest live units for one signal. `None` when the network has
    /// fewer than two units.
    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners>;

    /// Batched top-2 for `signals`, one [`Winners`] per signal, appended to
    /// `out` (cleared first). Default: loop over `find2`.
    fn find2_batch(
        &mut self,
        net: &Network,
        signals: &[Vec3],
        out: &mut Vec<Option<Winners>>,
    ) {
        out.clear();
        out.reserve(signals.len());
        for &s in signals {
            out.push(self.find2(net, s));
        }
    }

    /// Notification that the Update phase changed the network — index-based
    /// implementations maintain their structures here ("the maintenance of
    /// the index … is performed in the Update phase", §3.1).
    ///
    /// Contract: drivers call this **once per batch** with the *merged*
    /// change log of every signal applied in that batch (plus once per
    /// housekeeping scan). A unit may therefore appear multiple times and
    /// in several lists at once — moved twice, moved then removed, or
    /// removed with its slab slot reused by a later insert — and
    /// implementations must reconcile against the network's final state
    /// rather than replay entries as edits (see `Indexed::sync_with_net`).
    fn sync(&mut self, _net: &Network, _changes: &ChangeLog) {}

    /// (Re)build any internal structure from scratch (called once after
    /// `init`).
    fn rebuild(&mut self, _net: &Network) {}

    /// Offer a shared persistent worker pool for sharding `find2_batch`
    /// across `shards` workers (the engine calls this once per run, with
    /// the same pool the Update plan pass uses). Default: ignored —
    /// sharding is an implementation-private optimization and results must
    /// be identical with or without it.
    fn attach_pool(&mut self, _pool: Arc<WorkerPool>, _shards: usize) {}

    /// Offer the run's region geometry (`regions` knob > 1): batched
    /// implementations may then scan only a signal's region neighborhood,
    /// falling back to their global scan whenever a top-2 candidate could
    /// lie across a region boundary (see [`region_top2`]). Default:
    /// ignored — like pool sharding, the region scan is exact by
    /// construction and results must be identical with or without it.
    fn attach_regions(&mut self, _map: RegionMap) {}
}

/// Region-neighborhood top-2: scan only the rosters of the 3×3×3 cell
/// block around `signal`, merging candidates under the explicit
/// lexicographic `(distance, id)` order (roster order is arbitrary, so the
/// sequential scan's implicit tie-break must be made explicit — same trick
/// as the lane kernel's horizontal reduce).
///
/// Returns `Some` **only when the local result is provably the global
/// one**: the second-best local distance must be strictly below
/// [`RegionMap::outside_dist2`], the f32 lower bound on any unscanned
/// unit's distance — strict, so not even an exact distance tie with a
/// lower-id unit outside the block can be missed. Otherwise (`None`) the
/// caller falls back to its global scan; exactness never depends on the
/// grid resolution, only the fallback rate does.
///
/// `positions` must be the network's dense position mirror (the rosters
/// hold only live ids, so no aliveness test is needed here).
#[inline]
pub fn region_top2(grid: &RegionGrid, positions: &[Vec3], signal: Vec3) -> Option<Winners> {
    let map = grid.map();
    let (lo, hi) = map.neighborhood(signal);
    let mut acc = lanes::Top2::EMPTY;
    for cx in lo[0]..=hi[0] {
        for cy in lo[1]..=hi[1] {
            for cz in lo[2]..=hi[2] {
                let region = map.index([cx, cy, cz]);
                for &id in grid.roster(region) {
                    let d = signal.dist2(positions[id as usize]);
                    acc.lex_push(d, id);
                }
            }
        }
    }
    // `d2 = +inf` (fewer than two local candidates) can never pass the
    // strict test, so sparse neighborhoods fall back automatically.
    if acc.d2 < map.outside_dist2(lo, hi, signal) {
        acc.winners()
    } else {
        None
    }
}

/// Shared exhaustive top-2 core: scans live slots in id order (lowest-index
/// tie-break via strict `<`). This is the semantic reference every other
/// implementation — including the lane-blocked kernel in [`lanes`] — must
/// match bit-for-bit (public so benches and property tests can pin it).
#[inline]
pub fn exhaustive_top2(net: &Network, signal: Vec3) -> Option<Winners> {
    let mut w1 = u32::MAX;
    let mut w2 = u32::MAX;
    let mut d1 = f32::INFINITY;
    let mut d2 = f32::INFINITY;
    // Walk the dense position mirror: 12-byte stride, no alive branch (dead
    // slots hold DEAD_POS whose distance overflows to +inf) — ~1.6× faster
    // than walking the Unit slab (EXPERIMENTS.md §Perf).
    for (k, p) in net.positions().iter().enumerate() {
        let d = signal.dist2(*p);
        if d < d1 {
            d2 = d1;
            w2 = w1;
            d1 = d;
            w1 = k as u32;
        } else if d < d2 {
            d2 = d;
            w2 = k as u32;
        }
    }
    if w2 == u32::MAX || d2 == f32::INFINITY {
        None
    } else {
        Some(Winners { w1, w2, d1_sq: d1, d2_sq: d2 })
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::rng::Rng;

    /// Random test network with `n` units in the unit cube (some removed to
    /// exercise dead slots).
    pub fn random_net(n: usize, seed: u64, kill_every: usize) -> Network {
        let mut rng = Rng::seed_from(seed);
        let mut net = Network::new();
        let mut ids = Vec::new();
        for _ in 0..n {
            let p = Vec3::new(rng.f32(), rng.f32(), rng.f32());
            ids.push(net.insert(p, 0.1));
        }
        if kill_every > 0 {
            for (k, &id) in ids.iter().enumerate() {
                if k % kill_every == kill_every - 1 && net.len() > 2 {
                    net.remove(id);
                }
            }
        }
        net
    }

    pub fn random_signals(m: usize, seed: u64) -> Vec<Vec3> {
        let mut rng = Rng::seed_from(seed);
        (0..m)
            .map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn exhaustive_returns_two_distinct() {
        let net = random_net(50, 1, 0);
        for s in random_signals(20, 2) {
            let w = exhaustive_top2(&net, s).unwrap();
            assert_ne!(w.w1, w.w2);
            assert!(w.d1_sq <= w.d2_sq);
        }
    }

    #[test]
    fn exhaustive_none_for_tiny_net() {
        let net = random_net(1, 3, 0);
        assert!(exhaustive_top2(&net, Vec3::ZERO).is_none());
    }

    #[test]
    fn exhaustive_skips_dead_units() {
        let net = random_net(30, 4, 3);
        for s in random_signals(10, 5) {
            let w = exhaustive_top2(&net, s).unwrap();
            assert!(net.is_alive(w.w1));
            assert!(net.is_alive(w.w2));
        }
    }

    /// Satellite (PR 4): across random point clouds, region counts and
    /// boundary-straddling signals, the region-neighborhood scan must
    /// either fall back (`None`) or return the **bit-identical** top-2 of
    /// the exhaustive scan — indices, distances and the lowest-index
    /// tie-break included.
    #[test]
    fn prop_region_top2_bit_identical_to_exhaustive() {
        use crate::geometry::Aabb;
        use crate::proptest::{sized_usize, Prop};
        use crate::rng::Rng;
        use crate::som::{RegionGrid, RegionMap};

        let total_exact = std::cell::Cell::new(0u64);
        Prop::new(40, 0xA11CE).run(
            |rng, size| {
                let n = sized_usize(rng, size, 2, 400);
                let regions = [1usize, 2, 3, 8, 27, 64, 125][rng.index(7)];
                let kill = [0usize, 3, 5][rng.index(3)];
                (rng.next_u64(), n, regions, kill)
            },
            |&(seed, n, regions, kill)| {
                let net = random_net(n, seed, kill);
                let map = RegionMap::new(Aabb::new(Vec3::ZERO, Vec3::ONE), regions);
                let dims = map.dims();
                let mut grid = RegionGrid::new(map);
                grid.rebuild(&net);
                grid.check_invariants(&net)?;
                let mut rng = Rng::seed_from(seed ^ 0xBEEF);
                for k in 0..120 {
                    // Mix interior signals, signals snapped exactly onto
                    // the split planes (boundary-straddling: ties across
                    // the block edge), and out-of-bounds signals.
                    let coord = |rng: &mut Rng, a: usize| match rng.below(5) {
                        0 => {
                            // Exactly on a plane: k · (extent / dims), the
                            // map's own plane expression for the unit cube.
                            let cell = 1.0f32 / dims[a] as f32;
                            rng.index(dims[a] + 1) as f32 * cell
                        }
                        1 => rng.f32() * 3.0 - 1.0, // often out of bounds
                        _ => rng.f32(),
                    };
                    let s = Vec3::new(coord(&mut rng, 0), coord(&mut rng, 1), coord(&mut rng, 2));
                    let want = exhaustive_top2(&net, s);
                    if let Some(got) = region_top2(&grid, net.positions(), s) {
                        total_exact.set(total_exact.get() + 1);
                        let Some(want) = want else {
                            return Err(format!(
                                "sig {k}: region scan found winners, exhaustive none"
                            ));
                        };
                        if got.w1 != want.w1
                            || got.w2 != want.w2
                            || got.d1_sq.to_bits() != want.d1_sq.to_bits()
                            || got.d2_sq.to_bits() != want.d2_sq.to_bits()
                        {
                            return Err(format!("sig {k} (regions {regions}): {got:?} != {want:?}"));
                        }
                    }
                }
                Ok(())
            },
        );
        assert!(
            total_exact.get() > 0,
            "the region scan never resolved locally — the early exit is dead"
        );
    }

    #[test]
    fn winner_is_truly_nearest() {
        let net = random_net(100, 6, 0);
        for s in random_signals(50, 7) {
            let w = exhaustive_top2(&net, s).unwrap();
            for id in net.ids() {
                if id != w.w1 {
                    assert!(s.dist2(net.pos(id)) >= w.d1_sq);
                }
                if id != w.w1 && id != w.w2 {
                    assert!(s.dist2(net.pos(id)) >= w.d2_sq);
                }
            }
        }
    }
}
