//! `BatchRust`: the paper's **Multi-signal** reference implementation —
//! batched Find Winners with the same semantics as `Scalar`, "but without
//! any actual parallelization, in terms of execution" (§3.1).
//!
//! The scan is *unit-tiled*: a tile of unit positions is gathered into a
//! dense scratch buffer once and streamed over all signals, mirroring the
//! CUDA kernel's shared-memory staging (and the Pallas kernel's VMEM tiles)
//! on the CPU cache. Results are exactly those of `Scalar` (same distance
//! expression, same lowest-index tie-break) — the running merge visits
//! units in ascending id order.

use crate::geometry::Vec3;
use crate::som::{Network, Winners, DEAD_POS};

use super::{exhaustive_top2, FindWinners};

/// Cache-tiled batched Find Winners.
pub struct BatchRust {
    /// Units per tile (tuned so a tile fits in L1/L2: 3 f32 + id per unit).
    pub tile: usize,
    // Scratch (reused across calls).
    tile_pos: Vec<Vec3>,
    tile_ids: Vec<u32>,
}

impl Default for BatchRust {
    fn default() -> Self {
        Self::new(512)
    }
}

impl BatchRust {
    pub fn new(tile: usize) -> Self {
        assert!(tile > 0);
        Self { tile, tile_pos: Vec::new(), tile_ids: Vec::new() }
    }
}

impl FindWinners for BatchRust {
    fn name(&self) -> &'static str {
        "multi"
    }

    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners> {
        exhaustive_top2(net, signal)
    }

    fn find2_batch(
        &mut self,
        net: &Network,
        signals: &[Vec3],
        out: &mut Vec<Option<Winners>>,
    ) {
        out.clear();
        out.resize(
            signals.len(),
            Some(Winners { w1: u32::MAX, w2: u32::MAX, d1_sq: f32::INFINITY, d2_sq: f32::INFINITY }),
        );

        let positions = net.positions();
        let mut next_slot = 0usize;
        loop {
            // Gather the next tile of live units from the dense mirror
            // (dead slots hold DEAD_POS and are skipped at gather time so
            // the inner loop stays branch-free).
            self.tile_pos.clear();
            self.tile_ids.clear();
            while next_slot < positions.len() && self.tile_ids.len() < self.tile {
                let p = positions[next_slot];
                if p.x != DEAD_POS.x {
                    self.tile_ids.push(next_slot as u32);
                    self.tile_pos.push(p);
                }
                next_slot += 1;
            }
            if self.tile_ids.is_empty() {
                break;
            }
            // Stream every signal over the tile, merging into the running
            // top-2. Ids ascend across tiles, so strict `<` keeps the
            // lowest-index tie-break.
            for (s, slot) in signals.iter().zip(out.iter_mut()) {
                let w = slot.as_mut().unwrap();
                for (k, &p) in self.tile_pos.iter().enumerate() {
                    let d = s.dist2(p);
                    if d < w.d1_sq {
                        w.d2_sq = w.d1_sq;
                        w.w2 = w.w1;
                        w.d1_sq = d;
                        w.w1 = self.tile_ids[k];
                    } else if d < w.d2_sq {
                        w.d2_sq = d;
                        w.w2 = self.tile_ids[k];
                    }
                }
            }
        }

        for slot in out.iter_mut() {
            if slot.as_ref().unwrap().w2 == u32::MAX {
                *slot = None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::Scalar;
    use super::*;

    #[test]
    fn batch_matches_scalar_exactly() {
        let net = random_net(777, 31, 7);
        let signals = random_signals(301, 32);
        let mut batch = BatchRust::new(64);
        let mut scalar = Scalar::new();
        let mut got = Vec::new();
        batch.find2_batch(&net, &signals, &mut got);
        for (s, g) in signals.iter().zip(&got) {
            assert_eq!(*g, scalar.find2(&net, *s));
        }
    }

    #[test]
    fn tile_size_invariance() {
        let net = random_net(333, 33, 0);
        let signals = random_signals(64, 34);
        let mut base = Vec::new();
        BatchRust::new(1).find2_batch(&net, &signals, &mut base);
        for tile in [2, 7, 128, 1024] {
            let mut got = Vec::new();
            BatchRust::new(tile).find2_batch(&net, &signals, &mut got);
            assert_eq!(got, base, "tile {tile}");
        }
    }

    #[test]
    fn tiny_network_yields_none() {
        let net = random_net(1, 35, 0);
        let signals = random_signals(4, 36);
        let mut got = Vec::new();
        BatchRust::default().find2_batch(&net, &signals, &mut got);
        assert!(got.iter().all(|w| w.is_none()));
    }

    #[test]
    fn empty_batch_ok() {
        let net = random_net(10, 37, 0);
        let mut got = vec![None; 3];
        BatchRust::default().find2_batch(&net, &[], &mut got);
        assert!(got.is_empty());
    }
}
