//! `BatchRust`: the paper's **Multi-signal** reference implementation —
//! batched Find Winners with the same semantics as `Scalar`, vectorized and
//! (optionally) sharded, never approximated.
//!
//! The scan is *unit-tiled*: live units are gathered into lane-padded SoA
//! tiles (mirroring the CUDA kernel's shared-memory staging and the Pallas
//! kernel's VMEM tiles on the CPU cache) and each tile is streamed over all
//! signals with the runtime-dispatched SIMD block kernel ([`super::simd`]).
//! Three performance layers, all invisible to semantics:
//!
//! 1. **Tile cache**: the gather runs once and is reused across consecutive
//!    `find2_batch` calls; `sync`/`rebuild` invalidate it (the drivers'
//!    once-per-batch sync contract makes that exact). Aliveness comes from
//!    `Network::is_alive`, not a coordinate comparison — a unit that
//!    legitimately sits at `x = DEAD_POS.x` is still scanned.
//! 2. **Dispatched SIMD block kernel**: per-lane running top-2 plus one
//!    horizontal reduce per tile, on the widest ISA the host supports
//!    (AVX-512F/AVX2/NEON, portable `lanes` fallback) — every tier
//!    bit-identical to `exhaustive_top2` (see `simd`).
//! 3. **Signal sharding**: with an attached [`WorkerPool`] (`find_threads`
//!    knob), large batches are split into work-stealing chunks claimed by
//!    the persistent workers (a worker finishing a cheap chunk immediately
//!    claims the next, so a skewed chunk no longer idles the rest); each
//!    signal is computed independently and each chunk's outputs live at
//!    fixed offsets, so any shard count *and any claim schedule* yields
//!    the same bits.
//!
//! Results are exactly those of `Scalar` (same distance expression, same
//! lowest-index tie-break): tiles ascend in id order and tile candidates
//! merge into the running top-2 in lexicographic order, which preserves the
//! sequential scan's tie-break exactly.

use std::sync::{Arc, Mutex};

use crate::geometry::Vec3;
use crate::runtime::WorkerPool;
use crate::som::{ChangeLog, Network, RegionGrid, RegionMap, Winners, DEAD_POS};

use super::lanes::LANES;
use super::{region_top2, simd, FindWinners};

/// Running-state sentinel: a signal's top-2 before any unit was merged.
const PENDING: Winners =
    Winners { w1: u32::MAX, w2: u32::MAX, d1_sq: f32::INFINITY, d2_sq: f32::INFINITY };

/// Below this many signals per chunk, sharding overhead (one pool handoff)
/// outweighs the work; the batch runs inline instead. Also the chunk-size
/// floor for the work-stealing split.
const MIN_SHARD_SIGNALS: usize = 64;

/// One worker's scoped work item: its signal chunk and output chunk.
type ShardJob<'a> = Mutex<Option<(&'a [Vec3], &'a mut [Option<Winners>])>>;

/// Cache-tiled, lane-blocked batched Find Winners.
pub struct BatchRust {
    /// Units per tile (tuned so a tile fits in L1/L2: 3 f32 + id per unit;
    /// rounded up to the lane width internally).
    pub tile: usize,
    // Cached gather of the live units: lane-padded SoA tiles + id map,
    // ascending slab order (so tile-merge order preserves the tie-break).
    xs: Vec<f32>,
    ys: Vec<f32>,
    zs: Vec<f32>,
    ids: Vec<u32>,
    /// `(start, end)` ranges into the SoA buffers, one per tile; every
    /// range length is a multiple of `LANES`.
    tiles: Vec<(usize, usize)>,
    cache_valid: bool,
    cached_capacity: usize,
    cached_live: usize,
    /// Shared persistent pool + shard count for `find_threads` (None/1 =
    /// inline).
    pool: Option<Arc<WorkerPool>>,
    shards: usize,
    /// Region rosters for the `regions` knob: signals whose top-2 provably
    /// lies inside their 3×3×3 region neighborhood skip the global scan
    /// entirely ([`region_top2`]); the rest fall back to the tiles.
    /// Maintained through the same sync contract as the tile cache.
    grid: Option<RegionGrid>,
}

impl Default for BatchRust {
    fn default() -> Self {
        Self::new(512)
    }
}

impl BatchRust {
    pub fn new(tile: usize) -> Self {
        assert!(tile > 0);
        Self {
            tile,
            xs: Vec::new(),
            ys: Vec::new(),
            zs: Vec::new(),
            ids: Vec::new(),
            tiles: Vec::new(),
            cache_valid: false,
            cached_capacity: 0,
            cached_live: 0,
            pool: None,
            shards: 1,
            grid: None,
        }
    }

    /// Gather live units into lane-padded SoA tiles (ascending slab order).
    fn rebuild_cache(&mut self, net: &Network) {
        self.xs.clear();
        self.ys.clear();
        self.zs.clear();
        self.ids.clear();
        self.tiles.clear();
        let eff_tile = self.tile.next_multiple_of(LANES);
        let mut start = 0usize;
        for (slot, p) in net.positions().iter().enumerate() {
            // Exact aliveness test (not `p.x != DEAD_POS.x`): a unit that
            // legitimately sits at x = 1e30 must still be scanned.
            if !net.is_alive(slot as u32) {
                continue;
            }
            self.xs.push(p.x);
            self.ys.push(p.y);
            self.zs.push(p.z);
            self.ids.push(slot as u32);
            if self.ids.len() - start == eff_tile {
                self.tiles.push((start, self.ids.len()));
                start = self.ids.len();
            }
        }
        if self.ids.len() > start {
            // Lane-pad the final partial tile with poison (cannot win) and
            // an id that is never read (poison entries never become
            // candidates).
            while (self.ids.len() - start) % LANES != 0 {
                self.xs.push(DEAD_POS.x);
                self.ys.push(DEAD_POS.y);
                self.zs.push(DEAD_POS.z);
                self.ids.push(u32::MAX);
            }
            self.tiles.push((start, self.ids.len()));
        }
        self.cache_valid = true;
        self.cached_capacity = net.capacity();
        self.cached_live = net.len();
    }

    fn ensure_cache(&mut self, net: &Network) {
        // `sync`/`rebuild` clear the flag; capacity/live-count drift guards
        // against structural changes a caller applied without honoring the
        // sync contract. The region grid carries its own last-seen
        // counters (advanced by its `sync`), so a violation is caught even
        // when it lands *after* an honest sync already cleared the tile
        // flag — while honest syncs keep the rosters incremental (no
        // per-batch rebuild). Like the tile guard, pure position moves
        // without a sync stay undetectable.
        if let Some(grid) = &mut self.grid {
            if grid.is_stale(net) {
                grid.rebuild(net);
            }
        }
        let drift = self.cached_capacity != net.capacity() || self.cached_live != net.len();
        if !self.cache_valid || drift {
            self.rebuild_cache(net);
        }
    }
}

/// Merge one candidate into a signal's running top-2 with strict `<` — the
/// exhaustive scan's insertion rule. Candidates arrive tile by tile in
/// ascending id order (and in lexicographic order within a tile), which
/// preserves the lowest-index tie-break exactly.
#[inline]
fn merge_push(w: &mut Winners, d: f32, id: u32) {
    if d < w.d1_sq {
        w.d2_sq = w.d1_sq;
        w.w2 = w.w1;
        w.d1_sq = d;
        w.w1 = id;
    } else if d < w.d2_sq {
        w.d2_sq = d;
        w.w2 = id;
    }
}

/// One shard of signals. With a region grid: resolve each signal from its
/// region neighborhood when exact ([`region_top2`]), then stream the
/// cached tiles over only the fallback signals. Without: stream every tile
/// over every signal (tiles outer for cache reuse, exactly the staging
/// pattern of the CUDA kernel).
#[allow(clippy::too_many_arguments)] // one flat hot-path view per buffer
fn scan_shard(
    grid: Option<&RegionGrid>,
    positions: &[Vec3],
    xs: &[f32],
    ys: &[f32],
    zs: &[f32],
    ids: &[u32],
    tiles: &[(usize, usize)],
    signals: &[Vec3],
    out: &mut [Option<Winners>],
) {
    if let Some(grid) = grid {
        // Lazy: `Vec::new` does not allocate, so a shard whose signals all
        // resolve locally costs nothing. Shards with fallbacks pay one
        // small allocation per call — per-worker scratch reuse would save
        // it but would have to thread buffers through the shard jobs;
        // revisit if the microbench ever shows it.
        let mut fallback: Vec<usize> = Vec::new();
        for (k, s) in signals.iter().enumerate() {
            match region_top2(grid, positions, *s) {
                Some(w) => out[k] = Some(w),
                None => fallback.push(k),
            }
        }
        // Batched per shard (runs on pool workers — relaxed atomics).
        crate::telemetry::add(
            crate::telemetry::Counter::RegionLocalResolves,
            (signals.len() - fallback.len()) as u64,
        );
        crate::telemetry::add(
            crate::telemetry::Counter::RegionFallbackScans,
            fallback.len() as u64,
        );
        if fallback.is_empty() {
            return;
        }
        for &(start, end) in tiles {
            let (bx, by, bz) = (&xs[start..end], &ys[start..end], &zs[start..end]);
            let bids = &ids[start..end];
            for &k in &fallback {
                let t = simd::block_top2(bx, by, bz, signals[k]);
                let w = out[k].as_mut().unwrap();
                if t.w1 != u32::MAX {
                    merge_push(w, t.d1, bids[t.w1 as usize]);
                }
                if t.w2 != u32::MAX {
                    merge_push(w, t.d2, bids[t.w2 as usize]);
                }
            }
        }
        return;
    }
    for &(start, end) in tiles {
        let (bx, by, bz) = (&xs[start..end], &ys[start..end], &zs[start..end]);
        let bids = &ids[start..end];
        for (s, slot) in signals.iter().zip(out.iter_mut()) {
            let t = simd::block_top2(bx, by, bz, *s);
            let w = slot.as_mut().unwrap();
            if t.w1 != u32::MAX {
                merge_push(w, t.d1, bids[t.w1 as usize]);
            }
            if t.w2 != u32::MAX {
                merge_push(w, t.d2, bids[t.w2 as usize]);
            }
        }
    }
}

impl FindWinners for BatchRust {
    fn name(&self) -> &'static str {
        "multi"
    }

    fn find2(&mut self, net: &Network, signal: Vec3) -> Option<Winners> {
        simd::top2(net, signal)
    }

    fn find2_batch(
        &mut self,
        net: &Network,
        signals: &[Vec3],
        out: &mut Vec<Option<Winners>>,
    ) {
        out.clear();
        out.resize(signals.len(), Some(PENDING));
        if signals.is_empty() {
            return;
        }
        self.ensure_cache(net);

        let pool = self.pool.clone();
        let shards = pool.as_ref().map_or(1, |p| self.shards.min(p.size()));
        // Work-stealing split: more chunks than workers (floored at
        // MIN_SHARD_SIGNALS), claimed through the pool's shared index, so a
        // worker that lands on a cheap chunk immediately picks up another
        // instead of idling behind a skewed one.
        let chunk = crate::runtime::steal_chunk(signals.len(), shards, MIN_SHARD_SIGNALS);
        let jobs = signals.len().div_ceil(chunk);
        if jobs > 1 && shards > 1 {
            let pool = pool.as_ref().unwrap();
            // Scoped handoff: each claimed index maps to exactly one
            // (signals, out) chunk pair; the SoA cache, the position
            // mirror and the region rosters are shared read-only.
            let (xs, ys, zs) = (&self.xs, &self.ys, &self.zs);
            let (ids, tiles) = (&self.ids, &self.tiles);
            let grid = self.grid.as_ref();
            let positions = net.positions();
            let pairs: Vec<ShardJob<'_>> = signals
                .chunks(chunk)
                .zip(out.chunks_mut(chunk))
                .map(|pair| Mutex::new(Some(pair)))
                .collect();
            pool.run_indexed(shards, pairs.len(), &|j| {
                if let Some((sig, dst)) = pairs[j].lock().unwrap().take() {
                    scan_shard(grid, positions, xs, ys, zs, ids, tiles, sig, dst);
                }
            });
        } else {
            scan_shard(
                self.grid.as_ref(),
                net.positions(),
                &self.xs,
                &self.ys,
                &self.zs,
                &self.ids,
                &self.tiles,
                signals,
                out,
            );
        }

        for slot in out.iter_mut() {
            let w = slot.as_ref().unwrap();
            if w.w2 == u32::MAX || w.d2_sq == f32::INFINITY {
                *slot = None;
            }
        }
    }

    fn sync(&mut self, net: &Network, changes: &ChangeLog) {
        if !changes.is_empty() {
            self.cache_valid = false;
            if let Some(grid) = &mut self.grid {
                grid.sync(net, changes);
            }
        }
    }

    fn rebuild(&mut self, net: &Network) {
        if let Some(grid) = &mut self.grid {
            grid.rebuild(net);
        }
        self.rebuild_cache(net);
    }

    fn attach_pool(&mut self, pool: Arc<WorkerPool>, shards: usize) {
        self.shards = shards.max(1);
        self.pool = if self.shards > 1 { Some(pool) } else { None };
    }

    fn attach_regions(&mut self, map: RegionMap) {
        // Rosters fill at the next `rebuild` (the drivers rebuild once
        // after `init`); until then every signal falls back to the global
        // scan, which is always exact.
        self.grid = (map.region_count() > 1).then(|| RegionGrid::new(map));
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::Scalar;
    use super::*;

    #[test]
    fn batch_matches_scalar_exactly() {
        let net = random_net(777, 31, 7);
        let signals = random_signals(301, 32);
        let mut batch = BatchRust::new(64);
        let mut scalar = Scalar::new();
        let mut got = Vec::new();
        batch.find2_batch(&net, &signals, &mut got);
        for (s, g) in signals.iter().zip(&got) {
            assert_eq!(*g, scalar.find2(&net, *s));
        }
    }

    #[test]
    fn tile_size_invariance() {
        let net = random_net(333, 33, 0);
        let signals = random_signals(64, 34);
        let mut base = Vec::new();
        BatchRust::new(1).find2_batch(&net, &signals, &mut base);
        for tile in [2, 7, 128, 1024] {
            let mut got = Vec::new();
            BatchRust::new(tile).find2_batch(&net, &signals, &mut got);
            assert_eq!(got, base, "tile {tile}");
        }
    }

    #[test]
    fn tiny_network_yields_none() {
        let net = random_net(1, 35, 0);
        let signals = random_signals(4, 36);
        let mut got = Vec::new();
        BatchRust::default().find2_batch(&net, &signals, &mut got);
        assert!(got.iter().all(|w| w.is_none()));
    }

    #[test]
    fn empty_batch_ok() {
        let net = random_net(10, 37, 0);
        let mut got = vec![None; 3];
        BatchRust::default().find2_batch(&net, &[], &mut got);
        assert!(got.is_empty());
    }

    #[test]
    fn unit_on_dead_pos_axis_is_still_found() {
        // The fragile pre-SoA gather tested `p.x != DEAD_POS.x` and would
        // have dropped a unit that legitimately sits at x = 1e30.
        let mut net = crate::som::Network::new();
        let far_a = net.insert(Vec3::new(DEAD_POS.x, 0.0, 0.0), 0.1);
        let far_b = net.insert(Vec3::new(DEAD_POS.x, 3.0, 0.0), 0.1);
        let _near = net.insert(Vec3::new(0.2, 0.0, 0.0), 0.1);
        // A signal on the far axis has finite distances only to the two far
        // units — both of which the old gather would have dropped.
        let s = Vec3::new(DEAD_POS.x, 1.0, 0.0);
        let mut batch = BatchRust::default();
        let mut got = Vec::new();
        batch.find2_batch(&net, &[s], &mut got);
        let w = got[0].expect("two finite candidates");
        assert_eq!(w.w1, far_a, "units at x = DEAD_POS.x must be scanned");
        assert_eq!(w.w2, far_b);
        assert_eq!(w.d1_sq, 1.0);
        assert_eq!(w.d2_sq, 4.0);
        assert_eq!(got[0], Scalar::new().find2(&net, s));
    }

    #[test]
    fn cache_reused_until_sync_then_rebuilt() {
        let mut net = random_net(100, 41, 0);
        let signals = random_signals(16, 42);
        let mut batch = BatchRust::new(32);
        let mut got = Vec::new();
        batch.find2_batch(&net, &signals, &mut got);
        assert!(batch.cache_valid);
        let tiles_before = batch.tiles.len();

        // No changes: a second batch reuses the gather.
        batch.find2_batch(&net, &signals, &mut got);
        assert_eq!(batch.tiles.len(), tiles_before);

        // A position move reported via sync invalidates, and the next
        // batch sees the new position.
        let id = net.ids().next().unwrap();
        let old = net.pos(id);
        net.set_pos(id, Vec3::new(0.5, 0.5, 0.5));
        let mut log = ChangeLog::default();
        log.moved.push((id, old));
        batch.sync(&net, &log);
        assert!(!batch.cache_valid);
        batch.find2_batch(&net, &[Vec3::new(0.5, 0.5, 0.5)], &mut got);
        assert_eq!(got[0].unwrap().w1, id);

        // Structural drift without sync is caught by the capacity/live
        // guard (defense against contract violations).
        net.insert(Vec3::new(0.49, 0.5, 0.5), 0.1);
        batch.find2_batch(&net, &[Vec3::new(0.49, 0.5, 0.5)], &mut got);
        assert_eq!(
            got[0],
            Scalar::new().find2(&net, Vec3::new(0.49, 0.5, 0.5)),
            "insert without sync must still be visible via the guard"
        );
    }

    #[test]
    fn region_batch_identical_to_global_scan() {
        use crate::geometry::Aabb;
        let bounds = Aabb::new(Vec3::ZERO, Vec3::ONE);
        let net = random_net(400, 61, 7);
        let signals = random_signals(500, 62);
        let mut base = Vec::new();
        BatchRust::default().find2_batch(&net, &signals, &mut base);
        for regions in [2usize, 8, 64, 343] {
            let mut fw = BatchRust::default();
            fw.attach_regions(RegionMap::new(bounds, regions));
            fw.rebuild(&net);
            let mut got = Vec::new();
            fw.find2_batch(&net, &signals, &mut got);
            assert_eq!(got, base, "regions {regions}");

            // Composed with pool sharding: still bit-identical.
            let mut fw = BatchRust::default();
            fw.attach_regions(RegionMap::new(bounds, regions));
            fw.attach_pool(Arc::new(WorkerPool::new(3)), 3);
            fw.rebuild(&net);
            let mut got = Vec::new();
            fw.find2_batch(&net, &signals, &mut got);
            assert_eq!(got, base, "regions {regions} sharded");
        }
    }

    #[test]
    fn region_rosters_follow_sync() {
        use crate::geometry::Aabb;
        // Drive moves (incl. boundary crossings), removals and slot-reusing
        // insertions through the sync contract; the region path must stay
        // exact against a fresh scalar scan after every merged log.
        let mut net = random_net(120, 63, 0);
        let mut fw = BatchRust::default();
        fw.attach_regions(RegionMap::new(Aabb::new(Vec3::ZERO, Vec3::ONE), 27));
        fw.rebuild(&net);
        let mut scalar = Scalar::new();
        for round in 0..6u64 {
            let mut log = ChangeLog::default();
            let ids: Vec<u32> = net.ids().collect();
            let mover = ids[(round as usize * 7) % ids.len()];
            let old = net.pos(mover);
            net.set_pos(mover, Vec3::ONE - old); // mirror: crosses regions
            log.moved.push((mover, old));
            let gone = ids[(round as usize * 13 + 1) % ids.len()];
            if gone != mover && net.len() > 2 {
                let pos = net.pos(gone);
                net.remove(gone);
                log.removed.push((gone, pos));
                let reborn = net.insert(Vec3::new(0.31 * round as f32 % 1.0, 0.5, 0.7), 0.1);
                log.inserted.push(reborn);
            }
            fw.sync(&net, &log);
            let signals = random_signals(64, 100 + round);
            let mut got = Vec::new();
            fw.find2_batch(&net, &signals, &mut got);
            for (s, g) in signals.iter().zip(&got) {
                assert_eq!(*g, scalar.find2(&net, *s), "round {round}");
            }
        }
    }

    #[test]
    fn sharded_batch_identical_for_any_find_threads() {
        let net = random_net(500, 51, 9);
        let signals = random_signals(1000, 52);
        let mut base = Vec::new();
        BatchRust::default().find2_batch(&net, &signals, &mut base);
        for shards in [2usize, 3, 7] {
            let mut batch = BatchRust::default();
            batch.attach_pool(Arc::new(WorkerPool::new(shards)), shards);
            let mut got = Vec::new();
            batch.find2_batch(&net, &signals, &mut got);
            assert_eq!(got, base, "shards {shards}");
        }
    }
}
